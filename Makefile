# Developer targets.  PYTHONPATH=src is the repo's import convention.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test smoke-shard smoke-replica smoke-build smoke-cluster smoke-store smoke-obs smoke-profile smoke-health smoke-segments smoke-kernels bench bench-check bench-full

# tier-1 verify (ROADMAP.md); the host-seam lint runs first -- a
# time.*/metrics call inside a jitted body fails the build before any
# test does
test:
	$(PY) tools/check_host_seams.py
	$(PY) -m pytest -x -q

# tier-1 under 4 virtual host devices: exercises every mesh/shard_map path
# (dist annotations, moe shard-local dispatch, doc-sharded search) against
# real multi-device lowering instead of the 1-device no-op fallbacks
smoke-shard:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m pytest -x -q

# tier-1 under 8 virtual host devices (4 doc-shards x 2 replicas): the
# replica-tier analogue of smoke-shard -- in-process tests still see 1-shard
# meshes, but the subprocess parity tests get the full 4x2 (data, replica)
# mesh, and every other mesh path lowers against 8 devices
smoke-replica:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" $(PY) -m pytest -x -q

# quick on-device build + ingest smoke under 8 virtual devices: one-program
# SPMD build vs the from_index reference at every shard count that fits,
# plus append-segment ingest throughput (the _quick artifact name keeps it
# gitignored and out of the accumulating BENCH_build_scale.json trajectory)
smoke-build:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" $(PY) -m \
	  benchmarks.build_scale --shards 1,2,4,8 --docs 2000 --features 32 \
	  --ingest-batch 64 --ingest-batches 2 --repeats 1 \
	  --json artifacts/BENCH_build_scale_quick.json

# cluster control-plane smoke under 8 virtual devices (4 doc-shards x 2
# replica groups): per-group batchers, concurrent client streams, and the
# one-group-down failover parity assert, via the cluster bench in quick
# config (the _quick artifact name keeps it out of the accumulating
# BENCH_cluster_scale.json trajectory)
smoke-cluster:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" $(PY) -m \
	  benchmarks.cluster_scale --grid 4x2 --streams 1,4 --docs 2000 \
	  --features 32 --queries 16 --repeats 1 \
	  --json artifacts/BENCH_cluster_scale_quick.json

# durability smoke under 4 virtual devices: build -> commit -> hot ingest
# through the write-ahead translog -> kill (drop every in-memory index) ->
# crash-recover from the store directory alone -> assert bit-identical
# search results (the store dir is recreated fresh each run: this launcher
# always builds a fresh corpus, so a stale commit would be a lie)
smoke-store:
	rm -rf artifacts/store_smoke
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m \
	  repro.launch.serve --docs 2000 --features 32 --queries 32 \
	  --shards 4 --ingest 200 --store artifacts/store_smoke \
	  --kill-and-recover
	rm -rf artifacts/store_smoke

# observability smoke under 4 virtual devices (2 doc-shards x 2 replica
# groups): --stats-interval prints periodic _cat-style stats lines and a
# final stats + trace dump, and the launcher asserts the reconciliation
# contract -- submitted == completed == queries issued == sum of per-group
# completions.  The second run injects a group failure and additionally
# asserts exactly ONE health down transition (the one failover event) with
# at least one failover resubmit.
smoke-obs:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m \
	  repro.launch.serve --docs 2000 --features 32 --queries 32 \
	  --shards 2 --replicas 2 --cluster --stats-interval 0.5
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m \
	  repro.launch.serve --docs 2000 --features 32 --queries 32 \
	  --shards 2 --replicas 2 --cluster --fail-shard 0 --stats-interval 0.5

# observability v2 smoke under 4 virtual devices: the full
# instrumentation plane at once -- _profile execution trees (asserts
# each tree's phases tile its total and the dispatch phase reconciles
# with the latency histogram), slow log at threshold 0 (asserts 100%
# tail capture: captured == seen), recompile watch (asserts ZERO
# steady-state recompiles after the warmup pass), and the JSONL
# metrics-snapshot exporter
smoke-profile:
	mkdir -p artifacts
	rm -f artifacts/metrics_smoke.jsonl
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m \
	  repro.launch.serve --docs 2000 --features 32 --queries 32 \
	  --shards 2 --replicas 2 --cluster --stats-interval 0.5 \
	  --profile --slow-threshold 0 --fail-on-recompile \
	  --metrics-file artifacts/metrics_smoke.jsonl

# segment-lifecycle smoke under 4 virtual devices: sealed-generation
# ingest (flat vs seal vs seal+merge latency traces -- the no-stall
# evidence), per-generation commit bytes through a durable store (the
# O(changed) incremental-commit curve), ending in a kill -> recover ->
# bit-parity assert (the _quick artifact name keeps it out of the
# accumulating BENCH_segment_scale.json trajectory)
smoke-segments:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m \
	  benchmarks.segment_scale --shards 4 --docs 2000 --features 32 \
	  --ingest-batch 32 --batches 8 --seal-threshold 64 --queries 16 \
	  --search-calls 8 --repeats 1 \
	  --json artifacts/BENCH_segment_scale_quick.json

# kernel smoke: the fused/quantized parity property suites plus the
# measured fused-vs-composed scaling bench in quick config (asserts the
# fused path moves strictly fewer bytes AND finishes sooner than the
# composed path at its largest size; the _quick artifact name keeps it
# out of the accumulating BENCH_kernel_scale.json trajectory)
smoke-kernels:
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_quantized.py
	$(PY) -c "from benchmarks.roofline import kernel_scale; \
	  kernel_scale(quick=True, \
	    json_path='artifacts/BENCH_kernel_scale_quick.json')"

# observability v3 smoke under 8 virtual devices (4 doc-shards x 2
# replica groups): the ES _cluster/health verdict must walk green ->
# yellow -> green across an injected group failure with the transition
# ledger reconciling EXACTLY (one down event, counters match), and the
# run auto-dumps support-diagnostics bundles (at the failover and at
# exit) which the follow-up check reloads and validates section by
# section
smoke-health:
	rm -rf artifacts/diag_smoke
	XLA_FLAGS="--xla_force_host_platform_device_count=8" $(PY) -m \
	  repro.launch.serve --docs 2000 --features 32 --queries 32 \
	  --shards 4 --replicas 2 --cluster --fail-shard 0 \
	  --stats-interval 0.5 --slow-threshold 0 \
	  --diagnostics-on-exit artifacts/diag_smoke
	$(PY) tools/validate_diag_bundle.py artifacts/diag_smoke
	rm -rf artifacts/diag_smoke

bench:
	$(PY) -m benchmarks.run

# perf-regression gate over the committed artifacts/BENCH_*.json: latest
# run vs first-committed baseline per bench, the obs-overhead bars, and
# the fused-kernel byte claim; exits nonzero on any regression
bench-check:
	$(PY) -m benchmarks.run --check

bench-full:
	$(PY) -m benchmarks.run --full
