# Developer targets.  PYTHONPATH=src is the repo's import convention.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test smoke-shard smoke-replica bench bench-full

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# tier-1 under 4 virtual host devices: exercises every mesh/shard_map path
# (dist annotations, moe shard-local dispatch, doc-sharded search) against
# real multi-device lowering instead of the 1-device no-op fallbacks
smoke-shard:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m pytest -x -q

# tier-1 under 8 virtual host devices (4 doc-shards x 2 replicas): the
# replica-tier analogue of smoke-shard -- in-process tests still see 1-shard
# meshes, but the subprocess parity tests get the full 4x2 (data, replica)
# mesh, and every other mesh path lowers against 8 devices
smoke-replica:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" $(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run

bench-full:
	$(PY) -m benchmarks.run --full
