"""Train a small LM for a few hundred steps with the full substrate:
AdamW + grad accumulation + cosine schedule + async checkpointing +
fault-tolerant loop (kill it mid-run and re-run: it resumes).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys

import numpy as np
import jax, jax.numpy as jnp

from repro.models.transformer.model import LMConfig, init_params, lm_loss
from repro.train import (AdamWConfig, TrainLoopConfig, adamw_init,
                         cosine_schedule, make_train_step, run_train_loop)

steps = int(sys.argv[sys.argv.index("--steps") + 1]) if "--steps" in sys.argv else 300

cfg = LMConfig("demo-28m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
               d_head=32, d_ff=1024, vocab=32768, attn_pattern="swa", window=128,
               q_chunk=128, kv_chunk=128)
params = init_params(jax.random.PRNGKey(0), cfg)
n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
print(f"model: {n/1e6:.1f}M params")

opt = adamw_init(params)
step = jax.jit(make_train_step(
    lambda p, b: lm_loss(p, b, cfg), AdamWConfig(lr=3e-4), accum=2,
    lr_schedule=cosine_schedule(warmup=50, total=steps)))


def make_batch(i):
    r = np.random.default_rng(i)
    t = r.integers(0, cfg.vocab, size=(16, 256)).astype(np.int32)
    t[:, 1::2] = (t[:, ::2] * 7 + 13) % cfg.vocab  # learnable bigram structure
    return {"tokens": jnp.asarray(t), "labels": jnp.asarray(np.roll(t, -1, 1))}


params, opt, metrics = run_train_loop(
    step, params, opt, make_batch,
    TrainLoopConfig(total_steps=steps, ckpt_dir="artifacts/train_lm_ckpt",
                    ckpt_every=100, log_every=20),
    on_metrics=lambda s, m: print(f"step {s:4d}  loss {m['loss']:.4f}  "
                                  f"gnorm {m['grad_norm']:.2f}"),
)
print(f"final loss: {float(metrics['loss']):.4f}")
