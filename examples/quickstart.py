"""Quickstart: encode vectors, index them, search -- the paper in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (RoundingEncoder, TrimFilter, VectorIndex,
                        precision_at_k)

rng = np.random.default_rng(0)
vectors = rng.normal(size=(5000, 64)).astype(np.float32)   # any dense embeddings

# 1. build the index: unit-normalise + quantize to int8 feature codes
index = VectorIndex.build(vectors, encoder=RoundingEncoder(2))

# 2. two-phase search: phase-1 token match (choose an engine), phase-2 exact
queries = vectors[:8] + 0.05 * rng.normal(size=(8, 64)).astype(np.float32)
ids, cosines = index.search(
    jnp.asarray(queries), k=10, page=320,
    trim=TrimFilter(0.05),      # paper's recommended query-side filter
    engine="codes",             # "postings" = faithful inverted index
)
print("top-10 ids for query 0:", np.asarray(ids[0]))
print("cosines:", np.round(np.asarray(cosines[0]), 3))

# 3. compare against the brute-force gold standard
gold_ids, _ = index.gold_topk(jnp.asarray(queries), 10)
print("P@10 vs gold:", float(precision_at_k(ids, gold_ids).mean()))
