"""End-to-end driver: corpus -> TF-IDF -> LSA -> encoded index -> serving.

The paper's full pipeline (§3) at laptop scale: build LSA vectors for a
topic corpus, index them, evaluate quality against brute force, then serve
batched queries through the request engine.

    PYTHONPATH=src python examples/wiki_semantic_search.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import TrimFilter, VectorIndex, avg_diff, ndcg_k, precision_at_k
from repro.data import make_corpus
from repro.lsa import build_lsa
from repro.serve.engine import BatchedSearchEngine

print("== building corpus + LSA (paper §3: LSA over TF-IDF) ==")
t0 = time.time()
corpus = make_corpus(n_docs=8000, vocab_size=20000, n_topics=64, seed=0)
pipe = build_lsa(corpus, n_features=200)
print(f"   {corpus.doc_terms.shape[0]} docs embedded in {time.time()-t0:.0f}s")

index = VectorIndex.build(pipe.doc_vectors)
queries = pipe.doc_vectors[:64]
gold_ids, gold_sims = index.gold_topk(queries, 10)

print("== quality at paper's operating point (trim=0.05, page=320) ==")
ids, sims = index.search(queries, k=10, page=320, trim=TrimFilter(0.05),
                         engine="codes")
print(f"   P@10  = {float(precision_at_k(ids, gold_ids).mean()):.3f}")
print(f"   nDCG  = {float(ndcg_k(sims, gold_sims).mean()):.3f}")
print(f"   avg.diff = {float(avg_diff(sims, gold_sims).mean()):.5f}")

print("== serving batched requests ==")
engine = BatchedSearchEngine(index, batch_size=16, k=10, page=320)
try:
    t0 = time.time()
    futs = [engine.submit(np.asarray(pipe.doc_vectors[i])) for i in range(64)]
    results = [f.result(timeout=60) for f in futs]
    dt = time.time() - t0
    print(f"   64 requests in {dt:.2f}s ({dt/64*1e3:.1f} ms/req effective)")
    print(f"   first result ids: {results[0][0][:5]}")
finally:
    engine.close()
