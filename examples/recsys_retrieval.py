"""Recsys candidate retrieval with the paper's encoded search.

DIN user tower -> user embedding -> two-phase search over 200k candidate
item embeddings (the `retrieval_cand` serving shape, scaled to CPU),
compared against brute-force dot-product retrieval.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""
import time

import numpy as np
import jax, jax.numpy as jnp

from repro.data import recsys_batch
from repro.models.recsys.models import DINConfig, din_init, din_user_embedding
from repro.serve.retrieval import (brute_force_retrieval, encode_candidates,
                                   retrieval_step)

rng = np.random.default_rng(0)
cfg = DINConfig(item_vocab=200_000, seq_len=50)
params = din_init(jax.random.PRNGKey(0), cfg)

batch = {k: jnp.asarray(v) for k, v in
         recsys_batch(rng, 8, 1, [cfg.item_vocab], seq_len=50).items()}
user_vecs = din_user_embedding(params, batch, cfg)
print("user embeddings:", user_vecs.shape)

cand = jnp.asarray(rng.normal(size=(200_000, cfg.embed_dim)).astype(np.float32))
vecs, codes = encode_candidates(cand)
print(f"candidate index: {vecs.shape[0]} items, int8 codes {codes.shape}")

t0 = time.time()
ids, scores = retrieval_step(user_vecs, vecs, codes, page=512, k=100)
jax.block_until_ready(scores)
t_two_phase = time.time() - t0

t0 = time.time()
gold_ids, _ = brute_force_retrieval(user_vecs, vecs, k=100)
jax.block_until_ready(gold_ids)
t_brute = time.time() - t0

recall = np.mean([
    len(set(np.asarray(ids[i]).tolist()) & set(np.asarray(gold_ids[i]).tolist())) / 100
    for i in range(ids.shape[0])])
print(f"two-phase: {t_two_phase*1e3:.0f} ms   brute: {t_brute*1e3:.0f} ms   "
      f"recall@100 = {recall:.3f}")
