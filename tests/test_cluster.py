"""Cluster control plane (repro/cluster): routing, failover, maintenance.

The pinned invariants:

* routing is INVISIBLE -- whichever replica group serves a query, the
  result is bit-identical to a single batcher over the same index
  (groups are bit-identical full copies at identical batch shapes);
* failover is transparent -- a failed/failing group's requests replay on
  surviving copies, results unchanged, health updated; only a full
  outage surfaces an error (and a request that fails on EVERY copy is
  treated as a bad request, not a dead cluster);
* background auto-compaction fires past the tombstone-ratio threshold
  and hot-swaps without dropping or corrupting in-flight traffic;
* the data-plane hooks (exact df under tombstones, per-shard adaptive
  ``max_postings``, ``token_df``) are exact.

Multi-group-on-one-device tests pass an explicit list of group indexes
(full serving copies) to ClusterEngine; the real ``(data, replica)`` mesh
split runs in a subprocess on 8 virtual devices (the device-count flag
must precede jax init, same pattern as test_shard_index.py).
"""

import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.cluster import ClusterEngine, HealthMap, MaintenanceDaemon
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.serve.engine import BatchedSearchEngine

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_DOCS, N_FEAT = 60, 16


@pytest.fixture(scope="module")
def sidx():
    rng = np.random.default_rng(0)
    return ShardedVectorIndex.build_sharded(
        rng.normal(size=(N_DOCS, N_FEAT)).astype(np.float32),
        make_shard_mesh(1))


@pytest.fixture()
def queries():
    return np.random.default_rng(1).normal(
        size=(9, N_FEAT)).astype(np.float32)


class _Counting:
    """Group-index wrapper that counts searches (which copy served?)."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0

    def search(self, q, **kw):
        self.calls += 1
        return self.inner.search(q, **kw)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class _Gated:
    """Group index that parks every search until released -- deterministic
    in-flight state for spill/mark_down races."""

    def __init__(self, inner):
        self.inner = inner
        self.entered = threading.Event()
        self.release = threading.Event()

    def search(self, q, **kw):
        self.entered.set()
        assert self.release.wait(timeout=60), "gate never released"
        return self.inner.search(q, **kw)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def _mk_cluster(groups, **kw):
    opts = dict(batch_size=4, k=5, page=N_DOCS, trim=None, engine="codes")
    opts.update(kw)
    return ClusterEngine(groups, **opts)


# --------------------------------------------------------------- routing
def test_any_routing_matches_single_batcher(sidx, queries):
    """Whichever group serves, results == one BatchedSearchEngine over the
    same index, bit for bit (same batch shape => same bits)."""
    cl = _mk_cluster([sidx, sidx, sidx])
    gold = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                               trim=None, engine="codes")
    try:
        for i, q in enumerate(queries):
            ids, s = cl.search(q, stream=i % 3, timeout=60)
            gi, gs = gold.search(q, timeout=60)
            assert np.array_equal(ids, gi), i
            assert np.array_equal(s, gs), i
    finally:
        cl.close()
        gold.close()


def test_stream_affinity_pins_one_group(sidx, queries):
    """Sequential requests on one stream land on ONE group (ES
    preference-string stickiness); a second stream may pin elsewhere."""
    groups = [_Counting(sidx) for _ in range(3)]
    cl = _mk_cluster(groups)
    try:
        for q in queries:
            cl.search(q, stream="session-A", timeout=60)
        assert sum(g.calls > 0 for g in groups) == 1
    finally:
        cl.close()


def test_overflow_spills_to_least_loaded(sidx, queries):
    """A backed-up pinned group spills overflow to the least-loaded
    healthy copy; the pin survives the spike."""
    gated = _Gated(sidx)
    counting = _Counting(sidx)
    cl = _mk_cluster([gated, counting], batch_size=1, spill_factor=2.0)
    try:
        # pin the stream to group 0 (both empty, least-loaded = lowest id)
        futs = [cl.submit(queries[0], stream="s")]
        assert gated.entered.wait(timeout=60)
        # spill_threshold = 2: queue 2 more onto the stuck group...
        futs += [cl.submit(q, stream="s") for q in queries[1:3]]
        # ...now group 0's pending exceeds the threshold: spill to group 1
        spilled = cl.submit(queries[3], stream="s")
        spilled.result(timeout=60)
        assert counting.calls >= 1
        gated.release.set()
        for f in futs:
            f.result(timeout=60)
        # spike drained: the stream is still pinned to group 0
        before = counting.calls
        cl.search(queries[4], stream="s", timeout=60)
        assert counting.calls == before
    finally:
        gated.release.set()
        cl.close()


# -------------------------------------------------------------- failover
def test_mark_down_drains_inflight_and_reroutes(sidx, queries):
    """mark_down is a routing decision: futures already queued on the
    group drain normally (in-flight work is never dropped), while new
    requests -- same stream included -- route to surviving groups."""
    gated = _Gated(sidx)
    counting = _Counting(sidx)
    cl = _mk_cluster([gated, counting], batch_size=1)
    gold = BatchedSearchEngine(sidx, batch_size=1, k=5, page=N_DOCS,
                               trim=None, engine="codes")
    try:
        inflight = [cl.submit(q, stream="s") for q in queries[:3]]
        assert gated.entered.wait(timeout=60)
        assert cl.mark_down(0)
        # new work (same pinned stream) goes to the surviving group and
        # completes while group 0 is still stuck
        ids, s = cl.search(queries[3], stream="s", timeout=60)
        gi, gs = gold.search(queries[3], timeout=60)
        assert np.array_equal(ids, gi) and np.array_equal(s, gs)
        assert counting.calls >= 1
        # the stuck group's queue drains to correct results once released
        gated.release.set()
        for i, f in enumerate(inflight):
            ids, _ = f.result(timeout=60)
            gi, _ = gold.search(queries[i], timeout=60)
            assert np.array_equal(ids, gi), i
    finally:
        gated.release.set()
        cl.close()
        gold.close()


def test_injected_failure_fails_over_transparently(sidx, queries):
    """The full detect -> mark_down -> resubmit path: a poisoned group's
    requests transparently replay on a surviving copy (results correct),
    health flips down, and heal + mark_up restores service."""
    groups = [_Counting(sidx), _Counting(sidx)]
    cl = _mk_cluster(groups)
    gold = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                               trim=None, engine="codes")
    try:
        cl.search(queries[0], stream="s", timeout=60)   # pin to group 0
        assert groups[0].calls == 1
        cl.inject_failure(0)
        ids, s = cl.search(queries[1], stream="s", timeout=60)
        gi, gs = gold.search(queries[1], timeout=60)
        assert np.array_equal(ids, gi) and np.array_equal(s, gs)
        assert not cl.health.is_up(0)
        assert groups[1].calls >= 1
        # recovery: clear the fault, rejoin, and the group serves again
        cl.heal(0)
        assert cl.mark_up(0)
        before = groups[0].calls
        cl.search(queries[2], stream="s", timeout=60)
        assert groups[0].calls > before
    finally:
        cl.close()
        gold.close()


def test_full_outage_surfaces_error_and_restores_health(sidx, queries):
    """Every copy failing the SAME request means the request is at fault:
    the error surfaces, but the health map is restored so one poisoned
    query cannot black-hole the cluster."""
    cl = _mk_cluster([sidx, sidx])
    try:
        for g in (0, 1):
            cl.inject_failure(g, RuntimeError(f"boom {g}"))
        with pytest.raises(RuntimeError, match="boom"):
            cl.search(queries[0], timeout=60)
        assert cl.health.up_groups() == (0, 1)
        # after healing, service resumes with no operator intervention
        for g in (0, 1):
            cl.heal(g)
        ids, _ = cl.search(queries[0], timeout=60)
        assert ids.shape == (5,)
    finally:
        cl.close()


def test_marked_down_cluster_rejects_new_work(sidx, queries):
    """All groups administratively down -> submit fails fast with the
    no-healthy-copy error (explicit drain, unlike the poisoned-request
    case there is no evidence the groups are fine)."""
    cl = _mk_cluster([sidx, sidx])
    try:
        cl.mark_down(0)
        cl.mark_down(1)
        with pytest.raises(RuntimeError, match="no healthy replica group"):
            cl.search(queries[0], timeout=60)
        assert cl.health.up_groups() == ()
    finally:
        cl.close()


def test_close_closes_every_group_batcher(sidx, queries):
    """Cluster close tears down each per-group batcher: submit afterwards
    -- on the cluster AND on any per-group batcher -- raises."""
    cl = _mk_cluster([sidx, sidx])
    batchers = cl.batchers
    cl.close()
    with pytest.raises(RuntimeError, match="engine closed"):
        cl.submit(queries[0])
    for b in batchers:
        with pytest.raises(RuntimeError, match="engine closed"):
            b.submit(queries[0])


def test_health_map_contract():
    h = HealthMap(3)
    assert h.up_groups() == (0, 1, 2)
    assert h.mark_down(1) and not h.mark_down(1)
    assert h.up_groups() == (0, 2) and not h.is_up(1)
    assert h.generation == 1
    assert h.mark_up(1) and not h.mark_up(1)
    assert h.up_groups() == (0, 1, 2) and h.generation == 2
    with pytest.raises(ValueError, match="group must be in"):
        h.mark_down(3)
    with pytest.raises(ValueError, match="replica group"):
        HealthMap(0)


# ----------------------------------------------------------- maintenance
def _check_clean(index, queries, live_ids):
    live_ids = set(live_ids)
    ids, scores = index.search(queries, k=10, page=10_000, engine="codes")
    ids, scores = np.asarray(ids), np.asarray(scores)
    dead = ids == -1
    assert (np.isneginf(scores) == dead).all()
    assert all(i in live_ids for i in ids[~dead].ravel())


def test_auto_compact_lifecycle(sidx, queries):
    """THE acceptance lifecycle: add -> delete past threshold -> the
    BACKGROUND daemon compacts (hot swap under the engine lock), with
    sentinel-free, correct results served throughout."""
    rng = np.random.default_rng(7)
    W = rng.normal(size=(12, N_FEAT)).astype(np.float32)
    cl = _mk_cluster([sidx, sidx], auto_compact=0.2, compact_interval_s=0.01)
    try:
        first = cl.add_documents(W)
        assert first == N_DOCS
        ids, s = cl.search(W[0], stream=0, timeout=60)
        assert ids[0] == N_DOCS and abs(s[0] - 1) < 1e-5

        victims = list(range(0, 14)) + [N_DOCS + 1]     # base + segment
        cl.delete(victims)      # 15/72 dead: past the 0.2 threshold
        # (no ratio assert here: the daemon may legally compact the moment
        # the delete lands -- the trigger ratio is pinned via the event log)

        # keep traffic flowing while the daemon compacts underneath it
        deadline = time.monotonic() + 60
        while cl.maintenance.compactions < 2:
            assert time.monotonic() < deadline, "daemon never compacted"
            ids, s = cl.search(queries[0], stream=0, timeout=60)
            assert not np.isin(ids, victims).any()

        for g in range(2):
            idx = cl.group_index(g)
            assert idx.n_appended == 0 and idx.seg_capacity == 0
            assert idx.tombstone_ratio == 0.0
            _check_clean(idx, np.stack([queries[0], W[0]]),
                         set(range(N_DOCS + 12)) - set(victims))
        # post-compact serving: appended docs survive, victims stay dead
        ids, s = cl.search(W[0], stream=1, timeout=60)
        assert ids[0] == N_DOCS
        assert cl.maintenance.events[0]["tombstone_ratio"] > 0.2
    finally:
        cl.close()


def test_maintenance_cas_respects_racing_ingest(sidx):
    """A compaction computed from a stale snapshot must NOT clobber an
    ingest that landed mid-rebuild: the CAS fails, the ingest survives,
    and the next sweep compacts the fresh state."""
    rng = np.random.default_rng(8)
    W = rng.normal(size=(8, N_FEAT)).astype(np.float32)
    eng = BatchedSearchEngine(sidx, batch_size=2, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        eng.delete(list(range(14)))                      # ratio > 0.2
        snapshot = eng.index
        compacted = snapshot.compact()
        first = eng.add_documents(W)                     # races the rebuild
        assert not eng.swap_index(compacted, expected=snapshot)
        assert eng.index.n_appended == 8                 # ingest survived
        daemon = MaintenanceDaemon([eng], threshold=0.2)
        assert daemon.poll_once() == 1                   # fresh-state sweep
        idx = eng.index
        assert idx.n_appended == 0 and idx.tombstone_ratio == 0.0
        ids, _ = eng.search(W[3], timeout=60)
        assert ids[0] == first + 3                       # gids stable
    finally:
        eng.close()


def test_maintenance_quarantines_failing_rebuild(sidx):
    """A compact() that itself fails (device OOM, compile error) must be
    recorded -- not swallowed -- and must NOT hot-loop: the failed
    snapshot is quarantined until an ingest/delete produces new state."""

    class _BadCompact:
        def __init__(self, inner):
            self.inner = inner
            self.compact_calls = 0

        def compact(self):
            self.compact_calls += 1
            raise RuntimeError("simulated device OOM")

        def __getattr__(self, name):
            return getattr(self.inner, name)

    bad = _BadCompact(sidx.delete(list(range(14))))      # ratio > 0.2
    eng = BatchedSearchEngine(bad, batch_size=2, trim=None)
    try:
        daemon = MaintenanceDaemon([eng], threshold=0.2)
        assert daemon.poll_once() == 0
        assert daemon.failures and "OOM" in daemon.failures[0]["error"]
        assert daemon.poll_once() == 0                   # quarantined...
        assert bad.compact_calls == 1                    # ...no hot loop
        eng.swap_index(sidx.delete(list(range(15))))     # state moved on
        daemon.poll_once()                               # re-armed: retries
        assert len(daemon.failures) == 1                 # real index: works
        assert eng.index.tombstone_ratio == 0.0
    finally:
        eng.close()


def test_maintenance_skips_down_groups(sidx):
    """A dead copy is failover's problem: the daemon must not try to
    compact it (its device set may be gone)."""
    e0 = BatchedSearchEngine(sidx, batch_size=2, trim=None)
    e1 = BatchedSearchEngine(sidx, batch_size=2, trim=None)
    try:
        e0.delete(list(range(14)))
        e1.delete(list(range(14)))
        health = HealthMap(2)
        health.mark_down(0)
        daemon = MaintenanceDaemon([e0, e1], threshold=0.2, health=health)
        assert daemon.poll_once() == 1
        assert e0.index.tombstone_ratio > 0.2            # untouched
        assert e1.index.tombstone_ratio == 0.0
    finally:
        e0.close()
        e1.close()


# ---------------------------------------------------- data-plane hooks
def test_tombstone_accounting_is_exact(sidx):
    rng = np.random.default_rng(9)
    W = rng.normal(size=(6, N_FEAT)).astype(np.float32)
    assert sidx.tombstone_ratio == 0.0 and sidx.n_tombstones == 0
    grown = sidx.add_documents(W)
    pruned = grown.delete([0, 5, N_DOCS + 2])
    assert pruned.n_tombstones == 3
    assert pruned.tombstone_ratio == pytest.approx(3 / (N_DOCS + 6))
    again = pruned.delete([0, 5])                        # no-op re-delete
    assert again.n_tombstones == 3
    assert pruned.compact().n_tombstones == 0


def test_token_df_exact_under_tombstones_and_compact(sidx):
    """df == brute-force count over LIVE codes after delete (the eager
    postings refresh), and is invariant under compaction -- the pin
    behind 'idf-sensitive engines score identically across compaction'."""
    rng = np.random.default_rng(10)
    W = rng.normal(size=(7, N_FEAT)).astype(np.float32)
    Q = rng.normal(size=(4, N_FEAT)).astype(np.float32)
    pruned = sidx.add_documents(W).delete([0, 3, 17, N_DOCS + 2])

    import jax.numpy as jnp

    from repro.core.rerank import normalize

    qcodes = np.asarray(pruned.encoder.encode(normalize(jnp.asarray(Q))))
    C = pruned.codes.shape[-1]
    base = np.asarray(pruned.codes).reshape(-1, C)[: N_DOCS]
    live = np.asarray(pruned.live).reshape(-1)[: N_DOCS]
    seg = np.asarray(pruned.seg_codes).reshape(-1, C)
    sliv = np.asarray(pruned.seg_live).reshape(-1)
    live_codes = np.concatenate([base[live], seg[sliv]])
    expect = (qcodes[:, None, :] == live_codes[None, :, :]).sum(1)

    assert np.array_equal(np.asarray(pruned.token_df(Q)), expect)
    assert np.array_equal(np.asarray(pruned.compact().token_df(Q)), expect)


def test_idf_results_identical_across_compaction(sidx):
    """The satellite guarantee end to end: with exact df maintained under
    tombstones, idf-weighted search returns identical hits before and
    after compaction (scores to float tolerance: compaction re-normalises
    vectors, which can move the last ulp)."""
    rng = np.random.default_rng(11)
    W = rng.normal(size=(9, N_FEAT)).astype(np.float32)
    Q = rng.normal(size=(5, N_FEAT)).astype(np.float32)
    pruned = sidx.add_documents(W).delete([1, 4, 40, N_DOCS + 3])
    packed = pruned.compact()
    for engine in ("postings", "codes"):
        i1, s1 = pruned.search(Q, k=10, page=10_000, engine=engine,
                               weighting="idf")
        i2, s2 = packed.search(Q, k=10, page=10_000, engine=engine,
                               weighting="idf")
        assert np.array_equal(np.asarray(i1), np.asarray(i2)), engine
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-6, err_msg=engine)


def test_adaptive_max_postings_exact_and_smaller(sidx):
    """max_postings='auto' sizes the window from the real code
    distribution (max_df), stays exact (bit-identical to the full
    window), and the window is genuinely smaller than docs_per_shard."""
    rng = np.random.default_rng(12)
    Q = rng.normal(size=(5, N_FEAT)).astype(np.float32)
    assert 1 <= sidx.max_df < sidx.docs_per_shard

    import jax.numpy as jnp

    # numpy reference: longest run of equal live codes per column
    from repro.core.search import _SENTINEL
    sentinel = _SENTINEL[jnp.asarray(sidx.codes).dtype]
    codes = np.asarray(sidx.codes).astype(np.int64)
    codes = codes.reshape(-1, codes.shape[-1])
    expect = max(
        np.bincount(col[col != sentinel] - col.min()).max()
        for col in codes.T)
    assert sidx.max_df == expect

    ia, sa = sidx.search(Q, k=10, page=10_000, engine="postings",
                         max_postings="auto")
    ib, sb = sidx.search(Q, k=10, page=10_000, engine="postings",
                         max_postings=None)
    assert np.array_equal(np.asarray(ia), np.asarray(ib))
    assert np.array_equal(np.asarray(sa), np.asarray(sb))

    # engine pass-through: a batcher serving with the adaptive window
    # returns the same hits as the full-window batcher
    e_auto = BatchedSearchEngine(sidx, batch_size=2, k=5, page=N_DOCS,
                                 trim=None, engine="postings",
                                 max_postings="auto")
    e_full = BatchedSearchEngine(sidx, batch_size=2, k=5, page=N_DOCS,
                                 trim=None, engine="postings")
    try:
        for q in Q:
            ra = e_auto.search(q, timeout=60)
            rf = e_full.search(q, timeout=60)
            assert np.array_equal(ra[0], rf[0])
            assert np.array_equal(ra[1], rf[1])
    finally:
        e_auto.close()
        e_full.close()


def test_replica_group_validates(sidx):
    with pytest.raises(ValueError, match="replica group"):
        sidx.replica_group(1)           # 1-D mesh has exactly one group
    assert sidx.replica_group(0) is sidx


def test_live_groups_validates(sidx, queries):
    with pytest.raises(ValueError, match="live_groups"):
        sidx.search(queries, live_groups=())
    with pytest.raises(ValueError, match="live_groups"):
        sidx.search(queries, live_groups=(2,))
    ids, _ = sidx.search(queries, k=5, page=N_DOCS, live_groups=(0,))
    gi, _ = sidx.search(queries, k=5, page=N_DOCS)
    assert np.array_equal(np.asarray(ids), np.asarray(gi))


# ------------------------------------------------------- 4x2 mesh parity
def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_failover_parity_on_4x2_mesh():
    """THE acceptance pin: on a 4 shard x 2 replica-group virtual-device
    mesh, search results after mark_down of EITHER replica group are
    bit-identical to the healthy cluster, for all engines at
    page >= n_docs -- through the ClusterEngine routing path AND the
    in-mesh health-masked merge (live_groups)."""
    _run_subprocess(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.cluster import ClusterEngine
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh

rng = np.random.default_rng(0)
V = rng.normal(size=(50, 12)).astype(np.float32)
Q = np.concatenate([V[:4], rng.normal(size=(3, 12)).astype(np.float32)])
sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(4, 2))

for engine in ("postings", "codes", "onehot"):
    cl = ClusterEngine(sidx, batch_size=4, k=5, page=1000, trim=None,
                       engine=engine)
    try:
        healthy = [cl.submit(q, stream=i % 4) for i, q in enumerate(Q)]
        healthy = [f.result(timeout=300) for f in healthy]
        for down in (0, 1):
            after = [cl.submit(q, stream=i % 4) for i, q in enumerate(Q)]
            cl.mark_down(down)          # in-flight futures drain normally
            after = [f.result(timeout=300) for f in after]
            gone = [cl.submit(q, stream=i % 4) for i, q in enumerate(Q)]
            gone = [f.result(timeout=300) for f in gone]
            for (hi, hs), (ai, as_), (gi, gs) in zip(healthy, after, gone):
                assert np.array_equal(hi, ai) and np.array_equal(hs, as_), \
                    (engine, down)
                assert np.array_equal(hi, gi) and np.array_equal(hs, gs), \
                    (engine, down)
            cl.mark_up(down)
    finally:
        cl.close()

    # in-mesh health-masked merge: one live column == healthy cluster
    gi, gs = sidx.search(Q, k=5, page=1000, engine=engine)
    gi, gs = np.asarray(gi), np.asarray(gs)
    for down in (0, 1):
        fi, fs = sidx.search(Q, k=5, page=1000, engine=engine,
                             live_groups=(1 - down,))
        assert np.array_equal(np.asarray(fi), gi), (engine, down)
        assert np.array_equal(np.asarray(fs), gs), (engine, down)
print("OK")
""")


def test_cluster_ingest_failover_on_4x2_mesh():
    """Replica-group copies stay consistent through hot ingest + delete
    (down group included), so failover after ingest is still exact; the
    maintenance daemon then compacts every group on the real mesh."""
    _run_subprocess(r"""
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.cluster import ClusterEngine, MaintenanceDaemon
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh

rng = np.random.default_rng(1)
V = rng.normal(size=(37, 10)).astype(np.float32)
W = rng.normal(size=(8, 10)).astype(np.float32)
sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(4, 2))
cl = ClusterEngine(sidx, batch_size=2, k=3, page=1000, trim=None,
                   engine="codes")
try:
    cl.mark_down(1)                       # writes reach down groups too
    first = cl.add_documents(W)
    assert first == 37
    cl.delete([2, 11, 38])
    cl.mark_up(1)
    a = [cl.search(q, stream=0, timeout=300) for q in W[:4]]
    cl.inject_failure(0)                  # stream 0 pinned to group 0
    b = [cl.search(q, stream=0, timeout=300) for q in W[:4]]
    assert not cl.health.is_up(0)
    for (ai, asc), (bi, bsc) in zip(a, b):
        assert np.array_equal(ai, bi) and np.array_equal(asc, bsc)
    assert b[0][0][0] == 37                # hot-added doc is its own top hit
    assert 38 not in b[1][0]               # the deleted segment doc stays dead
    cl.heal(0); cl.mark_up(0)
    daemon = MaintenanceDaemon(cl.batchers, threshold=0.05)
    assert daemon.poll_once() == 2
    for g in range(2):
        idx = cl.group_index(g)
        assert idx.n_appended == 0 and idx.tombstone_ratio == 0.0
    ids, _ = cl.search(W[0], stream=1, timeout=300)
    assert ids[0] == 37
finally:
    cl.close()
print("OK")
""")
