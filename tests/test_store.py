"""Durability subsystem (repro/store): translog, commit points, recovery.

The pinned acceptance invariant: an index recovered from DISK ALONE
(latest commit point + translog replay, torn tails truncated) returns
BIT-IDENTICAL search results to the pre-kill live index -- at every
ingest/delete/compact stage boundary, for all engines at
``page >= n_docs``, on 1-, 4-, and 4x2-device meshes (multi-device in
subprocesses, the usual virtual-device pattern).  On the writer's own
mesh shape the pin is stronger: every LEAF is bit-identical, so parity
holds at any page.  Compaction pairs with a commit (the maintenance
daemon's behaviour): compaction is content-preserving but re-normalizes
vectors, so an uncommitted compact recovers to the equally-valid
pre-compact state (identical ids, last-ulp scores) -- the bit-parity
contract is over the acked op history, which is exactly what the log
holds.

Also pinned here: translog framing/torn-tail/corruption semantics,
commit fallback past a damaged newest generation, the maintenance
daemon's post-compaction commit + translog trim, ClusterEngine's
``restore_group`` (a downed group re-admitted from disk, bit-identical
to its surviving siblings), canary health probing, and the router's
stream-pin LRU eviction cap.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import ClusterEngine, MaintenanceDaemon
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.serve.engine import BatchedSearchEngine
from repro.store import (NoCommitError, Store, Translog,
                         TranslogCorruptedError, latest_commit, read_ops,
                         recover, restore, write_commit)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LEAVES = ("vectors", "codes", "post_docs", "post_codes", "offsets", "live",
           "seg_vectors", "seg_codes", "seg_gids", "seg_live")
_SEG_LEAVES = ("vectors", "codes", "gids", "live", "post_docs", "post_codes")
_ENGINES = ("postings", "codes", "onehot")


def _build(n_docs=30, dims=10, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, dims)).astype(np.float32)
    return V, rng


def _assert_bit_identical(live, rec, queries, ctx, *, leaves=True):
    if leaves:
        for name in _LEAVES:
            a = np.asarray(getattr(live, name))
            b = np.asarray(getattr(rec, name))
            assert np.array_equal(a, b), (ctx, name)
        assert tuple(live.shard_tombstones or ()) == \
            tuple(rec.shard_tombstones or ()), ctx
        # sealed generations survive the disk round trip structurally:
        # same count, same rows/tombstones, same leaves per segment
        assert live.seg_base == rec.seg_base, ctx
        assert live.active_tombstones == rec.active_tombstones, ctx
        assert len(live.segments) == len(rec.segments), ctx
        for si, (sa, sb) in enumerate(zip(live.segments, rec.segments)):
            assert sa.n_rows == sb.n_rows, (ctx, si)
            assert sa.tombstones == sb.tombstones, (ctx, si)
            for name in _SEG_LEAVES:
                assert np.array_equal(np.asarray(getattr(sa, name)),
                                      np.asarray(getattr(sb, name))), \
                    (ctx, si, name)
    assert live.n_ids == rec.n_ids and live.n_docs == rec.n_docs, ctx
    for engine in _ENGINES:
        i1, s1 = live.search(queries, k=8, page=2 * live.n_ids,
                             engine=engine)
        i2, s2 = rec.search(queries, k=8, page=2 * rec.n_ids, engine=engine)
        assert np.array_equal(np.asarray(i1), np.asarray(i2)), (ctx, engine)
        assert np.array_equal(np.asarray(s1), np.asarray(s2)), (ctx, engine)


# ---------------------------------------------------------------- translog
def test_translog_append_replay_roundtrip(tmp_path):
    log = Translog(str(tmp_path))
    rng = np.random.default_rng(0)
    V = rng.normal(size=(4, 6)).astype(np.float32)
    assert log.seqno == 0
    assert log.add(V) == 1
    assert log.delete([3, 7]) == 2
    assert log.add(V[:2]) == 3
    log.close()
    ops = list(read_ops(str(tmp_path)))
    assert [s for s, _, _ in ops] == [1, 2, 3]
    assert np.array_equal(ops[0][2], V)
    assert np.array_equal(ops[1][2], np.asarray([3, 7], np.int64))
    # replay past a commit point skips covered records
    assert [s for s, _, _ in read_ops(str(tmp_path), after_seq=2)] == [3]


def test_translog_truncates_torn_tail(tmp_path):
    log = Translog(str(tmp_path))
    V = np.ones((2, 4), np.float32)
    log.add(V)
    log.add(2 * V)
    path = os.path.join(str(tmp_path), f"translog-{log.generation:08d}.log")
    log.close()
    size = os.path.getsize(path)
    with open(path, "r+b") as f:        # crash mid-append: half a record
        f.truncate(size - 7)
    ops = list(read_ops(str(tmp_path)))             # truncates as it reads
    assert [s for s, _, _ in ops] == [1]
    assert os.path.getsize(path) < size - 7
    # the repaired log accepts new appends at the right seqno
    log = Translog(str(tmp_path))
    assert log.seqno == 1 and log.add(V) == 2
    log.close()


def test_translog_corruption_mid_stream_raises(tmp_path):
    log = Translog(str(tmp_path))
    log.add(np.ones((2, 4), np.float32))
    gen1 = log.generation
    log.roll()                                      # record 1 is no longer
    log.add(np.ones((1, 4), np.float32))            # in the newest gen
    log.close()
    path = os.path.join(str(tmp_path), f"translog-{gen1:08d}.log")
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) - 3)
        f.write(b"\xff\xff\xff")
    with pytest.raises(TranslogCorruptedError, match="corrupt record"):
        list(read_ops(str(tmp_path)))


def test_translog_torn_header_artifact_never_bricks(tmp_path):
    """Crash mid-roll can leave a generation file with a partial header.
    Reopening must DELETE the artifact -- merely skipping it would brick
    the log once newer generations hold records (the torn file would no
    longer be 'newest' and every later scan would raise on it)."""
    log = Translog(str(tmp_path))
    V = np.ones((2, 4), np.float32)
    log.add(V)
    gen = log.generation
    log.close()
    torn = os.path.join(str(tmp_path), f"translog-{gen + 1:08d}.log")
    with open(torn, "wb") as f:
        f.write(b"RT")                              # header torn mid-write
    log = Translog(str(tmp_path))                   # restart: artifact is
    assert log.seqno == 1                           # deleted (the gen number
    log.add(V)                                      # is reused for a FRESH,
    log.close()                                     # valid-header file)
    assert [s for s, _, _ in read_ops(str(tmp_path))] == [1, 2]
    log = Translog(str(tmp_path))                   # and reopens fine
    assert log.seqno == 2
    log.close()


def test_translog_gap_past_commit_raises(tmp_path):
    log = Translog(str(tmp_path))
    for _ in range(3):
        log.add(np.ones((1, 4), np.float32))
        log.roll()
    log.trim(2)                                     # gens for seq 1, 2 gone
    log.close()
    assert [s for s, _, _ in read_ops(str(tmp_path), after_seq=2)] == [3]
    with pytest.raises(TranslogCorruptedError, match="gap"):
        list(read_ops(str(tmp_path), after_seq=0))  # seq 1..2 unrecoverable


def test_translog_seqno_survives_trim_and_reopen(tmp_path):
    """The base-seqno anchor: after a commit trims every record away, a
    reopened writer must continue the sequence, not restart at 1 (restart
    would alias already-committed seqnos and lose the aliased ops)."""
    log = Translog(str(tmp_path))
    for _ in range(4):
        log.add(np.ones((1, 3), np.float32))
    log.roll()
    log.trim(4)
    log.close()
    log = Translog(str(tmp_path))
    assert log.seqno == 4
    assert log.add(np.ones((1, 3), np.float32)) == 5
    log.close()


def test_translog_durability_validates(tmp_path):
    with pytest.raises(ValueError, match="durability"):
        Translog(str(tmp_path), durability="yolo")
    log = Translog(str(tmp_path), durability="async")
    log.add(np.ones((1, 3), np.float32))
    log.sync()
    log.close()
    assert len(list(read_ops(str(tmp_path)))) == 1


# ------------------------------------------------------------ commit point
def test_commit_restore_leaf_identical_same_mesh(tmp_path):
    V, rng = _build()
    Q = rng.normal(size=(4, 10)).astype(np.float32)
    mesh = make_shard_mesh(1)
    sidx = ShardedVectorIndex.build_sharded(V, mesh)
    sidx = sidx.add_documents(rng.normal(size=(5, 10)).astype(np.float32))
    sidx = sidx.delete([2, 31])
    gen = write_commit(str(tmp_path), sidx, seq=7)
    commit = latest_commit(str(tmp_path))
    assert commit.generation == gen and commit.seq == 7
    rec = restore(commit, make_shard_mesh(1))
    _assert_bit_identical(sidx, rec, Q, "commit/restore")
    assert rec.encoder == sidx.encoder and rec.index_best == sidx.index_best


def test_commit_falls_back_past_damaged_newest(tmp_path):
    V, rng = _build()
    mesh = make_shard_mesh(1)
    sidx = ShardedVectorIndex.build_sharded(V, mesh)
    write_commit(str(tmp_path), sidx, seq=1)
    grown = sidx.add_documents(rng.normal(size=(3, 10)).astype(np.float32))
    write_commit(str(tmp_path), grown, seq=2)
    # tear a blob ONLY generation 2 references (the active-buffer blob:
    # gen 1 had no appended docs) -- shared blobs must stay intact or the
    # fallback would be damaged too
    with open(os.path.join(str(tmp_path), "commit-00000002.json")) as f:
        active = json.load(f)["files"]["active"]["file"]
    with open(os.path.join(str(tmp_path), active), "r+b") as f:
        f.seek(10)
        f.write(b"\x00" * 8)
    commit = latest_commit(str(tmp_path))
    assert commit is not None and commit.seq == 1   # previous generation
    assert restore(commit, mesh).n_ids == 30


def test_commit_retention_prunes_old_generations(tmp_path):
    V, rng = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    grown = sidx
    for seq in range(1, 5):
        grown = grown.add_documents(
            rng.normal(size=(2, 10)).astype(np.float32))
        write_commit(str(tmp_path), grown, seq=seq)
    names = sorted(os.listdir(str(tmp_path)))
    manifests = [n for n in names if n.startswith("commit-")]
    assert manifests == ["commit-00000003.json", "commit-00000004.json"]
    # blob GC: exactly the union of the two retained manifests' references
    # survives -- shared blobs (base vectors/state, written at gen 1) are
    # still on disk, and the pruned generations' unshared active blobs
    # are gone
    referenced = set()
    for m in manifests:
        with open(os.path.join(str(tmp_path), m)) as f:
            files = json.load(f)["files"]
        referenced |= {e["file"] for k, e in files.items()
                       if k != "segments" and e is not None}
        referenced |= {e["file"] for e in files["segments"]}
    blobs = {n for n in names if n.endswith(".seg")}
    assert blobs == referenced
    # both retained commits still fully restore
    for gen, n_ids in ((3, 36), (4, 38)):
        with open(os.path.join(str(tmp_path),
                               f"commit-{gen:08d}.json")) as f:
            assert json.load(f)["n_appended"] == n_ids - 30
    assert restore(latest_commit(str(tmp_path)),
                   make_shard_mesh(1)).n_ids == 38


def test_commit_bytes_are_o_changed(tmp_path):
    """The incremental-commit claim at the API level: a commit after a
    small ingest rewrites the changed blobs (active buffer), not the
    base vectors -- bytes_written << bytes_total on later generations."""
    V, rng = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    s0: dict = {}
    write_commit(str(tmp_path), sidx, seq=1, stats=s0)
    assert s0["bytes_written"] == s0["bytes_total"]    # first commit: all new
    grown = sidx.add_documents(rng.normal(size=(2, 10)).astype(np.float32))
    s1: dict = {}
    write_commit(str(tmp_path), grown, seq=2, stats=s1)
    # base vectors + base state blobs are re-referenced, only the active
    # blob is new
    assert 0 < s1["bytes_written"] < s1["bytes_total"]
    assert s1["blobs_written"] == 1
    # identical state -> zero new bytes
    s2: dict = {}
    write_commit(str(tmp_path), grown, seq=2, stats=s2)
    assert s2["bytes_written"] == 0 and s2["blobs_written"] == 0


def test_gc_keeps_blobs_referenced_by_fallback_commit(tmp_path):
    """Retention GC must never delete a blob the FALLBACK commit
    references, even when the newest generation no longer does (a merge
    rewrote those segments).  Pinned the hard way: tear the newest
    generation's fresh blob and recover through the fallback."""
    V, rng = _build()
    mesh = make_shard_mesh(1)
    sidx = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=4)
    for _ in range(2):
        sidx = sidx.add_documents(
            rng.normal(size=(5, 10)).astype(np.float32))
    assert sidx.n_segments == 2
    write_commit(str(tmp_path), sidx, seq=1)
    with open(os.path.join(str(tmp_path), "commit-00000001.json")) as f:
        gen1_seg_blobs = {e["file"]
                          for e in json.load(f)["files"]["segments"]}
    assert gen1_seg_blobs
    merged = sidx.merge_segments()        # gen 2 references NONE of them
    write_commit(str(tmp_path), merged, seq=2)
    for blob in gen1_seg_blobs:           # GC ran; fallback blobs intact
        assert os.path.exists(os.path.join(str(tmp_path), blob)), blob
    # the fallback is not just present but USABLE: tear gen 2's merged
    # segment blob, fall back a generation, restore
    with open(os.path.join(str(tmp_path), "commit-00000002.json")) as f:
        gen2_segs = {e["file"] for e in json.load(f)["files"]["segments"]}
    target = sorted(gen2_segs - gen1_seg_blobs)[0]
    with open(os.path.join(str(tmp_path), target), "r+b") as f:
        f.seek(10)
        f.write(b"\x00" * 8)
    commit = latest_commit(str(tmp_path))
    assert commit is not None and commit.seq == 1
    assert restore(commit, mesh).n_ids == 40


def test_recover_without_commit_raises(tmp_path):
    with pytest.raises(NoCommitError):
        recover(str(tmp_path), make_shard_mesh(1))


# ------------------------------------------------- crash-recovery property
@settings(max_examples=5)
@given(n_docs=st.integers(8, 40), dims=st.integers(4, 12),
       n_ops=st.integers(1, 5), seed=st.integers(0, 2**20))
def test_crash_recovery_bit_parity_sweep(n_docs, dims, n_ops, seed):
    """THE property: random ingest/delete/merge/compact/commit
    interleavings, with a kill point at EVERY stage boundary -- the
    recovered index (disk state only) is bit-identical to the live
    index, leaves and search results both.  The seal threshold is tiny
    so appends routinely seal into segments and recovery replay must
    re-seal at identical boundaries.  Merge and compact pair with
    commit (daemon semantics); the no-op boundary right after the
    baseline commit is stage 0."""
    import shutil
    import tempfile

    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, dims)).astype(np.float32)
    Q = rng.normal(size=(4, dims)).astype(np.float32)
    mesh = make_shard_mesh(1)
    store_dir = tempfile.mkdtemp(prefix="repro_store_")
    store = Store(store_dir,
                  durability=["request", "async"][int(rng.integers(2))])
    live = store.open_index(
        ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=4))
    if store.durability == "async":
        store.translog.sync()   # a kill is a process death, not power loss;
        #                         sync() stands in for the OS page cache
    try:
        for stage in range(n_ops + 1):
            rec, seq = recover(store_dir, make_shard_mesh(1))
            assert seq == live.translog_seq, stage
            _assert_bit_identical(live.inner, rec, Q, (seed, stage))
            if stage == n_ops:
                break
            op = rng.choice(["add", "delete", "merge", "compact"])
            if op == "add":
                m = int(rng.integers(1, 6))
                live = live.add_documents(
                    rng.normal(size=(m, dims)).astype(np.float32))
            elif op == "delete":
                ids = rng.choice(live.n_ids, size=min(3, live.n_ids),
                                 replace=False)
                live = live.delete(ids)
            elif op == "merge" and live.n_segments:
                count = int(rng.integers(1, live.n_segments + 1))
                live = live.merge_segments(0, count)
                store.commit(live)
            elif op == "compact":
                live = live.compact()
                store.commit(live)
            if rng.random() < 0.3:
                store.commit(live)                  # mid-stream commit
            if store.durability == "async":
                store.translog.sync()
    finally:
        store.close()
        shutil.rmtree(store_dir, ignore_errors=True)


# ----------------------------------------------------- engine/daemon wiring
def test_durable_index_logs_before_ack(tmp_path):
    """Write-through order: the translog seqno moves with every engine
    ingest/delete, and recovery replays exactly the acked history."""
    V, rng = _build()
    Q = rng.normal(size=(3, 10)).astype(np.float32)
    store = Store(str(tmp_path))
    idx = store.open_index(
        ShardedVectorIndex.build_sharded(V, make_shard_mesh(1)))
    eng = BatchedSearchEngine(idx, batch_size=2, trim=None, engine="codes")
    try:
        assert store.seqno == 0
        first = eng.add_documents(rng.normal(size=(4, 10)).astype(np.float32))
        assert first == 30 and store.seqno == 1
        eng.delete([1, 30])
        assert store.seqno == 2
        assert eng.index.translog_seq == 2
        rec, seq = recover(str(tmp_path), make_shard_mesh(1))
        assert seq == 2
        _assert_bit_identical(eng.index.inner, rec, Q, "engine write-through")
    finally:
        eng.close()
    store.close()


def test_failing_op_is_never_logged(tmp_path):
    """ES ordering: apply -> log -> ack.  An op that RAISES (malformed
    vectors, out-of-range id) must leave no translog record -- otherwise
    the same exception would resurface at every recovery replay and a
    single bad request would poison the store forever."""
    V, rng = _build()
    store = Store(str(tmp_path))
    idx = store.open_index(
        ShardedVectorIndex.build_sharded(V, make_shard_mesh(1)))
    with pytest.raises(ValueError, match="feature"):
        idx.add_documents(np.ones((2, 99), np.float32))  # wrong width
    with pytest.raises(ValueError, match="ids must be"):
        idx.delete([10_000])                             # out of range
    assert store.seqno == 0
    idx = idx.add_documents(rng.normal(size=(2, 10)).astype(np.float32))
    assert store.seqno == 1
    rec, seq = recover(str(tmp_path), make_shard_mesh(1))  # replay is clean
    assert seq == 1 and rec.n_ids == 32
    store.close()


def test_daemon_commits_after_compaction(tmp_path):
    """The maintenance flush: a successful compact-and-swap of a durable
    index rolls a commit point covering its translog_seq and trims the
    replayed translog -- recovery afterwards starts from the compacted
    form (bit-identical leaves, no replay needed)."""
    V, rng = _build()
    Q = rng.normal(size=(3, 10)).astype(np.float32)
    store = Store(str(tmp_path))
    idx = store.open_index(
        ShardedVectorIndex.build_sharded(V, make_shard_mesh(1)))
    eng = BatchedSearchEngine(idx, batch_size=2, trim=None, engine="codes")
    try:
        eng.delete(list(range(9)))                   # ratio 0.3 > 0.2
        daemon = MaintenanceDaemon([eng], threshold=0.2, store=store)
        assert daemon.poll_once() == 1
        assert daemon.commits == 1 and not daemon.failures
        assert eng.index.translog_seq == 1           # metadata rode the CAS
        commit = latest_commit(str(tmp_path))
        assert commit.seq == 1
        assert not list(read_ops(str(tmp_path), after_seq=commit.seq))
        rec, seq = recover(str(tmp_path), make_shard_mesh(1))
        assert seq == 1
        _assert_bit_identical(eng.index.inner, rec, Q, "daemon commit")
    finally:
        eng.close()
    store.close()


def test_merge_kill_points_recover_bit_identical(tmp_path):
    """A crash at EVERY boundary inside a background merge pass (before
    the swap installs the merged index, after the swap but before the
    commit, after the commit) recovers bit-identically.  A merge is not
    logged, so until its commit lands the acked history -- and therefore
    recovery -- names the PRE-merge layout; after the commit it names
    the merged one.  Both layouts answer searches identically, so no
    kill point can change what a recovered node serves."""
    V, rng = _build(n_docs=24)
    Q = rng.normal(size=(4, 10)).astype(np.float32)
    mesh = make_shard_mesh(1)
    store = Store(str(tmp_path))
    live = store.open_index(
        ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=4))
    for _ in range(3):                       # seal three generations
        live = live.add_documents(
            rng.normal(size=(5, 10)).astype(np.float32))
    live = live.delete([30, 31, 36])         # dead rows inside segments
    assert live.n_segments >= 2
    pre = live

    # kill point 1: merge computed, crash BEFORE the swap -- nothing
    # changed on disk, recovery is the pre-merge state
    merged = pre.merge_segments(0, 2)
    rec, seq = recover(str(tmp_path), make_shard_mesh(1))
    assert seq == pre.translog_seq
    _assert_bit_identical(pre.inner, rec, Q, "before swap")

    # kill point 2: swap installed (node was serving the merged index),
    # crash BEFORE the commit -- disk still holds the pre-merge commit +
    # the full translog, so recovery reproduces the pre-merge layout
    # leaf for leaf, and that layout answers exactly like the merged one
    live = merged                            # the CAS, collapsed
    rec, seq = recover(str(tmp_path), make_shard_mesh(1))
    assert seq == live.translog_seq
    _assert_bit_identical(pre.inner, rec, Q, "after swap")
    for engine in _ENGINES:
        i1, s1 = live.search(Q, k=8, page=2 * live.n_ids, engine=engine)
        i2, s2 = rec.search(Q, k=8, page=2 * rec.n_ids, engine=engine)
        assert np.array_equal(np.asarray(i1), np.asarray(i2)), engine
        assert np.array_equal(np.asarray(s1), np.asarray(s2)), engine

    # kill point 3: crash AFTER the commit -- recovery is the merged
    # layout itself, leaf for leaf
    store.commit(live)
    rec, seq = recover(str(tmp_path), make_shard_mesh(1))
    assert seq == live.translog_seq
    _assert_bit_identical(live.inner, rec, Q, "after commit")
    store.close()


def test_cluster_restore_group_readmits_from_disk(tmp_path):
    """PR 4's dead end, closed: a replica group whose memory is poisoned
    comes back from commit + translog replay, serves bit-identically to
    its surviving sibling, and is routable again."""
    V, rng = _build()
    W = rng.normal(size=(5, 10)).astype(np.float32)
    Q = rng.normal(size=(4, 10)).astype(np.float32)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    store = Store(str(tmp_path))
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=200, trim=None,
                       engine="codes", store=store)
    try:
        cl.add_documents(W)
        cl.delete([0, 31])
        ref = [cl.search(q, stream="a", timeout=60) for q in Q]
        cl.inject_failure(1)
        cl.mark_down(1)
        seq = cl.restore_group(1)
        assert seq == 2 and cl.health.is_up(1)
        got = [cl.search(q, stream="pin-b", timeout=60) for q in Q]
        for (ai, asc), (bi, bsc) in zip(ref, got):
            assert np.array_equal(ai, bi) and np.array_equal(asc, bsc)
        # group 0 (the primary) restores too, keeping write-through
        cl.mark_down(0)
        cl.restore_group(0)
        assert cl.health.is_up(0)
        first = cl.add_documents(W[:2])              # still logs: seq moves
        assert first == 35 and store.seqno == 3
    finally:
        cl.close()
    store.close()


def test_cluster_without_store_rejects_restore():
    V, _ = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    cl = ClusterEngine([sidx, sidx], batch_size=2, trim=None)
    try:
        with pytest.raises(RuntimeError, match="no store attached"):
            cl.restore_group(1)
    finally:
        cl.close()


# --------------------------------------------------------- health probing
def test_probe_readmits_healed_group():
    """Background probing: a downed group stays down while its fault is
    live, and re-admits on the first canary that answers -- no manual
    mark_up, no poisoned-request rollback."""
    V, _ = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    cl = ClusterEngine([sidx, sidx], batch_size=2, k=3, page=30, trim=None,
                       engine="codes")
    try:
        daemon = MaintenanceDaemon(cl.batchers, health=cl.health, probe=True)
        cl.inject_failure(1)
        cl.health.mark_down(1)          # a FAULT (what failover records)
        assert daemon.probe_once() == 0 and not cl.health.is_up(1)
        cl.heal(1)
        assert daemon.probe_once() == 1 and cl.health.is_up(1)
        assert daemon.probe_events == [{"group": 1}]
        assert daemon.probe_once() == 0              # steady state: no-op
    finally:
        cl.close()


def test_probe_respects_operator_drain():
    """cluster.mark_down is operator INTENT (a drain), not a fault: the
    prober must not re-admit a drained group however healthy its
    canaries look -- only mark_up (or restore_group) brings it back."""
    V, _ = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    cl = ClusterEngine([sidx, sidx], batch_size=2, k=3, page=30, trim=None,
                       engine="codes")
    try:
        daemon = MaintenanceDaemon(cl.batchers, health=cl.health, probe=True)
        cl.mark_down(1)                 # drain: the group itself is healthy
        assert cl.health.is_drained(1)
        assert daemon.probe_once() == 0 and not cl.health.is_up(1)
        assert cl.mark_up(1)            # explicit rejoin clears the drain
        assert not cl.health.is_drained(1) and cl.health.is_up(1)
    finally:
        cl.close()


def test_probe_background_loop_readmits(tmp_path):
    """The wired path: ClusterEngine(probe_s=...) runs the prober on the
    daemon thread, so heal() alone brings the group back."""
    import time

    V, _ = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    cl = ClusterEngine([sidx, sidx], batch_size=2, k=3, page=30, trim=None,
                       engine="codes", probe_s=0.01)
    try:
        assert cl.maintenance is not None and cl.maintenance.probe
        cl.inject_failure(1)
        cl.health.mark_down(1)          # fault-style mark: probe-eligible
        time.sleep(0.1)
        assert not cl.health.is_up(1)                # fault live: stays down
        cl.heal(1)
        deadline = time.monotonic() + 60
        while not cl.health.is_up(1):
            assert time.monotonic() < deadline, "prober never re-admitted"
            time.sleep(0.01)
    finally:
        cl.close()


def test_probe_requires_health():
    with pytest.raises(ValueError, match="probe"):
        MaintenanceDaemon([], probe=True)


def test_readmit_is_drain_atomic():
    """HealthMap.readmit (the prober's and failover rollback's entry
    point) must be a no-op under a drain -- even one recorded AFTER the
    fault, i.e. while a canary was already in flight -- while plain
    mark_up (the operator's explicit rejoin) clears it.  Drain mutations
    bump generation like any other cluster-state change."""
    from repro.cluster import HealthMap

    h = HealthMap(2)
    h.mark_down(1)                      # fault
    assert h.readmit(1) and h.is_up(1)  # no drain: readmit works
    h.mark_down(1)
    gen = h.generation
    assert h.mark_down(1, drain=True)   # drain lands mid-flight: changed
    assert h.generation == gen + 1      # ...and is observable via gen
    assert not h.readmit(1) and not h.is_up(1)   # canary success: ignored
    assert h.mark_up(1) and h.is_up(1) and not h.is_drained(1)
    assert not h.readmit(0)             # up group: nothing to do


def test_open_index_refuses_dirty_store(tmp_path):
    """Pairing a FRESH index with a store that already holds history
    would make recovery replay a different corpus than the one served --
    the library must refuse, pointing at recover() instead."""
    V, rng = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    store = Store(str(tmp_path))
    idx = store.open_index(sidx)
    idx.add_documents(rng.normal(size=(2, 10)).astype(np.float32))
    store.close()
    store = Store(str(tmp_path))        # restart on existing history
    with pytest.raises(ValueError, match="already holds history"):
        store.open_index(sidx)
    rec, seq = store.recover(make_shard_mesh(1))    # the supported path
    assert seq == 1 and rec.translog_seq == 1
    store.close()


# ------------------------------------------------------ stream-pin LRU cap
def test_stream_pin_map_is_lru_capped():
    """The affinity map must not grow monotonically with distinct stream
    ids: past ``max_stream_pins`` the coldest pin evicts (benign -- every
    group is a bit-identical copy, an evicted stream just re-pins)."""
    V, _ = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    cl = ClusterEngine([sidx, sidx], batch_size=2, k=3, page=30, trim=None,
                       engine="codes", max_stream_pins=3)
    try:
        for i in range(10):
            cl.search(np.ones((10,), np.float32), stream=f"s{i}", timeout=60)
        assert len(cl._streams) == 3
        assert set(cl._streams) == {"s7", "s8", "s9"}
        cl.search(np.ones((10,), np.float32), stream="s8", timeout=60)
        cl.search(np.ones((10,), np.float32), stream="s3", timeout=60)
        assert set(cl._streams) == {"s9", "s8", "s3"}  # s8 refreshed, s7 out
    finally:
        cl.close()


# ------------------------------------------------------- multi-device pins
def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_recovery_parity_4dev_and_cross_mesh(tmp_path):
    """Kill/recover bit-parity on a real 4-shard mesh at every lifecycle
    boundary, PLUS mesh-shape freedom: the same commit restores onto 1-,
    2- and 4-shard meshes with search results bit-identical to the live
    index at page >= n_docs (the repo's mesh-parity invariant, now
    through the disk path)."""
    _run_subprocess(rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.store import Store, recover

store_dir = {str(tmp_path)!r}
rng = np.random.default_rng(0)
V = rng.normal(size=(43, 10)).astype(np.float32)
Q = rng.normal(size=(4, 10)).astype(np.float32)
mesh = make_shard_mesh(4)
store = Store(store_dir)
live = store.open_index(ShardedVectorIndex.build_sharded(V, mesh))

LEAVES = ("vectors", "codes", "post_docs", "post_codes", "offsets", "live",
          "seg_vectors", "seg_codes", "seg_gids", "seg_live")

def check(live, tag):
    rec, seq = recover(store_dir, make_shard_mesh(4))
    assert seq == live.translog_seq, tag
    for name in LEAVES:
        assert np.array_equal(np.asarray(getattr(live, name)),
                              np.asarray(getattr(rec, name))), (tag, name)
    for engine in ("postings", "codes", "onehot"):
        i1, s1 = live.search(Q, k=7, page=2 * live.n_ids, engine=engine)
        for shards in (1, 2, 4):
            cross, _ = recover(store_dir, make_shard_mesh(shards))
            i2, s2 = cross.search(Q, k=7, page=2 * cross.n_ids,
                                  engine=engine)
            assert np.array_equal(np.asarray(i1), np.asarray(i2)), \
                (tag, engine, shards)
            assert np.array_equal(np.asarray(s1), np.asarray(s2)), \
                (tag, engine, shards)

check(live, "built")
live = live.add_documents(rng.normal(size=(9, 10)).astype(np.float32))
check(live, "ingested")
live = live.delete([1, 17, 44, 50])
check(live, "deleted")
live = live.compact()
store.commit(live)
check(live, "compacted+committed")
live = live.add_documents(rng.normal(size=(3, 10)).astype(np.float32))
check(live, "post-compact ingest")
store.close()
print("OK")
""")


def test_restore_scatter_free_on_replica_mesh(tmp_path):
    """The replica-mesh regression (the _merge_select_seg GSPMD gotcha,
    store-path variant): a commit with LIVE APPEND SEGMENTS restores onto
    a 4x2 (data, replica) mesh -- every leaf replica-replicated -- and
    both the restored leaves and the search results match the 1-device
    reference bit for bit.  A scatter-built placement would double-count
    base rows through GSPMD's cross-replica scatter reassembly; the
    host-assembled device_put placement cannot."""
    _run_subprocess(rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.store import Store, recover

store_dir = {str(tmp_path)!r}
rng = np.random.default_rng(1)
V = rng.normal(size=(37, 8)).astype(np.float32)
W = rng.normal(size=(9, 8)).astype(np.float32)
Q = rng.normal(size=(6, 8)).astype(np.float32)
store = Store(store_dir)
live = store.open_index(
    ShardedVectorIndex.build_sharded(V, make_shard_mesh(1)))
live = live.add_documents(W).delete([2, 38, 40])

ref = {{e: live.search(Q, k=7, page=1000, engine=e)
       for e in ("postings", "codes", "onehot")}}

rec, _ = recover(store_dir, make_shard_mesh(4, 2))
assert rec.n_replicas == 2 and rec.n_appended == 9
for engine, (ri, rs) in ref.items():
    for merge in ("gather", "stream"):
        gi, gs = rec.search(Q, k=7, page=1000, engine=engine, merge=merge)
        assert np.array_equal(np.asarray(ri), np.asarray(gi)), (engine, merge)
        assert np.array_equal(np.asarray(rs), np.asarray(gs)), (engine, merge)

# and per-group: each replica column is a full, correct, addressable copy
for g in (0, 1):
    grp = rec.replica_group(g)
    gi, gs = grp.search(Q, k=7, page=1000, engine="codes")
    assert np.array_equal(np.asarray(ref["codes"][0]), np.asarray(gi)), g
    assert np.array_equal(np.asarray(ref["codes"][1]), np.asarray(gs)), g
store.close()
print("OK")
""")


def test_cluster_restore_group_on_4x2_mesh(tmp_path):
    """THE cluster acceptance pin: on the 4x2 mesh, a replica group is
    poisoned and marked down, the cluster keeps ingesting, and
    restore_group rebuilds the group FROM DISK onto its own device
    column -- after which it serves results bit-identical to the
    surviving group, including ops acked while it was down."""
    _run_subprocess(rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.cluster import ClusterEngine
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.store import Store

rng = np.random.default_rng(2)
V = rng.normal(size=(41, 10)).astype(np.float32)
W = rng.normal(size=(7, 10)).astype(np.float32)
Q = rng.normal(size=(5, 10)).astype(np.float32)
sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(4, 2))
store = Store({str(tmp_path)!r})
cl = ClusterEngine(sidx, batch_size=4, k=5, page=1000, trim=None,
                   engine="codes", store=store)
try:
    cl.add_documents(W[:4])
    cl.inject_failure(1)
    cl.mark_down(1)
    cl.add_documents(W[4:])        # acked while group 1 is down
    cl.delete([3, 42])
    ref = [cl.search(q, stream="a", timeout=300) for q in Q]
    seq = cl.restore_group(1)
    assert seq == 3 and cl.health.is_up(1)
    got = [cl.search(q, stream="pin-elsewhere", timeout=300) for q in Q]
    for (ai, asc), (bi, bsc) in zip(ref, got):
        assert np.array_equal(ai, bi) and np.array_equal(asc, bsc)
finally:
    cl.close()
store.close()
print("OK")
""")
