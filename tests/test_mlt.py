"""More-Like-This baseline behaviour (paper §3.1 / Table 4)."""

import numpy as np
import jax.numpy as jnp

from repro.core import MLTIndex, VectorIndex, precision_at_k
from repro.data import make_corpus
from repro.lsa import build_lsa


def _corpus_index(seed=0, n_docs=400):
    corpus = make_corpus(n_docs=n_docs, vocab_size=3000, n_topics=10, seed=seed)
    mlt = MLTIndex.build(jnp.asarray(corpus.doc_terms), jnp.asarray(corpus.doc_tf),
                         corpus.vocab_size)
    return corpus, mlt


def test_self_retrieval():
    """A document's own text should be its best MLT match."""
    corpus, mlt = _corpus_index()
    q_terms = jnp.asarray(corpus.doc_terms[:8])
    q_tf = jnp.asarray(corpus.doc_tf[:8])
    ids, scores = mlt.more_like_this(q_terms, q_tf, max_query_terms=25, k=5)
    assert (np.asarray(ids)[:, 0] == np.arange(8)).all()


def test_more_query_terms_increase_scores():
    corpus, mlt = _corpus_index()
    q_terms = jnp.asarray(corpus.doc_terms[:4])
    q_tf = jnp.asarray(corpus.doc_tf[:4])
    _, s1 = mlt.more_like_this(q_terms, q_tf, max_query_terms=5, k=5)
    _, s2 = mlt.more_like_this(q_terms, q_tf, max_query_terms=50, k=5)
    assert float(np.asarray(s2).sum()) >= float(np.asarray(s1).sum()) - 1e-4


def test_encoded_vector_search_beats_mlt():
    """Paper C3: our method scores above the MLT baseline on P@10."""
    corpus = make_corpus(n_docs=500, vocab_size=4000, n_topics=12, seed=4)
    pipe = build_lsa(corpus, n_features=64)
    idx = VectorIndex.build(pipe.doc_vectors)
    nq = 16
    Q = pipe.doc_vectors[:nq]
    gold_ids, _ = idx.gold_topk(Q, 10)

    ids_ours, _ = idx.search(Q, k=10, page=320, engine="codes")
    p_ours = float(precision_at_k(ids_ours, gold_ids).mean())

    mlt = MLTIndex.build(jnp.asarray(corpus.doc_terms), jnp.asarray(corpus.doc_tf),
                         corpus.vocab_size)
    ids_mlt, _ = mlt.more_like_this(
        jnp.asarray(corpus.doc_terms[:nq]), jnp.asarray(corpus.doc_tf[:nq]),
        max_query_terms=25, k=10)
    p_mlt = float(precision_at_k(ids_mlt, gold_ids).mean())
    assert p_ours > p_mlt
