"""Sharded-vs-single-device search parity (dist/shard_index.py).

The pinned invariant: for ``page >= n_docs`` the doc-sharded index returns
ids AND scores bit-identical to ``VectorIndex.search`` for every engine --
sharding is a throughput axis, never a quality trade.  Multi-device cases
run in a subprocess because ``--xla_force_host_platform_device_count`` must
precede jax initialisation (same pattern as test_moe.py).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import TrimFilter, VectorIndex
from repro.launch.mesh import make_shard_mesh

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(n_docs=123, n_features=16, n_queries=7, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, n_features)).astype(np.float32)
    Q = rng.normal(size=(n_queries, n_features)).astype(np.float32)
    return VectorIndex.build(V), Q


@pytest.mark.parametrize("engine", ["postings", "codes", "onehot",
                                    "codes_pallas"])
def test_single_shard_is_identity(engine):
    """ns=1 runs in-process: one shard must already be bit-identical."""
    idx, Q = _build()
    sidx = idx.shard(make_shard_mesh(1))
    ids1, s1 = idx.search(Q, k=10, page=300, engine=engine)
    ids2, s2 = sidx.search(Q, k=10, page=300, engine=engine)
    assert np.array_equal(np.asarray(ids1), np.asarray(ids2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))


def test_single_shard_trimmed_small_page():
    """Approximate regime smoke: trim + page < n_docs stays well-formed."""
    idx, Q = _build()
    sidx = idx.shard(make_shard_mesh(1))
    ids, scores = sidx.search(Q, k=5, page=32, trim=TrimFilter(0.05),
                              engine="codes")
    assert ids.shape == (7, 5)
    assert np.isfinite(np.asarray(scores)).all()
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 123).all()


def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import VectorIndex
from repro.launch.mesh import make_shard_mesh

def build(n_docs, n_features=16, n_queries=7, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, n_features)).astype(np.float32)
    Q = rng.normal(size=(n_queries, n_features)).astype(np.float32)
    return VectorIndex.build(V), Q
"""


def test_four_shard_parity_all_engines():
    """4-device mesh, ragged (123 % 4 != 0) AND even (120 % 4 == 0) splits:
    ids/scores bit-identical for all three engines at page >= n_docs."""
    _run_subprocess(_PRELUDE + r"""
for n_docs in (123, 120):
    idx, Q = build(n_docs)
    sidx = idx.shard(make_shard_mesh(4))
    assert sidx.n_shards == 4 and sidx.n_docs == n_docs
    for engine in ("postings", "codes", "onehot", "codes_pallas"):
        ids1, s1 = idx.search(Q, k=10, page=2 * n_docs, engine=engine)
        ids2, s2 = sidx.search(Q, k=10, page=2 * n_docs, engine=engine)
        assert np.array_equal(np.asarray(ids1), np.asarray(ids2)), \
            (n_docs, engine)
        assert np.array_equal(np.asarray(s1), np.asarray(s2)), \
            (n_docs, engine)
print("OK")
""")


def test_four_shard_weighting_and_self_retrieval():
    """Global-psum idf == single-device idf; count weighting too; querying
    an indexed doc returns itself first (score 1.0) through the merge."""
    _run_subprocess(_PRELUDE + r"""
idx, _ = build(123)
sidx = idx.shard(make_shard_mesh(4))
V = np.asarray(idx.vectors)
for weighting in ("idf", "count"):
    ids1, s1 = idx.search(V[:9], k=10, page=200, weighting=weighting)
    ids2, s2 = sidx.search(V[:9], k=10, page=200, weighting=weighting)
    assert np.array_equal(np.asarray(ids1), np.asarray(ids2)), weighting
    assert np.array_equal(np.asarray(s1), np.asarray(s2)), weighting
assert (np.asarray(ids2)[:, 0] == np.arange(9)).all()
np.testing.assert_allclose(np.asarray(s2)[:, 0], 1.0, rtol=1e-5)
print("OK")
""")


def test_batched_engine_serves_sharded_index():
    """BatchedSearchEngine fronting a doc-sharded index: the third engine of
    the parity triangle (engine results == sharded == single-device)."""
    _run_subprocess(_PRELUDE + r"""
from repro.serve.engine import BatchedSearchEngine

idx, _ = build(123)
sidx = idx.shard(make_shard_mesh(4))
V = np.asarray(idx.vectors)
gold_ids, gold_s = idx.search(V[:8], k=5, page=300, trim=None, engine="codes")
eng = BatchedSearchEngine(sidx, batch_size=4, k=5, page=300, trim=None,
                          engine="codes")
try:
    futs = [eng.submit(V[i]) for i in range(8)]
    for i, f in enumerate(futs):
        ids, scores = f.result(timeout=60)
        assert ids[0] == i, (i, ids)
        assert np.array_equal(ids, np.asarray(gold_ids)[i])
        assert np.array_equal(scores, np.asarray(gold_s)[i])
finally:
    eng.close()
print("OK")
""")
