"""Sharded-vs-single-device search parity (dist/shard_index.py).

The pinned invariant: for ``page >= n_docs`` the doc-sharded index returns
ids AND scores bit-identical to ``VectorIndex.search`` for every engine,
every merge transport (blocking gather / ring stream) and every replica
count -- sharding and replication are throughput axes, never a quality
trade.  Multi-device cases run in a subprocess because
``--xla_force_host_platform_device_count`` must precede jax initialisation
(same pattern as test_moe.py); the replica cases force 8 devices (4 shards
x 2 replicas).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import TrimFilter, VectorIndex
from repro.launch.mesh import make_shard_mesh

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(n_docs=123, n_features=16, n_queries=7, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, n_features)).astype(np.float32)
    Q = rng.normal(size=(n_queries, n_features)).astype(np.float32)
    return VectorIndex.build(V), Q


@pytest.mark.parametrize("engine", ["postings", "codes", "onehot",
                                    "codes_pallas", "fused", "fused_int8"])
def test_single_shard_is_identity(engine):
    """ns=1 runs in-process: one shard must already be bit-identical."""
    idx, Q = _build()
    sidx = idx.shard(make_shard_mesh(1))
    ids1, s1 = idx.search(Q, k=10, page=300, engine=engine)
    ids2, s2 = sidx.search(Q, k=10, page=300, engine=engine)
    assert np.array_equal(np.asarray(ids1), np.asarray(ids2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))


def test_single_shard_trimmed_small_page():
    """Approximate regime smoke: trim + page < n_docs stays well-formed."""
    idx, Q = _build()
    sidx = idx.shard(make_shard_mesh(1))
    ids, scores = sidx.search(Q, k=5, page=32, trim=TrimFilter(0.05),
                              engine="codes")
    assert ids.shape == (7, 5)
    assert np.isfinite(np.asarray(scores)).all()
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 123).all()


def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


def _prelude(n_devices=4):
    return rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
import jax, jax.numpy as jnp, numpy as np
from repro.core import VectorIndex
from repro.launch.mesh import make_shard_mesh

def build(n_docs, n_features=16, n_queries=7, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, n_features)).astype(np.float32)
    Q = rng.normal(size=(n_queries, n_features)).astype(np.float32)
    return VectorIndex.build(V), Q
"""


_PRELUDE = _prelude(4)


def test_four_shard_parity_all_engines():
    """4-device mesh, ragged (123 % 4 != 0) AND even (120 % 4 == 0) splits:
    ids/scores bit-identical for every engine (the fused and quantized
    phase-1 paths included) at page >= n_docs."""
    _run_subprocess(_PRELUDE + r"""
for n_docs in (123, 120):
    idx, Q = build(n_docs)
    sidx = idx.shard(make_shard_mesh(4))
    assert sidx.n_shards == 4 and sidx.n_docs == n_docs
    for engine in ("postings", "codes", "onehot", "codes_pallas",
                   "fused", "fused_int8"):
        ids1, s1 = idx.search(Q, k=10, page=2 * n_docs, engine=engine)
        ids2, s2 = sidx.search(Q, k=10, page=2 * n_docs, engine=engine)
        assert np.array_equal(np.asarray(ids1), np.asarray(ids2)), \
            (n_docs, engine)
        assert np.array_equal(np.asarray(s1), np.asarray(s2)), \
            (n_docs, engine)
print("OK")
""")


def test_four_shard_weighting_and_self_retrieval():
    """Global-psum idf == single-device idf; count weighting too; querying
    an indexed doc returns itself first (score 1.0) through the merge."""
    _run_subprocess(_PRELUDE + r"""
idx, _ = build(123)
sidx = idx.shard(make_shard_mesh(4))
V = np.asarray(idx.vectors)
for weighting in ("idf", "count"):
    ids1, s1 = idx.search(V[:9], k=10, page=200, weighting=weighting)
    ids2, s2 = sidx.search(V[:9], k=10, page=200, weighting=weighting)
    assert np.array_equal(np.asarray(ids1), np.asarray(ids2)), weighting
    assert np.array_equal(np.asarray(s1), np.asarray(s2)), weighting
assert (np.asarray(ids2)[:, 0] == np.arange(9)).all()
np.testing.assert_allclose(np.asarray(s2)[:, 0], 1.0, rtol=1e-5)
print("OK")
""")


def test_single_shard_stream_merge_is_identity():
    """S=1 runs in-process: the stream transport degenerates to a sort +
    self-psum and must already be bit-identical to the gather path."""
    idx, Q = _build()
    sidx = idx.shard(make_shard_mesh(1))
    ids1, s1 = idx.search(Q, k=10, page=300, engine="codes")
    ids2, s2 = sidx.search(Q, k=10, page=300, engine="codes", merge="stream")
    assert np.array_equal(np.asarray(ids1), np.asarray(ids2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))


def test_unknown_merge_transport_rejected():
    idx, Q = _build()
    sidx = idx.shard(make_shard_mesh(1))
    with pytest.raises(ValueError, match="merge transport"):
        sidx.search(Q, merge="scatter")


def test_replica_parity_all_engines():
    """4 shards x 2 replicas on an 8-device (data, replica) mesh, ragged
    (123 % 4 != 0) AND even (120 % 4 == 0) splits: ids/scores bit-identical
    to the single-device index for every engine and both merge transports,
    at page >= n_docs.  n_queries=7 is odd, so the round-robin split across
    2 replica groups also exercises the query zero-pad + slice path."""
    _run_subprocess(_prelude(8) + r"""
for n_docs in (123, 120):
    idx, Q = build(n_docs)
    sidx = idx.shard(make_shard_mesh(4, 2))
    assert sidx.n_shards == 4 and sidx.n_replicas == 2
    assert sidx.n_docs == n_docs
    for engine in ("postings", "codes", "onehot", "codes_pallas",
                   "fused", "fused_int8"):
        ids1, s1 = idx.search(Q, k=10, page=2 * n_docs, engine=engine)
        for merge in ("gather", "stream"):
            ids2, s2 = sidx.search(Q, k=10, page=2 * n_docs, engine=engine,
                                   merge=merge)
            assert np.array_equal(np.asarray(ids1), np.asarray(ids2)), \
                (n_docs, engine, merge)
            assert np.array_equal(np.asarray(s1), np.asarray(s2)), \
                (n_docs, engine, merge)
print("OK")
""")


def test_replica_round_robin_and_stream_merge_invariants():
    """Replica-group round-robin is invisible to callers: every batch size
    0 < Q <= 8 (even, odd, and Q < R) returns the R=1 mesh's results
    bit-exactly, with the stream transport, on a 2x4 mesh (ragged corpus).
    Also pins the merged stream path for page < n_docs (approximate
    regime): well-formed ids/scores, no -inf leakage from pre-merge
    placeholder rows."""
    _run_subprocess(_prelude(8) + r"""
idx, Q = build(123, n_queries=8)
base = idx.shard(make_shard_mesh(4, 1))
sidx = idx.shard(make_shard_mesh(2, 4))
for nq in range(1, 9):
    ids1, s1 = base.search(Q[:nq], k=10, page=300, engine="codes")
    ids2, s2 = sidx.search(Q[:nq], k=10, page=300, engine="codes",
                           merge="stream")
    assert ids2.shape == (nq, 10), nq
    assert np.array_equal(np.asarray(ids1), np.asarray(ids2)), nq
    assert np.array_equal(np.asarray(s1), np.asarray(s2)), nq

ids, scores = sidx.search(Q, k=5, page=16, engine="codes", merge="stream")
assert ids.shape == (8, 5)
assert np.isfinite(np.asarray(scores)).all()
assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 123).all()
print("OK")
""")


def test_batched_engine_serves_sharded_index():
    """BatchedSearchEngine fronting a doc-sharded index: the third engine of
    the parity triangle (engine results == sharded == single-device).  The
    replicated mesh with the stream transport must serve the same bits --
    the whole replica tier is invisible behind the batcher."""
    _run_subprocess(_prelude(8) + r"""
from repro.serve.engine import BatchedSearchEngine

idx, _ = build(123)
V = np.asarray(idx.vectors)
gold_ids, gold_s = idx.search(V[:8], k=5, page=300, trim=None, engine="codes")
for mesh, merge in ((make_shard_mesh(4), None),
                    (make_shard_mesh(4, 2), "stream")):
    sidx = idx.shard(mesh)
    eng = BatchedSearchEngine(sidx, batch_size=4, k=5, page=300, trim=None,
                              engine="codes", merge=merge)
    try:
        futs = [eng.submit(V[i]) for i in range(8)]
        for i, f in enumerate(futs):
            ids, scores = f.result(timeout=60)
            assert ids[0] == i, (merge, i, ids)
            assert np.array_equal(ids, np.asarray(gold_ids)[i]), (merge, i)
            assert np.array_equal(scores, np.asarray(gold_s)[i]), (merge, i)
    finally:
        eng.close()
print("OK")
""")
