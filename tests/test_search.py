"""Two-phase search behaviour: exactness, filtering, metrics (paper §2.2/3.1)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core import (
    BestFilter,
    TrimFilter,
    VectorIndex,
    avg_diff,
    ndcg_k,
    precision_at_k,
)
from repro.core.encoding import IntervalEncoder, RoundingEncoder
from repro.core.rerank import normalize


def _setup(seed=0, d=400, n=32, nq=8):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(d, n)).astype(np.float32)
    idx = VectorIndex.build(V)
    Q = jnp.asarray(V[:nq] + 0.02 * rng.normal(size=(nq, n)).astype(np.float32))
    return idx, Q


class TestExactness:
    """Paper §2.2: with page >= |D| the two-phase search IS brute force (C4)."""

    @pytest.mark.parametrize("engine", ["postings", "codes", "onehot"])
    def test_full_page_equals_brute_force(self, engine):
        idx, Q = _setup()
        gold_ids, gold_s = idx.gold_topk(Q, 10)
        ids, s = idx.search(Q, k=10, page=idx.n_docs, engine=engine)
        assert (np.asarray(ids) == np.asarray(gold_ids)).all()
        assert_allclose(np.asarray(s), np.asarray(gold_s), rtol=1e-5, atol=1e-6)

    def test_rerank_scores_are_true_cosines(self):
        idx, Q = _setup()
        ids, s = idx.search(Q, k=5, page=64, trim=TrimFilter(0.05))
        qn = np.asarray(normalize(Q))
        V = np.asarray(idx.vectors)
        expect = np.take_along_axis(qn @ V.T, np.asarray(ids), axis=1)
        assert_allclose(np.asarray(s), expect, rtol=1e-4, atol=1e-5)

    def test_rerank_order_descending(self):
        idx, Q = _setup()
        _, s = idx.search(Q, k=10, page=128)
        s = np.asarray(s)
        assert (np.diff(s, axis=1) <= 1e-6).all()


class TestQualityMonotonicity:
    """Paper C1: quality improves with page size (larger candidate set E)."""

    def test_precision_increases_with_page(self):
        idx, Q = _setup(d=600)
        gold_ids, gold_s = idx.gold_topk(Q, 10)
        precs = []
        for page in [10, 40, 160, 600]:
            ids, _ = idx.search(Q, k=10, page=page, trim=TrimFilter(0.05), engine="codes")
            precs.append(float(precision_at_k(ids, gold_ids).mean()))
        assert precs[-1] >= precs[0]
        assert precs[-1] == 1.0  # page == n_docs: exact

    def test_avg_diff_decreases_with_page(self):
        idx, Q = _setup(d=600)
        gold_ids, gold_s = idx.gold_topk(Q, 10)
        diffs = []
        for page in [10, 160, 600]:
            _, s = idx.search(Q, k=10, page=page, trim=TrimFilter(0.05), engine="codes")
            diffs.append(float(avg_diff(s, gold_s).mean()))
        assert diffs[0] >= diffs[-1] - 1e-6
        assert abs(diffs[-1]) < 1e-5

    def test_avg_diff_nonnegative(self):
        idx, Q = _setup()
        gold_ids, gold_s = idx.gold_topk(Q, 10)
        _, s = idx.search(Q, k=10, page=32, trim=TrimFilter(0.1))
        assert float(avg_diff(s, gold_s).min()) >= -1e-5


class TestFiltering:
    def test_best_filter_counts(self):
        idx, Q = _setup()
        _, _, w = idx.encode_queries(Q, None, BestFilter(7), "count")
        assert (np.asarray((w > 0).sum(-1)) == 7).all()

    def test_trim_is_query_side_only(self):
        """Paper §5: filtering queries alone works; index stays untouched."""
        idx, Q = _setup()
        codes_before = np.asarray(idx.codes).copy()
        idx.search(Q, k=10, page=64, trim=TrimFilter(0.2))
        assert (np.asarray(idx.codes) == codes_before).all()

    def test_aggressive_trim_degrades_quality(self):
        idx, Q = _setup(d=600)
        gold_ids, _ = idx.gold_topk(Q, 10)
        p_mild = float(precision_at_k(
            idx.search(Q, 10, 64, trim=TrimFilter(0.01), engine="codes")[0], gold_ids
        ).mean())
        p_aggr = float(precision_at_k(
            idx.search(Q, 10, 64, trim=TrimFilter(0.4), engine="codes")[0], gold_ids
        ).mean())
        assert p_mild >= p_aggr


class TestMetrics:
    def test_precision_at_k(self):
        r = jnp.asarray([[1, 2, 3, 4]])
        g = jnp.asarray([[1, 9, 3, 8]])
        assert float(precision_at_k(r, g)[0]) == 0.5

    def test_ndcg_perfect_is_one(self):
        s = jnp.asarray([[0.9, 0.8, 0.7]])
        assert_allclose(float(ndcg_k(s, s)[0]), 1.0, rtol=1e-6)

    def test_ndcg_order(self):
        gold = jnp.asarray([[0.9, 0.8, 0.7]])
        worse = jnp.asarray([[0.5, 0.4, 0.3]])
        assert float(ndcg_k(worse, gold)[0]) < 1.0

    def test_avg_diff_zero_for_gold(self):
        s = jnp.asarray([[0.9, 0.8]])
        assert float(avg_diff(s, s)[0]) == 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([10, 40, 99]))
def test_two_phase_never_beats_gold(seed, page):
    """Property: retrieved cosines are <= the gold cosines rank-by-rank."""
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(99, 16)).astype(np.float32)
    idx = VectorIndex.build(V, IntervalEncoder(0.1))
    Q = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
    _, gold_s = idx.gold_topk(Q, 5)
    _, s = idx.search(Q, k=5, page=page, engine="codes")
    assert (np.asarray(s) <= np.asarray(gold_s) + 1e-5).all()
