"""Training substrate: optimizers, accumulation, checkpointing, fault
tolerance, gradient compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.models.transformer.model import LMConfig, init_params, lm_loss
from repro.train import (
    AdamWConfig,
    AsyncCheckpointer,
    TrainLoopConfig,
    adamw_init,
    cosine_schedule,
    ef_topk_step,
    int8_dequantize,
    int8_quantize,
    latest_step,
    make_train_step,
    restore_checkpoint,
    run_train_loop,
    save_checkpoint,
)
from repro.train.optimizer import adafactor_init, adafactor_update, adamw_update

CFG = LMConfig("tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
               d_ff=64, vocab=64, q_chunk=16, kv_chunk=16)


def _mk_batch(i, batch=8, seq=16):
    r = np.random.default_rng(i)
    t = r.integers(0, 64, size=(batch, seq)).astype(np.int32)
    t[:, 1::2] = t[:, ::2]  # deterministic intra-sequence structure
    return {"tokens": jnp.asarray(t), "labels": jnp.asarray(np.roll(t, -1, 1))}


class TestOptimizers:
    def test_adamw_reduces_loss(self):
        params = init_params(jax.random.PRNGKey(0), CFG)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(lambda p, b: lm_loss(p, b, CFG),
                                       AdamWConfig(lr=1e-2)))
        losses = []
        for i in range(60):
            params, opt, m = step(params, opt, _mk_batch(i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5

    def test_adafactor_reduces_loss(self):
        params = init_params(jax.random.PRNGKey(0), CFG)
        opt = adafactor_init(params)
        step = jax.jit(make_train_step(lambda p, b: lm_loss(p, b, CFG),
                                       AdamWConfig(lr=3e-2), optimizer="adafactor"))
        losses = []
        for i in range(60):
            params, opt, m = step(params, opt, _mk_batch(i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3

    def test_adafactor_state_is_factored(self):
        params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
        st = adafactor_init(params)
        assert st.vr["w"].shape == (64,)
        assert st.vc["w"].shape == (32,)
        assert st.v["b"].shape == (32,)

    def test_grad_clipping(self):
        params = {"w": jnp.ones((4,))}
        opt = adamw_init(params)
        huge = {"w": jnp.full((4,), 1e9)}
        new_p, _ = adamw_update(huge, opt, params, AdamWConfig(lr=1.0, clip_norm=1.0,
                                                               weight_decay=0.0))
        # clipped update magnitude bounded by lr
        assert float(jnp.abs(new_p["w"] - params["w"]).max()) < 1.1

    def test_cosine_schedule(self):
        sched = cosine_schedule(warmup=10, total=100)
        assert float(sched(jnp.int32(0))) == 0.0
        assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
        assert float(sched(jnp.int32(100))) <= 0.11


class TestAccumulation:
    def test_accum_matches_full_batch(self):
        """accum=4 must produce the same gradients as the full batch."""
        params = init_params(jax.random.PRNGKey(0), CFG)
        batch = _mk_batch(0, batch=8)
        loss_fn = lambda p, b: lm_loss(p, b, CFG)
        opt = adamw_init(params)
        p1, _, m1 = jax.jit(make_train_step(loss_fn, AdamWConfig()))(params, opt, batch)
        p2, _, m2 = jax.jit(make_train_step(loss_fn, AdamWConfig(), accum=4))(params, opt, batch)
        assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-3)
        d = jax.tree_util.tree_reduce(
            lambda a, xy: max(a, float(jnp.abs(xy).max())),
            jax.tree.map(lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32),
                         p1, p2), 0.0)
        assert d < 2e-2  # bf16 accumulation-order noise


class TestCheckpoint:
    def test_roundtrip(self):
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, tree)
            assert latest_step(d) == 7
            out, step = restore_checkpoint(d, jax.tree.map(jnp.zeros_like, tree))
            assert step == 7
            assert (np.asarray(out["a"]) == np.arange(5.0)).all()
            assert out["b"]["c"].dtype == jnp.bfloat16

    def test_incomplete_checkpoint_ignored(self):
        tree = {"a": jnp.ones(3)}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, tree)
            # simulate a crash mid-write: dir exists, no manifest
            os.makedirs(os.path.join(d, "step_00000002"))
            assert latest_step(d) == 1

    def test_async_checkpointer_gc(self):
        tree = {"a": jnp.ones(3)}
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d, keep=2)
            for s in [1, 2, 3, 4]:
                ck.save(s, tree)
            ck.wait()
            assert latest_step(d) == 4
            steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
            assert len(steps) == 2

    def test_resume_is_bit_exact(self):
        params = init_params(jax.random.PRNGKey(0), CFG)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(lambda p, b: lm_loss(p, b, CFG),
                                       AdamWConfig(lr=1e-2)))
        with tempfile.TemporaryDirectory() as d:
            pA, *_ = run_train_loop(step, params, opt, _mk_batch,
                                    TrainLoopConfig(12, d + "/a", ckpt_every=12))
            run_train_loop(step, params, opt, _mk_batch,
                           TrainLoopConfig(6, d + "/b", ckpt_every=6))
            pB, *_ = run_train_loop(step, params, opt, _mk_batch,
                                    TrainLoopConfig(12, d + "/b", ckpt_every=6))
            diff = jax.tree_util.tree_reduce(
                lambda a, l: max(a, float(jnp.abs(l).max())),
                jax.tree.map(lambda x, y: x - y, pA, pB), 0.0)
            assert diff == 0.0

    def test_straggler_hook_fires(self):
        import time
        params = init_params(jax.random.PRNGKey(0), CFG)
        opt = adamw_init(params)
        calls = []
        base = make_train_step(lambda p, b: lm_loss(p, b, CFG), AdamWConfig())
        jitted = jax.jit(base)
        state = {"i": 0}

        def slow_step(p, o, b):
            state["i"] += 1
            if state["i"] == 15:
                time.sleep(1.0)
            return jitted(p, o, b)

        with tempfile.TemporaryDirectory() as d:
            run_train_loop(slow_step, params, opt, _mk_batch,
                           TrainLoopConfig(16, d, ckpt_every=100,
                                           straggler_factor=4.0),
                           on_straggler=lambda s, ratio: calls.append((s, ratio)))
        assert calls, "straggler detector never fired"


class TestCompression:
    def test_ef_topk_conserves_mass(self):
        g = jnp.asarray(np.random.default_rng(0).normal(size=(128,)).astype(np.float32))
        err = jnp.zeros_like(g)
        sparse, err2 = ef_topk_step(g, err, ratio=0.1)
        assert_allclose(np.asarray(sparse + err2), np.asarray(g), rtol=1e-6)
        assert (np.asarray(sparse) != 0).sum() <= 13

    def test_ef_converges_over_steps(self):
        """Error feedback: cumulative transmitted ~= cumulative gradient."""
        rng_ = np.random.default_rng(1)
        err = jnp.zeros((64,))
        total_g = jnp.zeros((64,))
        total_tx = jnp.zeros((64,))
        for i in range(50):
            g = jnp.asarray(rng_.normal(size=(64,)).astype(np.float32))
            tx, err = ef_topk_step(g, err, ratio=0.25)
            total_g += g
            total_tx += tx
        assert_allclose(np.asarray(total_tx + err), np.asarray(total_g), rtol=1e-4)

    def test_int8_quantize_error_bound(self):
        g = jnp.asarray(np.random.default_rng(2).normal(size=(1000,)).astype(np.float32))
        q, s = int8_quantize(g)
        rec = int8_dequantize(q, s)
        assert float(jnp.abs(rec - g).max()) <= float(s) * 0.5 + 1e-7
        assert q.dtype == jnp.int8
