"""Minimal deterministic stand-in for the ``hypothesis`` API used by this
suite (``given`` / ``settings`` / ``strategies``).

Activated by tests/conftest.py only when the real package is missing.  Each
``@given`` test runs ``max_examples`` times over values drawn from a PRNG
seeded by the test's qualified name, so runs are reproducible and failures
re-fire on re-run.  No shrinking, no database -- just the sampling core.
"""

from __future__ import annotations

import functools
import random
import zlib

from . import strategies

__all__ = ["given", "settings", "strategies", "assume", "example"]

_DEFAULT_MAX_EXAMPLES = 20


class _Assumption(Exception):
    pass


def assume(condition) -> bool:
    """Skip the current example when ``condition`` is falsy."""
    if not condition:
        raise _Assumption()
    return True


def given(*strats, **kw_strats):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_hyp_settings", {})
            n = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.example(rng) for s in strats]
                drawn_kw = {k: s.example(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except _Assumption:
                    continue
        # pytest resolves fixture names through __wrapped__'s signature;
        # the drawn params are not fixtures, so hide the original signature
        del wrapper.__wrapped__
        # pytest plugins (anyio) introspect `.hypothesis.inner_test`
        wrapper.hypothesis = type("_Hyp", (), {"inner_test": staticmethod(fn)})()
        return wrapper

    return decorate


def settings(**config):
    """Decorator form only (the suite uses ``@settings(...)`` above
    ``@given``); stores config consumed by the ``given`` wrapper."""

    def decorate(fn):
        fn._hyp_settings = dict(config)
        return fn

    return decorate


def example(*args, **kwargs):  # pragma: no cover - API-compat no-op
    def decorate(fn):
        return fn

    return decorate
