"""Strategy objects for the vendored hypothesis shim.

Each strategy exposes ``example(rng) -> value``.  Draws are uniform over the
declared domain, with boundary values mixed in at a fixed rate (real
hypothesis biases toward boundaries too; encoder bucket edges live there).
"""

from __future__ import annotations

import struct

__all__ = ["integers", "floats", "sampled_from", "booleans", "just"]

_BOUNDARY_RATE = 0.15


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self._boundaries = tuple(boundaries)

    def example(self, rng):
        if self._boundaries and rng.random() < _BOUNDARY_RATE:
            return rng.choice(self._boundaries)
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self.example(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self.example(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive")

        return _Strategy(draw)


def integers(min_value, max_value):
    return _Strategy(
        lambda rng: rng.randint(min_value, max_value),
        boundaries=(min_value, max_value),
    )


def _to_width(x: float, width: int) -> float:
    if width == 32:
        return struct.unpack("f", struct.pack("f", x))[0]
    if width == 16:
        return struct.unpack("e", struct.pack("e", x))[0]
    return x


def floats(min_value, max_value, allow_nan=False, allow_infinity=False,
           width=64):
    del allow_nan, allow_infinity  # bounded domains are always finite
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        v = _to_width(rng.uniform(lo, hi), width)
        return min(max(v, lo), hi)  # width-rounding must not escape bounds

    bounds = {_to_width(b, width) for b in (lo, hi, 0.0) if lo <= b <= hi}
    return _Strategy(draw, boundaries=sorted(bounds))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def just(value):
    return _Strategy(lambda rng: value)
