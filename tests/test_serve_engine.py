"""BatchedSearchEngine contract: batching/padding correctness + lifecycle.

The engine is a thin request batcher over ``index.search``; these tests pin
that the batching is *invisible* (results identical to a direct search, pad
rows never leak) and that the lifecycle is safe (submit-after-close raises,
a poisoned batch fails only its own futures, close drains the queue).
"""

import threading
import time

import numpy as np
import pytest

from repro.core import VectorIndex
from repro.serve.engine import BatchedSearchEngine

N_DOCS, N_FEAT = 150, 16


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(0)
    return VectorIndex.build(
        rng.normal(size=(N_DOCS, N_FEAT)).astype(np.float32))


@pytest.fixture()
def queries():
    return np.random.default_rng(1).normal(
        size=(11, N_FEAT)).astype(np.float32)


def test_batched_results_match_direct_search(index, queries):
    """Full and partial batches return exactly what index.search returns."""
    gold_ids, gold_s = index.search(queries, k=5, page=N_DOCS, trim=None,
                                    engine="codes")
    eng = BatchedSearchEngine(index, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        futs = [eng.submit(q) for q in queries]   # 11 = 2 full + 1 partial
        for i, f in enumerate(futs):
            ids, scores = f.result(timeout=60)
            assert np.array_equal(ids, np.asarray(gold_ids)[i]), i
            assert np.array_equal(scores, np.asarray(gold_s)[i]), i
    finally:
        eng.close()


def test_partial_batch_pad_rows_never_leak(index, queries):
    """batch_size 8, one request: the 7 zero-pad rows must not surface.

    Bitwise reference is a direct search of the same zero-padded batch
    (XLA's einsum blocking depends on the batch shape, so a Q=1 search can
    differ in the last ulp); the unpadded gold pins ids + score closeness.
    """
    eng = BatchedSearchEngine(index, batch_size=8, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        ids, scores = eng.submit(queries[0]).result(timeout=60)
    finally:
        eng.close()
    padded = np.concatenate(
        [queries[:1], np.zeros((7, N_FEAT), np.float32)])
    batch_ids, batch_s = index.search(padded, k=5, page=N_DOCS, trim=None,
                                      engine="codes")
    gold_ids, gold_s = index.search(queries[:1], k=5, page=N_DOCS, trim=None,
                                    engine="codes")
    assert ids.shape == (5,) and scores.shape == (5,)
    assert np.array_equal(ids, np.asarray(batch_ids)[0])
    assert np.array_equal(scores, np.asarray(batch_s)[0])
    assert np.array_equal(ids, np.asarray(gold_ids)[0])
    np.testing.assert_allclose(scores, np.asarray(gold_s)[0], rtol=1e-6)


def test_close_drains_pending_requests(index, queries):
    """Everything queued before close() resolves; close() blocks until then."""
    eng = BatchedSearchEngine(index, batch_size=4, max_wait_s=10.0, k=5,
                              page=N_DOCS, trim=None, engine="codes")
    futs = [eng.submit(q) for q in queries]       # partial last batch queued
    eng.close()
    for f in futs:
        ids, _ = f.result(timeout=0)              # must already be resolved
        assert ids.shape == (5,)


def test_submit_after_close_raises(index, queries):
    """A closed engine has no worker: submit must fail fast, not hang."""
    eng = BatchedSearchEngine(index, batch_size=4, k=5, page=N_DOCS)
    eng.close()
    with pytest.raises(RuntimeError, match="engine closed"):
        eng.submit(queries[0])


class _FlakyIndex:
    """index.search stand-in that raises on marked batches."""

    def __init__(self, inner):
        self.inner = inner
        self.poison = threading.Event()
        self.calls = 0

    def search(self, queries, **kw):
        self.calls += 1
        if self.poison.is_set():
            raise ValueError("injected search failure")
        return self.inner.search(queries, **kw)


def test_worker_survives_search_exception(index, queries):
    """A raising search fails that batch's futures with the original error
    and the SAME worker keeps serving subsequent batches."""
    flaky = _FlakyIndex(index)
    eng = BatchedSearchEngine(flaky, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        flaky.poison.set()
        bad = [eng.submit(q) for q in queries[:4]]
        for f in bad:
            with pytest.raises(ValueError, match="injected search failure"):
                f.result(timeout=60)
        assert eng._worker.is_alive()

        flaky.poison.clear()
        gold_ids, _ = index.search(queries[4:8], k=5, page=N_DOCS, trim=None,
                                   engine="codes")
        good = [eng.submit(q) for q in queries[4:8]]
        for i, f in enumerate(good):
            ids, _ = f.result(timeout=60)
            assert np.array_equal(ids, np.asarray(gold_ids)[i])
    finally:
        eng.close()


def test_cancelled_future_does_not_kill_worker(index, queries):
    """A caller cancelling its queued future (e.g. after a search() timeout)
    must not crash result delivery -- set_result on a cancelled future
    raises InvalidStateError, which would strand every later future."""
    eng = BatchedSearchEngine(index, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        with eng._lock:                   # hold the worker off the queue
            futs = [eng.submit(q) for q in queries[:4]]
            assert futs[0].cancel()
        for f in futs[1:]:
            ids, _ = f.result(timeout=60)
            assert ids.shape == (5,)
        assert eng._worker.is_alive()
        ids, _ = eng.submit(queries[4]).result(timeout=60)
        assert ids.shape == (5,)
    finally:
        eng.close()


def test_concurrent_submitters_all_resolve(index):
    """Many threads submitting at once: every future resolves correctly
    (the batcher's lock/notify protocol loses no requests)."""
    rng = np.random.default_rng(2)
    Q = rng.normal(size=(24, N_FEAT)).astype(np.float32)
    gold_ids, _ = index.search(Q, k=5, page=N_DOCS, trim=None, engine="codes")
    eng = BatchedSearchEngine(index, batch_size=5, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    results = {}

    def worker(i):
        results[i] = eng.submit(Q[i]).result(timeout=60)

    try:
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(Q))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
    finally:
        eng.close()
    assert len(results) == len(Q)
    for i, (ids, _) in results.items():
        assert np.array_equal(ids, np.asarray(gold_ids)[i]), i


class _GatedFlakyIndex:
    """Blocks in search until released, then optionally raises -- the
    deterministic way to hold a batch in flight while the control plane
    races it."""

    def __init__(self, inner):
        self.inner = inner
        self.entered = threading.Event()
        self.release = threading.Event()
        self.poison = threading.Event()

    def search(self, queries, **kw):
        self.entered.set()
        assert self.release.wait(timeout=60), "gate never released"
        if self.poison.is_set():
            raise ValueError("injected search failure")
        return self.inner.search(queries, **kw)


def test_hot_swap_races_raising_search(index, queries):
    """Failover edge case: a hot swap lands while the in-flight batch is
    mid-raise.  The raising batch must fail only its own futures, the
    worker must survive, and the next batch must serve from the SWAPPED
    index -- the maintenance-daemon race in miniature."""
    gated = _GatedFlakyIndex(index)
    eng = BatchedSearchEngine(gated, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        gated.poison.set()
        doomed = [eng.submit(q) for q in queries[:4]]
        assert gated.entered.wait(timeout=60)     # batch is in flight
        # swap while the batch is mid-search: in-flight work keeps its
        # snapshot; the swap applies to the next dequeue
        assert eng.swap_index(index, expected=gated)
        gated.release.set()
        for f in doomed:
            with pytest.raises(ValueError, match="injected search failure"):
                f.result(timeout=60)
        assert eng._worker.is_alive()
        gold_ids, _ = index.search(queries[4:8], k=5, page=N_DOCS, trim=None,
                                   engine="codes")
        good = [eng.submit(q) for q in queries[4:8]]
        for i, f in enumerate(good):
            ids, _ = f.result(timeout=60)
            assert np.array_equal(ids, np.asarray(gold_ids)[i])
    finally:
        gated.release.set()
        eng.close()


def test_swap_index_cas_semantics(index):
    """swap_index is a compare-and-swap: a stale `expected` (e.g. an index
    that was hot-swapped away mid-rebuild) must NOT clobber the live one."""
    other = VectorIndex.build(
        np.random.default_rng(3).normal(size=(40, N_FEAT)).astype(np.float32))
    eng = BatchedSearchEngine(index, batch_size=2, k=3, page=N_DOCS)
    try:
        assert eng.swap_index(other, expected=index)
        assert eng.index is other
        assert not eng.swap_index(index, expected=index)  # stale snapshot
        assert eng.index is other
        eng.swap_index(index)                             # unconditional
        assert eng.index is index
    finally:
        eng.close()
    with pytest.raises(RuntimeError, match="engine closed"):
        eng.swap_index(other)


def test_pending_tracks_queue_and_inflight(index, queries):
    """`pending` (the cluster router's load signal) counts queued AND
    in-flight requests, and drains back to zero."""
    gated = _GatedFlakyIndex(index)
    eng = BatchedSearchEngine(gated, batch_size=2, k=3, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        futs = [eng.submit(q) for q in queries[:5]]
        assert gated.entered.wait(timeout=60)
        assert eng.pending >= 3          # 2 in flight + >= 3 queued - served
        gated.release.set()
        for f in futs:
            f.result(timeout=60)
        deadline = time.monotonic() + 60
        while eng.pending and time.monotonic() < deadline:
            time.sleep(0.005)
        assert eng.pending == 0
    finally:
        gated.release.set()
        eng.close()


@pytest.mark.parametrize("engine", ["codes", "fused"])
def test_kernel_path_counter_counts_dispatches(index, queries, engine):
    """engine.kernel_path counts one increment per dispatched batch,
    labelled by the serving engine name -- the fused-kernel rollout
    signal (a fleet registry shows the fused/composed dispatch mix)."""
    from repro.obs import MetricsRegistry

    reg = MetricsRegistry()
    eng = BatchedSearchEngine(index, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine=engine, metrics=reg)
    try:
        with eng._lock:              # hold the worker off until all queued
            futs = [eng.submit(q) for q in queries[:8]]
        for f in futs:
            f.result(timeout=60)
    finally:
        eng.close()
    assert reg.value("engine.kernel_path", engine=engine) == 2
    assert reg.value("engine.requests.completed") == 8
    assert eng.stats()["kernel_path"] == {engine: 2}


class _IngestRecorder:
    """Sharded-index stand-in recording the ``donate`` kwarg each hot add
    receives, with an optional gate to hold a batch in flight -- the
    deterministic probe for the serving-snapshot donation guard."""

    def __init__(self, inner, log=None, gate=None):
        self.inner = inner
        self.donate_log = [] if log is None else log
        self.gate = gate                      # (entered, release) or None

    @property
    def n_ids(self):
        return self.inner.n_ids

    def search(self, queries, **kw):
        if self.gate is not None:
            entered, release = self.gate
            entered.set()
            assert release.wait(timeout=60), "gate never released"
        return self.inner.search(queries, **kw)

    def add_documents(self, vectors, *, donate=False):
        self.donate_log.append(donate)
        return _IngestRecorder(self.inner.add_documents(vectors,
                                                        donate=donate),
                               self.donate_log, self.gate)


def test_donate_ingest_guarded_by_serving_snapshot():
    """donate_ingest=True donates the append buffers ONLY when the batch
    in flight is not reading them: an add landing while the CURRENT index
    serves must pass donate=False (a donated buffer a dispatched program
    still reads would be a use-after-free); once the served snapshot is a
    stale index, donation turns on -- and either way the ingest itself is
    semantically identical (new docs retrievable, ids dense)."""
    from repro.dist.shard_index import ShardedVectorIndex
    from repro.launch.mesh import make_shard_mesh

    rng = np.random.default_rng(11)
    V = rng.normal(size=(20, N_FEAT)).astype(np.float32)
    entered, release = threading.Event(), threading.Event()
    rec = _IngestRecorder(
        ShardedVectorIndex.build_sharded(V, make_shard_mesh(1)),
        gate=(entered, release))
    eng = BatchedSearchEngine(rec, batch_size=1, k=3, page=64, trim=None,
                              engine="codes", donate_ingest=True)
    try:
        fut = eng.submit(V[0])
        assert entered.wait(timeout=60)        # batch in flight on `rec`
        W1 = rng.normal(size=(3, N_FEAT)).astype(np.float32)
        assert eng.add_documents(W1) == 20
        assert rec.donate_log == [False]       # buffers being read: skip
        release.set()
        fut.result(timeout=60)
        W2 = rng.normal(size=(3, N_FEAT)).astype(np.float32)
        # the served snapshot (rec) is stale -- nothing holds the grown
        # index's buffers, so this add may donate
        assert eng.add_documents(W2) == 23
        assert rec.donate_log == [False, True]
        ids, _ = eng.submit(W2[0]).result(timeout=60)
        assert ids[0] == 23
    finally:
        release.set()
        eng.close()


def test_donate_ingest_off_never_donates():
    """The default (donate_ingest=False) never passes donate=True -- the
    conservative path stays byte-for-byte the old behaviour."""
    from repro.dist.shard_index import ShardedVectorIndex
    from repro.launch.mesh import make_shard_mesh

    rng = np.random.default_rng(12)
    V = rng.normal(size=(20, N_FEAT)).astype(np.float32)
    rec = _IngestRecorder(
        ShardedVectorIndex.build_sharded(V, make_shard_mesh(1)))
    eng = BatchedSearchEngine(rec, batch_size=2, k=3, page=64, trim=None,
                              engine="codes")
    try:
        eng.add_documents(rng.normal(size=(2, N_FEAT)).astype(np.float32))
        eng.add_documents(rng.normal(size=(2, N_FEAT)).astype(np.float32))
        assert rec.donate_log == [False, False]
    finally:
        eng.close()


def test_delete_requires_mutable_index(index, queries):
    """Plain VectorIndex has no tombstones: hot delete must fail fast, and
    a closed engine must refuse the control-plane call outright."""
    eng = BatchedSearchEngine(index, batch_size=2, k=3, page=N_DOCS)
    try:
        with pytest.raises(TypeError, match="does not support"):
            eng.delete([0, 1])
    finally:
        eng.close()
    with pytest.raises(RuntimeError, match="engine closed"):
        eng.delete([0])


def test_merge_kwarg_forwarded_only_when_set(index, queries):
    """merge=None keeps the plain-VectorIndex call signature; a sharded
    index gets the transport passed through (single-shard mesh in-process)."""
    from repro.launch.mesh import make_shard_mesh

    sidx = index.shard(make_shard_mesh(1))
    gold_ids, gold_s = index.search(queries, k=5, page=N_DOCS, trim=None,
                                    engine="codes")
    for merge in (None, "stream"):
        eng = BatchedSearchEngine(sidx if merge else index, batch_size=4,
                                  k=5, page=N_DOCS, trim=None,
                                  engine="codes", merge=merge)
        try:
            futs = [eng.submit(q) for q in queries]
            for i, f in enumerate(futs):
                ids, scores = f.result(timeout=60)
                assert np.array_equal(ids, np.asarray(gold_ids)[i]), (merge, i)
                assert np.array_equal(scores, np.asarray(gold_s)[i]), (merge, i)
        finally:
            eng.close()
