"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core.encoding import CombinedEncoder, IntervalEncoder, RoundingEncoder
from repro.core.quantize import quantize_rows, quantized_scores
from repro.core.rerank import normalize
from repro.kernels.bucketize import ops as bk_ops
from repro.kernels.bucketize.ref import bucketize_ref
from repro.kernels.code_match import ops as cm_ops
from repro.kernels.code_match.ref import code_match_ref
from repro.kernels.fused_phase1 import ops as fp_ops
from repro.kernels.fused_phase1.ref import (fused_phase1_quant_ref,
                                            fused_phase1_ref, match_scores)
from repro.kernels.rerank_topk import ops as rk_ops
from repro.kernels.rerank_topk.ref import rerank_scores_ref


class TestCodeMatchKernel:
    @pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
    @pytest.mark.parametrize("shape", [(64, 1, 8), (200, 3, 100), (512, 8, 128),
                                       (700, 5, 96), (1024, 2, 17)])
    def test_shapes_dtypes(self, dtype, shape):
        d, q, c = shape
        rng = np.random.default_rng(d + q + c)
        hi = min(100, np.iinfo(dtype).max)
        D = rng.integers(-hi, hi, size=(d, c)).astype(dtype)
        Q = rng.integers(-hi, hi, size=(q, c)).astype(dtype)
        W = rng.random((q, c)).astype(np.float32)
        got = cm_ops.code_match(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W),
                                force_pallas=True)
        want = code_match_ref(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_block_shape_invariance(self):
        rng = np.random.default_rng(0)
        D = rng.integers(-50, 50, size=(300, 64)).astype(np.int8)
        Q = rng.integers(-50, 50, size=(4, 64)).astype(np.int8)
        W = rng.random((4, 64)).astype(np.float32)
        outs = []
        for bq, bd, bc in [(2, 128, 32), (4, 64, 64), (1, 256, 128)]:
            outs.append(np.asarray(cm_ops.code_match(
                jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W),
                block_q=bq, block_d=bd, block_c=bc, force_pallas=True)))
        for o in outs[1:]:
            assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        d = int(rng.integers(1, 80))
        q = int(rng.integers(1, 5))
        c = int(rng.integers(1, 40))
        D = rng.integers(-10, 10, size=(d, c)).astype(np.int8)
        Q = rng.integers(-10, 10, size=(q, c)).astype(np.int8)
        W = rng.random((q, c)).astype(np.float32)
        got = cm_ops.code_match(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W),
                                force_pallas=True)
        want = code_match_ref(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_self_match_upper_bound(self):
        """A doc matched against itself scores the full weight sum."""
        rng = np.random.default_rng(5)
        D = rng.integers(-20, 20, size=(32, 24)).astype(np.int8)
        W = rng.random((32, 24)).astype(np.float32)
        got = np.asarray(cm_ops.code_match(
            jnp.asarray(D), jnp.asarray(D), jnp.asarray(W), force_pallas=True))
        assert_allclose(np.diag(got), W.sum(-1), rtol=1e-5)
        assert (got <= W.sum(-1)[:, None] + 1e-5).all()


class TestRerankKernel:
    @pytest.mark.parametrize("shape", [(1, 16, 8), (3, 300, 64), (8, 512, 400),
                                       (2, 77, 33)])
    def test_shapes(self, shape):
        q, p, n = shape
        rng = np.random.default_rng(sum(shape))
        CV = rng.normal(size=(q, p, n)).astype(np.float32)
        QV = rng.normal(size=(q, n)).astype(np.float32)
        got = rk_ops.rerank_scores(jnp.asarray(CV), jnp.asarray(QV), force_pallas=True)
        want = rerank_scores_ref(jnp.asarray(CV), jnp.asarray(QV))
        # atol covers f32 accumulation-order drift between the blocked pallas
        # loop and the XLA einsum at n=400 (observed max ~2e-5)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=5e-5)

    def test_topk_wrapper_matches_core(self):
        from repro.core.rerank import rerank_topk as core_rerank
        rng = np.random.default_rng(1)
        V = normalize(jnp.asarray(rng.normal(size=(200, 32)).astype(np.float32)))
        ids = jnp.asarray(rng.integers(0, 200, size=(4, 64)).astype(np.int32))
        Q = normalize(jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32)))
        i1, s1 = rk_ops.rerank_topk(V, ids, Q, k=5, force_pallas=True)
        i2, s2 = core_rerank(V, ids, Q, k=5)
        assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        q = int(rng.integers(1, 5))
        p = int(rng.integers(1, 90))
        n = int(rng.integers(1, 48))
        CV = rng.normal(size=(q, p, n)).astype(np.float32)
        QV = rng.normal(size=(q, n)).astype(np.float32)
        got = rk_ops.rerank_scores(jnp.asarray(CV), jnp.asarray(QV),
                                   force_pallas=True)
        want = rerank_scores_ref(jnp.asarray(CV), jnp.asarray(QV))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                        atol=5e-5)


class TestBucketizeKernel:
    @pytest.mark.parametrize("mode,param,dtype", [
        ("round", 100.0, jnp.int8),
        ("round", 1000.0, jnp.int16),
        ("floor", 0.1, jnp.int8),
        ("floor", 0.05, jnp.int8),
    ])
    @pytest.mark.parametrize("shape", [(16, 8), (255, 40), (256, 128)])
    def test_modes(self, mode, param, dtype, shape):
        rng = np.random.default_rng(int(param) + sum(shape))
        X = rng.normal(size=shape).astype(np.float32)
        got = np.asarray(bk_ops._single(jnp.asarray(X), mode, param, dtype, 64, True))
        want = np.asarray(bucketize_ref(jnp.asarray(X), mode, param, dtype))
        # float-boundary cells may differ by 1 bucket on <0.01% of entries
        assert (got == want).mean() > 0.9999

    def test_encoder_integration(self):
        rng = np.random.default_rng(9)
        X = rng.normal(size=(100, 16)).astype(np.float32)
        for enc in [RoundingEncoder(2), IntervalEncoder(0.1), CombinedEncoder()]:
            got = np.asarray(bk_ops.encode(jnp.asarray(X), enc, force_pallas=True))
            want = np.asarray(enc.encode(normalize(jnp.asarray(X))))
            assert (got == want).mean() > 0.9999

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        d = int(rng.integers(1, 300))
        n = int(rng.integers(1, 64))
        mode = ["round", "floor"][int(rng.integers(0, 2))]
        param = 100.0 if mode == "round" else 0.1
        X = rng.normal(size=(d, n)).astype(np.float32)
        got = np.asarray(bk_ops._single(jnp.asarray(X), mode, param,
                                        jnp.int8, 64, True))
        want = np.asarray(bucketize_ref(jnp.asarray(X), mode, param,
                                        jnp.int8))
        assert (got == want).mean() > 0.999


# --------------------------------------------------------- fused phase-1
def _assert_fused_parity(got, want, d, ctx=""):
    """The fused fp32 contract: scores bit-equal EVERYWHERE, ids bit-equal
    wherever the score is finite, and every id in range (the -inf slots
    carry unspecified-but-clamped ids -- ops.py's contract)."""
    s_g, i_g = np.asarray(got[0]), np.asarray(got[1])
    s_w, i_w = np.asarray(want[0]), np.asarray(want[1])
    assert np.array_equal(s_g, s_w), ctx
    fin = np.isfinite(s_w)
    assert np.array_equal(i_g[fin], i_w[fin]), ctx
    assert (i_g >= 0).all() and (i_g < d).all(), ctx


class TestFusedPhase1Kernel:
    """fused_phase1 (pallas interpret + stream fallback) vs the composed
    full-matrix oracle: BIT-exact, not allclose -- the whole family shares
    ref.match_scores' fixed pairwise-tree reduction, so per-cell bits
    cannot depend on tiling."""

    @pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
    @pytest.mark.parametrize("shape", [(64, 1, 8, 16), (700, 5, 37, 17),
                                       (513, 8, 48, 33), (100, 1, 1, 10),
                                       (1000, 9, 20, 320)])
    def test_shapes_dtypes(self, dtype, shape):
        d, q, c, page = shape
        rng = np.random.default_rng(d + q + c + page)
        hi = min(100, np.iinfo(dtype).max)
        D = jnp.asarray(rng.integers(-hi, hi, size=(d, c)).astype(dtype))
        Q = jnp.asarray(rng.integers(-hi, hi, size=(q, c)).astype(dtype))
        W = jnp.asarray(rng.random((q, c)).astype(np.float32))
        got = fp_ops.fused_phase1(D, Q, W, page=page, force_pallas=True)
        want = fused_phase1_ref(D, Q, W, page=page)
        _assert_fused_parity(got, want, d, (shape, dtype))

    def test_auto_path_matches_ref_and_pallas(self):
        """The public wrapper's automatic backend choice (interpret for
        small problems, the lax.scan stream past the element limit) is
        invisible: both routes bit-match the oracle.  (5001, 9, 100)
        crosses the 2^22 limit -> stream; (5001, 9, 23) stays interpret
        and is the historical shape where a jnp.sum-based tile scorer
        diverged in the last ulp."""
        for d, q, c, page in [(5001, 9, 100, 64), (5001, 9, 23, 33),
                              (300, 4, 17, 40)]:
            rng = np.random.default_rng(d + c)
            D = jnp.asarray(rng.integers(-50, 50, size=(d, c)).astype(np.int16))
            Q = jnp.asarray(rng.integers(-50, 50, size=(q, c)).astype(np.int16))
            W = jnp.asarray(rng.random((q, c)).astype(np.float32))
            want = fused_phase1_ref(D, Q, W, page=page)
            auto = fp_ops.fused_phase1(D, Q, W, page=page)
            _assert_fused_parity(auto, want, d, ("auto", d, c))
            forced = fp_ops.fused_phase1(D, Q, W, page=page,
                                         force_pallas=True)
            _assert_fused_parity(forced, want, d, ("pallas", d, c))

    def test_live_mask_and_inf_slots(self):
        """Fewer live docs than page: the finite prefix is exactly the
        live docs' ranking, dead slots report -inf with in-range ids."""
        d, q, c, page = 60, 3, 12, 32
        rng = np.random.default_rng(0)
        D = jnp.asarray(rng.integers(-20, 20, size=(d, c)).astype(np.int8))
        Q = jnp.asarray(rng.integers(-20, 20, size=(q, c)).astype(np.int8))
        W = jnp.asarray(rng.random((q, c)).astype(np.float32))
        live = jnp.asarray(rng.random(d) < 0.3)
        n_live = int(np.asarray(live).sum())
        assert 0 < n_live < page
        want = fused_phase1_ref(D, Q, W, page=page, live=live)
        for force in (False, True):
            got = fp_ops.fused_phase1(D, Q, W, page=page, live=live,
                                      force_pallas=force)
            _assert_fused_parity(got, want, d, ("live", force))
            s = np.asarray(got[0])
            assert (np.isfinite(s).sum(axis=1) == n_live).all()
            ids_fin = np.asarray(got[1])[np.isfinite(s)]
            assert np.asarray(live)[ids_fin].all()

    def test_block_shape_invariance(self):
        """Retuning (block_q, block_d) can never move a bit."""
        rng = np.random.default_rng(1)
        D = jnp.asarray(rng.integers(-50, 50, size=(300, 64)).astype(np.int8))
        Q = jnp.asarray(rng.integers(-50, 50, size=(4, 64)).astype(np.int8))
        W = jnp.asarray(rng.random((4, 64)).astype(np.float32))
        outs = [fp_ops.fused_phase1(D, Q, W, page=33, block_q=bq,
                                    block_d=bd, force_pallas=True)
                for bq, bd in [(2, 128), (4, 64), (1, 256), (8, 512)]]
        for o in outs[1:]:
            _assert_fused_parity(o, outs[0], 300, "block invariance")

    def test_match_scores_doc_tile_invariance(self):
        """The load-bearing property underneath everything: scoring a doc
        slice yields the SAME bits as slicing the full score matrix, for
        awkward odd split points too."""
        rng = np.random.default_rng(2)
        d, q, c = 301, 4, 23
        D = jnp.asarray(rng.integers(-30, 30, size=(d, c)).astype(np.int16))
        Q = jnp.asarray(rng.integers(-30, 30, size=(q, c)).astype(np.int16))
        W = jnp.asarray(rng.random((q, c)).astype(np.float32))
        full = np.asarray(match_scores(D, Q, W))
        for cut in (1, 37, 128, 300):
            lo = np.asarray(match_scores(D[:cut], Q, W))
            hi = np.asarray(match_scores(D[cut:], Q, W))
            assert np.array_equal(np.concatenate([lo, hi], axis=1), full), cut

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        d = int(rng.integers(1, 400))
        q = int(rng.integers(1, 5))
        c = int(rng.integers(1, 40))
        page = int(rng.integers(1, 64))
        dtype = [np.int8, np.int16, np.int32][int(rng.integers(0, 3))]
        D = jnp.asarray(rng.integers(-10, 10, size=(d, c)).astype(dtype))
        Q = jnp.asarray(rng.integers(-10, 10, size=(q, c)).astype(dtype))
        W = jnp.asarray(rng.random((q, c)).astype(np.float32))
        live = jnp.asarray(rng.random(d) < 0.8) if rng.random() < 0.5 \
            else None
        want = fused_phase1_ref(D, Q, W, page=page, live=live)
        for force in (False, True):
            got = fp_ops.fused_phase1(D, Q, W, page=page, live=live,
                                      force_pallas=force)
            _assert_fused_parity(got, want, d, (seed, force))


def _assert_quant_parity(got, want, d, ctx="", tol=1e-4):
    """The fused int8 contract: positional scores within float tolerance
    of the composed quantized reference (the blocked dot and the full
    einsum may differ in the last ulp), ids bit-equal wherever the
    reference score is separated from its neighbours by more than the
    tolerance (a last-ulp wobble may swap near-ties, never a real
    ranking), all ids in range."""
    s_g, i_g = np.asarray(got[0]), np.asarray(got[1])
    s_w, i_w = np.asarray(want[0]), np.asarray(want[1])
    fin = np.isfinite(s_w)
    assert np.array_equal(fin, np.isfinite(s_g)), ctx
    assert_allclose(s_g[fin], s_w[fin], rtol=1e-5, atol=tol, err_msg=str(ctx))
    sep = fin.copy()
    if s_w.shape[1] > 1:
        with np.errstate(invalid="ignore"):   # -inf slots: nan gap = no tie
            tie = np.abs(s_w[:, :-1] - s_w[:, 1:]) <= tol
        sep[:, 1:] &= ~tie
        sep[:, :-1] &= ~tie
    assert np.array_equal(i_g[sep], i_w[sep]), ctx
    assert (i_g >= 0).all() and (i_g < d).all(), ctx


class TestFusedPhase1QuantKernel:
    """fused_phase1_quant vs the composed quantized_scores + top_k oracle.
    int8 phase-1 is candidate selection only (callers always rescore the
    page exact fp32), so the pin is float-tolerance scores + ranking
    agreement away from ties, not bit equality."""

    @staticmethod
    def _mk(d, n, q, seed):
        rng = np.random.default_rng(seed)
        V = rng.normal(size=(d, n)).astype(np.float32) * \
            rng.uniform(0.1, 4.0, size=(d, 1)).astype(np.float32)
        codes, scale, zero = quantize_rows(jnp.asarray(V))
        Q = jnp.asarray(rng.normal(size=(q, n)).astype(np.float32))
        return jnp.asarray(V), codes, scale, zero, Q, rng

    @pytest.mark.parametrize("shape", [(64, 8, 1, 16), (300, 16, 4, 33),
                                       (513, 32, 8, 64), (100, 1, 2, 10)])
    def test_shapes(self, shape):
        d, n, q, page = shape
        _, codes, scale, zero, Q, _ = self._mk(d, n, q, sum(shape))
        got = fp_ops.fused_phase1_quant(codes, scale, zero, Q, page=page,
                                        force_pallas=True)
        want = fused_phase1_quant_ref(codes, scale, zero, Q, page=page)
        _assert_quant_parity(got, want, d, shape)

    def test_stream_path_matches_ref(self):
        """(20000, 32, 9) crosses the interpret element limit -> the
        lax.scan stream serves; same contract as the kernel path."""
        d, n, q, page = 20_000, 32, 9, 64
        _, codes, scale, zero, Q, _ = self._mk(d, n, q, 3)
        got = fp_ops.fused_phase1_quant(codes, scale, zero, Q, page=page)
        want = fused_phase1_quant_ref(codes, scale, zero, Q, page=page)
        _assert_quant_parity(got, want, d, "stream")

    def test_live_mask(self):
        d, n, q, page = 90, 12, 3, 48
        _, codes, scale, zero, Q, rng = self._mk(d, n, q, 4)
        live = jnp.asarray(rng.random(d) < 0.3)
        n_live = int(np.asarray(live).sum())
        assert 0 < n_live < page
        got = fp_ops.fused_phase1_quant(codes, scale, zero, Q, page=page,
                                        live=live, force_pallas=True)
        want = fused_phase1_quant_ref(codes, scale, zero, Q, page=page,
                                      live=live)
        _assert_quant_parity(got, want, d, "live")
        assert (np.isfinite(np.asarray(got[0])).sum(axis=1) == n_live).all()

    def test_scores_match_dequantized_oracle(self):
        """quantized_scores' factored form (scale * (q.a) + zero * sum(a))
        IS the dot against the dequantized rows -- algebra, checked to
        float tolerance."""
        V, codes, scale, zero, Q, _ = self._mk(200, 24, 4, 5)
        from repro.core.quantize import dequantize_rows
        deq = dequantize_rows(codes, scale, zero)
        want = np.asarray(Q) @ np.asarray(deq).T
        got = np.asarray(quantized_scores(codes, scale, zero, Q))
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        d = int(rng.integers(1, 300))
        n = int(rng.integers(1, 32))
        q = int(rng.integers(1, 4))
        page = int(rng.integers(1, 32))
        _, codes, scale, zero, Q, _ = self._mk(d, n, q, seed)
        want = fused_phase1_quant_ref(codes, scale, zero, Q, page=page)
        for force in (False, True):
            got = fp_ops.fused_phase1_quant(codes, scale, zero, Q,
                                            page=page, force_pallas=force)
            _assert_quant_parity(got, want, d, (seed, force))
