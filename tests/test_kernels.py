"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core.encoding import CombinedEncoder, IntervalEncoder, RoundingEncoder
from repro.core.rerank import normalize
from repro.kernels.bucketize import ops as bk_ops
from repro.kernels.bucketize.ref import bucketize_ref
from repro.kernels.code_match import ops as cm_ops
from repro.kernels.code_match.ref import code_match_ref
from repro.kernels.rerank_topk import ops as rk_ops
from repro.kernels.rerank_topk.ref import rerank_scores_ref


class TestCodeMatchKernel:
    @pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
    @pytest.mark.parametrize("shape", [(64, 1, 8), (200, 3, 100), (512, 8, 128),
                                       (700, 5, 96), (1024, 2, 17)])
    def test_shapes_dtypes(self, dtype, shape):
        d, q, c = shape
        rng = np.random.default_rng(d + q + c)
        hi = min(100, np.iinfo(dtype).max)
        D = rng.integers(-hi, hi, size=(d, c)).astype(dtype)
        Q = rng.integers(-hi, hi, size=(q, c)).astype(dtype)
        W = rng.random((q, c)).astype(np.float32)
        got = cm_ops.code_match(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W),
                                force_pallas=True)
        want = code_match_ref(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_block_shape_invariance(self):
        rng = np.random.default_rng(0)
        D = rng.integers(-50, 50, size=(300, 64)).astype(np.int8)
        Q = rng.integers(-50, 50, size=(4, 64)).astype(np.int8)
        W = rng.random((4, 64)).astype(np.float32)
        outs = []
        for bq, bd, bc in [(2, 128, 32), (4, 64, 64), (1, 256, 128)]:
            outs.append(np.asarray(cm_ops.code_match(
                jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W),
                block_q=bq, block_d=bd, block_c=bc, force_pallas=True)))
        for o in outs[1:]:
            assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        d = int(rng.integers(1, 80))
        q = int(rng.integers(1, 5))
        c = int(rng.integers(1, 40))
        D = rng.integers(-10, 10, size=(d, c)).astype(np.int8)
        Q = rng.integers(-10, 10, size=(q, c)).astype(np.int8)
        W = rng.random((q, c)).astype(np.float32)
        got = cm_ops.code_match(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W),
                                force_pallas=True)
        want = code_match_ref(jnp.asarray(D), jnp.asarray(Q), jnp.asarray(W))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_self_match_upper_bound(self):
        """A doc matched against itself scores the full weight sum."""
        rng = np.random.default_rng(5)
        D = rng.integers(-20, 20, size=(32, 24)).astype(np.int8)
        W = rng.random((32, 24)).astype(np.float32)
        got = np.asarray(cm_ops.code_match(
            jnp.asarray(D), jnp.asarray(D), jnp.asarray(W), force_pallas=True))
        assert_allclose(np.diag(got), W.sum(-1), rtol=1e-5)
        assert (got <= W.sum(-1)[:, None] + 1e-5).all()


class TestRerankKernel:
    @pytest.mark.parametrize("shape", [(1, 16, 8), (3, 300, 64), (8, 512, 400),
                                       (2, 77, 33)])
    def test_shapes(self, shape):
        q, p, n = shape
        rng = np.random.default_rng(sum(shape))
        CV = rng.normal(size=(q, p, n)).astype(np.float32)
        QV = rng.normal(size=(q, n)).astype(np.float32)
        got = rk_ops.rerank_scores(jnp.asarray(CV), jnp.asarray(QV), force_pallas=True)
        want = rerank_scores_ref(jnp.asarray(CV), jnp.asarray(QV))
        # atol covers f32 accumulation-order drift between the blocked pallas
        # loop and the XLA einsum at n=400 (observed max ~2e-5)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=5e-5)

    def test_topk_wrapper_matches_core(self):
        from repro.core.rerank import rerank_topk as core_rerank
        rng = np.random.default_rng(1)
        V = normalize(jnp.asarray(rng.normal(size=(200, 32)).astype(np.float32)))
        ids = jnp.asarray(rng.integers(0, 200, size=(4, 64)).astype(np.int32))
        Q = normalize(jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32)))
        i1, s1 = rk_ops.rerank_topk(V, ids, Q, k=5, force_pallas=True)
        i2, s2 = core_rerank(V, ids, Q, k=5)
        assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-5)


class TestBucketizeKernel:
    @pytest.mark.parametrize("mode,param,dtype", [
        ("round", 100.0, jnp.int8),
        ("round", 1000.0, jnp.int16),
        ("floor", 0.1, jnp.int8),
        ("floor", 0.05, jnp.int8),
    ])
    @pytest.mark.parametrize("shape", [(16, 8), (255, 40), (256, 128)])
    def test_modes(self, mode, param, dtype, shape):
        rng = np.random.default_rng(int(param) + sum(shape))
        X = rng.normal(size=shape).astype(np.float32)
        got = np.asarray(bk_ops._single(jnp.asarray(X), mode, param, dtype, 64, True))
        want = np.asarray(bucketize_ref(jnp.asarray(X), mode, param, dtype))
        # float-boundary cells may differ by 1 bucket on <0.01% of entries
        assert (got == want).mean() > 0.9999

    def test_encoder_integration(self):
        rng = np.random.default_rng(9)
        X = rng.normal(size=(100, 16)).astype(np.float32)
        for enc in [RoundingEncoder(2), IntervalEncoder(0.1), CombinedEncoder()]:
            got = np.asarray(bk_ops.encode(jnp.asarray(X), enc, force_pallas=True))
            want = np.asarray(enc.encode(normalize(jnp.asarray(X))))
            assert (got == want).mean() > 0.9999
