"""Property-based build parity (dist/shard_index.py on-device build).

The pinned invariant: ``ShardedVectorIndex.build_sharded`` -- the ONE-program
on-device SPMD build -- produces bit-identical codes/postings per shard, and
bit-identical ``search`` results at ``page >= n_docs``, versus the reference
path ``VectorIndex.build`` + ``from_index``, for random
(n_docs, dims, shards, replicas, engine, index_best, merge) draws including
ragged tail shards.  Draws come from the vendored deterministic hypothesis
shim (tests/_stubs), so every run replays the same examples.

Multi-device sweeps run in a subprocess (the virtual-device flag must
precede jax initialisation, same pattern as test_shard_index.py): one
4-device and one 8-device mesh sweep, each covering even AND ragged splits
(two fixed anchor examples guarantee both) plus shim-driven random draws.
A separate subprocess pins the one-compiled-program claim: ``build_postings``
is traced exactly once per build, for any shard count -- no per-shard host
loop.
"""

import os
import subprocess
import sys

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import VectorIndex
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LEAVES = ("vectors", "codes", "post_docs", "post_codes", "offsets", "live")


def _assert_same_index(ref, dev, ctx):
    for name in _LEAVES:
        a, b = np.asarray(getattr(ref, name)), np.asarray(getattr(dev, name))
        assert np.array_equal(a, b), (ctx, name)
    assert dev.seg_capacity == 0 and dev.n_appended == 0, ctx


@settings(max_examples=8)
@given(n_docs=st.integers(3, 40), dims=st.integers(4, 16),
       engine=st.sampled_from(["postings", "codes", "onehot", "codes_pallas"]),
       index_best=st.sampled_from([None, 3, 8]),
       merge=st.sampled_from(["gather", "stream"]),
       seed=st.integers(0, 2**20))
def test_build_parity_single_shard(n_docs, dims, engine, index_best, merge,
                                   seed):
    """S=1 runs in-process: the on-device build must already match the
    reference build leaf-for-leaf and search bit-for-bit."""
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, dims)).astype(np.float32)
    Q = rng.normal(size=(3, dims)).astype(np.float32)
    mesh = make_shard_mesh(1)
    single = VectorIndex.build(V, index_best=index_best)
    ref = ShardedVectorIndex.from_index(single, mesh)
    dev = ShardedVectorIndex.build_sharded(V, mesh, index_best=index_best)
    ctx = (n_docs, dims, engine, index_best, merge, seed)
    _assert_same_index(ref, dev, ctx)
    ids0, s0 = single.search(Q, k=5, page=2 * n_docs, engine=engine)
    ids2, s2 = dev.search(Q, k=5, page=2 * n_docs, engine=engine, merge=merge)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids2)), ctx
    assert np.array_equal(np.asarray(s0), np.asarray(s2)), ctx


def test_builder_accepts_device_arrays():
    """The fixed host-round-trip: device-resident vectors build without a
    numpy copy and produce the same index as the host-array path."""
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    V = rng.normal(size=(17, 8)).astype(np.float32)
    mesh = make_shard_mesh(1)
    host = ShardedVectorIndex.build(V, mesh)
    dev = ShardedVectorIndex.build(jnp.asarray(V), mesh)
    _assert_same_index(host, dev, "device-resident build")


def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


def _sweep_script(n_devices, cells, n_examples, seed):
    """Subprocess source: shim-driven random parity sweep over ``cells`` =
    [(shards, replicas), ...] on an ``n_devices`` virtual mesh."""
    return rf"""
import os, sys, random
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
sys.path.insert(0, os.path.join("tests", "_stubs"))  # vendored shim, always
from hypothesis import strategies as st
import numpy as np
from repro.core import VectorIndex
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh

rng = random.Random({seed})
cells = {cells!r}
n_docs_s = st.integers(5, 48)
dims_s = st.integers(4, 12)
engine_s = st.sampled_from(["postings", "codes", "onehot", "codes_pallas"])
best_s = st.sampled_from([None, 3])
merge_s = st.sampled_from(["gather", "stream"])

# anchors guarantee even AND ragged splits at the max shard count ...
smax = max(s for s, _ in cells)
examples = [(6 * smax, 8, cells[-1], "codes", None, "gather"),
            (6 * smax - 1, 8, cells[-1], "postings", 3, "stream")]
# ... then the shim drives the random sweep
for _ in range({n_examples}):
    examples.append((n_docs_s.example(rng), dims_s.example(rng),
                     cells[rng.randrange(len(cells))], engine_s.example(rng),
                     best_s.example(rng), merge_s.example(rng)))

for n_docs, dims, (s, r), engine, best, merge in examples:
    if s > n_docs:
        continue
    vrng = np.random.default_rng(hash((n_docs, dims, s, r)) % 2**32)
    V = vrng.normal(size=(n_docs, dims)).astype(np.float32)
    Q = vrng.normal(size=(3, dims)).astype(np.float32)
    mesh = make_shard_mesh(s, r)
    single = VectorIndex.build(V, index_best=best)
    ref = ShardedVectorIndex.from_index(single, mesh)
    dev = ShardedVectorIndex.build_sharded(V, mesh, index_best=best)
    ctx = (n_docs, dims, s, r, engine, best, merge)
    for name in {_LEAVES!r}:
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(dev, name))
        assert np.array_equal(a, b), (ctx, name)
    ids0, s0 = single.search(Q, k=5, page=2 * n_docs, engine=engine)
    ids2, s2 = dev.search(Q, k=5, page=2 * n_docs, engine=engine, merge=merge)
    assert np.array_equal(np.asarray(ids0), np.asarray(ids2)), ctx
    assert np.array_equal(np.asarray(s0), np.asarray(s2)), ctx
print("OK")
"""


def test_build_parity_sweep_4dev():
    """Random (n_docs, dims, shards, replicas, engine, index_best, merge)
    sweep on a 4-virtual-device mesh, all shard layouts that fit."""
    _run_subprocess(_sweep_script(
        4, [(1, 1), (2, 1), (2, 2), (4, 1)], n_examples=6, seed=401))


def test_build_parity_sweep_8dev():
    """The same sweep on an 8-virtual-device mesh, replica tiers included."""
    _run_subprocess(_sweep_script(
        8, [(2, 4), (4, 2), (8, 1)], n_examples=4, seed=801))


def test_build_is_one_compiled_program():
    """``build_sharded`` (and the loop-free ``from_index``) trace
    ``build_postings`` exactly ONCE regardless of shard count: the build is
    one compiled SPMD program, not an S-iteration host loop.  Fresh shapes
    guarantee a fresh trace (jit caching would otherwise hide calls)."""
    _run_subprocess(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import repro.dist.shard_index as si
from repro.core import VectorIndex
from repro.launch.mesh import make_shard_mesh

calls = []
orig = si.build_postings
si.build_postings = lambda c: (calls.append(1), orig(c))[1]

V = np.random.default_rng(3).normal(size=(37, 9)).astype(np.float32)
mesh = make_shard_mesh(4)
dev = si.ShardedVectorIndex.build_sharded(V, mesh)
assert len(calls) == 1, f"build_sharded traced build_postings {len(calls)}x"

calls.clear()
si.ShardedVectorIndex.from_index(VectorIndex.build(V[:35, :8]), mesh)
assert len(calls) == 1, f"from_index traced build_postings {len(calls)}x"
print("OK")
""")
