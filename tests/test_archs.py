"""Per-assigned-architecture smoke tests: REDUCED configs, real arrays, one
forward/train step on CPU, asserting output shapes and no NaNs (the FULL
configs are exercised only via the dry-run).  Also checks, for every
(arch x shape) cell, that the in_specs tree matches the abstract args tree
-- the cheap structural half of the dry-run contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, arch_shapes, get_arch
from repro.data import lm_batch, random_graph, recsys_batch
from repro.models.transformer import model as lm

rng = np.random.default_rng(0)

LM_IDS = ["llama4-maverick-400b-a17b", "mixtral-8x22b", "gemma2-27b",
          "starcoder2-3b", "qwen2-0.5b"]
RS_IDS = ["xdeepfm", "autoint", "din", "bst"]


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_smoke_forward_and_train(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.smoke()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             lm_batch(rng, batch=4, seq=32, vocab=cfg.vocab).items()}
    logits, aux, _ = lm.forward(params, batch["tokens"], cfg)
    assert logits.shape == (4, 32, cfg.vocab)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()
    loss = lm.lm_loss(params, batch, cfg)
    assert jnp.isfinite(loss)
    grads = jax.grad(lm.lm_loss)(params, batch, cfg)
    gn = jax.tree_util.tree_reduce(
        lambda a, g: a + float(jnp.abs(g.astype(jnp.float32)).sum()), grads, 0.0)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_smoke_decode_matches_forward(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.smoke()
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=8.0) if cfg.moe_experts else cfg
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 32)).astype(np.int32))
    logits, _, _ = lm.forward(params, tokens, cfg)
    _, cache = lm.prefill(params, tokens, cfg, max_seq=32)
    step_logits, _ = lm.serve_step(params, cache, tokens[:, -1:], jnp.int32(31), cfg)
    ref = logits[:, 31].astype(jnp.float32)
    got = step_logits[:, 0].astype(jnp.float32)
    rel = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 0.05, rel   # bf16 accumulation noise


def test_lm_masked_cache_update_equivalent():
    import dataclasses
    cfg = get_arch("qwen2-0.5b").smoke()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 16)).astype(np.int32))
    _, cache = lm.prefill(params, tokens, cfg, max_seq=16)
    l1, c1 = lm.serve_step(params, cache, tokens[:, -1:], jnp.int32(15), cfg)
    cfg2 = dataclasses.replace(cfg, cache_update="masked")
    l2, c2 = lm.serve_step(params, cache, tokens[:, -1:], jnp.int32(15), cfg2)
    np.testing.assert_allclose(np.asarray(l1, np.float32), np.asarray(l2, np.float32),
                               rtol=2e-2, atol=1e-2)
    for key in c1:
        np.testing.assert_array_equal(np.asarray(c1[key]["pos"]), np.asarray(c2[key]["pos"]))


def test_gin_smoke_all_modes():
    from repro.models.gnn import gin
    arch = get_arch("gin-tu")
    for shape in ["full_graph_sm", "molecule"]:
        cfg = arch.cfg_for(shape)
        import dataclasses
        cfg = dataclasses.replace(cfg, d_in=12, n_classes=3)
        params = gin.init_params(jax.random.PRNGKey(0), cfg)
        if shape == "molecule":
            batch = {
                "x": jnp.asarray(rng.normal(size=(4, 10, 12)).astype(np.float32)),
                "edge_src": jnp.asarray(rng.integers(-1, 10, size=(4, 20)).astype(np.int32)),
                "edge_dst": jnp.asarray(rng.integers(-1, 10, size=(4, 20)).astype(np.int32)),
                "node_mask": jnp.ones((4, 10)),
                "labels": jnp.asarray(rng.integers(0, 3, size=4)),
            }
            loss = gin.graph_loss(params, batch, cfg)
        else:
            g = random_graph(rng, 50, 200, 12, 3)
            batch = {k: jnp.asarray(v) for k, v in g.items()}
            loss = gin.node_loss(params, batch, cfg)
        assert jnp.isfinite(loss), shape


def test_gin_neighbor_sampler_block_trains():
    from repro.models.gnn import gin
    from repro.models.gnn.sampler import build_csr, sample_block
    cfg = get_arch("gin-tu").cfg_for("minibatch_lg")
    import dataclasses
    cfg = dataclasses.replace(cfg, d_in=8, n_classes=4)
    g = random_graph(rng, 200, 2000, 8, 4)
    csr = build_csr(200, g["edge_src"], g["edge_dst"], g["x"], g["labels"])
    blk = sample_block(csr, np.arange(16), (5, 3), rng)
    assert blk["x"].shape[0] == 16 + 16 * 5 + 16 * 15
    batch = {k: jnp.asarray(v) for k, v in blk.items()}
    loss = gin.node_loss(params=gin.init_params(jax.random.PRNGKey(1), cfg),
                         batch=batch, cfg=cfg)
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("arch_id", RS_IDS)
def test_recsys_smoke(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.smoke_cfg
    params = arch.init_fn(jax.random.PRNGKey(0), cfg)
    if arch.seq:
        b = recsys_batch(rng, 16, 1, [cfg.item_vocab], seq_len=cfg.seq_len)
    else:
        b = recsys_batch(rng, 16, cfg.n_sparse, cfg.vocab_sizes)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    logits = arch.forward_fn(params, batch, cfg)
    assert logits.shape == (16,)
    assert not jnp.isnan(logits).any()
    from repro.models.recsys.models import bce_loss
    g = jax.grad(lambda p: bce_loss(arch.forward_fn, p, batch, cfg))(params)
    assert jax.tree_util.tree_reduce(
        lambda a, x: a and bool(jnp.isfinite(x).all()), g, True)
    u = arch.user_fn(params, batch, cfg)
    assert u.shape == (16, cfg.embed_dim)


@pytest.mark.parametrize("arch_id", RS_IDS)
def test_recsys_retrieval_integration(arch_id):
    """The paper's two-phase search as the recsys candidate generator."""
    from repro.serve.retrieval import (brute_force_retrieval, encode_candidates,
                                       retrieval_step)
    arch = get_arch(arch_id)
    cfg = arch.smoke_cfg
    params = arch.init_fn(jax.random.PRNGKey(0), cfg)
    if arch.seq:
        b = recsys_batch(rng, 4, 1, [cfg.item_vocab], seq_len=cfg.seq_len)
    else:
        b = recsys_batch(rng, 4, cfg.n_sparse, cfg.vocab_sizes)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    u = arch.user_fn(params, batch, cfg)
    cand = jnp.asarray(rng.normal(size=(2000, cfg.embed_dim)).astype(np.float32))
    vecs, codes = encode_candidates(cand)
    ids, scores = retrieval_step(u, vecs, codes, page=2000, k=10)
    gold_ids, gold_s = brute_force_retrieval(u, vecs, k=10)
    assert (np.asarray(ids) == np.asarray(gold_ids)).all()  # page=N: exact


def test_all_cells_spec_structure():
    """Every (arch x shape) cell's in_specs tree must match its args tree."""
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    for arch_id in ARCH_IDS:
        arch = get_arch(arch_id)
        for shape in arch_shapes(arch_id):
            cell = arch.cell(shape, mesh)
            if cell is None:
                continue
            assert len(cell.args) == len(cell.in_specs), (arch_id, shape)
            for a, s in zip(cell.args, cell.in_specs):
                jax.tree.map(lambda *_: None, a, s)  # raises on mismatch


def test_qwen2_long500k_skipped_by_rule():
    assert "long_500k" not in arch_shapes("qwen2-0.5b")
    assert len(arch_shapes("qwen2-0.5b")) == 3
