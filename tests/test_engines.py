"""Engine equivalence: postings == codes == onehot == pallas (the key invariant).

The paper's inverted index and the TPU code-match engine are two lowerings of
the same score function (DESIGN.md §2); these tests pin that identity.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core import VectorIndex, TrimFilter, BestFilter
from repro.core.encoding import CombinedEncoder, IntervalEncoder, RoundingEncoder


def _index_and_queries(seed=0, d=300, n=24, nq=6, encoder=RoundingEncoder(2)):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(d, n)).astype(np.float32)
    idx = VectorIndex.build(V, encoder)
    Q = V[:nq] + 0.05 * rng.normal(size=(nq, n)).astype(np.float32)
    return idx, jnp.asarray(Q)


ENCODERS = [
    RoundingEncoder(2),
    RoundingEncoder(3),
    IntervalEncoder(0.1),
    IntervalEncoder(0.05),
    CombinedEncoder(RoundingEncoder(2), IntervalEncoder(0.1)),
]


@pytest.mark.parametrize("encoder", ENCODERS, ids=lambda e: e.scheme_id)
@pytest.mark.parametrize("weighting", ["idf", "count"])
def test_phase1_scores_identical_across_engines(encoder, weighting):
    idx, Q = _index_and_queries(encoder=encoder)
    q, qc, w = idx.encode_queries(Q, trim=TrimFilter(0.05), best=None, weighting=weighting)
    ref = idx.phase1_scores(qc, w, "postings", max_postings=None)
    for engine in ["codes", "onehot"]:
        got = idx.phase1_scores(qc, w, engine, max_postings=None)
        assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4,
                        err_msg=engine)


def test_pallas_engine_matches_postings():
    idx, Q = _index_and_queries(d=256, n=16, nq=4)
    q, qc, w = idx.encode_queries(Q, trim=None, best=BestFilter(8), weighting="idf")
    ref = idx.phase1_scores(qc, w, "postings", max_postings=None)
    got = idx.phase1_scores(qc, w, "codes_pallas", max_postings=None)
    assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_truncated_postings_lower_bound():
    """Capped posting windows can only lose score mass, never add it."""
    idx, Q = _index_and_queries(d=400)
    q, qc, w = idx.encode_queries(Q, trim=None, best=None, weighting="idf")
    full = np.asarray(idx.phase1_scores(qc, w, "postings", max_postings=None))
    capped = np.asarray(idx.phase1_scores(qc, w, "postings", max_postings=32))
    assert (capped <= full + 1e-5).all()


def test_index_side_best_filter_restricts_matches():
    rng = np.random.default_rng(1)
    V = rng.normal(size=(100, 16)).astype(np.float32)
    full = VectorIndex.build(V)
    trimmed = VectorIndex.build(V, index_best=4)
    Q = jnp.asarray(V[:3])
    _, qc, w = full.encode_queries(Q, None, None, "count")
    s_full = np.asarray(full.phase1_scores(qc, w, "codes", None))
    _, qc2, w2 = trimmed.encode_queries(Q, None, None, "count")
    s_trim = np.asarray(trimmed.phase1_scores(qc2, w2, "codes", None))
    assert (s_trim <= s_full + 1e-5).all()
    assert s_trim.max() <= 4 + 1e-5  # at most 4 tokens can match per doc


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_engine_equivalence_property(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(20, 120))
    n = int(rng.integers(4, 32))
    V = rng.normal(size=(d, n)).astype(np.float32)
    idx = VectorIndex.build(V, IntervalEncoder(0.1))
    Q = jnp.asarray(rng.normal(size=(2, n)).astype(np.float32))
    _, qc, w = idx.encode_queries(Q, TrimFilter(0.02), None, "idf")
    a = np.asarray(idx.phase1_scores(qc, w, "postings", None))
    b = np.asarray(idx.phase1_scores(qc, w, "codes", None))
    c = np.asarray(idx.phase1_scores(qc, w, "onehot", None))
    assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    assert_allclose(a, c, rtol=1e-4, atol=1e-4)
