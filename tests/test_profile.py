"""Observability v2 (repro/obs): _profile trees, slow log, compile
watch, exporter.

The pinned invariants:

* **bit-parity with everything ON** -- results served with metrics +
  tracing + slow log + compile watch + ``profile=True`` are
  bit-identical to a bare engine, for every engine including the fused
  kernels, on an index with appended segments and tombstones (all
  instrumentation is host-side; ``block_until_ready`` fences change
  when values are observed, never the values);
* **profile trees reconcile** -- a request's ``queue_wait`` +
  ``batch_form`` + ``dispatch`` children tile its root total exactly
  (shared clock reads; float addition error only), and the dispatch
  subtree names the kernel path taken;
* **tail capture beats head sampling** -- with a 1/16-sampled tracer,
  every slow or failed request is still captured by the slow log, with
  a promoted profile view; the ring stays bounded and the JSONL sink
  gets every capture;
* **recompiles are observable** -- compiles count per (region,
  signature), a repeat shape hits the jit cache silently, and after
  ``mark_steady()`` any attributed compile is a hard :meth:`check`
  failure while unattributed host compiles stay exempt;
* the Prometheus exposition and the snapshot-history exporter render
  exactly what the registry holds.
"""

import json
import time

import numpy as np
import pytest

from repro.cluster import ClusterEngine
from repro.core import VectorIndex
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.obs import (CompileWatch, MetricsExporter, MetricsRegistry,
                       ProfileNode, SlowLog, Tracer, format_profile_tree,
                       prometheus_text)
from repro.serve.engine import BatchedSearchEngine

N_DOCS, N_FEAT = 60, 16

ALL_ENGINES = ("codes", "postings", "onehot", "fused", "fused_int8")


@pytest.fixture(scope="module")
def sidx():
    """Sharded index with an appended generation and tombstones: the
    profile tree's per-generation children and the parity pins must
    hold on the full segment lifecycle, not just a fresh build."""
    rng = np.random.default_rng(0)
    idx = ShardedVectorIndex.build_sharded(
        rng.normal(size=(N_DOCS, N_FEAT)).astype(np.float32),
        make_shard_mesh(1), seal_threshold=16)
    idx = idx.add_documents(
        rng.normal(size=(24, N_FEAT)).astype(np.float32))
    return idx.delete(np.array([3, N_DOCS + 2]))


@pytest.fixture()
def queries():
    return np.random.default_rng(1).normal(
        size=(6, N_FEAT)).astype(np.float32)


def _full_obs_engine(index, engine, reg=None, batch_size=4, k=5, **kw):
    reg = reg if reg is not None else MetricsRegistry()
    return BatchedSearchEngine(
        index, batch_size=batch_size, k=k, page=N_DOCS, trim=None,
        engine=engine, metrics=reg, tracer=Tracer(sample=1.0 / 16),
        slowlog=SlowLog(threshold_s=0.0, metrics=reg),
        compile_watch=CompileWatch(metrics=reg), **kw)


# ------------------------------------------------------------ profile trees
def test_vector_index_profile_children_and_parity(queries):
    idx = VectorIndex.build(np.random.default_rng(2).normal(
        size=(N_DOCS, N_FEAT)).astype(np.float32))
    for engine in ALL_ENGINES:
        prof = ProfileNode("q")
        ids, scores = idx.search(queries, k=5, page=N_DOCS,
                                 engine=engine, profile=prof)
        bare_ids, bare_scores = idx.search(queries, k=5, page=N_DOCS,
                                           engine=engine)
        assert np.array_equal(np.asarray(ids), np.asarray(bare_ids))
        assert np.array_equal(np.asarray(scores), np.asarray(bare_scores))
        names = [c.name for c in prof.children]
        assert names == ["encode", "phase1", "rescore"], engine
        phase1 = prof.children[1]
        want_kernel = engine if engine in ("fused", "fused_int8") \
            else "composed"
        assert phase1.attrs["kernel"] == want_kernel
        assert phase1.attrs["candidates"] > 0
        assert all(c.duration_s >= 0.0 for c in prof.children)


def test_engine_profile_tree_reconciles(sidx, queries):
    reg = MetricsRegistry()
    eng = _full_obs_engine(sidx, "codes", reg=reg)
    try:
        ids, scores, tree = eng.search(queries[0], timeout=60,
                                       profile=True)
        bare_ids, bare_scores = eng.search(queries[0], timeout=60)
        assert np.array_equal(ids, bare_ids)
        assert np.array_equal(scores, bare_scores)
        assert tree["name"] == "query"
        kids = {c["name"]: c for c in tree["children"]}
        assert list(kids) == ["queue_wait", "batch_form", "dispatch"]
        # shared clock reads: the three phases tile the total EXACTLY
        # (float addition error only)
        tiled = sum(c["duration_s"] for c in kids.values())
        assert abs(tree["duration_s"] - tiled) < 1e-9
        disp = kids["dispatch"]
        assert disp["attrs"]["engine"] == "codes"
        disp_kids = {c["name"]: c for c in disp["children"]}
        assert {"encode", "phase1", "merge_select",
                "rescore"} <= set(disp_kids)
        phase1 = disp_kids["phase1"]
        assert phase1["attrs"]["kernel"] == "composed"
        # per-generation candidate children: base + the sealed/active
        # generations, candidate counts summing to the phase total
        gen_kids = {c["name"]: c for c in phase1["children"]}
        assert "base" in gen_kids
        assert sum(c["attrs"]["candidates"]
                   for n, c in gen_kids.items()
                   if not n.startswith("group")) \
            == phase1["attrs"]["candidates"]
        # the rendering names every phase
        text = format_profile_tree(tree)
        for name in ("query", "queue_wait", "dispatch", "phase1",
                     "rescore"):
            assert name in text
        # dispatch duration is the same observation the latency
        # histogram recorded (one request per batch here)
        assert reg.histogram("engine.dispatch.latency_s").count >= 1
    finally:
        eng.close()


def test_full_instrumentation_bit_parity_all_engines(sidx, queries):
    """THE acceptance pin: every engine, segments + tombstones live,
    metrics + tracer + slow log + compile watch + profile trees ON --
    and the v3 plane polled between requests (device byte accounting +
    node stats + cost capture) -- results bit-identical to a bare
    engine, and every region the watch saw compile has a cost row."""
    from repro.obs import device_bytes, missing_cost_regions, node_stats

    for engine in ALL_ENGINES:
        bare = BatchedSearchEngine(
            sidx, batch_size=4, k=5, page=N_DOCS, trim=None,
            engine=engine, metrics=MetricsRegistry(enabled=False))
        inst = _full_obs_engine(sidx, engine)
        try:
            for q in queries:
                bi, bs = bare.search(q, timeout=60)
                ii, iscore, tree = inst.search(q, timeout=60,
                                               profile=True)
                # poll the telemetry plane mid-serve, exactly like the
                # smoke-health poller thread does
                dev = device_bytes(sidx, reconcile=False)
                assert dev["total_bytes"] > 0
                node_stats(inst)
                assert np.array_equal(bi, ii), engine
                assert np.array_equal(bs, iscore), engine
                assert tree["children"], engine
            # cost attribution: no serving compile left unattributed
            assert missing_cost_regions(inst.compile_watch) == [], engine
        finally:
            bare.close()
            inst.close()


def test_cluster_profile_routing_and_counters(sidx, queries):
    reg = MetricsRegistry()
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=N_DOCS,
                       trim=None, engine="codes", metrics=reg)
    try:
        ids, scores, tree = cl.profile(queries[0], stream="s")
        ref = cl.search(queries[0], stream="s", timeout=60)
        assert np.array_equal(ids, ref[0])
        assert np.array_equal(scores, ref[1])
        assert tree["name"] == "cluster.query"
        assert tree["attrs"]["n_groups"] == 2
        route, query = tree["children"]
        assert route["name"] == "route"
        assert route["attrs"]["up_groups"] == 2
        assert query["name"] == "query"
        assert query["attrs"]["group"] == route["attrs"]["group"]
        # profiled requests ride the same counters as plain ones
        assert reg.value("cluster.requests.submitted") == 2
        assert reg.value("cluster.requests.completed") == 2
        g = route["attrs"]["group"]
        assert reg.value("cluster.requests.group_completed", group=g) == 2
    finally:
        cl.close()


# ----------------------------------------------------------------- slow log
def test_slowlog_tail_capture_beats_head_sampling(sidx, queries):
    """With a 1/16 tracer, 6 slow requests leave at most one sampled
    trace -- but the slow log captures ALL of them, each promoted to a
    profile view."""
    reg = MetricsRegistry()
    tr = Tracer(sample=1.0 / 16)
    slog = SlowLog(threshold_s=0.0, metrics=reg)   # everything is "slow"
    eng = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes", metrics=reg,
                              tracer=tr, slowlog=slog)
    try:
        for q in queries:
            eng.search(q, timeout=60)
    finally:
        eng.close()
    assert tr.stats()["sampled"] == 1              # head sampling dropped 5
    st = slog.stats()
    assert st["seen"] == len(queries)
    assert st["captured"] == len(queries)          # tail capture got all 6
    for rec in slog.dump():
        assert rec["slowlog"]["reason"] == "slow"
        assert rec["slowlog"]["duration_s"] >= 0.0
        prof = rec["profile"]
        assert {"queue_wait", "batch_form", "dispatch"} <= {
            c["name"] for c in prof["children"]}
    assert reg.value("slowlog.captured") == len(queries)


def test_slowlog_captures_errors_below_threshold(sidx, queries):
    """A failed request is captured even when it was fast (and head
    sampling would have dropped it)."""
    slog = SlowLog(threshold_s=10.0)               # nothing is "slow"
    eng = BatchedSearchEngine(sidx, batch_size=2, k=5, page=N_DOCS,
                              trim=None, engine="codes",
                              metrics=MetricsRegistry(),
                              tracer=Tracer(sample=1.0 / 16), slowlog=slog)
    try:
        eng.search(queries[0], timeout=60)         # fast + healthy: dropped
        with pytest.raises(Exception):
            eng.search(np.ones(N_FEAT + 3, np.float32), timeout=60)
    finally:
        eng.close()
    st = slog.stats()
    assert st["seen"] == 2
    assert st["captured"] == st["errors"] == 1
    (rec,) = slog.dump()
    assert rec["slowlog"]["reason"] == "error"
    assert "error" in rec["attrs"]


def test_slowlog_ring_bound_and_jsonl_sink(tmp_path):
    path = tmp_path / "slow.jsonl"
    slog = SlowLog(threshold_s=0.0, capacity=4, path=str(path))
    for i in range(7):
        t = slog.start("query", n=i)
        t.span("work").end()
        t.finish()
    st = slog.stats()
    assert st["seen"] == st["captured"] == 7
    assert st["retained"] == 4                     # ring keeps the newest
    assert [r["attrs"]["n"] for r in slog.dump()] == [3, 4, 5, 6]
    slog.close()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 7                         # the sink keeps ALL
    assert all("profile" in l and "slowlog" in l for l in lines)
    assert slog.dump(clear=True) and slog.dump() == []
    with pytest.raises(ValueError, match="threshold"):
        SlowLog(threshold_s=-1.0)
    with pytest.raises(ValueError, match="capacity"):
        SlowLog(capacity=0)


def test_slowlog_threshold_filters_fast_requests():
    slog = SlowLog(threshold_s=10.0, metrics=MetricsRegistry())
    t = slog.start("query")
    t.finish()                                     # fast, healthy: dropped
    st = slog.stats()
    assert st["seen"] == 1 and st["captured"] == 0
    t = slog.start("query")
    t.finish(error="boom")                         # errors always kept
    st = slog.stats()
    assert st["captured"] == st["errors"] == 1 and st["slow"] == 0


# ------------------------------------------------------------ compile watch
def test_compile_watch_counts_shapes_and_steady_state():
    import jax
    import jax.numpy as jnp

    reg = MetricsRegistry()
    w = CompileWatch(metrics=reg)
    f = jax.jit(lambda x: x * 2 + 1)
    # inputs built OUTSIDE any region: their own fill compiles must not
    # be attributed to "fn"
    x3, x4, x5 = jnp.ones((3,)), jnp.ones((4,)), jnp.ones((5,))
    with w.region("fn", sig=((3,),)):
        f(x3)
    base = w.compiles_total
    assert base >= 1
    with w.region("fn", sig=((3,),)):
        f(x3)                                      # jit cache hit: silent
    assert w.compiles_total == base
    with w.region("fn", sig=((4,),)):
        f(x4)                                      # new abstract shape
    assert w.compiles_total == base + 1
    st = w.stats()
    assert st["by_function"] == {"fn": base + 1}
    assert st["signatures"] == 2 and not st["steady"]
    assert reg.value("compile.total", fn="fn") == base + 1
    assert reg.histogram("compile.duration_s", fn="fn").count == base + 1

    w.mark_steady()
    w.check()                                      # clean: no-op
    assert w.compiles_steady_state == 0
    with w.region("fn", sig=((5,),)):
        f(x5)                                      # steady-state recompile
    assert w.compiles_steady_state == 1
    (ev,) = w.stats()["steady_events"]
    assert ev["fn"] == "fn" and not ev["repeat_sig"]
    with pytest.raises(RuntimeError, match="steady-state recompile"):
        w.check()
    w.reset()
    assert w.compiles_total == 0 and not w.stats()["steady"]


def test_compile_watch_unattributed_never_steady():
    """Host-side compiles outside any region must not trip the
    steady-state guard of a serving watch."""
    import jax
    import jax.numpy as jnp

    w = CompileWatch(metrics=MetricsRegistry())
    w.mark_steady()
    jax.jit(lambda x: x - 7)(jnp.ones((3,)))       # no region on this thread
    assert w.compiles_steady_state == 0
    w.check()                                      # still clean


def test_engine_dispatch_attributed_and_steady_after_warmup(sidx, queries):
    """The engine's serving path compiles land in the injected watch,
    and a warmed engine re-serving the same shapes stays steady."""
    reg = MetricsRegistry()
    w = CompileWatch(metrics=reg)
    # batch_size/k unique to this test: the jit cache is process-wide,
    # so a shape another test already compiled would record nothing here
    eng = BatchedSearchEngine(sidx, batch_size=3, k=7, page=N_DOCS,
                              trim=None, engine="codes", metrics=reg,
                              compile_watch=w)
    try:
        eng.search(queries[0], timeout=60)         # warmup
        assert w.compiles_total >= 1
        fns = set(w.stats()["by_function"])
        assert any(f.startswith(("engine.", "search.")) for f in fns)
        w.mark_steady()
        for q in queries:
            eng.search(q, timeout=60)
        assert w.compiles_steady_state == 0
        w.check()
    finally:
        eng.close()


# --------------------------------------------------------------- exporters
def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("engine.requests.completed", group=0).inc(5)
    reg.gauge("engine.queue.depth").set(3.0)
    h = reg.histogram("engine.queue.wait_s")
    h.observe_many([0.001, 0.002, 0.004])
    text = prometheus_text(reg.snapshot())
    lines = text.splitlines()
    assert "# TYPE repro_engine_requests_completed_total counter" in lines
    assert 'repro_engine_requests_completed_total{group="0"} 5' in lines
    assert "repro_engine_queue_depth 3.0" in lines
    assert "repro_engine_queue_wait_s_count 3" in lines
    for q in ("0.50", "0.90", "0.99", "0.999"):
        assert any(f'quantile="{q}"' in l for l in lines), q
    # sum line carries the exact histogram sum
    (sum_line,) = [l for l in lines
                   if l.startswith("repro_engine_queue_wait_s_sum")]
    assert float(sum_line.split()[-1]) == pytest.approx(0.007)


def test_metrics_exporter_history_and_jsonl(tmp_path):
    path = tmp_path / "metrics.jsonl"
    reg = MetricsRegistry()
    c = reg.counter("t.ticks")
    exp = MetricsExporter(reg, path=str(path), capacity=3)
    for i in range(5):
        c.inc()
        exp.collect()
    hist = exp.history()
    assert len(hist) == 3                          # bounded ring
    ts = [h["t_monotonic"] for h in hist]
    assert ts == sorted(ts)                        # monotonic timestamps
    assert [h["metrics"]["counters"]["t.ticks"][""] for h in hist] \
        == [3, 4, 5]
    exp.stop()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 5                         # the sink keeps ALL
    assert lines[0]["metrics"]["counters"]["t.ticks"][""] == 1
    assert "repro_t_ticks_total 5" in exp.text()


def test_metrics_exporter_background_thread():
    reg = MetricsRegistry()
    exp = MetricsExporter(reg, interval_s=0.01)
    exp.start()
    deadline = time.monotonic() + 5.0
    while not exp.history() and time.monotonic() < deadline:
        time.sleep(0.005)
    exp.stop()
    assert exp.history()                           # collected on its own
    n = len(exp.history())
    time.sleep(0.05)
    assert len(exp.history()) == n                 # stopped means stopped


# --------------------------------------------------- stats-layer integration
def test_engine_stats_carry_slowlog_and_compile_sections(sidx, queries):
    # k=6 keeps this dispatch shape un-cached by earlier tests, so the
    # compile section is guaranteed non-empty
    eng = _full_obs_engine(sidx, "fused", k=6)
    try:
        for q in queries[:3]:
            eng.search(q, timeout=60)
        st = eng.stats()
        assert st["slowlog"]["seen"] == 3
        assert st["slowlog"]["captured"] == 3
        assert st["compile"]["compiles_total"] >= 1
        assert "steady_events" not in st["compile"]   # stats stay compact
        assert st["kernel_path"] == {"fused": 3}
        assert "p999" in st["dispatch_latency_s"]
    finally:
        eng.close()


def test_cluster_stats_carry_slowlog_and_compile_sections(sidx, queries):
    reg = MetricsRegistry()
    # k=4 keeps the dispatch shape un-cached (see the engine stats test)
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=4, page=N_DOCS,
                       trim=None, engine="codes", metrics=reg,
                       slowlog=SlowLog(threshold_s=0.0, metrics=reg),
                       compile_watch=CompileWatch(metrics=reg))
    try:
        for i, q in enumerate(queries):
            cl.search(q, stream=i % 2, timeout=60)
        st = cl.stats()
        assert st["slowlog"]["seen"] == len(queries)
        assert st["slowlog"]["captured"] == len(queries)
        assert st["compile"]["compiles_total"] >= 1
        assert st["compile"]["compiles_steady_state"] == 0
    finally:
        cl.close()
