"""Encoding + token tests, including the paper's exact §2.2.1 examples."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.encoding import (
    CombinedEncoder,
    IntervalEncoder,
    RoundingEncoder,
    smallest_int_dtype,
)
from repro.core.tokens import tokens_for_vector
from repro.core.filtering import BestFilter, TrimFilter

W = np.array([0.12, -0.13, 0.065])


class TestPaperExamples:
    def test_rounding_p2(self):
        assert tokens_for_vector(W, RoundingEncoder(2)) == [
            "0P2i0d12", "1P2ineg0d13", "2P2i0d07",
        ]

    def test_interval_i10(self):
        assert tokens_for_vector(W, IntervalEncoder(0.1)) == [
            "0I10i0d1", "1I10ineg0d2", "2I10i0d0",
        ]

    def test_combined_p3_i5(self):
        enc = CombinedEncoder(RoundingEncoder(3), IntervalEncoder(0.2))
        assert tokens_for_vector(W, enc) == [
            "0P3i0d120", "1P3ineg0d130", "2P3i0d065",
            "0I5i0d0", "1I5ineg0d2", "2I5i0d0",
        ]

    def test_trim_drops_third_feature(self):
        # paper: |0.065| < 0.1 so the third feature's tokens are removed
        toks = tokens_for_vector(W, RoundingEncoder(2), trim=TrimFilter(0.1))
        assert toks == ["0P2i0d12", "1P2ineg0d13"]

    def test_best_1_keeps_largest_abs(self):
        # paper: with best=1 only -0.13 is considered
        toks = tokens_for_vector(W, RoundingEncoder(2), best=BestFilter(1))
        assert toks == ["1P2ineg0d13"]


class TestCodeProperties:
    def test_rounding_examples(self):
        codes = np.asarray(RoundingEncoder(2).encode(jnp.asarray(W)))
        assert codes.tolist() == [12, -13, 7]

    def test_interval_examples(self):
        codes = np.asarray(IntervalEncoder(0.1).encode(jnp.asarray(W)))
        assert codes.tolist() == [1, -2, 0]

    def test_dtype_selection(self):
        assert RoundingEncoder(2).code_dtype == np.int8
        assert RoundingEncoder(3).code_dtype == np.int16
        assert IntervalEncoder(0.1).code_dtype == np.int8
        assert smallest_int_dtype(127) == np.int8
        assert smallest_int_dtype(128) == np.int16
        assert smallest_int_dtype(40000) == np.int32

    def test_combined_concat_layout(self):
        enc = CombinedEncoder(RoundingEncoder(2), IntervalEncoder(0.1))
        codes = np.asarray(enc.encode(jnp.asarray(W)))
        assert codes.shape == (6,)
        assert codes[:3].tolist() == [12, -13, 7]
        assert codes[3:].tolist() == [1, -2, 0]
        assert enc.column_feature(3).tolist() == [0, 1, 2, 0, 1, 2]


@settings(max_examples=50, deadline=None)
@given(st.floats(-1, 1, allow_nan=False, width=32), st.integers(1, 3))
def test_rounding_bucket_stability(x, p):
    """Two values in the same rounding cell encode to the same bucket."""
    enc = RoundingEncoder(p)
    c = int(enc.encode(jnp.float32(x)))
    # the cell center must round back to the same bucket
    assert int(enc.encode(jnp.float32(c / enc.scale))) == c
    # bucket error is at most half a cell
    assert abs(c / enc.scale - x) <= 0.5 / enc.scale + 1e-6


@settings(max_examples=50, deadline=None)
@given(
    st.floats(-1, 1, allow_nan=False, width=32),
    st.sampled_from([0.05, 0.1, 0.2, 0.25]),
)
def test_interval_bucket_contains_value(x, width):
    enc = IntervalEncoder(width)
    b = int(enc.encode(jnp.float32(x)))
    assert b * width <= x + 1e-6 and x < (b + 1) * width + 1e-6


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_encoding_monotone_in_value(seed):
    """Buckets are monotone: x <= y implies bucket(x) <= bucket(y)."""
    rng = np.random.default_rng(seed)
    xs = np.sort(rng.uniform(-1, 1, size=16).astype(np.float32))
    for enc in [RoundingEncoder(2), IntervalEncoder(0.1)]:
        codes = np.asarray(enc.encode(jnp.asarray(xs))).astype(np.int64)
        assert (np.diff(codes) >= 0).all()


def test_tokens_have_no_special_characters():
    """Paper footnote 1: no '+', '-', '.', whitespace inside tokens."""
    rng = np.random.default_rng(3)
    x = rng.uniform(-1, 1, size=20).astype(np.float32)
    for enc in [RoundingEncoder(2), IntervalEncoder(0.1), CombinedEncoder()]:
        for t in tokens_for_vector(x, enc):
            assert all(ch.isalnum() for ch in t), t
