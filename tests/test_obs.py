"""Observability plane (repro/obs): metrics pins, traces, reconciliation.

The pinned invariants:

* histogram bucket math is EXACT -- quantiles report the upper bound of
  the bucket holding the rank-``max(1, ceil(q*n))`` sample, where the
  bucket mapping is ``Histogram.bucket_le`` (so tests compute expected
  quantiles independently, no tolerance);
* instrumentation is invisible to the data plane -- results served with
  metrics + full tracing enabled are bit-identical to an uninstrumented
  engine (all host-side timestamps, nothing inside jitted programs);
* counters reconcile exactly through a full cluster lifecycle (ingest,
  injected failure + failover, readmit, background compaction, restore
  from disk): queries issued == cluster completed == sum of per-group
  completions, and ONE injected failure == ONE down transition;
* traces are complete for the interesting paths -- a spilled query
  carries its spill event and serving group, a failed-over query carries
  group_down + failover_resubmit plus dispatch spans from BOTH groups;
* totals stay exact under concurrent submitters (the registry's lock
  discipline is not best-effort).
"""

import math
import threading

import numpy as np
import pytest

from repro.cluster import ClusterEngine
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.obs import (Histogram, MetricsRegistry, SlowLog, Tracer,
                       NULL_TRACE, format_stats_line)
from repro.serve.engine import BatchedSearchEngine
from repro.store.durable import Store

N_DOCS, N_FEAT = 60, 16


@pytest.fixture(scope="module")
def sidx():
    rng = np.random.default_rng(0)
    return ShardedVectorIndex.build_sharded(
        rng.normal(size=(N_DOCS, N_FEAT)).astype(np.float32),
        make_shard_mesh(1))


@pytest.fixture()
def queries():
    return np.random.default_rng(1).normal(
        size=(9, N_FEAT)).astype(np.float32)


class _Gated:
    """Group index that parks every search until released (deterministic
    in-flight state -- same helper as tests/test_cluster.py)."""

    def __init__(self, inner):
        self.inner = inner
        self.entered = threading.Event()
        self.release = threading.Event()

    def search(self, q, **kw):
        self.entered.set()
        assert self.release.wait(timeout=60), "gate never released"
        return self.inner.search(q, **kw)

    def __getattr__(self, name):
        return getattr(self.inner, name)


# ------------------------------------------------------------- histograms
def test_histogram_bucket_pins():
    """Quantiles == bucket_le of the rank-selected sample, computed
    independently from the documented rank rule -- no tolerances."""
    reg = MetricsRegistry()
    h = reg.histogram("t.lat")
    samples = [1.5e-6, 3.0e-6, 1.0e-3, 0.25, 2.0]
    for s in samples:
        h.observe(s)
    assert h.count == len(samples)
    assert h.sum == pytest.approx(sum(samples))
    snap = h.snapshot()
    assert snap["min"] == min(samples) and snap["max"] == max(samples)
    ordered = sorted(samples)
    for q in (0.0, 0.5, 0.9, 0.99, 1.0):
        rank = max(1, math.ceil(q * len(samples)))
        assert h.quantile(q) == Histogram.bucket_le(ordered[rank - 1]), q
    # a sample is never reported smaller than it was (le semantics)
    for s in samples:
        assert Histogram.bucket_le(s) >= s


def test_histogram_edge_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("t.edge")
    assert math.isnan(h.quantile(0.5))            # empty
    assert h.snapshot()["p50"] is None
    h.observe(0.0)                                # below the first bound
    assert h.quantile(0.0) == Histogram.bucket_le(0.0) == 1e-6
    h.observe(500.0)                              # past the last bound
    assert Histogram.bucket_le(500.0) == math.inf
    assert h.quantile(1.0) == math.inf
    assert h.snapshot()["max"] == 500.0           # min/max stay exact
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)


def test_histogram_single_observation_and_p999():
    """Every quantile of a one-sample histogram collapses to that
    sample's bucket bound -- exact, no tolerance -- and p999 (the tail
    the slow-log threshold keys off) rides every snapshot."""
    reg = MetricsRegistry()
    h = reg.histogram("t.one")
    for q in (0.0, 0.5, 1.0):
        assert math.isnan(h.quantile(q))          # empty: NaN everywhere
    snap = h.snapshot()
    assert snap["count"] == 0
    assert snap["p50"] is snap["p999"] is None
    h.observe(0.0123)
    b = Histogram.bucket_le(0.0123)
    for q in (0.0, 0.25, 0.5, 0.999, 1.0):
        assert h.quantile(q) == b
    snap = h.snapshot()
    assert snap["p50"] == snap["p90"] == snap["p99"] == snap["p999"] == b
    assert snap["min"] == snap["max"] == snap["mean"] == 0.0123
    assert snap["count"] == 1 and snap["sum"] == 0.0123


def test_observe_many_matches_observe():
    reg = MetricsRegistry()
    a, b = reg.histogram("t.a"), reg.histogram("t.b")
    xs = list(np.random.default_rng(2).exponential(0.01, size=40))
    for x in xs:
        a.observe(x)
    b.observe_many(xs)
    b.observe_many([])                            # no-op, not an error
    assert a.snapshot() == b.snapshot()


def test_disabled_registry_records_nothing():
    reg = MetricsRegistry(enabled=False)
    c, g, h = reg.counter("t.c"), reg.gauge("t.g"), reg.histogram("t.h")
    c.inc()
    g.set(3.0)
    h.observe(0.5)
    h.observe_many([0.1, 0.2])
    assert c.value == 0 and g.value == 0.0 and h.count == 0
    reg.enabled = True                            # flips ON without rewiring
    c.inc()
    assert c.value == 1


def test_registry_series_and_totals():
    reg = MetricsRegistry()
    reg.counter("t.done", group=0).inc(3)
    reg.counter("t.done", group=1).inc(4)
    assert reg.counter("t.done", group=0) is reg.counter("t.done", group=0)
    assert reg.value("t.done", group=0) == 3
    assert reg.value("t.done", group=2, default=0) == 0   # never created
    assert reg.total("t.done") == 7
    assert reg.total("t.missing", default=-1) == -1
    snap = reg.snapshot()
    assert snap["counters"]["t.done"] == {"group=0": 3, "group=1": 4}


# ---------------------------------------------------------------- tracing
def test_tracer_sampling_deterministic():
    tr = Tracer(sample=0.25)
    kept = [bool(tr.start("q")) for _ in range(8)]
    assert kept == [True, False, False, False, True, False, False, False]
    st = tr.stats()
    assert st["seen"] == 8 and st["sampled"] == 2
    assert not NULL_TRACE                          # falsy, methods no-op
    assert NULL_TRACE.span("x").end() is NULL_TRACE
    with pytest.raises(ValueError, match="sample"):
        Tracer(sample=0.0)
    with pytest.raises(ValueError, match="capacity"):
        Tracer(capacity=0)


def test_trace_ring_retention():
    tr = Tracer(capacity=2, sample=1.0)
    for i in range(5):
        t = tr.start("q")
        t.span("work").end()
        t.finish()
        t.finish()                                 # idempotent
    dump = tr.dump()
    assert [d["trace_id"] for d in dump] == [4, 5]  # oldest first, capped
    assert tr.dump(clear=True) and tr.dump() == []


# ----------------------------------------------- instrumented single engine
def test_instrumented_results_bit_identical(sidx, queries):
    """Bit-parity with instrumentation enabled: the acceptance pin that
    metrics + full tracing never touch the jitted data plane."""
    bare = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                               trim=None, engine="codes",
                               metrics=MetricsRegistry(enabled=False))
    reg = MetricsRegistry()
    inst = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                               trim=None, engine="codes", metrics=reg,
                               tracer=Tracer(sample=1.0))
    try:
        for q in queries:
            bi, bs = bare.search(q, timeout=60)
            ii, iscore = inst.search(q, timeout=60)
            assert np.array_equal(bi, ii)
            assert np.array_equal(bs, iscore)
        n = len(queries)
        assert reg.value("engine.requests.submitted") == n
        assert reg.value("engine.requests.completed") == n
        assert reg.value("engine.requests.failed") == 0
        assert reg.histogram("engine.queue.wait_s").count == n
        st = inst.stats()
        assert st["requests"] == {"submitted": n, "completed": n,
                                  "failed": 0}
        assert st["index"]["n_ids"] == N_DOCS
        assert st["dispatch_latency_s"]["count"] >= 1
        line = format_stats_line(st)
        assert f"done={n}/{n}" in line and "failed=0" in line
    finally:
        bare.close()
        inst.close()


def test_trace_spans_complete_for_plain_query(sidx, queries):
    tr = Tracer(sample=1.0)
    eng = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes",
                              metrics=MetricsRegistry(), tracer=tr)
    try:
        eng.search(queries[0], timeout=60)
    finally:
        eng.close()
    (trace,) = tr.dump()
    assert trace["t1"] is not None and "error" not in trace["attrs"]
    spans = {s["name"]: s for s in trace["spans"]}
    assert {"queue_wait", "batch_form", "dispatch"} <= set(spans)
    # contiguous phases from shared clock reads: wait ends where batch
    # formation starts, which ends where dispatch starts
    assert spans["queue_wait"]["t1"] == spans["batch_form"]["t0"]
    assert spans["batch_form"]["t1"] == spans["dispatch"]["t0"]
    for s in spans.values():
        assert s["duration_s"] >= 0.0


# -------------------------------------------------------- cluster tracing
def test_trace_records_spill_event(sidx, queries):
    """A spilled query's trace names both groups: the spill event (from
    the pinned group) and dispatch spans on the group that served it."""
    gated = _Gated(sidx)
    reg = MetricsRegistry()
    tr = Tracer(sample=1.0)
    cl = ClusterEngine([gated, sidx], batch_size=1, k=5, page=N_DOCS,
                       trim=None, engine="codes", spill_factor=2.0,
                       metrics=reg, tracer=tr)
    try:
        futs = [cl.submit(queries[0], stream="s")]     # pin to group 0
        assert gated.entered.wait(timeout=60)
        futs += [cl.submit(q, stream="s") for q in queries[1:3]]
        spilled = cl.submit(queries[3], stream="s")    # over the threshold
        spilled.result(timeout=60)
        assert reg.value("cluster.routing.spills") == 1
        # only the spilled query has finished, so it is the whole dump
        (trace,) = tr.dump()
        events = [(e["name"], e["attrs"]) for s in trace["spans"]
                  for e in s["events"]]
        assert ("spill", {"from_group": 0, "to_group": 1}) in events
        dispatch = [s for s in trace["spans"] if s["name"] == "dispatch"]
        assert [s["attrs"]["group"] for s in dispatch] == [1]
        gated.release.set()
        for f in futs:
            f.result(timeout=60)
    finally:
        gated.release.set()
        cl.close()


def test_trace_records_failover_resubmit(sidx, queries):
    """A failed-over query's ONE trace tells the whole story: a dispatch
    span with the error on the poisoned group, group_down +
    failover_resubmit events, then clean spans from the surviving copy."""
    reg = MetricsRegistry()
    tr = Tracer(sample=1.0)
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=N_DOCS,
                       trim=None, engine="codes", metrics=reg, tracer=tr)
    try:
        cl.search(queries[0], stream="s", timeout=60)  # pin to group 0
        cl.inject_failure(0)
        cl.search(queries[1], stream="s", timeout=60)  # fails over
        assert reg.value("cluster.failover.resubmits") == 1
        assert reg.total("health.down_transitions") == 1
        trace = tr.dump()[-1]
        assert trace["t1"] is not None and "error" not in trace["attrs"]
        events = {e["name"] for s in trace["spans"] for e in s["events"]}
        assert {"group_down", "failover_resubmit"} <= events
        dispatch = [s for s in trace["spans"] if s["name"] == "dispatch"]
        assert sorted(s["attrs"]["group"] for s in dispatch) == [0, 1]
        by_group = {s["attrs"]["group"]: s for s in dispatch}
        assert "error" in by_group[0]["attrs"]
        assert "error" not in by_group[1]["attrs"]
        cl.heal(0)
        assert cl.health.readmit(0)
        assert reg.total("health.readmits") == 1
    finally:
        cl.close()


# -------------------------------------------------- lifecycle reconciliation
def test_lifecycle_stats_reconcile_exactly(sidx, queries, tmp_path):
    """THE reconciliation pin, through a full lifecycle -- serve, hot
    ingest, injected failure + failover, readmit, background compaction
    (with durability commits), restore-from-disk -- every query issued is
    counted exactly once at cluster level and exactly once in some
    group's completions; one injected failure is one down transition."""
    import time

    rng = np.random.default_rng(7)
    W = rng.normal(size=(12, N_FEAT)).astype(np.float32)
    reg = MetricsRegistry()
    tr = Tracer(sample=1.0)
    store = Store(str(tmp_path))
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=10_000,
                       trim=None, engine="codes", metrics=reg, tracer=tr,
                       store=store, auto_compact=0.2,
                       compact_interval_s=0.01)
    n_issued = 0
    try:
        assert store.metrics is reg                # one registry everywhere
        for i, q in enumerate(queries[:4]):        # healthy serving
            cl.search(q, stream=i % 2, timeout=60)
            n_issued += 1

        first = cl.add_documents(W)                # hot ingest, all groups
        assert first == N_DOCS
        assert store.seqno == 1                    # one logged op so far

        cl.search(W[0], stream=0, timeout=60)      # stream 0's group fails
        n_issued += 1
        cl.inject_failure(0)
        cl.search(W[1], stream=None, timeout=60)   # may route anywhere
        n_issued += 1
        cl.search(queries[4], stream=0, timeout=60)
        n_issued += 1
        cl.heal(0)
        assert cl.health.readmit(0)

        victims = list(range(0, 14)) + [N_DOCS + 1]
        cl.delete(victims)                         # past the 0.2 threshold
        assert store.seqno == 2
        deadline = time.monotonic() + 60
        while cl.maintenance.compactions < 2:      # background compaction
            assert time.monotonic() < deadline, "daemon never compacted"
            cl.search(queries[5], stream=1, timeout=60)
            n_issued += 1

        seq = cl.restore_group(1)                  # re-admit from disk
        assert seq == 2
        a = cl.search(W[2], stream=0, timeout=60)
        b = cl.search(W[2], stream=1, timeout=60)  # restored copy serves
        n_issued += 2
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])

        st = cl.stats()
        req = st["requests"]
        assert req["submitted"] == n_issued
        assert req["completed"] == n_issued
        assert req["failed"] == 0
        assert sum(req["group_completed"].values()) == n_issued
        assert st["health"]["down_transitions"] == 1   # ONE injected fault
        assert st["health"]["readmits"] == 1
        assert st["routing"]["failover_resubmits"] >= 1
        assert all(g["health"] == "up" for g in st["groups"].values())
        # per-group engine counters cover the cluster total (resubmits
        # mean group-level submits can exceed it, never undercount)
        assert sum(g["requests"]["completed"]
                   for g in st["groups"].values()) >= n_issued
        assert st["maintenance"]["compactions"] >= 2
        assert st["store"]["recoveries"] == 1
        assert st["store"]["commits"] >= 2         # baseline + maintenance
        assert st["store"]["translog"]["seqno"] == 2
        assert "groups=2/2up" in format_stats_line(st)
        # trace completeness: every issued query left ONE finished trace
        ts = tr.stats()
        assert ts["seen"] == ts["sampled"] == n_issued
        assert all(d["t1"] is not None for d in tr.dump())
    finally:
        cl.close()
        store.close()


# ------------------------------------------------------------- concurrency
def test_concurrent_submitters_exact_totals(sidx):
    """Counter/histogram/tracer totals are exact -- not approximate --
    under concurrent submitters."""
    n_threads, per_thread = 4, 12
    total = n_threads * per_thread
    rng = np.random.default_rng(3)
    Q = rng.normal(size=(total, N_FEAT)).astype(np.float32)
    reg = MetricsRegistry()
    tr = Tracer(capacity=total, sample=1.0)
    eng = BatchedSearchEngine(sidx, batch_size=8, k=5, page=N_DOCS,
                              trim=None, engine="codes", metrics=reg,
                              tracer=tr)
    errors = []

    def drive(t):
        try:
            for i in range(per_thread):
                ids, _ = eng.search(Q[t * per_thread + i], timeout=60)
                assert ids.shape == (5,)
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append(exc)

    try:
        threads = [threading.Thread(target=drive, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors
        assert reg.value("engine.requests.submitted") == total
        assert reg.value("engine.requests.completed") == total
        assert reg.value("engine.requests.failed") == 0
        assert reg.histogram("engine.queue.wait_s").count == total
        ts = tr.stats()
        assert ts["seen"] == ts["sampled"] == ts["retained"] == total
        assert all(d["t1"] is not None for d in tr.dump())
    finally:
        eng.close()


def test_tracer_dump_clear_races_retain():
    """``dump(clear=True)`` racing concurrent ``finish()`` calls loses
    no trace and doubles none: every retained trace appears in exactly
    one dump, and no dump ever exceeds the ring capacity."""
    n_threads, per_thread = 4, 200
    total = n_threads * per_thread
    tr = Tracer(capacity=total, sample=1.0)   # capacity == total: a lost
    #                                           trace can't hide behind
    #                                           ring eviction
    stop = threading.Event()
    collected, coll_lock = [], threading.Lock()
    errors = []

    def dumper():
        try:
            while not stop.is_set():
                out = tr.dump(clear=True)
                assert len(out) <= total
                with coll_lock:
                    collected.extend(out)
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append(exc)

    def producer():
        try:
            for _ in range(per_thread):
                t = tr.start("q")
                t.span("work").end()
                t.finish()
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append(exc)

    dump_thread = threading.Thread(target=dumper)
    producers = [threading.Thread(target=producer)
                 for _ in range(n_threads)]
    dump_thread.start()
    for th in producers:
        th.start()
    for th in producers:
        th.join()
    stop.set()
    dump_thread.join()
    collected.extend(tr.dump(clear=True))
    assert not errors
    ids = sorted(d["trace_id"] for d in collected)
    assert ids == list(range(1, total + 1))   # none lost, none doubled
    assert tr.stats()["retained"] == 0


# ---------------------------------------------------------- kernel-path mix
def test_kernel_mix_in_stats_and_cat_line(sidx, queries):
    """The ``kernel_path`` dispatch mix: two engines sharing one fleet
    registry roll up into one fused/composed split, rendered
    deterministically in the ``_cat`` line -- and the cluster branch
    sums its groups' mixes the same way."""
    reg = MetricsRegistry()
    fused = BatchedSearchEngine(sidx, batch_size=2, k=5, page=N_DOCS,
                                trim=None, engine="fused", metrics=reg)
    comp = BatchedSearchEngine(sidx, batch_size=2, k=5, page=N_DOCS,
                               trim=None, engine="codes", metrics=reg)
    try:
        for q in queries[:4]:
            fused.search(q, timeout=60)
        for q in queries[:2]:
            comp.search(q, timeout=60)
        st = fused.stats()
        # one dispatch per (sequential) search; shared registry -> the
        # stats of either engine show the whole fleet's mix
        assert st["kernel_path"] == {"codes": 2, "fused": 4}
        assert "kernel=codes:2/fused:4" in format_stats_line(st)
    finally:
        fused.close()
        comp.close()

    creg = MetricsRegistry()
    cl = ClusterEngine([sidx, sidx], batch_size=2, k=5, page=N_DOCS,
                       trim=None, engine="codes", metrics=creg)
    try:
        for i, q in enumerate(queries[:3]):
            cl.search(q, stream=i % 2, timeout=60)
        st = cl.stats()
        assert sum(g["kernel_path"].get("codes", 0)
                   for g in st["groups"].values()) == 3
        assert "kernel=codes:3" in format_stats_line(st)
    finally:
        cl.close()


# ----------------------------------- concurrent reconciliation, full plane
def test_stats_reconcile_concurrent_with_profiling_and_slowlog(sidx,
                                                               queries):
    """The PR-6 reconciliation contract survives the v2 plane running
    flat out: concurrent searchers (a quarter of them via the _profile
    API) race hot ingest, deletes, and the background compaction daemon
    -- with head-sampled tracing AND a threshold-0 slow log attached.
    Submitted == completed == issued, group completions tile the total,
    and the slow log captures exactly the submit-path population."""
    import time

    rng = np.random.default_rng(11)
    W = rng.normal(size=(16, N_FEAT)).astype(np.float32)
    reg = MetricsRegistry()
    tr = Tracer(capacity=1024, sample=1.0 / 4)
    slog = SlowLog(threshold_s=0.0, capacity=1024, metrics=reg)
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=10_000,
                       trim=None, engine="codes", metrics=reg, tracer=tr,
                       slowlog=slog, auto_compact=0.2,
                       compact_interval_s=0.01)
    counts, errors = [], []

    def drive(t):
        plain = prof = 0
        try:
            for i in range(12):
                q = queries[(t + i) % len(queries)]
                if i % 4 == 0:     # every 4th request asks for a profile
                    ids, _, tree = cl.profile(q, stream=t % 2, timeout=60)
                    assert tree["name"] == "cluster.query"
                    assert [c["name"] for c in tree["children"]] \
                        == ["route", "query"]
                    prof += 1
                else:
                    ids, _ = cl.search(q, stream=t % 2, timeout=60)
                    plain += 1
                assert ids.shape == (5,)
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append(exc)
        finally:
            counts.append((plain, prof))

    try:
        threads = [threading.Thread(target=drive, args=(t,))
                   for t in range(4)]
        for th in threads:
            th.start()
        # race the searchers with ingest, then enough deletes to trip
        # the 0.2 tombstone threshold and wake the compaction daemon
        cl.add_documents(W)
        cl.delete(list(range(15)) + [N_DOCS + 1])
        for th in threads:
            th.join()
        assert not errors
        extra = 0
        deadline = time.monotonic() + 60
        while cl.maintenance.compactions < 1:     # background merge ran
            assert time.monotonic() < deadline, "daemon never compacted"
            cl.search(queries[0], stream=0, timeout=60)
            extra += 1
        n_plain = sum(p for p, _ in counts) + extra
        n_prof = sum(pr for _, pr in counts)
        n_issued = n_plain + n_prof
        # trace finish runs in a future done-callback that can trail the
        # caller's wake-up by an instant -- settle before reconciling
        deadline = time.monotonic() + 10
        while (reg.value("slowlog.captured") < n_plain
               and time.monotonic() < deadline):
            time.sleep(0.005)
        st = cl.stats()
        req = st["requests"]
        assert req["submitted"] == req["completed"] == n_issued
        assert req["failed"] == 0
        assert sum(req["group_completed"].values()) == n_issued
        assert st["maintenance"]["compactions"] >= 1
        # profile() bypasses submit-path admission, so the slow log's
        # population is exactly the plain searches -- and at threshold 0
        # tail capture means captured == seen, even mid-contention
        assert st["slowlog"]["seen"] == n_plain
        assert st["slowlog"]["captured"] == n_plain
        assert tr.stats()["seen"] == n_plain
        assert all(d["t1"] is not None for d in tr.dump())
    finally:
        cl.close()
