"""Test bootstrap: fall back to the vendored deterministic hypothesis shim
(tests/_stubs/) when the real package is absent -- the container has no
network, and property tests degrade gracefully to seeded random sampling."""

import os
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))
