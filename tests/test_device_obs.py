"""Observability v3 (repro/obs): device byte accounting, compile-time
cost attribution, ES _cluster/health, diagnostics bundles, exposition
hardening, the host-seam lint, and the perf-regression gate.

The pinned invariants:

* **byte accounting is exact** -- ``device_bytes()`` totals equal the
  sum of unique leaf ``nbytes`` (shape x dtype, never measured) for
  flat, sharded, segmented and quantized indexes; aliased leaves count
  once; totals SHRINK after ``compact()``; on a replicated mesh the
  per-device attribution exceeds the logical total by exactly the
  replication factor;
* **no unattributed serving compiles** -- every region the compile
  watch saw compile has a cost-analysis row (FLOPs / bytes accessed /
  peak temp) captured at compile time, and the fused kernel's live
  HBM-byte ratio vs the composed pipeline stays under the committed
  ``BENCH_kernel_scale`` claim;
* **health reconciles** -- ``cluster_health()`` walks green -> yellow
  -> red -> green exactly as failures are injected, and its transition
  ledger matches the health counters one-for-one;
* **the bundle is complete** -- ``diagnostics_bundle()`` contains every
  documented section and survives a JSON round trip;
* **exposition always parses** -- metric names are sanitized, label
  values escaped, comma-bearing label identities kept lossless;
* **the lint lints** -- ``tools/check_host_seams.py`` passes the repo
  and fails a seeded host call inside a jitted body;
* **the gate gates** -- ``benchmarks.check`` flags a halved headline,
  a busted overhead bar, and an inverted kernel-byte claim, and SKIPs
  (never silently passes) single-run artifacts.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.cluster import ClusterEngine
from repro.core import VectorIndex
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.obs import (BUNDLE_SECTIONS, CompileWatch, MetricsRegistry,
                       cluster_health, device_bytes, device_gauges,
                       diagnostics_bundle, format_device_line,
                       format_health_line, health_gauges, kernel_byte_ratio,
                       missing_cost_regions, node_stats, prometheus_text,
                       resident_leaf_entries, roofline, verify_kernel_claim,
                       write_diagnostics)
from repro.serve.engine import BatchedSearchEngine

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_DOCS, N_FEAT = 60, 16


@pytest.fixture(scope="module")
def sidx():
    """Sharded index with an appended generation and tombstones, so the
    accounting sees the full segment lifecycle."""
    rng = np.random.default_rng(0)
    idx = ShardedVectorIndex.build_sharded(
        rng.normal(size=(N_DOCS, N_FEAT)).astype(np.float32),
        make_shard_mesh(1), seal_threshold=16)
    idx = idx.add_documents(
        rng.normal(size=(24, N_FEAT)).astype(np.float32))
    return idx.delete(np.array([3, N_DOCS + 2]))


@pytest.fixture()
def queries():
    return np.random.default_rng(1).normal(
        size=(6, N_FEAT)).astype(np.float32)


def _leaf_total(index) -> int:
    """Reference total: sum of unique leaf nbytes, straight off the
    leaf iterator the accounting itself consumes."""
    seen = {}
    for _path, _section, arr in resident_leaf_entries(index):
        if arr is not None and hasattr(arr, "nbytes"):
            seen[id(arr)] = arr
    return sum(int(a.nbytes) for a in seen.values())


# ------------------------------------------------------------ byte totals
def test_device_bytes_flat_index():
    idx = VectorIndex.build(np.random.default_rng(2).normal(
        size=(N_DOCS, N_FEAT)).astype(np.float32))
    dev = device_bytes(idx)
    assert dev["total_bytes"] == _leaf_total(idx) > 0
    assert dev["total_bytes"] == sum(l["nbytes"] for l in dev["leaves"])
    assert dev["total_bytes"] == sum(dev["sections"].values())
    assert dev["n_leaves"] == len(dev["leaves"])
    line = format_device_line(dev)
    assert "device_bytes total=" in line and "leaves=" in line


def test_device_bytes_sharded_segmented(sidx):
    dev = device_bytes(sidx)
    assert dev["total_bytes"] == _leaf_total(sidx) > 0
    assert dev["total_bytes"] == sum(l["nbytes"] for l in dev["leaves"])
    # the module fixture sealed one generation: base AND segments present
    assert dev["sections"]["base"] > 0
    assert dev["sections"]["segments"] > 0
    for leaf in dev["leaves"]:       # drained active buffers may be empty
        assert leaf["nbytes"] >= 0 and leaf["dtype"] != "?", leaf
    # every accounted leaf is a live device array (reconciliation)
    rec = dev["reconciliation"]
    assert rec["live_leaves"] == dev["n_leaves"]
    assert rec["accounted_bytes"] == dev["total_bytes"]
    assert rec["process_live_bytes"] >= dev["total_bytes"]


def test_device_bytes_quant_tables_counted(sidx, queries):
    before = device_bytes(sidx, reconcile=False)
    assert "quant" not in before["sections"]
    # int8 scoring lazily derives the quant tables; the ledger must see
    # them even though they are not pytree children
    sidx.search(queries, k=5, page=N_DOCS, engine="fused_int8")
    after = device_bytes(sidx, reconcile=False)
    assert after["sections"].get("quant", 0) > 0
    grown = after["total_bytes"] - before["total_bytes"]
    assert grown == after["sections"]["quant"]
    assert after["total_bytes"] == _leaf_total(sidx)


def test_device_bytes_shrink_after_compact():
    rng = np.random.default_rng(3)
    idx = ShardedVectorIndex.build_sharded(
        rng.normal(size=(64, N_FEAT)).astype(np.float32),
        make_shard_mesh(1), seal_threshold=16)
    idx = idx.add_documents(rng.normal(size=(32, N_FEAT)).astype(np.float32))
    idx = idx.delete(np.arange(40))
    before = device_bytes(idx, reconcile=False)["total_bytes"]
    compacted = idx.compact()
    after = device_bytes(compacted, reconcile=False)["total_bytes"]
    assert after < before, (after, before)
    assert after == _leaf_total(compacted)


def test_device_bytes_replicated_mesh_per_device():
    """On a 4 shard x 2 replica mesh every leaf is resident on 8 devices
    with 2x physical replication: per-device attribution must sum to
    exactly twice the logical total."""
    _run_subprocess(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.obs import device_bytes, resident_leaf_entries

rng = np.random.default_rng(0)
idx = ShardedVectorIndex.build_sharded(
    rng.normal(size=(64, 16)).astype(np.float32), make_shard_mesh(4, 2))
dev = device_bytes(idx)
seen = {}
for _p, _s, arr in resident_leaf_entries(idx):
    if arr is not None and hasattr(arr, "nbytes"):
        seen[id(arr)] = arr
want = sum(int(a.nbytes) for a in seen.values())
assert dev["total_bytes"] == want, (dev["total_bytes"], want)
assert len(dev["per_device"]) == 8, dev["per_device"]
resident = sum(dev["per_device"].values())
assert resident == 2 * dev["total_bytes"], (resident, dev["total_bytes"])
assert dev["reconciliation"]["device_resident_bytes"] == resident
print("OK")
""")


def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


# ------------------------------------------------------- cost attribution
def test_cost_rows_cover_every_compiled_region(queries):
    """Fresh shapes force real compiles; afterwards every region the
    watch counted a compile for must hold a cost-analysis row -- no
    unattributed serving compiles."""
    rng = np.random.default_rng(4)
    idx = ShardedVectorIndex.build_sharded(
        rng.normal(size=(52, 12)).astype(np.float32), make_shard_mesh(1),
        seal_threshold=64)
    reg = MetricsRegistry()
    watch = CompileWatch(metrics=reg)
    q = queries[:, :12].astype(np.float32)
    for engine in ("codes", "fused"):
        eng = BatchedSearchEngine(idx, batch_size=4, k=5, page=52,
                                  trim=None, engine=engine, metrics=reg,
                                  compile_watch=watch)
        try:
            for v in q:
                eng.search(v, timeout=60)
        finally:
            eng.close()
    assert watch.compiles_total > 0
    assert missing_cost_regions(watch) == []
    stats = watch.costs.stats()
    assert stats["n_rows"] > 0
    for region, agg in stats["by_region"].items():
        assert agg["compiles"] >= 1, region
        assert agg["bytes_accessed"] >= 0, region
    # the live fused kernel must move fewer phase-1 bytes than the
    # composed pipeline, within the committed claim's slack
    ratio = kernel_byte_ratio(watch)
    assert ratio is not None and 0 < ratio["ratio"] < 1.0, ratio
    claim = verify_kernel_claim(
        watch, os.path.join(_REPO, "artifacts", "BENCH_kernel_scale.json"))
    assert claim["live"]["ratio"] < 1.0 and claim["claimed_ratio"], claim
    # a measured phase latency joins into an achieved-GB/s roofline row
    rows = roofline(watch, {"search.query_phase": 1e-3})
    by_region = {r["region"]: r for r in rows}
    assert by_region["search.query_phase"]["achieved_gbps"] > 0


# ----------------------------------------------------------- cluster health
def test_cluster_health_transitions_reconcile(sidx, queries):
    reg = MetricsRegistry()
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=N_DOCS,
                       trim=None, engine="codes", metrics=reg)
    try:
        h = cl.cluster_health()
        assert h["status"] == "green"
        assert h["up_groups"] == h["n_groups"] == 2
        assert h["transitions"] == [] and h["pending_requests"] == 0
        assert "2/2up" in format_health_line(h)

        cl.mark_down(0)
        h = cl.cluster_health()
        assert h["status"] == "yellow" and list(h["down"]) == [0]
        cl.mark_down(1)
        h = cl.cluster_health()
        assert h["status"] == "red" and h["up_groups"] == 0

        cl.mark_up(0)
        cl.mark_up(1)
        h = cl.cluster_health()
        assert h["status"] == "green"
        # ledger vs counters: one-for-one
        events = [e["event"] for e in h["transitions"]]
        assert events.count("down") == 2
        assert events.count("up") == 2
        assert h["counters"]["down_transitions"] == 2
        assert h["counters"]["mark_ups"] == 2
        # every entry carries the generation that produced it, ordered
        gens = [e["generation"] for e in h["transitions"]]
        assert gens == sorted(gens)
        assert gens[-1] == h["generation"]
        # and the cluster still serves after the walk
        futs = [cl.submit(v, stream=i) for i, v in enumerate(queries)]
        assert all(f.result(timeout=60) for f in futs)
    finally:
        cl.close()


def test_node_stats_covers_every_device(sidx):
    import jax

    eng = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes")
    try:
        ns = node_stats(eng)
        assert ns["n_devices"] == len(jax.devices())
        assert set(ns["nodes"]) == {str(d) for d in jax.devices()}
        assert ns["total_index_bytes"] == \
            device_bytes(sidx, reconcile=False)["total_bytes"]
        assert ns["device_resident_bytes"] == \
            sum(n["index_bytes"] for n in ns["nodes"].values())
        for node in ns["nodes"].values():
            assert node["platform"] == jax.devices()[0].platform
    finally:
        eng.close()


# ------------------------------------------------------- diagnostics bundle
def test_diagnostics_bundle_sections_roundtrip(sidx, queries, tmp_path):
    from repro.obs import MetricsExporter, SlowLog, Tracer

    reg = MetricsRegistry()
    eng = BatchedSearchEngine(sidx, batch_size=4, k=5, page=N_DOCS,
                              trim=None, engine="codes", metrics=reg,
                              tracer=Tracer(sample=1.0),
                              slowlog=SlowLog(threshold_s=0.0, metrics=reg),
                              compile_watch=CompileWatch(metrics=reg))
    exporter = MetricsExporter(reg)
    try:
        for v in queries:
            eng.search(v, timeout=60)
        exporter.collect()
        bundle = diagnostics_bundle(eng, exporter=exporter, reason="test")
        assert set(BUNDLE_SECTIONS) <= set(bundle)
        assert bundle["meta"]["reason"] == "test"
        assert bundle["stats"]["requests"]["completed"] == len(queries)
        assert bundle["device"]["0"]["total_bytes"] > 0
        assert bundle["slowlog"]["stats"]["captured"] == len(queries)
        assert bundle["metrics_history"], "exporter history missing"
        path = write_diagnostics(eng, str(tmp_path), exporter=exporter,
                                 reason="unit test!")
        assert os.path.dirname(path) == str(tmp_path)
        with open(path) as f:
            loaded = json.load(f)          # survives a JSON round trip
        assert set(BUNDLE_SECTIONS) <= set(loaded)
        assert loaded["meta"]["reason"] == "unit test!"
    finally:
        eng.close()


def test_diagnostics_bundle_cluster_and_unwired_sections(sidx):
    """A bare cluster engine: every section key still present (None or
    empty where the plane is unwired), device table keyed per group."""
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=N_DOCS,
                       trim=None, engine="codes")
    try:
        bundle = diagnostics_bundle(cl)
        assert set(BUNDLE_SECTIONS) <= set(bundle)
        assert set(bundle["device"]) == {"0", "1"}
        assert bundle["health"]["status"] == "green"
        json.dumps(bundle)                  # no unserializable leaves
    finally:
        cl.close()


# ------------------------------------------------------ exposition hardening
def test_prometheus_name_and_label_sanitization():
    text = prometheus_text({
        "counters": {"weird-metric.9x total": {"q=hi": 3}},
        "gauges": {"9lead": {"bad-key!=v": 1.5}},
    })
    assert "repro_weird_metric_9x_total_total" in text
    assert "repro__9lead" in text
    assert 'bad_key_="v"' in text
    for line in text.splitlines():
        if line.startswith("#"):
            continue
        name = line.split("{")[0].split(" ")[0]
        assert all(c.isalnum() or c in "_:" for c in name), line


def test_prometheus_label_value_escaping_roundtrip():
    reg = MetricsRegistry()
    reg.counter("hits", path='a\\b"c\nd').inc(2)
    text = prometheus_text(reg.snapshot())
    line = [l for l in text.splitlines()
            if l.startswith("repro_hits_total{")][0]
    assert '\\\\' in line and '\\"' in line and "\\n" in line
    assert "\n" not in line                 # the raw newline never leaks
    assert line.endswith(" 2")


def test_prometheus_comma_in_label_value_lossless():
    text = prometheus_text(
        {"gauges": {"g": {"device=TFRT_CPU_0,TFRT_CPU_1": 7}}})
    assert 'device="TFRT_CPU_0,TFRT_CPU_1"' in text


def test_health_and_device_gauges(sidx):
    reg = MetricsRegistry()
    cl = ClusterEngine([sidx, sidx], batch_size=4, k=5, page=N_DOCS,
                       trim=None, engine="codes")
    try:
        health_gauges(reg, cl.cluster_health())
        assert reg.value("cluster.health.status") == 0       # green
        assert reg.value("cluster.health.up_groups") == 2
        dev = device_bytes(sidx, reconcile=False)
        device_gauges(reg, dev, group="0")
        assert reg.value("device.index_bytes", group="0") == \
            dev["total_bytes"]
        text = prometheus_text(reg.snapshot())
        assert "repro_cluster_health_status" in text
        assert "repro_device_index_section_bytes" in text
        cl.mark_down(0)
        health_gauges(reg, cl.cluster_health())
        assert reg.value("cluster.health.status") == 1       # yellow
    finally:
        cl.close()


# ------------------------------------------------------------ host-seam lint
_LINT = os.path.join(_REPO, "tools", "check_host_seams.py")


def test_host_seam_lint_repo_clean():
    out = subprocess.run([sys.executable, _LINT],
                         capture_output=True, text=True, cwd=_REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_host_seam_lint_catches_violations(tmp_path):
    (tmp_path / "bad.py").write_text(
        "import time\n"
        "import jax\n"
        "from repro.obs import MetricsRegistry\n"
        "@jax.jit\n"
        "def scores(x):\n"
        "    t0 = time.monotonic()\n"
        "    return x * t0\n"
        "def host_side():\n"
        "    time.sleep(0)              # NOT jitted: allowed\n"
        "def traced(x):\n"
        "    MetricsRegistry\n"
        "    return x\n"
        "y = jax.jit(traced)\n")
    out = subprocess.run([sys.executable, _LINT, str(tmp_path)],
                         capture_output=True, text=True)
    assert out.returncode == 1, out.stdout + out.stderr
    assert "time.monotonic" in out.stderr
    assert "MetricsRegistry" in out.stderr
    assert "host_side" not in out.stderr


# --------------------------------------------------------- regression gate
def _gate(tmp_path, files):
    sys.path.insert(0, _REPO)
    try:
        from benchmarks import check
    finally:
        sys.path.pop(0)
    for name, doc in files.items():
        with open(tmp_path / f"BENCH_{name}.json", "w") as f:
            json.dump(doc, f)
    return check.main(["--artifacts", str(tmp_path)])


def _runs(*rowsets):
    return {"bench": "x", "runs": [{"rows": rows} for rows in rowsets]}


def test_gate_skips_single_run_then_catches_regression(tmp_path, capsys):
    assert _gate(tmp_path, {"shard_scale": _runs([{"qps": 100.0}])}) == 0
    assert "SKIP" in capsys.readouterr().out
    assert _gate(tmp_path, {"shard_scale": _runs(
        [{"qps": 100.0}], [{"qps": 30.0}])}) == 1
    assert "REGRESSION" in capsys.readouterr().out
    assert _gate(tmp_path, {"shard_scale": _runs(
        [{"qps": 100.0}], [{"qps": 80.0}])}) == 0


def test_gate_overhead_bars(tmp_path, capsys):
    doc = _runs([{"config": "off", "qps": 100.0},
                 {"config": "overhead", "relative_overhead": 0.08}])
    assert _gate(tmp_path, {"obs_scale": doc}) == 1
    assert "relative_overhead" in capsys.readouterr().out
    doc = _runs([{"config": "off", "qps": 100.0},
                 {"config": "overhead", "relative_overhead": 0.01},
                 {"config": "overhead_full", "relative_overhead": 0.04}])
    assert _gate(tmp_path, {"obs_scale": doc}) == 0


def test_gate_kernel_claim(tmp_path, capsys):
    rows = [{"n_docs": 100, "variant": "composed", "hbm_bytes": 1000,
             "wall_s": 1.0},
            {"n_docs": 100, "variant": "fused", "hbm_bytes": 2000,
             "wall_s": 0.5}]
    assert _gate(tmp_path, {"kernel_scale": {"rows": rows}}) == 1
    assert "fused bytes >= composed" in capsys.readouterr().out
    rows = [{"n_docs": 100, "variant": "composed", "hbm_bytes": 2000,
             "wall_s": 1.0},
            {"n_docs": 100, "variant": "fused", "hbm_bytes": 1000,
             "wall_s": 0.5},
            {"n_docs": 100, "variant": "fused_int8", "hbm_bytes": 400,
             "wall_s": 0.4}]
    assert _gate(tmp_path, {"kernel_scale": {"rows": rows}}) == 0


def test_gate_on_committed_artifacts():
    """The gate must pass (or skip) on exactly what is committed --
    otherwise `make bench-check` is red at HEAD."""
    sys.path.insert(0, _REPO)
    try:
        from benchmarks import check
    finally:
        sys.path.pop(0)
    assert check.main([]) == 0
