"""Incremental ingest regressions (dist/shard_index.py segments/tombstones).

The pinned invariant: padded and tombstoned sentinel docs NEVER surface in
search results at any (k, page) -- before and after ``add_documents`` /
``delete`` / ``compact`` -- for both phase-1 engine families (postings
range-lookup and direct code match) and both merge transports.  Result
slots beyond the live doc count report ``(id=-1, score=-inf)`` instead of
leaking a pad.  Multi-shard cases run in a subprocess (virtual-device flag
precedes jax init, same pattern as test_shard_index.py).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import VectorIndex
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_KP_GRID = [(1, 1), (3, 8), (10, 23), (10, 10_000), (64, 64)]


def _check_clean(sidx, queries, live_ids, *, engines=("postings", "codes"),
                 merge="gather"):
    """No dead/pad/sentinel id in any result cell, -inf slots are id -1."""
    live_ids = set(live_ids)
    for engine in engines:
        for k, page in _KP_GRID:
            ids, scores = sidx.search(queries, k=k, page=page, engine=engine,
                                      merge=merge)
            ids, scores = np.asarray(ids), np.asarray(scores)
            dead = (ids == -1)
            assert (np.isneginf(scores) == dead).all(), (engine, k, page)
            assert all(i in live_ids for i in ids[~dead].ravel()), \
                (engine, k, page, ids)


def _build(n_docs=23, dims=12, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, dims)).astype(np.float32)
    W = rng.normal(size=(9, dims)).astype(np.float32)
    return V, W


def test_sentinel_never_surfaces_through_ingest_lifecycle():
    """The satellite regression: every (k, page) cell stays sentinel-free
    before ingest, after add_documents, after delete, and after compact."""
    V, W = _build()
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    Q = np.concatenate([V[:3], W[:3]])

    _check_clean(sidx, Q, range(23))

    grown = sidx.add_documents(W)                    # gids 23..31
    assert grown.n_ids == 32 and grown.seg_capacity == 9
    _check_clean(grown, Q, range(32))

    pruned = grown.delete([0, 7, 25, 31])            # base + segment dead
    _check_clean(pruned, Q, set(range(32)) - {0, 7, 25, 31})

    packed = pruned.compact()
    assert packed.n_docs == 32 and packed.n_appended == 0
    assert packed.seg_capacity == 0
    _check_clean(packed, Q, set(range(32)) - {0, 7, 25, 31})

    # compaction folds tombstones out of the posting lists too: the dead
    # rows' codes are the sentinel, so they sort to every list's tail
    codes = np.asarray(packed.codes).reshape(-1, packed.codes.shape[-1])
    from repro.core.search import _SENTINEL
    sentinel = _SENTINEL[codes.dtype]
    assert (codes[[0, 7, 25, 31]] == sentinel).all()


def test_appended_docs_are_searchable_and_exact():
    """A hot-added doc is retrievable as its own top hit (score ~1), and a
    compacted index returns the same live result set."""
    V, W = _build(seed=1)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    grown = sidx.add_documents(W)
    ids, scores = grown.search(W, k=3, page=1_000, engine="codes")
    ids, scores = np.asarray(ids), np.asarray(scores)
    assert (ids[:, 0] == np.arange(23, 32)).all()
    np.testing.assert_allclose(scores[:, 0], 1.0, rtol=1e-5)

    packed = grown.compact()
    ids2, _ = packed.search(W, k=32, page=1_000, engine="postings")
    idsf, _ = grown.search(W, k=32, page=1_000, engine="postings")
    assert np.array_equal(np.sort(np.asarray(ids2), 1),
                          np.sort(np.asarray(idsf), 1))


def test_delete_is_immediate_for_every_engine():
    """Tombstones vanish from results before compaction, under BOTH engine
    families: the live mask blocks postings-range hits, the sentinel codes
    block direct code-match hits."""
    V, W = _build(seed=2)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    target = int(np.asarray(sidx.search(V[5], k=1, page=100)[0])[0, 0])
    assert target == 5
    pruned = sidx.delete([5])
    for engine in ("postings", "codes", "onehot"):
        ids, _ = pruned.search(V[5], k=23, page=100, engine=engine)
        assert 5 not in np.asarray(ids), engine
    # deleting an already-dead id is a no-op; out-of-range raises
    pruned.delete([5])
    with pytest.raises(ValueError, match="ids must be in"):
        pruned.delete([23])


def test_gids_stay_monotonic_across_delete():
    V, W = _build(seed=3)
    sidx = ShardedVectorIndex.build_sharded(V[:5], make_shard_mesh(1))
    grown = sidx.add_documents(W[:2]).delete([5, 6]).add_documents(W[2:4])
    assert grown.n_ids == 9
    ids, _ = grown.search(W[2:4], k=2, page=20, engine="codes")
    assert (np.asarray(ids)[:, 0] == [7, 8]).all()


def test_add_documents_validates_and_noops():
    V, W = _build(seed=4)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    assert sidx.add_documents(np.zeros((0, 12), np.float32)) is sidx
    with pytest.raises(ValueError, match="feature"):
        sidx.add_documents(np.zeros((2, 5), np.float32))


def test_ingest_within_capacity_reuses_compiled_search():
    """Hot-ingest must not recompile the SPMD query program per batch:
    segment capacity grows geometrically and n_ids is a traced scalar, so
    adds that fit the existing capacity leave shapes AND treedef unchanged
    -- the second search is a pure jit-cache hit (phase1_engine_scores is
    only called when _query_phase re-traces).  Holds in the serving regime
    ``page < n_ids``; a page clamped by the corpus size legitimately
    re-specialises when the corpus grows past it."""
    import repro.dist.shard_index as si

    V, W = _build(seed=6)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    calls = []
    orig = si.phase1_engine_scores
    si.phase1_engine_scores = \
        lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1]
    try:
        g1 = sidx.add_documents(W[:2])          # capacity grows 0 -> 8
        assert g1.seg_capacity == 8
        g1.search(V[:2], k=3, page=16, engine="codes")
        traced = len(calls)
        assert traced >= 1
        g2 = g1.add_documents(W[2:5])           # fits: same shapes/treedef
        assert g2.seg_capacity == 8
        g2.search(V[:2], k=3, page=16, engine="codes")
        assert len(calls) == traced, "search recompiled within capacity"
    finally:
        si.phase1_engine_scores = orig


def test_batched_engine_hot_ingest():
    """BatchedSearchEngine.add_documents: the hot-add path serves the new
    docs to every subsequently dequeued batch, and plain VectorIndex
    (immutable) is rejected."""
    from repro.serve.engine import BatchedSearchEngine

    V, W = _build(seed=5)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1))
    eng = BatchedSearchEngine(sidx, batch_size=2, k=3, page=1_000, trim=None,
                              engine="codes")
    try:
        ids0, _ = eng.search(V[0], timeout=60)
        assert ids0[0] == 0
        first = eng.add_documents(W)
        assert first == 23
        ids1, s1 = eng.search(W[4], timeout=60)
        assert ids1[0] == 27 and abs(s1[0] - 1) < 1e-5
    finally:
        eng.close()
    with pytest.raises(RuntimeError, match="closed"):
        eng.add_documents(W)

    eng2 = BatchedSearchEngine(VectorIndex.build(V), trim=None)
    try:
        with pytest.raises(TypeError, match="incremental ingest"):
            eng2.add_documents(W)
    finally:
        eng2.close()


def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_multi_shard_ingest_lifecycle():
    """4 shards x 2 replicas, ragged base: round-robin segment routing,
    both merge transports, tombstones in base AND segments, compact -- the
    sentinel-free invariant holds in every cell."""
    _run_subprocess(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh

rng = np.random.default_rng(0)
V = rng.normal(size=(27, 10)).astype(np.float32)
W = rng.normal(size=(10, 10)).astype(np.float32)
Q = np.concatenate([V[:3], W[:4]])          # 7 queries: odd, pads replicas

def check(sidx, live):
    live = set(live)
    for merge in ("gather", "stream"):
        for engine in ("postings", "codes"):
            for k, page in ((1, 1), (5, 16), (16, 37), (40, 10_000)):
                ids, s = sidx.search(Q, k=k, page=page, engine=engine,
                                     merge=merge)
                ids, s = np.asarray(ids), np.asarray(s)
                dead = ids == -1
                assert (np.isneginf(s) == dead).all(), (merge, engine, k, page)
                assert all(i in live for i in ids[~dead].ravel()), \
                    (merge, engine, k, page, ids)

sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(4, 2))
check(sidx, range(27))
grown = sidx.add_documents(W)               # gids 27..36, round-robin shards
assert int((np.asarray(grown.seg_gids) >= 0).sum()) == 10
assert grown.n_ids == 37
check(grown, range(37))
ids, s = grown.search(W[:4], k=1, page=1_000, engine="codes")
assert (np.asarray(ids)[:, 0] == np.arange(27, 31)).all()
pruned = grown.delete([2, 11, 28, 36])
check(pruned, set(range(37)) - {2, 11, 28, 36})
packed = pruned.compact()
assert packed.n_docs == 37 and packed.seg_capacity == 0
check(packed, set(range(37)) - {2, 11, 28, 36})
print("OK")
""")
