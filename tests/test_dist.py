"""Distribution layer: sharding rules, annotations, elastic resharding,
HLO analysis, serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.dist.annotate import constrain, use_mesh
from repro.dist.sharding import (
    batch_axes,
    generic_param_spec,
    lm_param_spec,
    opt_state_spec,
    tree_specs,
)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_local_mesh, make_production_mesh


class FakeMesh:
    """Shape-only stand-in so spec rules are testable without 512 devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _leaf(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


class TestLMSpecs:
    def test_divisible_heads_get_model_axis(self):
        spec = lm_param_spec((jax.tree_util.DictKey("wq"),), _leaf((23, 4608, 32, 128)), MESH1)
        assert spec == P(None, "data", "model", None)

    def test_indivisible_heads_fall_back_to_fsdp_only(self):
        spec = lm_param_spec((jax.tree_util.DictKey("wq"),), _leaf((24, 896, 14, 64)), MESH1)
        assert spec == P(None, "data", None, None)

    def test_moe_expert_parallel_when_divisible(self):
        spec = lm_param_spec((jax.tree_util.DictKey("wg"),), _leaf((12, 128, 5120, 8192)), MESH1)
        assert spec == P(None, "model", None, "data")

    def test_moe_tp_fallback_mixtral(self):
        spec = lm_param_spec((jax.tree_util.DictKey("wg"),), _leaf((56, 8, 6144, 16384)), MESH1)
        assert spec == P(None, None, "data", "model")

    def test_embed_never_vocab_sharded(self):
        spec = lm_param_spec((jax.tree_util.DictKey("embed"),), _leaf((256000, 4608)), MESH1)
        assert spec[0] is None  # d_model sharding only (gather-safe)

    def test_every_arch_leaf_divides_both_meshes(self):
        """No spec may request an indivisible shard on either mesh."""
        for arch_id in ["llama4-maverick-400b-a17b", "mixtral-8x22b", "gemma2-27b",
                        "starcoder2-3b", "qwen2-0.5b"]:
            arch = get_arch(arch_id)
            pa = arch.params_abstract()
            for mesh in (MESH1, MESH2):
                specs = tree_specs(pa, mesh, lm_param_spec)

                def check(leaf, spec):
                    parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
                    for dim, axes in enumerate(parts):
                        if axes is None:
                            continue
                        axes = axes if isinstance(axes, tuple) else (axes,)
                        n = int(np.prod([mesh.shape[a] for a in axes]))
                        assert leaf.shape[dim] % n == 0, (arch_id, leaf.shape, spec)

                jax.tree.map(check, pa, specs)

    def test_opt_state_spec_drops_dims(self):
        assert opt_state_spec(P(None, "model", None, "data"), 4, "vr") == P(None, "model", None)
        assert opt_state_spec(P(None, "model", None, "data"), 4, "vc") == P(None, "model", "data")


class TestGenericSpecs:
    def test_embedding_table_row_sharded(self):
        spec = generic_param_spec((jax.tree_util.DictKey("table"),), _leaf((1048576 * 39, 10)), MESH1)
        assert spec == P("model", None)

    def test_small_leaves_replicate(self):
        spec = generic_param_spec((jax.tree_util.DictKey("w"),), _leaf((64, 128)), MESH1)
        assert spec == P()


class TestAnnotate:
    def test_noop_without_mesh(self):
        x = jnp.ones((8, 4))
        assert constrain(x, "batch", None) is x

    def test_constrains_under_mesh(self):
        mesh = make_local_mesh(1, 1)
        with use_mesh(mesh):
            out = jax.jit(lambda x: constrain(x, "batch", None))(jnp.ones((8, 4)))
        assert out.shape == (8, 4)

    def test_indivisible_dims_dropped(self):
        mesh = make_local_mesh(1, 1)
        with use_mesh(mesh):
            x = jnp.ones((7, 3))
            out = constrain(x, "batch", "model")  # neither divides -> no-op spec
            assert out.shape == (7, 3)


class TestHloAnalysis:
    def test_dot_flops_exact(self):
        comp = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile()
        out = analyze_hlo(comp.as_text())
        assert out["dot_flops"] == 2 * 32 * 64 * 16

    def test_scan_multiplier(self):
        def f(w, xs):
            def body(c, x):
                return c, x @ w
            _, ys = jax.lax.scan(body, 0.0, xs)
            return ys.sum()
        comp = jax.jit(f).lower(jax.ShapeDtypeStruct((16, 16), jnp.float32),
                                jax.ShapeDtypeStruct((7, 8, 16), jnp.float32)).compile()
        out = analyze_hlo(comp.as_text())
        assert out["dot_flops"] == 7 * 2 * 8 * 16 * 16

    def test_nested_scan_multiplier(self):
        def f(w, xs):
            def outer(c, x):
                def inner(ci, xi):
                    return ci, xi @ w
                _, ys = jax.lax.scan(inner, 0.0, x)
                return c, ys.sum()
            _, out = jax.lax.scan(outer, 0.0, xs)
            return out.sum()
        comp = jax.jit(f).lower(jax.ShapeDtypeStruct((16, 16), jnp.float32),
                                jax.ShapeDtypeStruct((3, 5, 8, 16), jnp.float32)).compile()
        out = analyze_hlo(comp.as_text())
        assert out["dot_flops"] == 3 * 5 * 2 * 8 * 16 * 16


class TestElastic:
    def test_reshard_between_meshes(self):
        from repro.train.elastic import reshard_tree
        m1 = make_local_mesh(1, 1)
        tree = {"w": jnp.arange(16.0).reshape(4, 4), "s": jnp.float32(3)}
        out = reshard_tree(tree, m1, lambda path, leaf: P())
        assert (np.asarray(out["w"]) == np.asarray(tree["w"])).all()


class TestServeEngine:
    def test_batched_engine_end_to_end(self):
        from repro.core import VectorIndex
        from repro.serve.engine import BatchedSearchEngine
        rng = np.random.default_rng(0)
        V = rng.normal(size=(300, 16)).astype(np.float32)
        idx = VectorIndex.build(V)
        eng = BatchedSearchEngine(idx, batch_size=4, k=5, page=300, trim=None)
        try:
            futs = [eng.submit(V[i]) for i in range(8)]
            for i, f in enumerate(futs):
                ids, scores = f.result(timeout=30)
                assert ids[0] == i  # self-retrieval at page=N
        finally:
            eng.close()
