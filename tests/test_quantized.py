"""int8 quantized phase-1 (core/quantize.py + the ``fused_int8`` engine).

The quantization contract, pinned at three levels:

* **numeric**: per-row affine round-trip error is bounded by ``scale / 2``
  per element, degenerate rows (all-zero shard padding, constant rows)
  reconstruct EXACTLY, and quantization is a pure per-row function --
  a row quantizes to the same bits alone or inside any larger table
  (what keeps lazily-derived shard/segment tables seg-vs-flat consistent);
* **selection**: int8 only ever picks the candidate page; the final page
  is ALWAYS rescored against the exact fp32 vectors, so when the page
  covers the corpus the ``fused_int8`` engine returns ids AND scores
  bit-identical to the exact engines -- quantization becomes invisible;
* **quality**: on an LSA corpus (the test_quality_claims setup, scaled
  down), int8 phase-1 keeps recall@10 against the brute-force gold above
  a pinned floor, improving with page -- the paper's speed/quality knob
  extended one level down the numeric stack.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from repro.core import VectorIndex, precision_at_k
from repro.core.quantize import (QMAX, dequantize_rows, quantize_rows,
                                 quantize_table)
from repro.data import make_corpus
from repro.lsa import build_lsa


# ------------------------------------------------------------- numeric level
class TestQuantizeRows:
    def test_round_trip_error_bound(self):
        """|dequant - v| <= scale/2 per element (the affine scheme's
        worst case: rounding to the nearest of 255 levels), with per-row
        magnitudes spanning two orders so every row gets its own scale."""
        rng = np.random.default_rng(0)
        V = rng.normal(size=(200, 48)).astype(np.float32) * \
            rng.uniform(0.05, 5.0, size=(200, 1)).astype(np.float32)
        codes, scale, zero = quantize_rows(jnp.asarray(V))
        assert codes.dtype == jnp.int8
        err = np.abs(np.asarray(dequantize_rows(codes, scale, zero)) - V)
        bound = np.asarray(scale)[:, None] / 2
        assert (err <= bound * (1 + 1e-5) + 1e-7).all()
        # the row extremes land on the code-range ends: no clipping loss
        assert (np.abs(np.asarray(codes)).max(axis=1) == QMAX).all()

    def test_degenerate_rows_reconstruct_exactly(self):
        """All-zero rows (shard padding) -> codes 0, zero 0, exact zeros
        back; constant rows -> codes 0, exact constant back."""
        V = np.zeros((3, 8), np.float32)
        V = np.concatenate([V, np.full((2, 8), 1.75, np.float32)])
        codes, scale, zero = quantize_rows(jnp.asarray(V))
        assert not np.asarray(codes).any()
        assert_allclose(np.asarray(zero), [0, 0, 0, 1.75, 1.75], rtol=0)
        assert np.array_equal(
            np.asarray(dequantize_rows(codes, scale, zero)), V)

    def test_subbatch_determinism(self):
        """Quantizing any sub-batch yields the bits it gets inside the
        full table -- the property that lets sharded/segmented indexes
        derive per-leaf tables lazily yet stay seg-vs-flat bit-equal."""
        rng = np.random.default_rng(1)
        V = rng.normal(size=(64, 16)).astype(np.float32)
        c_all, s_all, z_all = quantize_rows(jnp.asarray(V))
        for lo, hi in [(0, 1), (7, 30), (30, 64)]:
            c, s, z = quantize_rows(jnp.asarray(V[lo:hi]))
            assert np.array_equal(np.asarray(c), np.asarray(c_all)[lo:hi])
            assert np.array_equal(np.asarray(s), np.asarray(s_all)[lo:hi])
            assert np.array_equal(np.asarray(z), np.asarray(z_all)[lo:hi])

    def test_table_cached_per_instance(self):
        rng = np.random.default_rng(2)
        idx = VectorIndex.build(
            rng.normal(size=(50, 12)).astype(np.float32))
        qt = idx.quantized
        assert idx.quantized is qt                  # derived once
        assert qt.nbytes_codes == 50 * 12           # one byte per element
        assert np.array_equal(
            np.asarray(qt.codes),
            np.asarray(quantize_table(idx.vectors).codes))


# ----------------------------------------------------------- selection level
class TestFinalPageBitIdentity:
    """page >= n_docs: every doc reaches the exact fp32 rescore, so the
    quantized engine's output must be bit-identical to the exact ones --
    int8 can change WHICH candidates reach the rescore, never the score
    of a hit, and with a full page there is nothing left to change."""

    @pytest.fixture(scope="class")
    def setup(self):
        rng = np.random.default_rng(3)
        idx = VectorIndex.build(
            rng.normal(size=(150, 16)).astype(np.float32))
        Q = rng.normal(size=(7, 16)).astype(np.float32)
        return idx, Q

    @pytest.mark.parametrize("engine", ["fused", "fused_int8"])
    def test_full_page_matches_exact_engine(self, setup, engine):
        idx, Q = setup
        gold_ids, gold_s = idx.search(Q, k=10, page=300, trim=None,
                                      engine="codes")
        ids, s = idx.search(Q, k=10, page=300, trim=None, engine=engine)
        assert np.array_equal(np.asarray(ids), np.asarray(gold_ids)), engine
        assert np.array_equal(np.asarray(s), np.asarray(gold_s)), engine

    def test_partial_page_scores_stay_exact(self, setup):
        """Even when int8 picks a DIFFERENT candidate page, every reported
        score is the exact fp32 cosine of that doc -- never a dequantized
        approximation."""
        idx, Q = setup
        ids, s = idx.search(Q, k=5, page=20, trim=None, engine="fused_int8")
        gold_all = np.asarray(idx.gold_topk(Q, idx.n_docs)[1])
        order = np.asarray(idx.gold_topk(Q, idx.n_docs)[0])
        exact = np.take_along_axis(
            np.take_along_axis(gold_all, np.argsort(order), axis=1),
            np.asarray(ids), axis=1)
        assert_allclose(np.asarray(s), exact, rtol=1e-6, atol=1e-7)


# ------------------------------------------------------------- quality level
@pytest.fixture(scope="module")
def lsa_setup():
    corpus = make_corpus(n_docs=800, vocab_size=4000, n_topics=20, seed=11)
    pipe = build_lsa(corpus, n_features=64)
    idx = VectorIndex.build(pipe.doc_vectors)
    Q = pipe.doc_vectors[:16]
    gold_ids, _ = idx.gold_topk(Q, 10)
    return idx, Q, gold_ids


def test_int8_phase1_recall_floor(lsa_setup):
    """int8 candidate selection keeps recall@10 against brute-force gold
    >= 0.9 at page=80 on a real LSA corpus (fig2's quantization-axis
    claim, in test form), and a larger page can only help."""
    idx, Q, gold_ids = lsa_setup
    recalls = {}
    for page in (20, 80, 320):
        ids, _ = idx.search(Q, k=10, page=page, trim=None,
                            engine="fused_int8")
        recalls[page] = float(precision_at_k(ids, gold_ids).mean())
    assert recalls[80] >= 0.9, recalls
    assert recalls[320] >= recalls[20] - 1e-6, recalls


def test_fused_fp32_recall_matches_codes_engine(lsa_setup):
    """The fused fp32 engine selects through the same exact phase-1
    scores as the composed engines, so at equal page its quality is the
    composed engine's quality."""
    idx, Q, gold_ids = lsa_setup
    ids_f, s_f = idx.search(Q, k=10, page=80, trim=None, engine="fused")
    ids_c, s_c = idx.search(Q, k=10, page=80, trim=None, engine="codes")
    r_f = float(precision_at_k(ids_f, gold_ids).mean())
    r_c = float(precision_at_k(ids_c, gold_ids).mean())
    assert r_f == pytest.approx(r_c, abs=0.05), (r_f, r_c)
