"""LSA substrate: tf-idf, randomized SVD vs dense numpy oracle, pipeline."""

import numpy as np
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.data import make_corpus
from repro.lsa import build_lsa, fit_tfidf, randomized_svd, transform
from repro.lsa.svd import fold_in, matvec_bags, rmatvec_bags


def _dense(terms, weights, vocab):
    A = np.zeros((terms.shape[0], vocab), np.float32)
    for i in range(terms.shape[0]):
        for t, w in zip(terms[i], weights[i]):
            if t >= 0:
                A[i, t] += w
    return A


def test_matvec_oracle():
    rng = np.random.default_rng(0)
    terms = rng.integers(-1, 50, size=(20, 12)).astype(np.int32)
    weights = rng.random((20, 12)).astype(np.float32) * (terms >= 0)
    Y = rng.normal(size=(50, 7)).astype(np.float32)
    A = _dense(terms, weights, 50)
    got = matvec_bags(jnp.asarray(terms), jnp.asarray(weights), jnp.asarray(Y))
    assert_allclose(np.asarray(got), A @ Y, rtol=1e-4, atol=1e-5)
    X = rng.normal(size=(20, 7)).astype(np.float32)
    got2 = rmatvec_bags(jnp.asarray(terms), jnp.asarray(weights), jnp.asarray(X), 50)
    assert_allclose(np.asarray(got2), A.T @ X, rtol=1e-4, atol=1e-5)


def test_randomized_svd_matches_numpy():
    rng = np.random.default_rng(1)
    d, v, k = 120, 80, 10
    terms = rng.integers(0, v, size=(d, 16)).astype(np.int32)
    weights = rng.random((d, 16)).astype(np.float32)
    A = _dense(terms, weights, v)
    model = randomized_svd(jnp.asarray(terms), jnp.asarray(weights), v, k=k,
                           oversample=20, n_iter=6)
    _, s_np, _ = np.linalg.svd(A, full_matrices=False)
    assert_allclose(np.asarray(model.s), s_np[:k], rtol=1e-3)
    # doc_vecs rows unit-normalised
    assert_allclose(np.linalg.norm(np.asarray(model.doc_vecs), axis=1), 1.0, rtol=1e-5)


def test_fold_in_recovers_training_docs():
    corpus = make_corpus(n_docs=300, vocab_size=2000, n_topics=8, seed=2)
    pipe = build_lsa(corpus, n_features=32)
    refold = pipe.embed(jnp.asarray(corpus.doc_terms), jnp.asarray(corpus.doc_tf))
    sims = (np.asarray(refold) * np.asarray(pipe.doc_vectors)).sum(-1)
    assert sims.mean() > 0.98  # folding a training doc lands on its own vector


def test_tfidf_rare_terms_weigh_more():
    terms = jnp.asarray([[0, 1], [0, 2], [0, 3], [0, -1]])
    tf = jnp.ones((4, 2))
    model = fit_tfidf(terms, 4)
    idf = np.asarray(model.idf)
    assert idf[1] > idf[0]  # term 0 appears in 4 docs, term 1 in one


def test_lsa_neighbours_share_topics():
    corpus = make_corpus(n_docs=400, vocab_size=3000, n_topics=10, seed=3)
    pipe = build_lsa(corpus, n_features=24)
    V = np.asarray(pipe.doc_vectors)
    sims = V @ V.T
    np.fill_diagonal(sims, -1)
    nn = sims.argmax(1)
    mix = corpus.doc_topics
    mix = mix / np.linalg.norm(mix, axis=1, keepdims=True)
    nn_topic_sim = (mix * mix[nn]).sum(-1).mean()
    rand_topic_sim = (mix * np.roll(mix, 37, axis=0)).sum(-1).mean()
    assert nn_topic_sim > rand_topic_sim + 0.2
