"""MoE dispatch: global sort-based path, token chunking, shard-local path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.models.transformer.moe import _moe_ffn_chunk, moe_ffn, moe_init
from repro.models.transformer.moe_local import moe_ffn_local


@pytest.fixture(scope="module")
def setup():
    p = moe_init(jax.random.PRNGKey(0), 16, 32, 4, n_shared=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 16), jnp.float32)
    return p, x


def test_chunked_matches_unchunked(setup):
    p, x = setup
    y1, a1 = moe_ffn(p, x, top_k=2, capacity_factor=8.0, token_chunk=10**9)
    y2, a2 = moe_ffn(p, x, top_k=2, capacity_factor=8.0, token_chunk=8)
    assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-5)


def test_local_fallback_matches_global(setup):
    """Without a mesh, the local dispatcher falls back bit-identically."""
    p, x = setup
    y1, _ = _moe_ffn_chunk(p, x, 2, 8.0, "silu")
    y2, _ = moe_ffn_local(p, x, 2, capacity_factor=8.0)
    assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


def test_local_dispatch_under_mesh_matches_global():
    """shard-local dispatch == global dispatch on a real multi-device mesh
    (size-1 mesh axes break partial-manual shard_map in this jax version, so
    this runs in a subprocess with 8 host devices)."""
    import subprocess, sys, os

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist.annotate import use_mesh
from repro.models.transformer.moe import _moe_ffn_chunk, moe_init
from repro.models.transformer.moe_local import moe_ffn_local

p = moe_init(jax.random.PRNGKey(0), 16, 32, 4, n_shared=1)
x = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
y_ref, a_ref = _moe_ffn_chunk(p, x, 2, 8.0, "silu")
# per-shard capacity differs from global capacity; use cf large enough that
# no drops happen either way -> outputs must match exactly
pp = jax.tree_util.tree_map_with_path(
    lambda path, t: jax.device_put(t, NamedSharding(mesh, P())), p)
xx = jax.device_put(x, NamedSharding(mesh, P("data", None)))
with mesh, use_mesh(mesh):
    y, a = jax.jit(lambda p_, x_: moe_ffn_local(p_, x_, 2, capacity_factor=8.0))(pp, xx)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-4)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_capacity_drops_are_bounded(setup):
    """With cf=1.0 at most C tokens per expert survive; outputs stay finite."""
    p, x = setup
    y, aux = moe_ffn(p, x, top_k=2, capacity_factor=1.0)
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)


def test_aux_loss_balanced_vs_collapsed():
    """The Switch aux loss must penalise router collapse."""
    p = moe_init(jax.random.PRNGKey(0), 8, 16, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8), jnp.float32)
    _, aux_balanced = moe_ffn(p, x, top_k=1)
    p_collapsed = dict(p)
    p_collapsed["router"] = p["router"] + jnp.asarray(
        [[100.0, 0, 0, 0]] * 8, jnp.float32)
    _, aux_collapsed = moe_ffn(p_collapsed, x, top_k=1)
    assert float(aux_collapsed) > float(aux_balanced)
