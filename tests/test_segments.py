"""The Lucene segment story (generational indexes + tiered merges).

The pinned invariants:

* sealing is INVISIBLE: a segmented index (tiny ``seal_threshold``)
  returns ids AND scores bit-identical to the flat append path
  (``seal_threshold=None``) for every engine at every (k, page) pair,
  through a full ingest -> delete -> merge -> compact lifecycle;
* sealing structure is deterministic: the active buffer seals the moment
  it reaches the threshold, a pure function of the op history (what lets
  translog replay re-seal identically -- tests/test_store.py pins the
  recovery side);
* ``merge_segments`` folds a contiguous run, reclaims exactly its
  tombstones, preserves search results bitwise, and validates its range;
* :class:`TieredMergePolicy` plans like Lucene's: delete-pressure
  singleton rewrites first (per-SEGMENT deleted ratios -- the thing the
  whole-index ``tombstone_ratio`` cannot see), then similar-sized tier
  folds, ``None`` for flat indexes;
* the maintenance daemon applies planned merges per replica group
  (concurrently when several have work), off the query path, via the
  ``swap_index`` CAS, with events/metrics/stats reconciling;
* the whole lifecycle holds on multi-device meshes (4 shards, and
  4 shards x 2 replicas on 8 devices) -- subprocesses, the usual
  virtual-device pattern.
"""

import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro.cluster import MaintenanceDaemon, TieredMergePolicy
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh
from repro.obs import MetricsRegistry, format_segments_line, index_stats
from repro.serve.engine import BatchedSearchEngine

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_ENGINES = ("postings", "codes", "onehot")
N_FEAT = 12


def _build(n_docs=40, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n_docs, N_FEAT)).astype(np.float32)
    Q = rng.normal(size=(5, N_FEAT)).astype(np.float32)
    return V, Q, rng


def _assert_same_results(a, b, queries, ctx, *, ks=(1, 5, 13),
                         pages=(7, 33, None), engines=_ENGINES):
    assert a.n_ids == b.n_ids, ctx
    for engine in engines:
        for k in ks:
            for page in pages:
                p = 2 * a.n_ids if page is None else page
                i1, s1 = a.search(queries, k=k, page=p, engine=engine)
                i2, s2 = b.search(queries, k=k, page=p, engine=engine)
                assert np.array_equal(np.asarray(i1), np.asarray(i2)), \
                    (ctx, engine, k, p)
                assert np.array_equal(np.asarray(s1), np.asarray(s2)), \
                    (ctx, engine, k, p)


# ------------------------------------------------------------ the big pin
def test_lifecycle_parity_segmented_vs_flat():
    """THE acceptance invariant: the same op history applied to a
    segmented index (seal_threshold=4) and a flat one
    (seal_threshold=None) gives bit-identical search at every
    (engine, k, page) after EVERY stage -- ingest that seals, deletes
    hitting base + sealed + active rows, a partial merge, a full
    compact."""
    V, Q, rng = _build()
    mesh = make_shard_mesh(1)
    seg = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=4)
    flat = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=None)
    _assert_same_results(seg, flat, Q, "built")

    for step in range(3):                       # ingest: seals twice
        W = rng.normal(size=(5, N_FEAT)).astype(np.float32)
        seg, flat = seg.add_documents(W), flat.add_documents(W)
        _assert_same_results(seg, flat, Q, ("ingest", step))
    assert seg.n_segments >= 2 and flat.n_segments == 0

    victims = [2, 3, 41, 42, 47, 54]            # base + sealed + active
    seg, flat = seg.delete(victims), flat.delete(victims)
    _assert_same_results(seg, flat, Q, "deleted")

    merged = seg.merge_segments(0, 2)           # partial fold, seg only
    assert merged.n_segments == seg.n_segments - 1
    _assert_same_results(merged, flat, Q, "merged")
    _assert_same_results(merged, seg, Q, "merge is invisible")

    seg, flat = merged.compact(), flat.compact()
    _assert_same_results(seg, flat, Q, "compacted")
    assert seg.n_segments == 0 and seg.tombstone_ratio == 0.0


def test_lifecycle_parity_fused_engines():
    """The fused phase-1 engines ride the same sealing-is-invisible
    invariant: ``fused`` streams every generation through the shared
    fixed-tree scorer (bit-identical phase-1 to the flat layout by
    construction), ``fused_int8`` derives per-generation quantized tables
    lazily -- both must return flat-vs-segmented bit-identical ids AND
    scores through ingest, deletes hitting every generation, a partial
    merge, and a compact."""
    V, Q, rng = _build()
    mesh = make_shard_mesh(1)
    seg = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=4)
    flat = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=None)
    kw = dict(ks=(1, 9), pages=(9, None), engines=("fused", "fused_int8"))
    _assert_same_results(seg, flat, Q, "built", **kw)

    for step in range(2):                       # ingest: seals at least once
        W = rng.normal(size=(5, N_FEAT)).astype(np.float32)
        seg, flat = seg.add_documents(W), flat.add_documents(W)
        _assert_same_results(seg, flat, Q, ("ingest", step), **kw)
    assert seg.n_segments >= 1 and flat.n_segments == 0

    victims = [2, 3, 41, 42, 47]                # base + sealed + active
    seg, flat = seg.delete(victims), flat.delete(victims)
    _assert_same_results(seg, flat, Q, "deleted", **kw)

    if seg.n_segments >= 2:
        seg = seg.merge_segments(0, 2)
        _assert_same_results(seg, flat, Q, "merged", **kw)

    seg, flat = seg.compact(), flat.compact()
    _assert_same_results(seg, flat, Q, "compacted", **kw)
    assert seg.n_segments == 0 and seg.tombstone_ratio == 0.0


def test_seal_structure_is_deterministic():
    """The buffer seals exactly when it reaches the threshold -- a pure
    function of the op history -- and the sealed generation carries the
    right rows/gids while the buffer resets."""
    V, _, rng = _build(n_docs=20)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1),
                                            seal_threshold=4)
    sidx = sidx.add_documents(rng.normal(size=(5, N_FEAT))
                              .astype(np.float32))
    assert sidx.n_segments == 1 and sidx.n_active == 0
    assert sidx.segments[0].n_rows == 5 and sidx.seg_base == 5
    assert sorted(np.asarray(sidx.segments[0].gids).ravel()
                  [np.asarray(sidx.segments[0].gids).ravel() >= 0]) \
        == [20, 21, 22, 23, 24]
    sidx = sidx.add_documents(rng.normal(size=(3, N_FEAT))
                              .astype(np.float32))
    assert sidx.n_segments == 1 and sidx.n_active == 3   # below threshold
    sidx = sidx.add_documents(rng.normal(size=(2, N_FEAT))
                              .astype(np.float32))
    assert sidx.n_segments == 2 and sidx.n_active == 0   # 3 + 2 sealed
    assert sidx.segments[1].n_rows == 5
    assert sidx.n_ids == 30 and sidx.segment_rows == 10


def test_segment_tombstone_accounting_and_exact_df():
    """Deletes land in the right generation's ``tombstones`` (what the
    merge policy consults) and keep df EXACT -- ``token_df`` stays
    bit-equal to the flat index's through sealed + active deletes."""
    V, Q, rng = _build(n_docs=20)
    mesh = make_shard_mesh(1)
    seg = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=4)
    flat = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=None)
    W = rng.normal(size=(5, N_FEAT)).astype(np.float32)
    seg, flat = seg.add_documents(W), flat.add_documents(W)
    W2 = rng.normal(size=(2, N_FEAT)).astype(np.float32)
    seg, flat = seg.add_documents(W2), flat.add_documents(W2)
    assert seg.n_segments == 1 and seg.n_active == 2
    # 20..24 sealed, 25..26 active; hit one of each + a base doc
    seg, flat = seg.delete([5, 21, 26]), flat.delete([5, 21, 26])
    assert seg.segments[0].tombstones == 1
    assert seg.segments[0].deleted_ratio == pytest.approx(1 / 5)
    assert seg.active_tombstones == 1
    assert seg.n_tombstones == flat.n_tombstones == 3
    assert np.array_equal(np.asarray(seg.token_df(Q)),
                          np.asarray(flat.token_df(Q)))
    _assert_same_results(seg, flat, Q, "df after segment deletes")


def test_merge_segments_reclaims_and_validates():
    V, Q, rng = _build(n_docs=16)
    sidx = ShardedVectorIndex.build_sharded(V, make_shard_mesh(1),
                                            seal_threshold=4)
    with pytest.raises(ValueError, match="no sealed segments"):
        sidx.merge_segments()
    for _ in range(3):
        sidx = sidx.add_documents(rng.normal(size=(4, N_FEAT))
                                  .astype(np.float32))
    assert sidx.n_segments == 3
    sidx = sidx.delete([17, 18, 21])            # 2 dead in seg0, 1 in seg1
    with pytest.raises(ValueError, match="invalid merge range"):
        sidx.merge_segments(2, 2)
    with pytest.raises(ValueError, match="invalid merge range"):
        sidx.merge_segments(-1, 1)
    with pytest.raises(ValueError, match="invalid merge range"):
        sidx.merge_segments(0, 0)
    merged = sidx.merge_segments(0, 2)
    assert merged.n_segments == 2
    assert merged.segments[0].n_rows == 5       # 8 rows - 3 tombstones
    assert merged.segments[0].tombstones == 0
    assert merged.segments[1].n_rows == sidx.segments[2].n_rows
    assert merged.n_reclaimed == sidx.n_reclaimed + 3
    assert merged.n_ids == sidx.n_ids
    _assert_same_results(merged, sidx, Q, "merge preserves results")


# ------------------------------------------------------------ merge policy
def _fake_index(*rows_tombs):
    segs = tuple(SimpleNamespace(n_rows=r, tombstones=t,
                                 deleted_ratio=t / max(r, 1))
                 for r, t in rows_tombs)
    return SimpleNamespace(segments=segs)


def test_merge_policy_validates():
    with pytest.raises(ValueError, match="merge_factor"):
        TieredMergePolicy(merge_factor=1)
    with pytest.raises(ValueError, match="segment_deletes"):
        TieredMergePolicy(segment_deletes=0.0)


def test_merge_policy_none_without_segments():
    pol = TieredMergePolicy()
    assert pol.select(_fake_index()) is None
    assert pol.select(SimpleNamespace()) is None     # flat VectorIndex


def test_merge_policy_delete_pressure_beats_tier():
    """A generation past ``segment_deletes`` is rewritten ALONE, even
    when a tier fold is also available -- reclaiming deletes is the
    priority, exactly ES ``deletes_pct_allowed``."""
    pol = TieredMergePolicy(merge_factor=2, segment_deletes=0.2)
    sel = pol.select(_fake_index((8, 0), (8, 3), (8, 0)))
    assert sel == {"start": 1, "count": 1, "reason": "deletes",
                   "deleted_ratio": pytest.approx(3 / 8)}


def test_merge_policy_tier_window():
    """Without delete pressure, the first contiguous run of
    ``merge_factor`` SIMILAR-sized segments folds; a giant next to minis
    is left alone (max > mf * min -- Lucene's tier criterion)."""
    pol = TieredMergePolicy(merge_factor=2, segment_deletes=0.5)
    assert pol.select(_fake_index((100, 0), (4, 0))) is None
    sel = pol.select(_fake_index((100, 0), (4, 0), (5, 0)))
    assert sel == {"start": 1, "count": 2, "reason": "tier"}
    assert pol.select(_fake_index((6, 0))) is None   # below merge_factor


# ----------------------------------------------------------------- daemon
def _segmented_engine(rng, *, n_docs=16, adds=3):
    sidx = ShardedVectorIndex.build_sharded(
        rng.normal(size=(n_docs, N_FEAT)).astype(np.float32),
        make_shard_mesh(1), seal_threshold=4)
    for _ in range(adds):
        sidx = sidx.add_documents(rng.normal(size=(4, N_FEAT))
                                  .astype(np.float32))
    return BatchedSearchEngine(sidx, batch_size=2, trim=None, engine="codes")


def test_daemon_applies_planned_merges_concurrently():
    """One sweep, two groups with tier-fold work: both merge (the
    concurrent apply path), events/metrics/stats reconcile, the global
    compact never fires."""
    rng = np.random.default_rng(3)
    reg = MetricsRegistry()
    engines = [_segmented_engine(rng), _segmented_engine(rng)]
    try:
        daemon = MaintenanceDaemon(
            engines, threshold=0.9, metrics=reg,
            merge_policy=TieredMergePolicy(merge_factor=3))
        for e in engines:
            assert e.index.n_segments == 3
        assert daemon.poll_once() == 2
        assert daemon.merges == 2 and daemon.compactions == 0
        assert not daemon.failures
        for e in engines:
            assert e.index.n_segments == 1          # 3 folded into 1
        assert sorted(ev["group"] for ev in daemon.merge_events) == [0, 1]
        for ev in daemon.merge_events:
            assert ev["reason"] == "tier"
            assert (ev["start"], ev["count"]) == (0, 3)
        assert reg.series("maintenance.merges") == \
            {"group=0": 1, "group=1": 1}
        assert daemon.poll_once() == 0              # steady state
    finally:
        for e in engines:
            e.close()


def test_daemon_delete_pressure_singleton_rewrite():
    """A delete-heavy generation triggers a reason='deletes' singleton
    merge that reclaims exactly its tombstones -- and the reclaim shows
    up in the per-group counters the stats layer reads."""
    rng = np.random.default_rng(4)
    reg = MetricsRegistry()
    eng = _segmented_engine(rng)
    try:
        eng.delete([18, 19])                        # 2/4 dead in segment 0
        snapshot = eng.index
        assert snapshot.segments[0].deleted_ratio == pytest.approx(0.5)
        daemon = MaintenanceDaemon(
            [eng], threshold=0.9, metrics=reg,
            merge_policy=TieredMergePolicy(merge_factor=4,
                                           segment_deletes=0.2))
        assert daemon.poll_once() == 1
        ev = daemon.merge_events[0]
        assert ev["reason"] == "deletes"
        assert (ev["start"], ev["count"], ev["reclaimed"]) == (0, 1, 2)
        assert eng.index.segments[0].tombstones == 0
        assert eng.index.segments[0].n_rows == 2
        assert reg.series("maintenance.merge.reclaimed") == {"group=0": 2}
    finally:
        eng.close()


def test_daemon_merge_policy_off_keeps_old_behavior():
    """merge_policy=None (what probe-only daemons get): segments are
    never touched; only the global compact threshold acts."""
    rng = np.random.default_rng(5)
    eng = _segmented_engine(rng)
    try:
        daemon = MaintenanceDaemon([eng], threshold=0.9, merge_policy=None)
        assert daemon.poll_once() == 0
        assert eng.index.n_segments == 3
    finally:
        eng.close()


# ------------------------------------------------------------------ stats
def test_index_stats_exposes_segment_story():
    rng = np.random.default_rng(6)
    sidx = ShardedVectorIndex.build_sharded(
        rng.normal(size=(16, N_FEAT)).astype(np.float32),
        make_shard_mesh(1), seal_threshold=4)
    sidx = sidx.add_documents(rng.normal(size=(4, N_FEAT))
                              .astype(np.float32))
    sidx = sidx.add_documents(rng.normal(size=(2, N_FEAT))
                              .astype(np.float32))
    sidx = sidx.delete([17, 20])                    # one sealed, one active
    st = index_stats(sidx)
    assert st["n_segments"] == 1
    assert st["segments"] == [{"rows": 4, "width": 4, "tombstones": 1,
                               "deleted_ratio": pytest.approx(0.25)}]
    assert st["n_active"] == 2 and st["active_tombstones"] == 1
    assert st["seg_base"] == 4 and st["n_reclaimed"] == 0
    line = format_segments_line(st)
    assert line == ("segments base=16 seg0=4-1 active=2-1 tombstones=2")
    merged = sidx.merge_segments()
    st2 = index_stats(merged)
    assert st2["n_reclaimed"] == 1
    assert st2["segments"][0]["tombstones"] == 0
    assert "reclaimed=1" in format_segments_line(st2)


# ----------------------------------------------------- multi-device parity
def _run_subprocess(script: str) -> None:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_REPO)
    assert "OK" in out.stdout, out.stdout + out.stderr


def _prelude(n_devices):
    return rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
import numpy as np
from repro.dist.shard_index import ShardedVectorIndex
from repro.launch.mesh import make_shard_mesh

def check(seg, flat, Q, ctx):
    assert seg.n_ids == flat.n_ids, ctx
    for engine in ("postings", "codes", "onehot", "fused", "fused_int8"):
        for k in (1, 9):
            i1, s1 = flat.search(Q, k=k, page=2 * flat.n_ids, engine=engine)
            i2, s2 = seg.search(Q, k=k, page=2 * seg.n_ids, engine=engine)
            assert np.array_equal(np.asarray(i1), np.asarray(i2)), \
                (ctx, engine, k)
            assert np.array_equal(np.asarray(s1), np.asarray(s2)), \
                (ctx, engine, k)

def lifecycle(mesh):
    rng = np.random.default_rng(0)
    V = rng.normal(size=(54, 12)).astype(np.float32)
    Q = rng.normal(size=(7, 12)).astype(np.float32)
    seg = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=6)
    flat = ShardedVectorIndex.build_sharded(V, mesh, seal_threshold=None)
    for step in range(3):
        W = rng.normal(size=(7, 12)).astype(np.float32)
        seg, flat = seg.add_documents(W), flat.add_documents(W)
        check(seg, flat, Q, ("ingest", step))
    assert seg.n_segments >= 2
    victims = [1, 55, 56, 60, 71]
    seg, flat = seg.delete(victims), flat.delete(victims)
    check(seg, flat, Q, "deleted")
    merged = seg.merge_segments(0, 2)
    check(merged, flat, Q, "merged")
    seg, flat = merged.compact(), flat.compact()
    assert seg.n_segments == 0
    check(seg, flat, Q, "compacted")
"""


def test_four_shard_lifecycle_parity():
    """4-device mesh: the full segment lifecycle stays bit-identical to
    the flat path (ragged splits included -- 54 % 4 != 0)."""
    _run_subprocess(_prelude(4) + r"""
lifecycle(make_shard_mesh(4))
print("OK")
""")


def test_replica_mesh_lifecycle_parity():
    """4 shards x 2 replicas on 8 devices: sealing/merging touches every
    replica column identically (the replica axis stays unmentioned in
    every segment leaf's spec), so parity holds through the lifecycle."""
    _run_subprocess(_prelude(8) + r"""
lifecycle(make_shard_mesh(4, 2))
print("OK")
""")
