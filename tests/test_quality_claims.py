"""Directional reproduction of the paper's empirical claims (C1-C5,
DESIGN.md §1) on the synthetic topic corpus with a real LSA pipeline.

Scaled down from 4.18M Wikipedia articles to a 3k-doc corpus; the claims are
about curve SHAPES and orderings, which are scale-robust.  Exact paper-scale
numbers are produced by benchmarks/table2_quality.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    BestFilter,
    MLTIndex,
    TrimFilter,
    VectorIndex,
    avg_diff,
    ndcg_k,
    precision_at_k,
)
from repro.data import make_corpus
from repro.lsa import build_lsa


@pytest.fixture(scope="module")
def setup():
    corpus = make_corpus(n_docs=3000, vocab_size=12000, n_topics=40, seed=7)
    pipe = build_lsa(corpus, n_features=128)
    idx = VectorIndex.build(pipe.doc_vectors)
    nq = 32
    Q = pipe.doc_vectors[:nq]
    gold_ids, gold_sims = idx.gold_topk(Q, 10)
    return corpus, pipe, idx, Q, gold_ids, gold_sims


def test_c1_avg_diff_decreases_with_page(setup):
    """C1: avg.diff decreases (log-like) as page grows, up to page=640."""
    _, _, idx, Q, gold_ids, gold_sims = setup
    diffs = []
    for page in [20, 80, 320, 640]:
        _, sims = idx.search(Q, k=10, page=page, trim=TrimFilter(0.05), engine="codes")
        diffs.append(float(avg_diff(sims, gold_sims).mean()))
    assert all(a >= b - 1e-6 for a, b in zip(diffs, diffs[1:])), diffs
    assert diffs[0] > diffs[-1]


def test_c2_trim_005_close_to_unfiltered(setup):
    """C2: trim=0.05 quality ~ unfiltered quality at the same page."""
    _, _, idx, Q, gold_ids, gold_sims = setup
    ids_f, s_f = idx.search(Q, k=10, page=320, engine="codes")
    ids_t, s_t = idx.search(Q, k=10, page=320, trim=TrimFilter(0.05), engine="codes")
    p_f = float(precision_at_k(ids_f, gold_ids).mean())
    p_t = float(precision_at_k(ids_t, gold_ids).mean())
    assert p_t >= p_f - 0.08, (p_t, p_f)
    # ...while touching far fewer features
    _, _, w = idx.encode_queries(Q, TrimFilter(0.05), None, "idf")
    kept = float((w > 0).sum(-1).mean())
    assert kept < 0.75 * idx.n_features


def test_c2b_aggressive_trim_is_lossy(setup):
    """C2: trimming to very few features visibly degrades avg.diff."""
    _, _, idx, Q, gold_ids, gold_sims = setup
    _, s_mild = idx.search(Q, k=10, page=320, best=BestFilter(90), engine="codes")
    _, s_aggr = idx.search(Q, k=10, page=320, best=BestFilter(6), engine="codes")
    assert float(avg_diff(s_aggr, gold_sims).mean()) > \
        float(avg_diff(s_mild, gold_sims).mean())


def test_c3_beats_mlt_baseline(setup):
    """C3: encoded-vector search beats MLT on P@10, nDCG and avg.diff."""
    corpus, pipe, idx, Q, gold_ids, gold_sims = setup
    nq = Q.shape[0]
    ids_ours, sims_ours = idx.search(Q, k=10, page=320, trim=TrimFilter(0.05),
                                     engine="codes")
    mlt = MLTIndex.build(jnp.asarray(corpus.doc_terms), jnp.asarray(corpus.doc_tf),
                         corpus.vocab_size)
    ids_mlt, _ = mlt.more_like_this(jnp.asarray(corpus.doc_terms[:nq]),
                                    jnp.asarray(corpus.doc_tf[:nq]),
                                    max_query_terms=25, k=10)
    V = np.asarray(idx.vectors)
    qn = np.asarray(idx.vectors[:nq])
    sims_mlt = jnp.asarray(np.take_along_axis(qn @ V.T, np.asarray(ids_mlt), axis=1))

    assert float(precision_at_k(ids_ours, gold_ids).mean()) > \
        float(precision_at_k(ids_mlt, gold_ids).mean())
    assert float(ndcg_k(sims_ours, gold_sims).mean()) > \
        float(ndcg_k(sims_mlt, gold_sims).mean())
    assert float(avg_diff(sims_ours, gold_sims).mean()) < \
        float(avg_diff(sims_mlt, gold_sims).mean())


def test_c4_full_page_is_exact(setup):
    """C4: page >= |D| makes the two-phase search identical to brute force."""
    _, _, idx, Q, gold_ids, gold_sims = setup
    ids, sims = idx.search(Q, k=10, page=idx.n_docs, engine="codes")
    assert (np.asarray(ids) == np.asarray(gold_ids)).all()


def test_c5_query_side_only_filtering(setup):
    """C5: filters apply per-request without touching the index, and
    different requests can use different filters."""
    _, _, idx, Q, gold_ids, _ = setup
    codes_before = np.asarray(idx.codes).copy()
    p = []
    for f in [None, TrimFilter(0.05), TrimFilter(0.2)]:
        ids, _ = idx.search(Q, k=10, page=160, trim=f, engine="codes")
        p.append(float(precision_at_k(ids, gold_ids).mean()))
    assert (np.asarray(idx.codes) == codes_before).all()
    assert p[0] >= p[2] - 1e-6  # stronger filtering never helps quality
