#!/usr/bin/env python3
"""Static lint: no host-side observability calls inside jitted bodies.

The entire obs plane (PRs 6-9) rests on one convention: instrumentation
records timestamps and metrics AROUND jitted program dispatch, never
inside it.  A ``time.monotonic()`` or ``MetricsRegistry`` call that
drifts into a traced function body would either burn a constant into
the compiled program (silently wrong telemetry) or force a host
callback (silently slow kernels) -- and nothing enforced the convention
mechanically.  This lint does:

1. parse every module under ``src/repro`` and collect the *jit roots*:
   function defs decorated with ``jax.jit`` / ``partial(jax.jit, ...)``,
   plus any local ``def``/``lambda`` passed positionally to ``jax.jit``
   or ``shard_map`` (name lookup is by simple module-wide match -- an
   over-approximation, which for a lint is the right direction);
2. walk each root's body INCLUDING nested defs (inner functions run
   traced too) and fail on:
   - any ``time.*`` call (or a call to a name imported from ``time``),
   - any reference to ``MetricsRegistry`` / ``default_registry`` or a
     method call on an attribute named ``metrics``.

Exit 0 when clean, 1 with ``file:line`` diagnostics otherwise.  Wired
into ``make test`` so the seam invariant fails the build, not a code
review.  No JAX import, no repo import -- pure ``ast``, so it runs in
milliseconds anywhere.
"""

from __future__ import annotations

import ast
import os
import sys
from typing import List, Optional, Tuple

DEFAULT_ROOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src", "repro")


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_ref(node: ast.AST) -> bool:
    d = _dotted(node)
    return d in ("jax.jit", "jit")


def _is_partial_jit(call: ast.Call) -> bool:
    """``partial(jax.jit, ...)`` / ``functools.partial(jax.jit, ...)``."""
    if _dotted(call.func) not in ("partial", "functools.partial"):
        return False
    return any(_is_jit_ref(a) for a in call.args)


def _is_jitted_def(fn) -> bool:
    for dec in fn.decorator_list:
        if _is_jit_ref(dec):
            return True
        if isinstance(dec, ast.Call) and (_is_jit_ref(dec.func)
                                          or _is_partial_jit(dec)):
            return True
    return False


class _RootCollector(ast.NodeVisitor):
    """Names passed to jax.jit/shard_map + inline lambdas/defs."""

    def __init__(self):
        self.jitted_names: set = set()
        self.inline_roots: List[ast.AST] = []

    def visit_Call(self, call: ast.Call):
        callee = _dotted(call.func)
        if _is_jit_ref(call.func) or callee in ("shard_map",
                                                "jax.shard_map"):
            for arg in call.args[:1]:     # the traced callable is arg 0
                if isinstance(arg, ast.Name):
                    self.jitted_names.add(arg.id)
                elif isinstance(arg, (ast.Lambda, ast.Call)):
                    self.inline_roots.append(arg)
        self.generic_visit(call)


class _SeamChecker(ast.NodeVisitor):
    """Walk one jitted body; record host-seam violations."""

    def __init__(self, path: str, root_name: str, time_names: set):
        self.path = path
        self.root_name = root_name
        self.time_names = time_names
        self.violations: List[Tuple[str, int, str]] = []

    def _flag(self, node: ast.AST, what: str):
        self.violations.append(
            (self.path, node.lineno,
             f"{what} inside jitted body of '{self.root_name}'"))

    def visit_Call(self, call: ast.Call):
        d = _dotted(call.func)
        if d is not None:
            head, _, _rest = d.partition(".")
            if head == "time" and "." in d:
                self._flag(call, f"'{d}()' (host clock)")
            elif d in self.time_names:
                self._flag(call, f"'{d}()' (imported from time)")
            elif "metrics." in d or d.startswith("metrics."):
                self._flag(call, f"'{d}()' (metrics record)")
        self.generic_visit(call)

    def visit_Name(self, name: ast.Name):
        if name.id in ("MetricsRegistry", "default_registry"):
            self._flag(name, f"'{name.id}' reference")
        self.generic_visit(name)


def _time_imports(tree: ast.Module) -> set:
    """Names bound from ``from time import ...`` at module level."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                names.add(alias.asname or alias.name)
    return names


def check_file(path: str) -> List[Tuple[str, int, str]]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [(path, exc.lineno or 0, f"syntax error: {exc.msg}")]
    time_names = _time_imports(tree)

    collector = _RootCollector()
    collector.visit(tree)

    roots: List[Tuple[str, ast.AST]] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_jitted_def(node) or node.name in collector.jitted_names:
                roots.append((node.name, node))
    for node in collector.inline_roots:
        roots.append(("<lambda>", node))

    violations: List[Tuple[str, int, str]] = []
    for name, root in roots:
        checker = _SeamChecker(path, name, time_names)
        body = root.body if hasattr(root, "body") else [root]
        if isinstance(body, list):
            for stmt in body:
                checker.visit(stmt)
        else:                               # lambda body: an expression
            checker.visit(body)
        violations.extend(checker.violations)
    return violations


def main(argv: List[str]) -> int:
    root = argv[1] if len(argv) > 1 else DEFAULT_ROOT
    files = []
    for dirpath, _dirs, fnames in os.walk(root):
        files.extend(os.path.join(dirpath, fn)
                     for fn in fnames if fn.endswith(".py"))
    violations = []
    for path in sorted(files):
        violations.extend(check_file(path))
    if violations:
        for path, line, msg in violations:
            print(f"{path}:{line}: {msg}", file=sys.stderr)
        print(f"check_host_seams: {len(violations)} violation(s) in "
              f"{root}", file=sys.stderr)
        return 1
    print(f"check_host_seams: OK ({len(files)} files, "
          f"no host calls in jitted bodies)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
