#!/usr/bin/env python3
"""Validate auto-dumped diagnostics bundles (the ``make smoke-health``
follow-up check).

Given a directory of ``diagnostics-*.json`` files written by
``serve.py --diagnostics-on-exit``, assert that:

* at least one bundle exists for each expected reason (``failover`` is
  required when any bundle names it; ``exit`` always);
* every bundle parses as JSON and contains EVERY documented section key
  (``BUNDLE_SECTIONS`` is loaded from the diagnostics module itself, so
  this check can never drift from the writer);
* the load-bearing sections are populated: stats counted requests,
  health reports a status, the device table holds bytes, and the
  failover bundle's health section shows the down group the incident
  injected.

Pure stdlib + one by-path module load (no jax import): the validator
must be able to run anywhere the JSON can.
"""

from __future__ import annotations

import importlib.util
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bundle_sections():
    """Load BUNDLE_SECTIONS straight from the module file -- not through
    the package, whose __init__ would pull in jax."""
    path = os.path.join(_ROOT, "src", "repro", "obs", "diagnostics.py")
    spec = importlib.util.spec_from_file_location("_diag", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return tuple(mod.BUNDLE_SECTIONS)


def validate(directory: str) -> int:
    sections = _bundle_sections()
    files = sorted(fn for fn in os.listdir(directory)
                   if fn.startswith("diagnostics-") and fn.endswith(".json"))
    if not files:
        print(f"validate_diag_bundle: no bundles in {directory}",
              file=sys.stderr)
        return 1
    failures = []
    reasons = []
    for fn in files:
        path = os.path.join(directory, fn)
        try:
            with open(path) as f:
                b = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            failures.append(f"{fn}: unparseable: {exc}")
            continue
        missing = [s for s in sections if s not in b]
        if missing:
            failures.append(f"{fn}: missing section(s) {missing}")
            continue
        reason = (b["meta"] or {}).get("reason")
        reasons.append(reason)
        if not b["stats"] or b["stats"]["requests"]["completed"] < 1:
            failures.append(f"{fn}: stats section has no completed requests")
        if b["health"] is not None and "status" not in b["health"]:
            failures.append(f"{fn}: health section has no status")
        dev = b["device"] or {}
        if not any(d.get("total_bytes", 0) > 0 for d in dev.values()):
            failures.append(f"{fn}: device table holds no bytes")
        if reason == "failover":
            h = b["health"] or {}
            if h.get("status") != "yellow" or not h.get("down"):
                failures.append(
                    f"{fn}: failover bundle should capture the yellow "
                    f"mid-incident state, got {h.get('status')!r} "
                    f"down={h.get('down')!r}")
        print(f"validate_diag_bundle: {fn}: reason={reason} "
              f"sections={len(sections)} ok")
    if "exit" not in reasons:
        failures.append("no bundle with reason=exit (the end-of-run dump)")
    if failures:
        for msg in failures:
            print(f"validate_diag_bundle: FAIL {msg}", file=sys.stderr)
        return 1
    print(f"validate_diag_bundle: OK ({len(files)} bundle(s), every "
          f"section present: {', '.join(sections)})")
    return 0


if __name__ == "__main__":
    sys.exit(validate(sys.argv[1] if len(sys.argv) > 1
                      else os.path.join(_ROOT, "artifacts", "diag_smoke")))
