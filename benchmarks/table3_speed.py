"""Paper Table 3: speed grid -- engine x trim x page x query-batch.

The paper's 'parallel queries 1/4/16' maps to the query batch dimension
(DESIGN.md §2); 'ES took' maps to the jitted search step time;
'Vec. size avg/std' = features surviving the trim, exactly as in the paper.

Usage: PYTHONPATH=src python -m benchmarks.table3_speed [--quick]
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import TrimFilter

from .common import ART, fixture, timed


def run(quick: bool = False):
    fx = fixture()
    idx = fx.index

    engines = ["codes", "postings"]
    trims = [0.0, 0.05, 0.1]
    pages = [20, 80, 320]
    batches = [1, 4, 16]
    if quick:
        engines, trims, pages, batches = ["codes"], [0.0, 0.1], [20, 320], [4]

    rows = []
    for engine in engines:
        for nb in batches:
            Q = fx.queries[:nb]
            for trim in trims:
                tf = TrimFilter(trim) if trim else None
                _, _, w = idx.encode_queries(Q, tf, None, "idf")
                sizes = np.asarray((w > 0).sum(-1), np.float64)
                for page in pages:
                    fn = lambda: idx.search(Q, k=10, page=page, trim=tf,
                                            engine=engine,
                                            max_postings=4096 if engine == "postings" else None)
                    _, secs = timed(fn, repeats=2 if quick else 3)
                    rows.append({
                        "engine": engine, "parallel_q": nb, "trim": trim,
                        "page": page, "step_avg_s": secs,
                        "per_query_s": secs / nb,
                        "vec_size_avg": float(sizes.mean()),
                        "vec_size_std": float(sizes.std()),
                    })
                    print(f"{engine:9s} q={nb:<3d} trim={trim:<5.2f} page={page:<4d} "
                          f"step={secs*1e3:8.2f}ms per_q={secs/nb*1e3:8.2f}ms "
                          f"vec={sizes.mean():6.1f}±{sizes.std():4.1f}")

    import csv, os
    with open(os.path.join(ART, "table3_speed.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
