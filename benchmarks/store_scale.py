"""Durability cost curves: ingest throughput vs translog policy, recovery
time vs translog length.

    PYTHONPATH=src python -m benchmarks.store_scale \
        [--shards 1,4] [--docs 20000] [--ingest-batch 64] [--batches 8] \
        [--json out]

Two questions the store subsystem (repro/store) makes measurable:

1. **What does durability cost on the ingest path?**  The same hot-add
   stream runs three ways: no store (the PR 3 memory-only baseline),
   ``durability=async`` (translog append, buffered), and
   ``durability=request`` (fsync before every ack, the ES default).  The
   spread between the three is the price of the write-ahead log and of
   the fsync respectively.
2. **What does recovery cost, and how does it scale with the translog?**
   ``recover()`` = restore the latest commit point + replay the
   uncommitted ops; recovery wall time is measured at increasing
   translog lengths (0, then after each batch of ops) against a fixed
   commit, plus once more after a fresh commit (zero replay -- the
   commit-restore floor).  The gap between the floor and the replay
   curve is the argument for the maintenance daemon's post-compaction
   commits trimming the log.  The fresh commit also logs its honest
   cost -- ``bytes_written`` vs ``bytes_total`` -- so the
   content-addressed O(changed) claim rides in this artifact too
   (benchmarks/segment_scale.py has the full bytes-vs-generation curve).

Rows *append* to ``artifacts/BENCH_store_scale.json`` (one run entry per
invocation) so the trajectory accumulates across PRs.  ``benchmarks/
run.py`` invokes this in a subprocess (the virtual-device flag must
precede jax initialisation).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile

# XLA_FLAGS must be set before the first jax import
_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--shards", default="1,4",
                   help="comma-separated shard counts (each its own mesh)")
_ARGS.add_argument("--docs", type=int, default=20000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--ingest-batch", type=int, default=64)
_ARGS.add_argument("--batches", type=int, default=8,
                   help="ingest batches per policy (also the recovery-curve "
                        "translog lengths)")
_ARGS.add_argument("--queries", type=int, default=32,
                   help="queries for the recovered-vs-live parity assert")
_ARGS.add_argument("--repeats", type=int, default=3)
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "BENCH_store_scale.json"))


def _parse():
    args = _ARGS.parse_args()
    args.shard_counts = sorted(
        {int(s) for s in args.shards.split(",") if s.strip()})
    return args


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.hostdev import force_host_devices

    _early = _parse()
    force_host_devices(max(_early.shard_counts))

import time

import numpy as np


def run(shard_counts, n_docs=20000, n_features=64, ingest_batch=64,
        n_batches=8, repeats=3, n_queries=32):
    import jax
    from repro.dist.shard_index import ShardedVectorIndex
    from repro.launch.mesh import make_shard_mesh
    from repro.store import Store, recover

    rng = np.random.default_rng(0)
    V = rng.normal(size=(n_docs, n_features)).astype(np.float32)
    Q = V[rng.choice(n_docs, size=n_queries, replace=False)]
    batches = [rng.normal(size=(ingest_batch, n_features)).astype(np.float32)
               for _ in range(n_batches)]

    rows = []
    for s in shard_counts:
        if s > len(jax.devices()):
            print(f"store_scale,shards={s},0,"
                  f"SKIPPED_only_{len(jax.devices())}_devices")
            rows.append({"shards": s, "skipped": True,
                         "reason": f"only {len(jax.devices())} devices"})
            continue
        mesh = make_shard_mesh(s)
        base = ShardedVectorIndex.build_sharded(V, mesh)

        # ---- ingest throughput vs durability policy ------------------
        for policy in ("none", "async", "request"):
            best, best_lat = np.inf, []
            for _ in range(repeats):
                tmp = tempfile.mkdtemp(prefix="bench_store_")
                try:
                    if policy == "none":
                        idx = base
                    else:
                        store = Store(tmp, durability=policy)
                        idx = store.open_index(base)
                    idx.add_documents(batches[0])       # compile warm-up
                    # per-op wall = the ack latency an ingest client sees
                    # (durability=request pays its fsync INSIDE this window)
                    lats = []
                    t0 = time.perf_counter()
                    run_idx = idx
                    for b in batches:
                        t1 = time.perf_counter()
                        run_idx = run_idx.add_documents(b)
                        lats.append(time.perf_counter() - t1)
                    jax.block_until_ready(run_idx.seg_vectors)
                    wall = time.perf_counter() - t0
                    if wall < best:
                        best, best_lat = wall, lats
                    if policy != "none":
                        store.close()
                finally:
                    shutil.rmtree(tmp, ignore_errors=True)
            total = n_batches * ingest_batch
            from benchmarks.common import latency_percentiles

            tails = latency_percentiles(best_lat)
            rows.append({
                "mode": "ingest", "shards": s, "durability": policy,
                "docs_per_s": total / best, "latency": tails,
                "ingest_batch": ingest_batch,
                "n_batches": n_batches, "n_docs": n_docs,
                "n_features": n_features,
            })
            print(f"store_scale,shards={s},{best / total * 1e6:.0f},"
                  f"mode=ingest;durability={policy};"
                  f"docs_per_s={total / best:.0f};"
                  f"p50_ms={tails['p50_ms']:.2f};p99_ms={tails['p99_ms']:.2f}")

        # ---- recovery time vs translog length ------------------------
        tmp = tempfile.mkdtemp(prefix="bench_store_")
        try:
            store = Store(tmp, durability="async")
            idx = store.open_index(base)            # commit point at seq 0
            for n_ops in range(n_batches + 1):
                if n_ops:
                    idx = idx.add_documents(batches[n_ops - 1])
                    store.translog.sync()
                best, samples = np.inf, []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    rec, seq = recover(tmp, make_shard_mesh(s))
                    jax.block_until_ready(rec.vectors)
                    samples.append(time.perf_counter() - t0)
                    best = min(best, samples[-1])
                assert seq == n_ops and rec.n_ids == idx.n_ids
                from benchmarks.common import latency_percentiles
                rows.append({
                    "mode": "recover", "shards": s, "translog_ops": n_ops,
                    "recover_s": best,
                    "latency": latency_percentiles(samples),
                    "n_ids": int(idx.n_ids),
                    "n_docs": n_docs, "n_features": n_features,
                })
                print(f"store_scale,shards={s},{best * 1e6:.0f},"
                      f"mode=recover;translog_ops={n_ops};"
                      f"recover_s={best:.4f}")
            # the commit-restore floor: fresh commit, zero replay -- and
            # the honest commit cost: bytes actually written vs bytes the
            # commit references (content-addressed blobs re-reference
            # unchanged parts, so written << total past generation 1)
            store.commit(idx)
            reg = store.metrics
            written = reg.value("store.commit.last_bytes_written")
            total_b = reg.value("store.commit.last_bytes_total")
            rows.append({
                "mode": "commit", "shards": s,
                "bytes_written": written, "bytes_total": total_b,
                "n_ids": int(idx.n_ids), "n_docs": n_docs,
                "n_features": n_features,
            })
            print(f"store_scale,shards={s},{written:.0f},"
                  f"mode=commit;bytes_written={written:.0f};"
                  f"bytes_total={total_b:.0f}")
            best, samples = np.inf, []
            for _ in range(repeats):
                t0 = time.perf_counter()
                rec, _ = recover(tmp, make_shard_mesh(s))
                jax.block_until_ready(rec.vectors)
                samples.append(time.perf_counter() - t0)
                best = min(best, samples[-1])
            rows.append({
                "mode": "recover", "shards": s, "translog_ops": 0,
                "post_commit": True, "recover_s": best,
                "latency": latency_percentiles(samples),
                "n_ids": int(idx.n_ids), "n_docs": n_docs,
                "n_features": n_features,
            })
            print(f"store_scale,shards={s},{best * 1e6:.0f},"
                  f"mode=recover;post_commit=1;recover_s={best:.4f}")
            # recovered-vs-live bit-parity: the durability analogue of
            # cluster_scale's failover parity assert
            li, ls = idx.search(Q, k=10, page=2 * idx.n_ids)
            ri, rs = rec.search(Q, k=10, page=2 * rec.n_ids)
            assert np.array_equal(np.asarray(li), np.asarray(ri)) and \
                np.array_equal(np.asarray(ls), np.asarray(rs)), \
                "recovered index diverged from live"
            store.close()
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    return rows


def main(argv_args=None):
    args = argv_args or _parse()
    rows = run(args.shard_counts, n_docs=args.docs,
               n_features=args.features, ingest_batch=args.ingest_batch,
               n_batches=args.batches, repeats=args.repeats,
               n_queries=args.queries)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # append, never overwrite: the trajectory accumulates across PRs
    doc = {"bench": "store_scale", "runs": []}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass  # unreadable history: start a fresh file rather than crash
    doc["runs"].append({"rows": rows})
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {len(doc['runs'])} to {out}")


if __name__ == "__main__":
    main(_early)
