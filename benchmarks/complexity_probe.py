"""Paper §2.3 complexity table, probed empirically.

Checks the scaling claims: naive search O(nd) in docs; postings time driven
by posting-window work (trim cuts it ~linearly); codes engine linear in d
with a small constant (int8 stream).
Usage: PYTHONPATH=src python -m benchmarks.complexity_probe
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import TrimFilter, VectorIndex

from .common import ART, timed


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    n = 64
    rows = []
    sizes = [2000, 8000] if quick else [2000, 8000, 32000]
    for d in sizes:
        V = rng.normal(size=(d, n)).astype(np.float32)
        idx = VectorIndex.build(V)
        Q = jnp.asarray(V[:8])
        for name, fn in {
            "naive": lambda: idx.gold_topk(Q, 10),
            "codes": lambda: idx.search(Q, k=10, page=min(320, d), engine="codes"),
            "postings": lambda: idx.search(Q, k=10, page=min(320, d),
                                           engine="postings", max_postings=2048),
            "codes_trim": lambda: idx.search(Q, k=10, page=min(320, d),
                                             trim=TrimFilter(0.1), engine="codes"),
        }.items():
            _, secs = timed(fn, repeats=2)
            rows.append({"n_docs": d, "engine": name, "s": secs})
            print(f"d={d:<7d} {name:12s} {secs*1e3:9.2f} ms")

    import csv, os
    with open(os.path.join(ART, "complexity_probe.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
