"""Observability overhead: serving QPS with instrumentation on vs off.

    PYTHONPATH=src python -m benchmarks.obs_overhead \
        [--docs 8000] [--queries 64] [--max-overhead 0.03] [--json out]

The obs layer (:mod:`repro.obs`) promises to be cheap enough to leave on
in production: counters/histograms are a dict lookup + bisect per record,
and tracing admits one query in 16 by default (counter-based, no RNG).
This bench measures the promise instead of asserting it by construction.
The same query load runs through two ``BatchedSearchEngine``s over one
shared index:

* **off** -- ``MetricsRegistry(enabled=False)`` and no tracer: every
  record collapses to a single attribute check, the configuration a
  latency-critical deployment would pick;
* **on**  -- an enabled registry plus a ``Tracer`` at the default 1/16
  sampling rate: the configuration everything else in this repo runs
  with;
* **full** -- everything v2 added on top of ``on``: a tail-sampled
  :class:`~repro.obs.slowlog.SlowLog` (every request gets a span
  skeleton), a :class:`~repro.obs.compile_watch.CompileWatch` wrapping
  the dispatch seams (which now also captures per-program FLOP/byte
  cost analysis at compile time), and ``profile=True`` on every submit
  (per-phase ``block_until_ready`` fences + a profile tree per
  request); v3 adds a concurrent 50ms poller hammering the device-side
  surfaces while the pass serves (``device_bytes`` + ``node_stats`` +
  ``stats()`` -- the health/telemetry scrape loop).  Pinned under a
  separate, looser ``--max-overhead-full`` bar (default 5%): the
  _profile fences genuinely serialize the dispatch phases, so this
  config buys attribution with a real (bounded) cost.

Configs are timed interleaved (off, on, off, on, ...) over many SHORT
passes with the order alternating each repeat, and per-query
submit-to-done latencies ride along (done-callback clock stamps, the
benchmarks/cluster_scale.py technique).  The headline overhead is
``min(best-pass wall ratio, median pair ratio)``: on a contended host
individual pass walls swing far more than the effect being measured
(observed up to 3x under CPU-stolen neighbours), but contention only
ever ADDS time, so with enough short passes the min-over-repeats walls
converge on the uncontended cost of each config -- the quantity the <3%
bar is about -- and the median of per-pair ratios cross-checks it (a
REAL regression shows in both; a one-off stall corrupts at most one).
Keeping passes short (one queue drain, default ~2 batches) maximises
the chance each config lands a stall-free pass; the per-pair wall
ratios are recorded in the JSON row for noise forensics.  The run
asserts the combined overhead stays under ``--max-overhead`` (default
3%, the PR 6 acceptance bar), re-measuring up to twice before failing.

Rows *append* to ``artifacts/BENCH_obs_scale.json`` (one run entry per
invocation) so the overhead trajectory accumulates across PRs.
``benchmarks/run.py`` invokes this in a subprocess like the other serving
benches.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--docs", type=int, default=8000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--queries", type=int, default=32)
_ARGS.add_argument("--batch-size", type=int, default=16)
_ARGS.add_argument("--page", type=int, default=320)
_ARGS.add_argument("--engine", default="codes")
_ARGS.add_argument("--repeats", type=int, default=80)
_ARGS.add_argument("--rounds", type=int, default=1,
                   help="times the query set is replayed per timed pass "
                        "(keep passes short: the min-ratio estimator "
                        "wants many chances at a stall-free pass)")
_ARGS.add_argument("--sample", type=float, default=1.0 / 16,
                   help="trace sampling rate for the on-config (default "
                        "1/16, the Tracer default)")
_ARGS.add_argument("--max-overhead", type=float, default=0.03,
                   help="acceptance bar: relative QPS loss of the "
                        "on-config (default 3%%)")
_ARGS.add_argument("--max-overhead-full", type=float, default=0.05,
                   help="acceptance bar for the full config (metrics + "
                        "tracer + slow log + compile watch + profile "
                        "trees on every request; default 5%%)")
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "BENCH_obs_scale.json"))

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    _early = _ARGS.parse_args()

import numpy as np


def _one_pass(engine, queries, rounds=1, timeout=120.0, profile=False,
              poll=None):
    """Submit the query set ``rounds`` times, wait, -> (wall_s, per-query
    latencies).  ``poll`` (full config) is called concurrently every
    50ms for the duration of the pass -- the stats/health/device-
    telemetry poller a monitored deployment runs against a serving
    engine, at ~200x a production scrape cadence."""
    import threading

    stop = poller = None
    if poll is not None:
        stop = threading.Event()

        def _poll_loop():
            while not stop.wait(0.05):
                poll()

        poller = threading.Thread(target=_poll_loop, daemon=True)
        poller.start()
    try:
        lats = []
        futs = []
        t0 = time.perf_counter()
        for _ in range(rounds):
            for q in queries:
                t_sub = time.perf_counter()
                f = (engine.submit(q, profile=True) if profile
                     else engine.submit(q))
                f.add_done_callback(lambda _f, t_sub=t_sub: lats.append(
                    time.perf_counter() - t_sub))
                futs.append(f)
        for f in futs:
            f.result(timeout=timeout)
        wall = time.perf_counter() - t0
    finally:
        if stop is not None:
            stop.set()
            poller.join()
    # done-callbacks land after result() unblocks; settle for a full set
    deadline = time.perf_counter() + 5.0
    while len(lats) < len(futs) and time.perf_counter() < deadline:
        time.sleep(0.001)
    return wall, lats


def run(n_docs=8000, n_features=64, n_queries=32, batch_size=16, page=320,
        engine="codes", repeats=80, rounds=1, sample=1.0 / 16,
        max_overhead=0.03, max_overhead_full=0.05):
    import jax.numpy as jnp
    from benchmarks.common import latency_percentiles
    from repro.core import (CombinedEncoder, IntervalEncoder,
                            RoundingEncoder, VectorIndex)
    from repro.core.rerank import normalize
    from repro.obs import CompileWatch, MetricsRegistry, SlowLog, Tracer
    from repro.serve.engine import BatchedSearchEngine

    rng = np.random.default_rng(0)
    V = np.asarray(normalize(jnp.asarray(
        rng.normal(size=(n_docs, n_features)).astype(np.float32))))
    queries = V[rng.choice(n_docs, size=n_queries, replace=False)]
    index = VectorIndex.build(
        V, CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1)))

    # every pass must run the same number of batches in both configs: trim
    # the load to whole batches and let the worker wait for FULL batches
    # (generous max_wait_s) -- otherwise partial-batch luck quantises the
    # pass wall by +-1 dispatch and drowns the effect being measured
    batch_size = min(batch_size, n_queries)
    n_queries = max(batch_size, n_queries - n_queries % batch_size)
    queries = queries[:n_queries]
    # isolated registries: the off-engine must not share series with the
    # on-engine, and neither should pollute the process default registry
    full_reg = MetricsRegistry()
    engines = {
        "off": BatchedSearchEngine(
            index, batch_size=batch_size, max_wait_s=1.0, page=page,
            trim=None, engine=engine,
            metrics=MetricsRegistry(enabled=False)),
        "on": BatchedSearchEngine(
            index, batch_size=batch_size, max_wait_s=1.0, page=page,
            trim=None, engine=engine, metrics=MetricsRegistry(),
            tracer=Tracer(sample=sample)),
        "full": BatchedSearchEngine(
            index, batch_size=batch_size, max_wait_s=1.0, page=page,
            trim=None, engine=engine, metrics=full_reg,
            tracer=Tracer(sample=sample),
            slowlog=SlowLog(threshold_s=0.1, metrics=full_reg),
            compile_watch=CompileWatch(metrics=full_reg)),
    }
    profiled = {"full"}             # submits carry profile=True
    names = ("off", "on", "full")

    # v3: the full config also pays the DEVICE-side plane while serving --
    # a concurrent poller hitting the index byte accounting, the engine
    # stats rollup, and the per-device node_stats every 50ms (still
    # ~200x a production scrape cadence), plus compile-time cost capture
    # riding the CompileWatch.  The <5% bar therefore covers the WHOLE
    # plane, polled hot.
    from repro.obs import device_bytes, node_stats

    def _poll_full(_eng=engines["full"]):
        device_bytes(_eng.index, reconcile=False)
        node_stats(_eng)
        _eng.stats()

    pollers = {"full": _poll_full}

    def _measure():
        best = {name: (np.inf, []) for name in engines}
        walls = {name: [] for name in engines}
        for rep in range(repeats):                    # interleaved triples,
            r = rep % len(names)                      # order rotating so no
            order = names[r:] + names[:r]             # config always runs
            for name in order:                        # cache-warm last
                wall, lats = _one_pass(engines[name], queries,
                                       rounds=rounds,
                                       profile=name in profiled,
                                       poll=pollers.get(name))
                walls[name].append(wall)
                if wall < best[name][0]:
                    best[name] = (wall, lats)
        return best, walls

    rows = []
    total_q = n_queries * rounds
    try:
        for name, eng in engines.items():             # compile + warm all
            _one_pass(eng, queries, profile=name in profiled)
        # the true cost (~1%) sits well under the bar, but so does the
        # noise floor of wall timing on a contended host: combine two
        # estimators (a REAL >bar regression shows in both) and
        # re-measure before failing on what is usually a neighbour's
        # CPU burst
        def _estimate(name):
            ratios = [x / off
                      for off, x in zip(walls["off"], walls[name])]
            return (min(best[name][0] / best["off"][0],
                        float(np.median(ratios))) - 1.0, ratios)

        for attempt in range(3):
            best, walls = _measure()
            overhead, ratios = _estimate("on")
            overhead_full, ratios_full = _estimate("full")
            if ((overhead < max_overhead
                 and overhead_full < max_overhead_full) or attempt == 2):
                break
            print(f"# overhead on={overhead:.2%} full={overhead_full:.2%} "
                  f"over a bar -- re-measuring (attempt {attempt + 2}/3)")
    finally:
        for eng in engines.values():
            eng.close()

    for name in names:
        wall, lats = best[name]
        tails = latency_percentiles(lats)
        rows.append({
            "config": name,
            "qps": total_q / wall,
            "per_query_s": wall / total_q,
            "latency": tails,
            "sample": 0.0 if name == "off" else sample,
            "batch_size": batch_size,
            "engine": engine,
            "n_docs": n_docs,
            "n_features": n_features,
            "page": page,
        })
        print(f"obs_overhead,{wall / total_q * 1e6:.0f},"
              f"config={name};qps={total_q / wall:.1f};"
              f"p50_ms={tails['p50_ms']:.2f};p99_ms={tails['p99_ms']:.2f}")

    # headline = min(best-pass ratio, median pair ratio): contention only
    # adds time, so the minima converge on each config's uncontended cost
    # (see module docstring), and the median cross-checks it
    rows.append({"config": "overhead", "relative_overhead": overhead,
                 "best_pass_ratio": best["on"][0] / best["off"][0],
                 "median_pair_ratio": float(np.median(ratios)),
                 "pair_ratios": [float(r) for r in ratios],
                 "max_overhead": max_overhead, "repeats": repeats,
                 "rounds": rounds})
    rows.append({"config": "overhead_full",
                 "relative_overhead": overhead_full,
                 "best_pass_ratio": best["full"][0] / best["off"][0],
                 "median_pair_ratio": float(np.median(ratios_full)),
                 "pair_ratios": [float(r) for r in ratios_full],
                 "max_overhead": max_overhead_full, "repeats": repeats,
                 "rounds": rounds})
    print(f"obs_overhead,0,overhead={overhead * 100:.2f}%;"
          f"bar={max_overhead * 100:.0f}%")
    print(f"obs_overhead,0,overhead_full={overhead_full * 100:.2f}%;"
          f"bar={max_overhead_full * 100:.0f}%")
    assert overhead < max_overhead, (
        f"instrumentation overhead {overhead:.1%} exceeds the "
        f"{max_overhead:.0%} acceptance bar "
        f"(pair ratios: {[round(r, 4) for r in ratios]})")
    assert overhead_full < max_overhead_full, (
        f"full-instrumentation overhead {overhead_full:.1%} (profile + "
        f"slow log + compile watch) exceeds the {max_overhead_full:.0%} "
        f"acceptance bar "
        f"(pair ratios: {[round(r, 4) for r in ratios_full]})")
    return rows


def main(argv_args=None):
    args = argv_args or _ARGS.parse_args()
    rows = run(n_docs=args.docs, n_features=args.features,
               n_queries=args.queries, batch_size=args.batch_size,
               page=args.page, engine=args.engine, repeats=args.repeats,
               rounds=args.rounds, sample=args.sample,
               max_overhead=args.max_overhead,
               max_overhead_full=args.max_overhead_full)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # append, never overwrite: the overhead trajectory accumulates across PRs
    doc = {"bench": "obs_overhead", "runs": []}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass  # unreadable history: start a fresh file rather than crash
    doc["runs"].append({"rows": rows})
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {len(doc['runs'])} to {out}")


if __name__ == "__main__":
    main(_early)
