"""QPS/latency vs (shards, replicas) for the replica serving tier.

    PYTHONPATH=src python -m benchmarks.replica_scale \
        [--grid 1x1,2x1,2x2,4x2] [--merge gather,stream] [--json out]

The paper scales reads the way Elasticsearch does: doc-shards partition the
corpus (PR 1, benchmarks/shard_scale.py), replica shards multiply the
serving copies.  This measures the second axis: for every ``SxR`` cell the
same corpus/index is sharded over S devices, replicated R times, and a
fixed query batch is timed through ``ShardedVectorIndex.search`` under each
merge transport -- QPS, per-query latency, and P@10 vs the brute-force gold
standard (exactly 1.0 while ``page >= n_docs``: replication and the merge
transport are throughput knobs, never a quality trade).

Rows *append* to ``artifacts/BENCH_replica_scale.json`` (one run entry per
invocation) so the perf trajectory accumulates across PRs.  On one host
fanned out into virtual devices the numbers measure protocol overhead, not
scaling -- real-device runs should append theirs to the same file.
``benchmarks/run.py`` invokes this in a subprocess (the virtual-device flag
must precede jax initialisation).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# XLA_FLAGS must be set before the first jax import
_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--grid", default="1x1,2x1,2x2,4x2",
                   help="comma-separated SxR cells (shards x replicas)")
_ARGS.add_argument("--merge", default="gather,stream",
                   help="comma-separated merge transports to time")
_ARGS.add_argument("--docs", type=int, default=20000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--queries", type=int, default=64)
_ARGS.add_argument("--page", type=int, default=320)
_ARGS.add_argument("--engine", default="codes")
_ARGS.add_argument("--repeats", type=int, default=3)
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "BENCH_replica_scale.json"))


def _parse():
    args = _ARGS.parse_args()
    cells = []
    for cell in args.grid.split(","):
        s, r = cell.lower().split("x")
        cells.append((int(s), int(r)))
    args.cells = sorted(set(cells))
    args.merges = [m.strip() for m in args.merge.split(",") if m.strip()]
    return args


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.hostdev import force_host_devices

    _early = _parse()
    force_host_devices(max(s * r for s, r in _early.cells))

import time

import numpy as np


def run(cells, merges=("gather", "stream"), n_docs=20000, n_features=64,
        n_queries=64, page=320, engine="codes", repeats=3):
    import jax
    import jax.numpy as jnp
    from repro.core import (CombinedEncoder, IntervalEncoder, RoundingEncoder,
                            VectorIndex, precision_at_k)
    from repro.core.rerank import normalize
    from repro.launch.mesh import make_shard_mesh

    # topic-mixture vectors, same rationale as benchmarks/shard_scale.py:
    # phase-1 bucket matches must carry signal for a meaningful P@10
    rng = np.random.default_rng(0)
    topics = rng.normal(size=(32, n_features)).astype(np.float32)
    assign = rng.integers(0, len(topics), size=n_docs)
    V = topics[assign] + 0.7 * rng.normal(
        size=(n_docs, n_features)).astype(np.float32)
    V = np.asarray(normalize(jnp.asarray(V)))
    queries = V[rng.choice(n_docs, size=n_queries, replace=False)]
    index = VectorIndex.build(
        V, CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1)))
    gold_ids, _ = index.gold_topk(queries, 10)

    rows = []
    for s, r in cells:
        if s * r > len(jax.devices()):
            # on stdout AND in the JSON: a silently missing cell would read
            # as "covered" in the accumulated perf trajectory
            print(f"replica_scale,shards={s}x{r},0,"
                  f"SKIPPED_only_{len(jax.devices())}_devices")
            rows.append({"shards": s, "replicas": r, "skipped": True,
                         "reason": f"only {len(jax.devices())} devices"})
            continue
        sidx = index.shard(make_shard_mesh(s, r))
        for merge in merges:
            search = lambda: sidx.search(jnp.asarray(queries), k=10,
                                         page=page, engine=engine,
                                         merge=merge)
            jax.block_until_ready(search())                   # compile + warm
            best = np.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                ids, _scores = search()
                jax.block_until_ready((ids, _scores))
                best = min(best, time.perf_counter() - t0)
            p10 = float(np.asarray(precision_at_k(ids, gold_ids)).mean())
            # per-query tails from batch-1 singles (benchmarks/shard_scale.py
            # rationale: batched timing is throughput, singles are latency)
            from benchmarks.common import latency_percentiles

            single = lambda q: sidx.search(jnp.asarray(q[None]), k=10,
                                           page=page, engine=engine,
                                           merge=merge)
            jax.block_until_ready(single(queries[0]))         # batch-1 compile
            lat = []
            for q in queries:
                t0 = time.perf_counter()
                jax.block_until_ready(single(q))
                lat.append(time.perf_counter() - t0)
            tails = latency_percentiles(lat)
            rows.append({
                "shards": s,
                "replicas": r,
                "merge": merge,
                "qps": n_queries / best,
                "per_query_s": best / n_queries,
                "latency": tails,
                "p10": p10,
                "engine": engine,
                "n_docs": n_docs,
                "n_features": n_features,
                "page": page,
            })
            print(f"replica_scale,shards={s}x{r},"
                  f"{best / n_queries * 1e6:.0f},"
                  f"merge={merge};qps={n_queries / best:.1f};p10={p10:.4f};"
                  f"p50_ms={tails['p50_ms']:.2f};p99_ms={tails['p99_ms']:.2f}")
    return rows


def main(argv_args=None):
    args = argv_args or _parse()
    rows = run(args.cells, merges=args.merges, n_docs=args.docs,
               n_features=args.features, n_queries=args.queries,
               page=args.page, engine=args.engine, repeats=args.repeats)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # append, never overwrite: the (S, R) trajectory accumulates across PRs
    doc = {"bench": "replica_scale", "runs": []}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass  # unreadable history: start a fresh file rather than crash
    doc["runs"].append({"rows": rows})
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {len(doc['runs'])} to {out}")


if __name__ == "__main__":
    main(_early)
