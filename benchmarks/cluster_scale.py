"""QPS vs (concurrent streams x replica groups) for the cluster control plane.

    PYTHONPATH=src python -m benchmarks.cluster_scale \
        [--grid 1x1,2x2,4x2] [--streams 1,4] [--json out]

The replica tier (benchmarks/replica_scale.py) measures the data plane:
one batcher fronting the whole mesh, parallelism materialising inside a
single SPMD batch.  This measures the CONTROL plane: ``ClusterEngine``
runs one independent batcher per replica group, so R groups serve R
batches concurrently -- the ES arrangement where concurrent QPS scales
with replica count.  For every ``SxR`` cell and stream count N, N client
threads each push a stream of queries through the cluster (stream
affinity pins a client to a group; overflow spills least-loaded), and the
wall time gives cluster QPS.  With R > 1 each cell is additionally
re-timed with one replica group marked down -- the failover cost curve --
and the down-run asserts result parity against the healthy run.

Rows *append* to ``artifacts/BENCH_cluster_scale.json`` (one run entry
per invocation) so the perf trajectory accumulates across PRs.  On one
host fanned out into virtual devices the numbers measure protocol
overhead, not scaling -- real-device runs should append theirs to the
same file.  ``benchmarks/run.py`` invokes this in a subprocess (the
virtual-device flag must precede jax initialisation).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# XLA_FLAGS must be set before the first jax import
_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--grid", default="1x1,2x2,4x2",
                   help="comma-separated SxR cells (shards x replica groups)")
_ARGS.add_argument("--streams", default="1,4",
                   help="comma-separated concurrent client-stream counts")
_ARGS.add_argument("--docs", type=int, default=20000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--queries", type=int, default=32,
                   help="queries per client stream")
_ARGS.add_argument("--page", type=int, default=320)
_ARGS.add_argument("--engine", default="codes")
_ARGS.add_argument("--batch-size", type=int, default=8)
_ARGS.add_argument("--repeats", type=int, default=3)
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "BENCH_cluster_scale.json"))


def _parse():
    args = _ARGS.parse_args()
    cells = []
    for cell in args.grid.split(","):
        s, r = cell.lower().split("x")
        cells.append((int(s), int(r)))
    args.cells = sorted(set(cells))
    args.stream_counts = sorted(
        {int(n) for n in args.streams.split(",") if n.strip()})
    return args


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.hostdev import force_host_devices

    _early = _parse()
    force_host_devices(max(s * r for s, r in _early.cells))

import threading
import time

import numpy as np


def _drive(cluster, queries, n_streams, timeout=300.0):
    """N client threads, each a pinned stream of queries -> (wall_s, results
    keyed (stream, i), per-query latencies).  Latency is submit-to-done per
    future (a done-callback stamps the clock in the completing worker), so
    it includes queue wait under real contention -- the same quantity the
    engine's queue-wait + dispatch histograms decompose."""
    results = {}
    latencies = []
    errors = []

    def client(sid):
        try:
            futs = []
            for q in queries:
                t_sub = time.perf_counter()
                f = cluster.submit(q, stream=sid)
                f.add_done_callback(
                    lambda _f, t_sub=t_sub: latencies.append(
                        time.perf_counter() - t_sub))
                futs.append(f)
            for i, f in enumerate(futs):
                results[(sid, i)] = f.result(timeout=timeout)
        except Exception as exc:  # noqa: BLE001 - surfaced to the caller
            errors.append(exc)

    threads = [threading.Thread(target=client, args=(sid,))
               for sid in range(n_streams)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    # done-callbacks run in the completing worker AFTER result() unblocks;
    # settle so the sample set is complete before percentiles are taken
    deadline = time.perf_counter() + 5.0
    while (len(latencies) < n_streams * len(queries)
           and time.perf_counter() < deadline):
        time.sleep(0.001)
    return wall, results, latencies


def run(cells, stream_counts=(1, 4), n_docs=20000, n_features=64,
        n_queries=32, page=320, engine="codes", batch_size=8, repeats=3):
    import jax
    import jax.numpy as jnp
    from repro.cluster import ClusterEngine
    from repro.core import (CombinedEncoder, IntervalEncoder, RoundingEncoder,
                            VectorIndex, precision_at_k)
    from repro.core.rerank import normalize
    from repro.launch.mesh import make_shard_mesh

    # topic-mixture vectors, same rationale as benchmarks/shard_scale.py:
    # phase-1 bucket matches must carry signal for a meaningful P@10
    rng = np.random.default_rng(0)
    topics = rng.normal(size=(32, n_features)).astype(np.float32)
    assign = rng.integers(0, len(topics), size=n_docs)
    V = topics[assign] + 0.7 * rng.normal(
        size=(n_docs, n_features)).astype(np.float32)
    V = np.asarray(normalize(jnp.asarray(V)))
    queries = V[rng.choice(n_docs, size=n_queries, replace=False)]
    index = VectorIndex.build(
        V, CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1)))
    gold_ids, _ = index.gold_topk(queries, 10)

    rows = []
    for s, r in cells:
        if s * r > len(jax.devices()):
            # on stdout AND in the JSON: a silently missing cell would read
            # as "covered" in the accumulated perf trajectory
            print(f"cluster_scale,shards={s}x{r},0,"
                  f"SKIPPED_only_{len(jax.devices())}_devices")
            rows.append({"shards": s, "replicas": r, "skipped": True,
                         "reason": f"only {len(jax.devices())} devices"})
            continue
        sidx = index.shard(make_shard_mesh(s, r))
        cluster = ClusterEngine(sidx, batch_size=batch_size, k=10, page=page,
                                trim=None, engine=engine)
        try:
            scenarios = [("healthy", None)]
            if r > 1:
                scenarios.append(("one_down", 0))
            baseline = {}
            for scenario, down in scenarios:
                if down is not None:
                    cluster.mark_down(down)
                for n_streams in stream_counts:
                    _drive(cluster, queries[: min(4, n_queries)],
                           n_streams)                 # compile + warm
                    best, res, lat = np.inf, None, []
                    for _ in range(repeats):
                        wall, got, lats = _drive(cluster, queries, n_streams)
                        if wall < best:
                            best, res, lat = wall, got, lats
                    total_q = n_streams * n_queries
                    from benchmarks.common import latency_percentiles

                    tails = latency_percentiles(lat)
                    ids = jnp.asarray(
                        np.stack([res[(0, i)][0] for i in range(n_queries)]))
                    p10 = float(np.asarray(
                        precision_at_k(ids, gold_ids)).mean())
                    if scenario == "healthy":
                        baseline[n_streams] = res
                    else:
                        # failover parity: every (stream, i) result must
                        # match the healthy cluster bit for bit
                        ref = baseline[n_streams]
                        assert all(
                            np.array_equal(res[key][0], ref[key][0])
                            and np.array_equal(res[key][1], ref[key][1])
                            for key in res), "one_down diverged from healthy"
                    rows.append({
                        "shards": s,
                        "replicas": r,
                        "scenario": scenario,
                        "n_streams": n_streams,
                        "qps": total_q / best,
                        "per_query_s": best / total_q,
                        "latency": tails,
                        "p10": p10,
                        "engine": engine,
                        "batch_size": batch_size,
                        "n_docs": n_docs,
                        "n_features": n_features,
                        "page": page,
                    })
                    print(f"cluster_scale,shards={s}x{r},"
                          f"{best / total_q * 1e6:.0f},"
                          f"scenario={scenario};streams={n_streams};"
                          f"qps={total_q / best:.1f};p10={p10:.4f};"
                          f"p50_ms={tails['p50_ms']:.2f};"
                          f"p99_ms={tails['p99_ms']:.2f}")
                if down is not None:
                    cluster.mark_up(down)
        finally:
            cluster.close()
    return rows


def main(argv_args=None):
    args = argv_args or _parse()
    rows = run(args.cells, stream_counts=args.stream_counts,
               n_docs=args.docs, n_features=args.features,
               n_queries=args.queries, page=args.page, engine=args.engine,
               batch_size=args.batch_size, repeats=args.repeats)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # append, never overwrite: the trajectory accumulates across PRs
    doc = {"bench": "cluster_scale", "runs": []}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass  # unreadable history: start a fresh file rather than crash
    doc["runs"].append({"rows": rows})
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {len(doc['runs'])} to {out}")


if __name__ == "__main__":
    main(_early)
