"""Index-build wall-clock and ingest throughput vs shard count.

    PYTHONPATH=src python -m benchmarks.build_scale [--shards 1,2,4] \
        [--ingest-batch 256] [--json out]

The third leg of the shard/replica/build scaling triangle: PR 1 measured
query QPS vs shards, PR 2 vs replicas; this measures *construction*.  For
every shard count the same corpus is built twice -- via the reference path
(``VectorIndex.build`` on one device, then ``from_index`` partitioning) and
via the on-device one-program SPMD build (``build_sharded``) -- and then a
stream of ``add_documents`` batches measures incremental ingest throughput
(docs/s through the append-segment path, including the post-ingest search
validating the new docs are live).

Rows *append* to ``artifacts/BENCH_build_scale.json`` (one run entry per
invocation) so the build-time trajectory accumulates across PRs.  On one
host fanned out into virtual devices the numbers measure protocol/dispatch
overhead, not scaling -- real-device runs should append theirs to the same
file.  ``benchmarks/run.py`` invokes this in a subprocess (the
virtual-device flag must precede jax initialisation).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# XLA_FLAGS must be set before the first jax import
_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--shards", default="1,2,4")
_ARGS.add_argument("--docs", type=int, default=20000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--queries", type=int, default=32,
                   help="sanity-search batch validating the built index")
_ARGS.add_argument("--ingest-batch", type=int, default=256)
_ARGS.add_argument("--ingest-batches", type=int, default=4)
_ARGS.add_argument("--repeats", type=int, default=3)
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "BENCH_build_scale.json"))


def _parse():
    args = _ARGS.parse_args()
    args.shard_counts = sorted({int(s) for s in args.shards.split(",")})
    return args


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.hostdev import force_host_devices

    _early = _parse()
    force_host_devices(max(_early.shard_counts))

import time

import numpy as np


def run(shard_counts, n_docs=20000, n_features=64, n_queries=32,
        ingest_batch=256, ingest_batches=4, repeats=3):
    import jax
    import jax.numpy as jnp
    from repro.core import (CombinedEncoder, IntervalEncoder, RoundingEncoder,
                            VectorIndex)
    from repro.core.rerank import normalize
    from repro.dist.shard_index import ShardedVectorIndex
    from repro.launch.mesh import make_shard_mesh

    encoder = CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1))
    rng = np.random.default_rng(0)
    topics = rng.normal(size=(32, n_features)).astype(np.float32)
    assign = rng.integers(0, len(topics), size=n_docs)
    V = topics[assign] + 0.7 * rng.normal(
        size=(n_docs, n_features)).astype(np.float32)
    V = np.asarray(normalize(jnp.asarray(V)))
    extra = topics[rng.integers(0, len(topics),
                                size=ingest_batch * ingest_batches)]
    extra = extra + 0.7 * rng.normal(size=extra.shape).astype(np.float32)
    queries = V[rng.choice(n_docs, size=n_queries, replace=False)]

    def leaves(sidx):
        return (sidx.vectors, sidx.codes, sidx.post_docs, sidx.post_codes,
                sidx.seg_vectors, sidx.seg_codes)

    rows = []
    for s in shard_counts:
        if s > len(jax.devices()):
            # on stdout AND in the JSON: a silently missing row would read
            # as "covered" in the accumulated build-time trajectory
            print(f"build_scale,shards={s},0,"
                  f"SKIPPED_only_{len(jax.devices())}_devices")
            rows.append({"shards": s, "skipped": True,
                         "reason": f"only {len(jax.devices())} devices"})
            continue
        mesh = make_shard_mesh(s)

        def on_device():
            idx = ShardedVectorIndex.build_sharded(V, mesh, encoder=encoder)
            jax.block_until_ready(leaves(idx))
            return idx

        def reference():
            idx = ShardedVectorIndex.from_index(
                VectorIndex.build(V, encoder), mesh)
            jax.block_until_ready(leaves(idx))
            return idx

        best_dev, best_ref = np.inf, np.inf
        for timer_target in range(repeats + 1):          # first = compile+warm
            t0 = time.perf_counter()
            sidx = on_device()
            dt = time.perf_counter() - t0
            if timer_target:
                best_dev = min(best_dev, dt)
            t0 = time.perf_counter()
            reference()
            dt = time.perf_counter() - t0
            if timer_target:
                best_ref = min(best_ref, dt)

        # incremental ingest throughput: a batch stream through the
        # append-segment path, closed by a search so the timing covers the
        # full hot-add-to-visible cycle (the ES refresh story).  Every
        # cumulative segment width hits its own jit cache entry, so the
        # warm-up pass must replay the EXACT batch/search shape sequence
        # the timed pass will see -- anything less leaves a trace+compile
        # inside dt_ingest and the recorded docs/s becomes compile noise.
        def ingest_cycle():
            grown = sidx
            for b in range(ingest_batches):
                grown = grown.add_documents(
                    extra[b * ingest_batch:(b + 1) * ingest_batch])
                jax.block_until_ready(leaves(grown))
            jax.block_until_ready(grown.search(jnp.asarray(queries), k=10))
            return grown
        ingest_cycle()                                    # compile + warm
        t0 = time.perf_counter()
        grown = ingest_cycle()
        dt_ingest = time.perf_counter() - t0
        added = ingest_batch * ingest_batches
        assert grown.n_ids == n_docs + added

        rows.append({
            "shards": s,
            "build_on_device_s": best_dev,
            "build_from_index_s": best_ref,
            "speedup": best_ref / best_dev,
            "ingest_docs_per_s": added / dt_ingest,
            "ingest_batch": ingest_batch,
            "n_docs": n_docs,
            "n_features": n_features,
        })
        print(f"build_scale,shards={s},{best_dev * 1e6:.0f},"
              f"on_device_s={best_dev:.3f};from_index_s={best_ref:.3f};"
              f"ingest_dps={added / dt_ingest:.0f}")
    return rows


def main(argv_args=None):
    args = argv_args or _parse()
    rows = run(args.shard_counts, n_docs=args.docs, n_features=args.features,
               n_queries=args.queries, ingest_batch=args.ingest_batch,
               ingest_batches=args.ingest_batches, repeats=args.repeats)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # append, never overwrite: the build-time trajectory accumulates
    doc = {"bench": "build_scale", "runs": []}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass  # unreadable history: start a fresh file rather than crash
    doc["runs"].append({"rows": rows})
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {len(doc['runs'])} to {out}")


if __name__ == "__main__":
    main(_early)
