"""Benchmark aggregator: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--check]

Default is the quick grid (CPU-friendly); --full runs the complete paper
grids.  Prints ``name,us_per_call,derived`` CSV lines per the scaffold
contract, then the roofline summary from the dry-run artifacts.

``--check`` runs the perf-regression gate (:mod:`benchmarks.check`)
over the committed ``artifacts/BENCH_*.json`` instead of the suites:
each bench's latest-run headline is compared against its first
committed run (ratio thresholds per metric, explicit SKIP when only one
run exists), the obs-overhead bars and the fused-kernel byte claim are
re-asserted, and the process exits nonzero on any regression -- the
``make bench-check`` entry point.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time


def _run_device_bench(name: str, grid_args: list, full: bool) -> None:
    """A virtual-device bench (shard_scale / replica_scale) in a subprocess:
    the device fan-out flag must precede jax initialisation, and jax is
    already live here.  Each emits its artifacts/BENCH_<name>.json."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, "-m", f"benchmarks.{name}"] + grid_args
    if not full:
        # quick-config rows are not comparable to the full trajectory; keep
        # them out of the accumulating BENCH_<name>.json
        cmd += ["--docs", "4000", "--features", "32", "--queries", "32",
                "--json", os.path.join(root, "artifacts",
                                       f"BENCH_{name}_quick.json")]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    t0 = time.perf_counter()
    try:
        out = subprocess.run(cmd, cwd=root, env=env, capture_output=True,
                             text=True, timeout=1800)
    except subprocess.TimeoutExpired:
        print(f"{name},{(time.perf_counter()-t0)*1e6:.0f},FAILED_timeout")
        return
    for line in out.stdout.splitlines():
        if line.startswith(f"{name},"):
            print(line)
    if out.returncode != 0:
        print(f"{name},{(time.perf_counter()-t0)*1e6:.0f},"
              f"FAILED_rc={out.returncode}")
        sys.stderr.write(out.stderr[-2000:])


def main() -> None:
    if "--check" in sys.argv:
        from . import check

        sys.exit(check.main([a for a in sys.argv[1:] if a != "--check"]))
    full = "--full" in sys.argv
    quick = not full
    from . import (complexity_probe, fig1_page_sweep, fig2_tradeoff, roofline,
                   table2_quality, table3_speed, table4_mlt)

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    rows2 = table2_quality.run(quick=quick)
    best = max(r["avg_p10"] for r in rows2 if r["system"] == "encoded")
    mlt = max((r["avg_p10"] for r in rows2 if r["system"] == "MLT"), default=0)
    print(f"table2_quality,{(time.perf_counter()-t0)*1e6:.0f},"
          f"best_avg_p10={best:.4f};mlt_avg_p10={mlt:.4f}")

    t0 = time.perf_counter()
    rows3 = table3_speed.run(quick=quick)
    fastest = min(r["per_query_s"] for r in rows3)
    print(f"table3_speed,{(time.perf_counter()-t0)*1e6:.0f},"
          f"fastest_per_query_s={fastest:.5f}")

    t0 = time.perf_counter()
    rows4 = table4_mlt.run(quick=quick)
    print(f"table4_mlt,{(time.perf_counter()-t0)*1e6:.0f},"
          f"mlt25_per_query_s={rows4[0]['per_query_s']:.5f}")

    t0 = time.perf_counter()
    rows_f1 = fig1_page_sweep.run(quick=quick)
    print(f"fig1_page_sweep,{(time.perf_counter()-t0)*1e6:.0f},rows={len(rows_f1)}")

    t0 = time.perf_counter()
    rows_f2 = fig2_tradeoff.run(quick=quick)
    print(f"fig2_tradeoff,{(time.perf_counter()-t0)*1e6:.0f},rows={len(rows_f2)}")

    t0 = time.perf_counter()
    rows_cp = complexity_probe.run(quick=quick)
    print(f"complexity_probe,{(time.perf_counter()-t0)*1e6:.0f},rows={len(rows_cp)}")

    _run_device_bench("shard_scale", ["--shards", "1,2,4"], full)
    _run_device_bench("replica_scale", ["--grid", "1x1,2x1,2x2,4x2"], full)
    _run_device_bench("build_scale", ["--shards", "1,2,4"], full)
    _run_device_bench("cluster_scale", ["--grid", "1x1,2x2,4x2",
                                        "--streams", "1,4"], full)
    _run_device_bench("store_scale", ["--shards", "1,4"], full)
    _run_device_bench("segment_scale", ["--shards", "1,4"], full)
    _run_device_bench("obs_overhead", [], full)
    _run_device_bench("profile_overhead", [], full)

    t0 = time.perf_counter()
    roofline.main(full=full)
    print(f"roofline,{(time.perf_counter()-t0)*1e6:.0f},"
          "see_EXPERIMENTS_md_and_BENCH_kernel_scale")


if __name__ == "__main__":
    main()
