"""Segment-lifecycle cost curves: ingest latency, search tails, and
commit bytes under the generational index.

    PYTHONPATH=src python -m benchmarks.segment_scale \
        [--shards 1,4] [--docs 8000] [--ingest-batch 64] [--batches 12] \
        [--seal-threshold 128] [--json out]

Three questions the segment story (PR 7) makes measurable:

1. **Does sealing keep ingest flat?**  The same hot-add stream runs
   three ways: ``flat`` (``seal_threshold=None`` -- the old single
   append buffer, whose growth path is the full-rebuild stall the
   segment refactor exists to kill), ``seal`` (generational sealing, no
   merges), and ``seal+merge`` (sealing plus a
   :class:`~repro.cluster.maintenance.TieredMergePolicy` pass after each
   batch -- the maintenance daemon's plan, applied synchronously so the
   bench is deterministic).  Every row carries the FULL per-batch
   latency trace (``lat_ms_trace``) plus ``max_ms``: the no-stall claim
   is checkable from the artifact, not asserted by prose.  Merge passes
   are timed separately (``merge_ms_total``) -- in production they run
   off the query path on the daemon thread.
2. **What do merges buy search?**  After ingest, the same query batch is
   timed against the end state of each config; ``seal`` serves N sealed
   generations, ``seal+merge`` serves the folded tiers.  p50/p99 per
   call, same corpus, same engine.
3. **Are commits O(changed)?**  A durable store commits after every
   ingest batch; each generation's row records ``bytes_written`` vs
   ``bytes_total`` straight from the store's own metrics
   (content-addressed blobs: unchanged segments are re-referenced, so
   written stays ~flat while total grows with the corpus -- the ES
   incremental-snapshot shape).  The section ends with a kill ->
   ``recover()`` -> bit-parity assert against the live index, so the
   numbers are only ever reported for a store that provably restores.

Rows *append* to ``artifacts/BENCH_segment_scale.json`` (one run entry
per invocation).  ``benchmarks/run.py`` invokes this in a subprocess
(the virtual-device flag must precede jax initialisation); ``make
smoke-segments`` runs the quick 4-device config.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile

# XLA_FLAGS must be set before the first jax import
_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--shards", default="1,4",
                   help="comma-separated shard counts (each its own mesh)")
_ARGS.add_argument("--docs", type=int, default=8000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--ingest-batch", type=int, default=64)
_ARGS.add_argument("--batches", type=int, default=12)
_ARGS.add_argument("--seal-threshold", type=int, default=128)
_ARGS.add_argument("--merge-factor", type=int, default=4)
_ARGS.add_argument("--queries", type=int, default=32)
_ARGS.add_argument("--search-calls", type=int, default=24,
                   help="timed search calls per config (the p99 base)")
_ARGS.add_argument("--repeats", type=int, default=3)
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts",
    "BENCH_segment_scale.json"))


def _parse():
    args = _ARGS.parse_args()
    args.shard_counts = sorted(
        {int(s) for s in args.shards.split(",") if s.strip()})
    return args


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.hostdev import force_host_devices

    _early = _parse()
    force_host_devices(max(_early.shard_counts))

import time

import numpy as np

_CONFIGS = ("flat", "seal", "seal+merge")


def _ingest_pass(base, batches, config, policy):
    """One warm ingest pass -> (index, per-batch latencies, merge seconds,
    merges applied).  Merge passes (seal+merge only) are timed apart from
    the add path, mirroring the daemon running them off the query path."""
    import jax

    idx = base
    lats, merge_s, merges = [], 0.0, 0
    for b in batches:
        t1 = time.perf_counter()
        idx = idx.add_documents(b)
        jax.block_until_ready(idx.seg_vectors)
        lats.append(time.perf_counter() - t1)
        if policy is not None:
            sel = policy.select(idx)
            if sel is not None:
                t2 = time.perf_counter()
                idx = idx.merge_segments(sel["start"], sel["count"])
                jax.block_until_ready(idx.segments[sel["start"]].vectors)
                merge_s += time.perf_counter() - t2
                merges += 1
    return idx, lats, merge_s, merges


def run(shard_counts, n_docs=8000, n_features=64, ingest_batch=64,
        n_batches=12, seal_threshold=128, merge_factor=4, n_queries=32,
        n_search=24, repeats=3):
    import jax
    from repro.cluster.maintenance import TieredMergePolicy
    from repro.dist.shard_index import ShardedVectorIndex
    from repro.launch.mesh import make_shard_mesh
    from repro.store import Store

    from benchmarks.common import latency_percentiles

    rng = np.random.default_rng(0)
    V = rng.normal(size=(n_docs, n_features)).astype(np.float32)
    Q = V[rng.choice(n_docs, size=n_queries, replace=False)]
    batches = [rng.normal(size=(ingest_batch, n_features)).astype(np.float32)
               for _ in range(n_batches)]

    rows = []
    for s in shard_counts:
        if s > len(jax.devices()):
            print(f"segment_scale,shards={s},0,"
                  f"SKIPPED_only_{len(jax.devices())}_devices")
            rows.append({"shards": s, "skipped": True,
                         "reason": f"only {len(jax.devices())} devices"})
            continue
        mesh = make_shard_mesh(s)

        # ---- ingest trace + search tails, per config ------------------
        for config in _CONFIGS:
            thr = None if config == "flat" else seal_threshold
            policy = (TieredMergePolicy(merge_factor=merge_factor)
                      if config == "seal+merge" else None)
            base = ShardedVectorIndex.build_sharded(V, mesh,
                                                    seal_threshold=thr)
            # warm-up pass compiles every generation shape this config
            # will visit, so the timed trace measures the rebuild/data
            # path, not one-time jit compilation
            _ingest_pass(base, batches, config, policy)
            best = None
            for _ in range(repeats):
                idx, lats, merge_s, merges = _ingest_pass(
                    base, batches, config, policy)
                if best is None or sum(lats) < sum(best[1]):
                    best = (idx, lats, merge_s, merges)
            idx, lats, merge_s, merges = best
            total = n_batches * ingest_batch
            tails = latency_percentiles(lats)
            row = {
                "mode": "ingest", "shards": s, "config": config,
                "docs_per_s": total / sum(lats), "latency": tails,
                "max_ms": max(lats) * 1e3,
                "lat_ms_trace": [round(t * 1e3, 3) for t in lats],
                "merge_ms_total": merge_s * 1e3, "merges": merges,
                "n_segments_final": int(getattr(idx, "n_segments", 0)),
                "ingest_batch": ingest_batch, "n_batches": n_batches,
                "seal_threshold": thr, "n_docs": n_docs,
                "n_features": n_features,
            }
            print(f"segment_scale,shards={s},"
                  f"{sum(lats) / total * 1e6:.0f},"
                  f"mode=ingest;config={config};"
                  f"docs_per_s={total / sum(lats):.0f};"
                  f"max_ms={row['max_ms']:.2f};"
                  f"segments={row['n_segments_final']};merges={merges}")

            # search tails against this config's end state
            idx.search(Q, k=10, page=2 * idx.n_ids)        # warm-up
            samples = []
            for _ in range(n_search):
                t1 = time.perf_counter()
                ids, _sc = idx.search(Q, k=10, page=2 * idx.n_ids)
                jax.block_until_ready(ids)
                samples.append(time.perf_counter() - t1)
            st = latency_percentiles(samples)
            row["search"] = st
            rows.append(row)
            print(f"segment_scale,shards={s},"
                  f"{np.mean(samples) * 1e6:.0f},"
                  f"mode=search;config={config};"
                  f"p50_ms={st['p50_ms']:.2f};p99_ms={st['p99_ms']:.2f}")

        # ---- commit bytes vs generation (O(changed) evidence) ---------
        tmp = tempfile.mkdtemp(prefix="bench_segment_")
        try:
            from repro.obs.metrics import MetricsRegistry
            from repro.store import recover

            store = Store(tmp, durability="async",
                          metrics=MetricsRegistry())
            policy = TieredMergePolicy(merge_factor=merge_factor)
            idx = store.open_index(ShardedVectorIndex.build_sharded(
                V, mesh, seal_threshold=seal_threshold))
            reg = store.metrics
            for gen, b in enumerate(batches, start=1):
                idx = idx.add_documents(b)
                sel = policy.select(idx)
                if sel is not None:
                    idx = idx.merge_segments(sel["start"], sel["count"])
                store.commit(idx)
                written = reg.value("store.commit.last_bytes_written")
                total_b = reg.value("store.commit.last_bytes_total")
                rows.append({
                    "mode": "commit", "shards": s, "generation": gen,
                    "merged": sel is not None,
                    "bytes_written": written, "bytes_total": total_b,
                    "n_segments": int(idx.n_segments),
                    "n_ids": int(idx.n_ids),
                    "seal_threshold": seal_threshold,
                    "n_docs": n_docs, "n_features": n_features,
                })
                print(f"segment_scale,shards={s},{written:.0f},"
                      f"mode=commit;generation={gen};"
                      f"bytes_written={written:.0f};"
                      f"bytes_total={total_b:.0f};"
                      f"segments={idx.n_segments}")
            # kill -> recover -> bit-parity: the commit numbers above are
            # only reported for a store that provably restores
            store.translog.sync()
            rec, seq = recover(tmp, make_shard_mesh(s))
            li, ls = idx.search(Q, k=10, page=2 * idx.n_ids)
            ri, rs = rec.search(Q, k=10, page=2 * rec.n_ids)
            assert seq == idx.translog_seq
            assert np.array_equal(np.asarray(li), np.asarray(ri)) and \
                np.array_equal(np.asarray(ls), np.asarray(rs)), \
                "recovered index diverged from live"
            print(f"segment_scale,shards={s},0,mode=recover;parity=ok;"
                  f"seq={seq}")
            store.close()
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    return rows


def main(argv_args=None):
    args = argv_args or _parse()
    rows = run(args.shard_counts, n_docs=args.docs,
               n_features=args.features, ingest_batch=args.ingest_batch,
               n_batches=args.batches, seal_threshold=args.seal_threshold,
               merge_factor=args.merge_factor, n_queries=args.queries,
               n_search=args.search_calls, repeats=args.repeats)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # append, never overwrite: the trajectory accumulates across PRs
    doc = {"bench": "segment_scale", "runs": []}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass  # unreadable history: start a fresh file rather than crash
    doc["runs"].append({"rows": rows})
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {len(doc['runs'])} to {out}")


if __name__ == "__main__":
    main(_early)
