import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Perf hillclimb variants (EXPERIMENTS.md §Perf): lower+compile modified
configurations of the three chosen cells and record the same roofline
artifacts as the baseline dry-run, under artifacts/dryrun_variants/<name>/.

    PYTHONPATH=src python -m benchmarks.hillclimb [variant ...]

Chosen cells (from the baseline table):
* llama4-maverick train_4k  -- worst useful-fraction + largest memory term
* mixtral decode_32k/long_500k -- most collective-bound
* vectordb-wiki search_b128/b1 -- the paper's own technique
"""

import dataclasses
import functools
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _lm_variant(arch_id, shape, cfg_patch, accum=None, cache_seq_model=False,
                inference_specs=False):
    from repro.configs import get_arch
    from repro.configs.base import LMArch
    from repro.dist.sharding import (batch_axes, lm_param_spec_inference,
                                     tree_specs)
    from repro.launch.mesh import make_production_mesh

    base = get_arch(arch_id)
    cfg = dataclasses.replace(base.cfg, **cfg_patch)
    arch = LMArch(cfg, optimizer=base.optimizer,
                  skip_shapes=base.skip_shapes, accum=accum or base.accum)
    if inference_specs:
        arch.param_specs = lambda mesh, pa: tree_specs(pa, mesh, lm_param_spec_inference)
    mesh = make_production_mesh()
    cell = arch.cell(shape, mesh)
    if cache_seq_model:
        # shard the KV-cache seq axis over "model" (kv heads indivisible):
        # decode attention reduces over the sharded seq with one small psum
        bd = batch_axes(mesh)
        nb = 1
        for a in bd:
            nb *= mesh.shape[a]

        def patch_spec(leaf_spec, leaf):
            if not isinstance(leaf_spec, P) or len(leaf.shape) != 5:
                return leaf_spec
            if leaf.shape[1] % nb == 0 and leaf.shape[1] >= nb:
                return P(None, bd, "model", None, None)
            # batch=1 (long_500k): seq over data axes AND model
            return P(None, None, (*bd, "model"), None, None)

        cache_abs = cell.args[1]
        new_cache_specs = jax.tree.map(
            patch_spec, cell.in_specs[1], cache_abs,
            is_leaf=lambda x: isinstance(x, P))
        pos_fix = jax.tree_util.tree_map_with_path(
            lambda path, s: P(None, "model")
            if "pos" in str(path[-2:]) and isinstance(s, P) else s,
            new_cache_specs, is_leaf=lambda x: isinstance(x, P))
        cell = dataclasses.replace(
            cell,
            in_specs=(cell.in_specs[0], pos_fix, *cell.in_specs[2:]),
            out_specs=(cell.out_specs[0], pos_fix),
        )
    return cell, mesh


def _vectordb_variant(shape, engine):
    from repro.configs.base import SDS, _bspec
    from repro.configs.vectordb_wiki import ENCODER, N_DOCS, N_FEATURES, VectorDBArch
    from repro.configs.base import Cell
    from repro.core.codes import score_onehot
    from repro.core.filtering import TrimFilter, expand_mask, feature_mask
    from repro.core.rerank import normalize, rerank_topk
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    nq = 128 if shape == "search_b128" else 1

    if engine == "onehot":
        def fn(doc_vecs, doc_codes, queries):
            q = normalize(queries.astype(jnp.float32))
            qcodes = ENCODER.encode(q)
            mask = expand_mask(feature_mask(q, trim=TrimFilter(0.05)), qcodes.shape[-1])
            w = jnp.where(mask, 1.0, 0.0)
            s1 = score_onehot(doc_codes, qcodes, w, ENCODER.max_abs_bucket)
            _, cand = jax.lax.top_k(s1, 320)
            return rerank_topk(doc_vecs, cand, q, 10)
    elif engine == "colmajor":
        def fn(doc_vecs, codes_T, queries):
            # column-major codes: only the query's surviving columns are
            # streamed -- bytes ~ m/C of the full matrix for small batches
            q = normalize(queries.astype(jnp.float32))
            qcodes = ENCODER.encode(q)                       # (Q, C)
            m = 120
            _, sel = jax.lax.top_k(jnp.abs(q[0]), m)         # (m,) columns
            sub = jnp.take(codes_T, sel, axis=0)             # (m, N)
            qsel = jnp.take(qcodes, sel, axis=1)             # (Q, m)
            eq = (sub[None] == qsel[:, :, None]).astype(jnp.int8)
            s1 = jnp.einsum("qmn,qm->qn", eq,
                            jnp.ones((q.shape[0], m), jnp.float32),
                            preferred_element_type=jnp.float32)
            _, cand = jax.lax.top_k(s1, 320)
            return rerank_topk(doc_vecs, cand, q, 10)
    else:
        raise ValueError(engine)

    vecs = SDS((N_DOCS, N_FEATURES), jnp.float32)
    if engine == "colmajor":
        codes = SDS((N_FEATURES, N_DOCS), jnp.dtype(ENCODER.code_dtype))
        codes_spec = P(None, ("pod", "data") if "pod" in mesh.axis_names else ("data",))
        codes_spec = _bspec(mesh, codes, batch_dim=1)
    else:
        codes = SDS((N_DOCS, N_FEATURES), jnp.dtype(ENCODER.code_dtype))
        codes_spec = _bspec(mesh, codes)
    qs = SDS((nq, N_FEATURES), jnp.float32)
    return Cell(
        arch="vectordb-wiki", shape=shape, kind="search", fn=fn,
        args=(vecs, codes, qs),
        in_specs=(_bspec(mesh, vecs), codes_spec, P()),
        out_specs=(P(), P()), note=f"variant engine={engine}",
    ), mesh


VARIANTS = {
    # --- llama4 train_4k (worst useful fraction / memory term) ---
    "llama4_moechunk8k": lambda: _lm_variant(
        "llama4-maverick-400b-a17b", "train_4k", dict(moe_token_chunk=8192)),
    "llama4_seqpar": lambda: _lm_variant(
        "llama4-maverick-400b-a17b", "train_4k",
        dict(seq_parallel_attn=True, q_chunk=256)),
    "llama4_seqpar_moechunk": lambda: _lm_variant(
        "llama4-maverick-400b-a17b", "train_4k",
        dict(seq_parallel_attn=True, q_chunk=256, moe_token_chunk=8192)),
    "llama4_seqpar_localmoe": lambda: _lm_variant(
        "llama4-maverick-400b-a17b", "train_4k",
        dict(seq_parallel_attn=True, q_chunk=256, moe_dispatch="local")),
    # --- mixtral decode (most collective-bound) ---
    "mixtral_decode_seqcache": lambda: _lm_variant(
        "mixtral-8x22b", "decode_32k", dict(cache_update="masked"),
        cache_seq_model=True),
    "mixtral_long_seqcache": lambda: _lm_variant(
        "mixtral-8x22b", "long_500k", dict(cache_update="masked"),
        cache_seq_model=True),
    "mixtral_decode_noFSDP": lambda: _lm_variant(
        "mixtral-8x22b", "decode_32k", dict(), inference_specs=True),
    "mixtral_decode_noFSDP_seqcache": lambda: _lm_variant(
        "mixtral-8x22b", "decode_32k", dict(cache_update="masked"),
        cache_seq_model=True, inference_specs=True),
    "mixtral_long_noFSDP_seqcache": lambda: _lm_variant(
        "mixtral-8x22b", "long_500k", dict(cache_update="masked"),
        cache_seq_model=True, inference_specs=True),
    # --- vectordb (the paper's cell) ---
    "vectordb_b128_onehot": lambda: _vectordb_variant("search_b128", "onehot"),
    "vectordb_b1_colmajor": lambda: _vectordb_variant("search_b1", "colmajor"),
    "vectordb_b128_colmajor": lambda: _vectordb_variant("search_b128", "colmajor"),
}


def main():
    from repro.launch.dryrun import run_cell

    names = sys.argv[1:] or list(VARIANTS)
    for name in names:
        try:
            cell, mesh = VARIANTS[name]()
            rec = run_cell(cell, mesh, name, "artifacts/dryrun_variants", force=True)
            mem = rec.get("memory_analysis") or {}
            print(f"{name:28s} flops/dev={rec['flops_per_device']:.3e} "
                  f"bytes/dev={rec['bytes_per_device']:.3e} "
                  f"coll/dev={rec['collective_bytes_per_device']:.3e} "
                  f"temp={(mem.get('temp_size_in_bytes') or 0)/2**30:.1f}GiB")
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"{name:28s} FAILED: {e!r}")


if __name__ == "__main__":
    main()
