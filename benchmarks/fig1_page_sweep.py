"""Paper Figure 1: best-m and page-size sweeps -> avg.diff and P@10 curves.

Reproduces the two claims read off the figure: avg.diff decays ~log in page,
and best>=90 ~= no filtering while best<=6 visibly hurts.
Usage: PYTHONPATH=src python -m benchmarks.fig1_page_sweep [--quick]
"""

from __future__ import annotations

import math
import sys

import numpy as np

from repro.core import BestFilter, avg_diff, precision_at_k

from .common import ART, fixture


def run(quick: bool = False):
    fx = fixture()
    idx, Q = fx.index, fx.queries
    gold_ids, gold_sims = fx.gold_ids, fx.gold_sims

    bests = [6, 17, 40, 90, None]
    pages = [10, 20, 40, 80, 160, 320, 640]
    if quick:
        bests, pages = [6, 90, None], [20, 160, 640]

    rows = []
    for best in bests:
        for page in pages:
            ids, sims = idx.search(Q, k=10, page=page,
                                   best=BestFilter(best) if best else None,
                                   engine="codes")
            rows.append({
                "best": best if best else "all", "page": page,
                "avg_p10": float(precision_at_k(ids, gold_ids).mean()),
                "avg_diff": float(avg_diff(sims, gold_sims).mean()),
            })

    import csv, os
    with open(os.path.join(ART, "fig1_page_sweep.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)

    # the figure's qualitative claims, checked numerically
    by_best = {}
    for r in rows:
        by_best.setdefault(r["best"], []).append(r)
    for best, rs in by_best.items():
        rs.sort(key=lambda r: r["page"])
        print(f"best={best}: avg.diff " +
              " -> ".join(f"{r['avg_diff']:.4f}" for r in rs))
    # log-like decay: diff(page) roughly linear in log(page)
    rs = by_best.get("all", rs)
    if len(rs) >= 3:
        xs = np.log([r["page"] for r in rs])
        ys = np.array([r["avg_diff"] for r in rs])
        corr = np.corrcoef(xs, ys)[0, 1]
        print(f"log-page vs avg.diff correlation: {corr:.3f} (paper: strongly negative)")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
