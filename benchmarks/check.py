"""Perf-regression gate over the committed ``artifacts/BENCH_*.json``.

The BENCH artifacts accumulate one run per bench invocation (the
``runs`` list), which until now was write-only: a PR could halve QPS
and nothing would notice.  This gate makes the trajectory enforced:

* **ratio checks** -- for every runs-format bench, the LATEST run's
  headline number (best QPS / docs-per-second over its rows) must stay
  within a per-metric ratio of the FIRST run (the committed baseline).
  A bench with a single run has no history to compare -- reported as an
  explicit SKIP, never silently passed.
* **absolute checks** -- numbers that are commitments rather than
  trajectories: the obs-plane overhead rows must stay under their
  documented bars (3% metrics-on, 5% full plane).
* **claim checks** -- invariants the paper-facing artifacts assert:
  ``BENCH_kernel_scale`` must show the fused kernel moving fewer HBM
  bytes than the composed pipeline (and int8 fewer than f32) at every
  measured size, and winning wall-clock at the largest size.

Usage (also ``python -m benchmarks.run --check`` / ``make bench-check``)::

    PYTHONPATH=src python -m benchmarks.check [--artifacts DIR]

Exits 0 when every check passes or skips, 1 on any regression.  Pure
stdlib -- no jax import -- so the gate itself can never perturb what it
measures.
"""

from __future__ import annotations

import json
import os
import sys
from typing import List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_ARTIFACTS = os.path.join(_ROOT, "artifacts")

# Allowed regression per headline metric: latest must be >= baseline *
# MIN_RATIO.  Generous on purpose -- the gate exists to catch structural
# regressions (a 2x cliff from an accidental recompile or a lost fast
# path), not scheduler noise on shared CI hardware.
MIN_RATIO = 0.5

# bench -> (headline metric, row filter, aggregate) for runs-format files
RATIO_SUITES = {
    "shard_scale": ("qps", None),
    "replica_scale": ("qps", None),
    "cluster_scale": ("qps", {"scenario": "healthy"}),
    "obs_scale": ("qps", {"config": "off"}),
    "profile_scale": ("qps", {"config": "off"}),
    "segment_scale": ("docs_per_s", None),
    "store_scale": ("docs_per_s", None),
    "build_scale": ("ingest_docs_per_s", None),
}

# (bench, row filter, metric, max allowed value) -- documented bars
ABS_CHECKS = [
    ("obs_scale", {"config": "overhead"}, "relative_overhead", 0.03),
    ("obs_scale", {"config": "overhead_full"}, "relative_overhead", 0.05),
]


def _load(artifacts: str, bench: str) -> Optional[dict]:
    path = os.path.join(artifacts, f"BENCH_{bench}.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        return {"_error": f"{path}: {exc}"}


def _rows(doc: dict, run: int) -> List[dict]:
    """Rows of run ``run`` (-1 latest, 0 baseline) for either format;
    flat files have exactly one 'run'."""
    if "runs" in doc:
        runs = doc["runs"]
        return runs[run].get("rows", []) if runs else []
    return doc.get("rows", []) if run in (0, -1) else []


def _n_runs(doc: dict) -> int:
    return len(doc["runs"]) if "runs" in doc else 1


def _best(rows: List[dict], metric: str,
          where: Optional[dict]) -> Optional[float]:
    vals = [r[metric] for r in rows if metric in r
            and (where is None
                 or all(r.get(k) == v for k, v in where.items()))]
    # fall back to the unfiltered rows when the filter matches nothing
    # (older runs predate the filtered config) -- comparing best-overall
    # beats silently skipping
    if not vals and where is not None:
        vals = [r[metric] for r in rows if metric in r]
    return max(vals) if vals else None


class Gate:
    def __init__(self):
        self.failures: List[str] = []
        self.lines: List[str] = []

    def report(self, status: str, bench: str, detail: str):
        line = f"GATE {bench}: {status} {detail}"
        self.lines.append(line)
        print(line)
        if status == "REGRESSION" or status == "ERROR":
            self.failures.append(line)


def _check_ratio(gate: Gate, bench: str, doc: dict, metric: str,
                 where: Optional[dict]) -> None:
    if _n_runs(doc) < 2:
        gate.report("SKIP", bench,
                    f"no baseline history (1 run committed; {metric} "
                    "gate arms on the next appended run)")
        return
    base = _best(_rows(doc, 0), metric, where)
    cur = _best(_rows(doc, -1), metric, where)
    if base is None or cur is None:
        gate.report("SKIP", bench, f"metric '{metric}' absent from rows")
        return
    ratio = cur / base if base else float("inf")
    detail = (f"{metric} latest={cur:.4g} baseline={base:.4g} "
              f"ratio={ratio:.2f} (min {MIN_RATIO})")
    if ratio < MIN_RATIO:
        gate.report("REGRESSION", bench, detail)
    else:
        gate.report("OK", bench, detail)


def _check_abs(gate: Gate, bench: str, doc: dict, where: dict,
               metric: str, limit: float) -> None:
    rows = _rows(doc, -1)
    vals = [r[metric] for r in rows if metric in r
            and all(r.get(k) == v for k, v in where.items())]
    tag = ",".join(f"{k}={v}" for k, v in where.items())
    if not vals:
        gate.report("SKIP", bench, f"no {tag} row yet")
        return
    worst = max(vals)
    detail = f"{tag} {metric}={worst:.4f} (max {limit})"
    if worst > limit:
        gate.report("REGRESSION", bench, detail)
    else:
        gate.report("OK", bench, detail)


def _check_kernel_claim(gate: Gate, doc: dict) -> None:
    rows = _rows(doc, -1)
    by_size: dict = {}
    for r in rows:
        if "variant" in r and "hbm_bytes" in r:
            by_size.setdefault(r["n_docs"], {})[r["variant"]] = r
    if not by_size:
        gate.report("SKIP", "kernel_scale", "no variant rows")
        return
    bad = []
    for n, v in sorted(by_size.items()):
        comp, fused, int8 = (v.get("composed"), v.get("fused"),
                             v.get("fused_int8"))
        if comp and fused and fused["hbm_bytes"] >= comp["hbm_bytes"]:
            bad.append(f"n_docs={n}: fused bytes >= composed")
        if fused and int8 and int8["hbm_bytes"] >= fused["hbm_bytes"]:
            bad.append(f"n_docs={n}: int8 bytes >= fused")
    top = max(by_size)
    comp, fused = by_size[top].get("composed"), by_size[top].get("fused")
    if comp and fused and fused["wall_s"] >= comp["wall_s"]:
        bad.append(f"n_docs={top}: fused wall_s >= composed")
    if bad:
        gate.report("REGRESSION", "kernel_scale", "; ".join(bad))
    else:
        ratio = (fused["hbm_bytes"] / comp["hbm_bytes"]
                 if comp and fused else float("nan"))
        gate.report("OK", "kernel_scale",
                    f"fused/composed bytes={ratio:.2f} at n_docs={top}; "
                    "byte + wall ordering holds at every size")


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    artifacts = DEFAULT_ARTIFACTS
    if "--artifacts" in argv:
        artifacts = argv[argv.index("--artifacts") + 1]
    gate = Gate()
    for bench, (metric, where) in RATIO_SUITES.items():
        doc = _load(artifacts, bench)
        if doc is None:
            gate.report("SKIP", bench, "no committed artifact")
            continue
        if "_error" in doc:
            gate.report("ERROR", bench, doc["_error"])
            continue
        _check_ratio(gate, bench, doc, metric, where)
    for bench, where, metric, limit in ABS_CHECKS:
        doc = _load(artifacts, bench)
        if doc is None or "_error" in doc:
            gate.report("SKIP", bench, "no committed artifact")
            continue
        _check_abs(gate, bench, doc, where, metric, limit)
    doc = _load(artifacts, "kernel_scale")
    if doc is None:
        gate.report("SKIP", "kernel_scale", "no committed artifact")
    elif "_error" in doc:
        gate.report("ERROR", "kernel_scale", doc["_error"])
    else:
        _check_kernel_claim(gate, doc)
    if gate.failures:
        print(f"bench-check: {len(gate.failures)} regression(s)",
              file=sys.stderr)
        return 1
    print("bench-check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
