"""Paper Figure 2: the precision / request-time trade-off scatter.

Points = (avg precision, per-request seconds) for trim x page; the paper's
reading: page size is nearly free, trim dominates latency -- retrieve as
large a page as latency allows, trim to ~0.05.

Beyond the paper's engine axis: ``fused`` (bit-identical selection to the
composed code-match path, so it inherits those points' quality) and
``fused_int8`` (per-row int8 quantized phase-1) extend the frontier --
each int8 row reports recall@10 against the brute-force gold, showing
what the 4x phase-1 byte saving costs in candidate recall at each page.
Usage: PYTHONPATH=src python -m benchmarks.fig2_tradeoff [--quick]
"""

from __future__ import annotations

import sys

from repro.core import TrimFilter, precision_at_k

from .common import ART, fixture, timed


def run(quick: bool = False):
    fx = fixture()
    idx = fx.index
    nb = 4
    Q = fx.queries[:nb]
    gold = fx.gold_ids[:nb]

    trims = [0.0, 0.05, 0.1]
    pages = [20, 80, 320]
    if quick:
        trims, pages = [0.0, 0.1], [20, 320]

    rows = []
    for trim in trims:
        tf = TrimFilter(trim) if trim else None
        for page in pages:
            (ids, _), secs = timed(
                lambda: idx.search(Q, k=10, page=page, trim=tf, engine="postings",
                                   max_postings=4096),
                repeats=2 if quick else 3)
            p = float(precision_at_k(ids, gold).mean())
            rows.append({"engine": "postings", "trim": trim, "page": page,
                         "avg_p10": p, "per_request_s": secs / nb})
            print(f"postings   trim={trim:<5.2f} page={page:<4d} P@10={p:.4f} "
                  f"t/req={secs/nb*1e3:8.2f}ms")

    # the quantization axis: fused fp32 (selection bit-identical to the
    # composed code-match engine) vs fused int8 (4x fewer phase-1 table
    # bytes; recall@10 = overlap with brute-force gold measures what
    # quantized candidate selection gives up at each page)
    for eng in ("fused", "fused_int8"):
        for page in pages:
            (ids, _), secs = timed(
                lambda: idx.search(Q, k=10, page=page, trim=None, engine=eng),
                repeats=2 if quick else 3)
            r = float(precision_at_k(ids, gold).mean())
            rows.append({"engine": eng, "trim": 0.0, "page": page,
                         "avg_p10": r, "recall_at_10": r,
                         "per_request_s": secs / nb})
            print(f"{eng:10s} trim=0.00  page={page:<4d} R@10={r:.4f} "
                  f"t/req={secs/nb*1e3:8.2f}ms")

    import csv, os
    fields = ["engine", "trim", "page", "avg_p10", "recall_at_10",
              "per_request_s"]
    with open(os.path.join(ART, "fig2_tradeoff.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
