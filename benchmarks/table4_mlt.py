"""Paper Table 4: native MLT search speed vs max_query_terms.

Usage: PYTHONPATH=src python -m benchmarks.table4_mlt [--quick]
"""

from __future__ import annotations

import sys

import jax.numpy as jnp

from repro.core import MLTIndex

from .common import ART, fixture, timed


def run(quick: bool = False):
    fx = fixture()
    mlt = MLTIndex.build(jnp.asarray(fx.doc_terms), jnp.asarray(fx.doc_tf),
                         fx.vocab_size)
    nq = 16
    qt = jnp.asarray(fx.doc_terms[fx.query_ids[:nq]])
    qtf = jnp.asarray(fx.doc_tf[fx.query_ids[:nq]])

    rows = []
    for mqt in ([25, 90] if quick else [17, 25, 40, 90, 400]):
        mqt_eff = min(mqt, qt.shape[1])
        fn = lambda: mlt.more_like_this(qt, qtf, max_query_terms=mqt_eff, k=10)
        _, secs = timed(fn, repeats=2 if quick else 3)
        rows.append({"max_query_terms": mqt, "step_s": secs,
                     "per_query_s": secs / nq})
        print(f"MLT mqt={mqt:<4d} step={secs*1e3:8.2f}ms per_q={secs/nq*1e3:7.2f}ms")

    import csv, os
    with open(os.path.join(ART, "table4_mlt.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
