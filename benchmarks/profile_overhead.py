"""_profile cost + per-phase latency breakdown at serving scale.

    PYTHONPATH=src python -m benchmarks.profile_overhead \
        [--docs 8000] [--queries 32] [--shards 2] [--max-overhead 0.05] \
        [--json out]

The companion to :mod:`benchmarks.obs_overhead`: that bench pins the
cost of the always-on plane (metrics + sampled tracing); this one pins
the cost of asking *why* -- every request served with the FULL v2
instrumentation (metrics + tracer + tail-sampled slow log + compile
watch + ``profile=True`` execution trees) against a bare engine over
the same sharded index.  The _profile fences (``block_until_ready``
between encode / phase-1 / merge / rescore) genuinely serialize the
dispatch phases, so unlike the passive plane this cost is real; the
acceptance bar is 5% (``--max-overhead``).

The same min(best-pass ratio, median pair ratio) estimator as
obs_overhead handles host contention, with up to two re-measures before
failing.  Alongside the overhead row, the run aggregates every profile
tree it collected into per-phase p50/p99 wall times (queue_wait,
batch_form, dispatch, encode, phase1, merge_select, rescore) -- the
serving-latency decomposition the JSON trajectory tracks across PRs.

Rows *append* to ``artifacts/BENCH_profile_scale.json`` (one run entry
per invocation).  ``benchmarks/run.py`` invokes this in a subprocess
like the other virtual-device benches.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--docs", type=int, default=8000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--queries", type=int, default=32)
_ARGS.add_argument("--batch-size", type=int, default=16)
_ARGS.add_argument("--page", type=int, default=320)
_ARGS.add_argument("--engine", default="fused")
_ARGS.add_argument("--shards", type=int, default=2)
_ARGS.add_argument("--repeats", type=int, default=60)
_ARGS.add_argument("--max-overhead", type=float, default=0.05,
                   help="acceptance bar: relative QPS loss of serving "
                        "every request fully instrumented with a profile "
                        "tree (default 5%%)")
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts",
    "BENCH_profile_scale.json"))

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    _early = _ARGS.parse_args()
    # the device fan-out must precede the first jax import
    from repro.launch.hostdev import force_host_devices

    force_host_devices(_early.shards)

import numpy as np


def _one_pass(engine, queries, profile=False, timeout=120.0):
    """Submit the query set once, wait -> (wall_s, profile trees)."""
    t0 = time.perf_counter()
    futs = [engine.submit(q, profile=True) if profile else engine.submit(q)
            for q in queries]
    out = [f.result(timeout=timeout) for f in futs]
    wall = time.perf_counter() - t0
    return wall, [r[2] for r in out] if profile else []


def _walk_phases(tree, acc):
    """Accumulate every timed node's duration under its phase name."""
    d = tree.get("duration_s")
    if d is not None and tree.get("name") not in ("query", "cluster.query"):
        acc.setdefault(tree["name"], []).append(d)
    for c in tree.get("children", ()):
        _walk_phases(c, acc)


def run(n_docs=8000, n_features=64, n_queries=32, batch_size=16, page=320,
        engine="fused", n_shards=2, repeats=60, max_overhead=0.05):
    import jax.numpy as jnp
    from repro.core import CombinedEncoder, IntervalEncoder, RoundingEncoder
    from repro.core.rerank import normalize
    from repro.dist.shard_index import ShardedVectorIndex
    from repro.launch.mesh import make_shard_mesh
    from repro.obs import CompileWatch, MetricsRegistry, SlowLog, Tracer
    from repro.serve.engine import BatchedSearchEngine

    rng = np.random.default_rng(0)
    V = np.asarray(normalize(jnp.asarray(
        rng.normal(size=(n_docs, n_features)).astype(np.float32))))
    queries = V[rng.choice(n_docs, size=n_queries, replace=False)]
    mesh = make_shard_mesh(n_shards)
    index = ShardedVectorIndex.build_sharded(
        V, mesh, encoder=CombinedEncoder(RoundingEncoder(1),
                                         IntervalEncoder(0.1)))

    batch_size = min(batch_size, n_queries)
    n_queries = max(batch_size, n_queries - n_queries % batch_size)
    queries = queries[:n_queries]
    full_reg = MetricsRegistry()
    engines = {
        "off": BatchedSearchEngine(
            index, batch_size=batch_size, max_wait_s=1.0, page=page,
            trim=None, engine=engine,
            metrics=MetricsRegistry(enabled=False)),
        "profile": BatchedSearchEngine(
            index, batch_size=batch_size, max_wait_s=1.0, page=page,
            trim=None, engine=engine, metrics=full_reg,
            tracer=Tracer(sample=1.0 / 16),
            slowlog=SlowLog(threshold_s=0.1, metrics=full_reg),
            compile_watch=CompileWatch(metrics=full_reg)),
    }
    phases: dict = {}

    def _measure():
        best = {name: np.inf for name in engines}
        walls = {name: [] for name in engines}
        for rep in range(repeats):
            order = (("off", "profile") if rep % 2
                     else ("profile", "off"))
            for name in order:
                wall, trees = _one_pass(engines[name], queries,
                                        profile=name == "profile")
                for t in trees:
                    _walk_phases(t, phases)
                walls[name].append(wall)
                best[name] = min(best[name], wall)
        return best, walls

    try:
        for name, eng in engines.items():             # compile + warm both
            _one_pass(eng, queries, profile=name == "profile")
        for attempt in range(3):
            best, walls = _measure()
            ratios = [p / off
                      for off, p in zip(walls["off"], walls["profile"])]
            overhead = min(best["profile"] / best["off"],
                           float(np.median(ratios))) - 1.0
            if overhead < max_overhead or attempt == 2:
                break
            print(f"# overhead {overhead:.2%} over the bar -- "
                  f"re-measuring (attempt {attempt + 2}/3)")
    finally:
        for eng in engines.values():
            eng.close()

    rows = []
    for name in ("off", "profile"):
        rows.append({
            "config": name,
            "qps": n_queries / best[name],
            "per_query_s": best[name] / n_queries,
            "batch_size": batch_size,
            "engine": engine,
            "n_shards": n_shards,
            "n_docs": n_docs,
            "n_features": n_features,
            "page": page,
        })
        print(f"profile_overhead,{best[name] / n_queries * 1e6:.0f},"
              f"config={name};qps={n_queries / best[name]:.1f}")

    def _q(vals, frac):
        s = sorted(vals)
        return s[min(len(s) - 1, int(frac * len(s)))]

    phase_row = {"config": "phases", "per_phase": {}}
    for name in sorted(phases):
        vals = phases[name]
        p50, p99 = _q(vals, 0.5), _q(vals, 0.99)
        phase_row["per_phase"][name] = {
            "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3, "n": len(vals)}
        print(f"profile_overhead,{p50 * 1e6:.0f},"
              f"phase={name};p50_ms={p50 * 1e3:.3f};p99_ms={p99 * 1e3:.3f}")
    rows.append(phase_row)

    rows.append({"config": "overhead", "relative_overhead": overhead,
                 "best_pass_ratio": best["profile"] / best["off"],
                 "median_pair_ratio": float(np.median(ratios)),
                 "pair_ratios": [float(r) for r in ratios],
                 "max_overhead": max_overhead, "repeats": repeats})
    print(f"profile_overhead,0,overhead={overhead * 100:.2f}%;"
          f"bar={max_overhead * 100:.0f}%")
    assert overhead < max_overhead, (
        f"full _profile instrumentation overhead {overhead:.1%} exceeds "
        f"the {max_overhead:.0%} acceptance bar "
        f"(pair ratios: {[round(r, 4) for r in ratios]})")
    return rows


def main(argv_args=None):
    args = argv_args or _ARGS.parse_args()
    rows = run(n_docs=args.docs, n_features=args.features,
               n_queries=args.queries, batch_size=args.batch_size,
               page=args.page, engine=args.engine, n_shards=args.shards,
               repeats=args.repeats, max_overhead=args.max_overhead)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    # append, never overwrite: the trajectory accumulates across PRs
    doc = {"bench": "profile_overhead", "runs": []}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            if isinstance(prev.get("runs"), list):
                doc = prev
        except (OSError, ValueError):
            pass  # unreadable history: start a fresh file rather than crash
    doc["runs"].append({"rows": rows})
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# appended run {len(doc['runs'])} to {out}")


if __name__ == "__main__":
    main(_early)
