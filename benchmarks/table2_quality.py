"""Paper Table 2: quality grid -- trim x best x page -> P@10 / nDCG10 / avg.diff,
plus the MLT baseline rows (max_query_terms sweep).

Usage: PYTHONPATH=src python -m benchmarks.table2_quality [--quick]
Writes artifacts/table2_quality.csv; prints the table.
"""

from __future__ import annotations

import sys

import jax.numpy as jnp
import numpy as np

from repro.core import (BestFilter, MLTIndex, TrimFilter, avg_diff, ndcg_k,
                        precision_at_k)

from .common import ART, fixture


def run(quick: bool = False):
    fx = fixture()
    idx, Q = fx.index, fx.queries
    gold_ids, gold_sims = fx.gold_ids, fx.gold_sims

    trims = [0.0, 0.05, 0.1]
    bests = [17, 40, 90, None]          # None = all features
    pages = [10, 20, 40, 80, 160, 320, 640]
    if quick:
        trims, bests, pages = [0.0, 0.05], [40, None], [20, 160, 640]

    rows = []
    for trim in trims:
        for best in bests:
            for page in pages:
                ids, sims = idx.search(
                    Q, k=10, page=page,
                    trim=TrimFilter(trim) if trim else None,
                    best=BestFilter(best) if best else None,
                    engine="codes",
                )
                p = precision_at_k(ids, gold_ids)
                rows.append({
                    "system": "encoded", "trim": trim,
                    "best": best if best else "all", "page": page,
                    "min_p10": float(p.min()), "avg_p10": float(p.mean()),
                    "max_p10": float(p.max()),
                    "ndcg10": float(ndcg_k(sims, gold_sims).mean()),
                    "avg_diff": float(avg_diff(sims, gold_sims).mean()),
                })

    # MLT baseline (paper: max_query_terms in the 'best' column, page=10)
    mlt = MLTIndex.build(jnp.asarray(fx.doc_terms), jnp.asarray(fx.doc_tf),
                         fx.vocab_size)
    qt = jnp.asarray(fx.doc_terms[fx.query_ids])
    qtf = jnp.asarray(fx.doc_tf[fx.query_ids])
    V = np.asarray(idx.vectors)
    qn = np.asarray(fx.queries)
    for mqt in ([25] if quick else [17, 25, 40, 90, 400]):
        ids, _ = mlt.more_like_this(qt, qtf, max_query_terms=mqt, k=10)
        sims = jnp.asarray(np.take_along_axis(qn @ V.T, np.asarray(ids), axis=1))
        p = precision_at_k(ids, gold_ids)
        rows.append({
            "system": "MLT", "trim": "-", "best": mqt, "page": 10,
            "min_p10": float(p.min()), "avg_p10": float(p.mean()),
            "max_p10": float(p.max()),
            "ndcg10": float(ndcg_k(sims, gold_sims).mean()),
            "avg_diff": float(avg_diff(sims, gold_sims).mean()),
        })

    import csv, os
    path = os.path.join(ART, "table2_quality.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    hdr = f"{'system':8s} {'trim':>5s} {'best':>5s} {'page':>5s} {'avgP@10':>8s} {'nDCG10':>7s} {'avg.diff':>9s}"
    print(hdr)
    for r in rows:
        print(f"{r['system']:8s} {str(r['trim']):>5s} {str(r['best']):>5s} "
              f"{r['page']:>5d} {r['avg_p10']:8.4f} {r['ndcg10']:7.4f} {r['avg_diff']:9.5f}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
