"""QPS vs shard count for the doc-sharded index (the paper's horizontal axis).

    PYTHONPATH=src python -m benchmarks.shard_scale [--shards 1,2,4] [--json out]

The paper scales by adding Elasticsearch doc-shards; this measures the same
trajectory on one host fanned out into virtual devices.  For every shard
count: build one corpus/index, doc-shard it, run batched queries, report
QPS and P@10 vs the brute-force gold standard (which is exactly 1.0 while
``page >= n_docs`` -- sharding is a throughput axis, not a quality trade).

Emits ``artifacts/BENCH_shard_scale.json`` so the perf trajectory
accumulates across PRs; ``benchmarks/run.py`` invokes this in a subprocess
(the virtual-device flag must precede jax initialisation).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# XLA_FLAGS must be set before the first jax import
_ARGS = argparse.ArgumentParser()
_ARGS.add_argument("--shards", default="1,2,4")
_ARGS.add_argument("--docs", type=int, default=20000)
_ARGS.add_argument("--features", type=int, default=64)
_ARGS.add_argument("--queries", type=int, default=64)
_ARGS.add_argument("--page", type=int, default=320)
_ARGS.add_argument("--engine", default="codes")
_ARGS.add_argument("--repeats", type=int, default=3)
_ARGS.add_argument("--json", default=os.path.join(
    os.path.dirname(__file__), "..", "artifacts", "BENCH_shard_scale.json"))


def _parse():
    args = _ARGS.parse_args()
    args.shard_counts = sorted({int(s) for s in args.shards.split(",")})
    return args


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch.hostdev import force_host_devices

    _early = _parse()
    force_host_devices(max(_early.shard_counts))

import time

import numpy as np


def run(shard_counts, n_docs=20000, n_features=64, n_queries=64, page=320,
        engine="codes", repeats=3):
    import jax
    import jax.numpy as jnp
    from repro.core import (CombinedEncoder, IntervalEncoder, RoundingEncoder,
                            VectorIndex, precision_at_k)
    from repro.core.rerank import normalize
    from repro.launch.mesh import make_shard_mesh

    # topic-mixture vectors (cheap stand-in for the LSA pipeline): docs
    # cluster around topic directions, so phase-1 bucket matches carry
    # signal the way real LSA features do -- pure gaussians would make
    # every cosine ~0 and measure only the encoder's noise floor
    rng = np.random.default_rng(0)
    topics = rng.normal(size=(32, n_features)).astype(np.float32)
    assign = rng.integers(0, len(topics), size=n_docs)
    V = topics[assign] + 0.7 * rng.normal(
        size=(n_docs, n_features)).astype(np.float32)
    V = np.asarray(normalize(jnp.asarray(V)))
    queries = V[rng.choice(n_docs, size=n_queries, replace=False)]
    # P1+I0.1: the bucket scale benchmarks/common.py established for
    # unit vectors at this feature count (P2 cells are too fine)
    index = VectorIndex.build(
        V, CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1)))
    gold_ids, _ = index.gold_topk(queries, 10)

    rows = []
    for s in shard_counts:
        if s > len(jax.devices()):
            # on stdout AND in the JSON: a silently missing row would read
            # as "covered" in the accumulated perf trajectory
            print(f"shard_scale,shards={s},0,"
                  f"SKIPPED_only_{len(jax.devices())}_devices")
            rows.append({"shards": s, "skipped": True,
                         "reason": f"only {len(jax.devices())} devices"})
            continue
        idx = index if s == 1 else index.shard(make_shard_mesh(s))
        search = lambda: idx.search(jnp.asarray(queries), k=10, page=page,
                                    engine=engine)
        jax.block_until_ready(search())                       # compile + warm
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            ids, _scores = search()
            jax.block_until_ready((ids, _scores))
            best = min(best, time.perf_counter() - t0)
        p10 = float(np.asarray(precision_at_k(ids, gold_ids)).mean())
        # per-query latency tails: the batched timing above is throughput;
        # singles (batch-1 searches, their own compile warmed first) give
        # the per-query distribution the stats layer reports at runtime
        from benchmarks.common import latency_percentiles

        single = lambda q: idx.search(jnp.asarray(q[None]), k=10, page=page,
                                      engine=engine)
        jax.block_until_ready(single(queries[0]))             # batch-1 compile
        lat = []
        for q in queries:
            t0 = time.perf_counter()
            jax.block_until_ready(single(q))
            lat.append(time.perf_counter() - t0)
        tails = latency_percentiles(lat)
        rows.append({
            "shards": s,
            "qps": n_queries / best,
            "per_query_s": best / n_queries,
            "latency": tails,
            "p10": p10,
            "engine": engine,
            "n_docs": n_docs,
            "n_features": n_features,
            "page": page,
        })
        print(f"shard_scale,shards={s},{best / n_queries * 1e6:.0f},"
              f"qps={n_queries / best:.1f};p10={p10:.4f};"
              f"p50_ms={tails['p50_ms']:.2f};p99_ms={tails['p99_ms']:.2f}")
    return rows


def main(argv_args=None):
    args = argv_args or _parse()
    rows = run(args.shard_counts, n_docs=args.docs, n_features=args.features,
               n_queries=args.queries, page=args.page, engine=args.engine,
               repeats=args.repeats)
    out = os.path.abspath(args.json)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"bench": "shard_scale", "rows": rows}, f, indent=2)
    print(f"# wrote {out}")


if __name__ == "__main__":
    main(_early)
