"""Roofline analysis: dry-run artifacts + the measured fused-kernel bench.

Two sections:

* the HLO dry-run roofline (EXPERIMENTS.md §Roofline) over the arch grid;
* :func:`kernel_scale` -- a MEASURED fused-vs-composed phase-1 comparison
  emitting ``artifacts/BENCH_kernel_scale.json``.  Per corpus size it
  times the composed hot path (dense ``score_codes`` matrix + global
  ``top_k``), the fused fp32 kernel (streamed scoring + running top-k, no
  (Q, d) score matrix), and the fused int8 kernel (quantized table, 4x
  fewer table bytes), and pairs each wall time with its analytic HBM
  byte count and roofline bound.  The composed path's extra traffic is
  exactly the score matrix it writes then re-reads (2*Q*d*4 bytes); the
  fused paths never materialize it, so they move strictly fewer bytes at
  every size -- the wall-time column shows that winning on this host too.

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_bw
(all in seconds/step/device; the max = the bound), plus
    MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) -- per device,
    usefulness = MODEL_FLOPS / HLO_FLOPs  (remat/replication waste shows up
    here), and the dominant term.

Hardware model (brief-mandated): TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (we charge the whole collective byte count against one
link's bandwidth: a conservative single-bottleneck-link model).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops_per_device(rec: Dict) -> Optional[float]:
    """Analytic 6*N(_active)*D for the cell, divided over chips."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs import ARCH_IDS, get_arch
    from repro.configs.base import GNNArch, LMArch, RecsysArch

    n_chips = 1
    for v in rec["mesh_shape"].values():
        n_chips *= v
    arch_id = rec["arch"]
    if arch_id == "vectordb-wiki":
        # search: phase1 ~ 2*Q*N*C (int8 compare+acc ~ 2 ops) + rerank 2*Q*page*n
        from repro.configs.vectordb_wiki import N_DOCS, N_FEATURES
        if rec["kind"] == "encode":
            return 3.0 * N_DOCS * N_FEATURES / n_chips
        q = 128 if "b128" in rec["shape"] else 1
        return (2.0 * q * N_DOCS * N_FEATURES + 2.0 * q * 320 * N_FEATURES) / n_chips
    try:
        arch = get_arch(arch_id)
    except KeyError:
        return None
    if isinstance(arch, LMArch):
        cfg = arch.cfg
        info = LMArch.SHAPES[rec["shape"]]
        if rec["kind"] == "train":
            tokens = info["batch"] * info["seq"]
            fl = 6.0 * cfg.active_param_count() * tokens
        elif rec["kind"] == "prefill":
            tokens = info["batch"] * info["seq"]
            fl = 2.0 * cfg.active_param_count() * tokens
        else:  # decode: one token per sequence
            fl = 2.0 * cfg.active_param_count() * info["batch"]
        return fl / n_chips
    if isinstance(arch, GNNArch):
        info = GNNArch.SHAPES[rec["shape"]]
        cfg = arch.cfg_for(rec["shape"])
        # per GIN layer: MLP 2*(d_in*2h + 2h*h) per node (x3 for train) + edges
        n = info.get("nodes", 0) * info.get("batch", 1)
        e = info.get("edges", 0) * info.get("batch", 1)
        h = cfg.d_hidden
        per_node = 0
        d = cfg.d_in
        for i in range(cfg.n_layers):
            per_node += 2 * (d * 2 * h + 2 * h * h)
            d = h
        fl = 3.0 * (n * per_node + e * h * 2)      # fwd+bwd ~ 3x fwd
        return fl / n_chips
    if isinstance(arch, RecsysArch):
        info = RecsysArch.SHAPES[rec["shape"]]
        b = info["batch"]
        c = arch.cfg
        name = c.name
        if name == "xdeepfm":
            m, D = c.n_sparse, c.embed_dim
            cin = 0
            hp = m
            for hk in c.cin_layers:
                cin += 2 * hp * m * D + 2 * hp * m * hk * D
                hp = hk
            mlp = 2 * (m * D + c.n_dense) * c.mlp[0] + 2 * c.mlp[0] * c.mlp[1]
            fl = b * (cin + mlp)
        elif name == "autoint":
            m, D, H, dk = c.n_sparse, c.embed_dim, c.n_heads, c.d_attn
            att = 3 * 2 * m * D * H * dk + 2 * m * m * H * dk * 2 + 2 * m * D * H * dk
            fl = b * att * c.n_attn_layers
        elif name == "din":
            D, L = c.embed_dim, c.seq_len
            att = 2 * L * 4 * D * c.attn_mlp[0] + 2 * L * c.attn_mlp[0] * c.attn_mlp[1]
            mlp = 2 * (2 * D + c.n_dense) * c.mlp[0] + 2 * c.mlp[0] * c.mlp[1]
            fl = b * (att + mlp)
        else:  # bst
            D, L = c.embed_dim, c.seq_len + 1
            att = 4 * 2 * L * D * D + 4 * L * L * D + 8 * L * D * D
            mlp = 2 * (L * D + c.n_dense) * c.mlp[0] + \
                2 * c.mlp[0] * c.mlp[1] + 2 * c.mlp[1] * c.mlp[2]
            fl = b * (att * c.n_blocks + mlp)
        if info["kind"] == "train":
            fl *= 3.0
        if info["kind"] == "retrieval":
            from repro.configs.base import RecsysArch as RA
            fl = 2.0 * info["n_cand"] * c.embed_dim * (1 + b)
        return fl / n_chips
    return None


def load_records(mesh: str = "single_16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Dict:
    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    t_mem = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    useful = (mf / rec["flops_per_device"]) if (mf and rec["flops_per_device"]) else None
    bound = max(terms.values())
    mem = rec.get("memory_analysis") or {}
    hbm_gib = None
    if mem.get("temp_size_in_bytes") is not None:
        hbm_gib = (mem["temp_size_in_bytes"] + (mem.get("argument_size_in_bytes") or 0)) / 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bound_s": bound, "dominant": dom,
        "model_flops_per_device": mf, "useful_fraction": useful,
        "hbm_gib": hbm_gib,
    }


def _timed(fn, repeats=3):
    import time

    import jax
    out = fn()
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def kernel_scale(quick: bool = True, json_path: str = None):
    """Measured fused-vs-composed phase-1 scaling (see module doc).

    Emits ``artifacts/BENCH_kernel_scale.json`` with one row per
    (n_docs x variant): best-of-3 wall seconds, analytic HBM bytes, the
    HBM roofline bound at v5e bandwidth, and the achieved fraction.
    """
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core.codes import score_codes
    from repro.core.quantize import quantize_table
    from repro.kernels.fused_phase1 import ops as fp_ops

    # paper-scale shapes: LSA 200 features, combined encoder -> C = 400
    Q, page, n_feat, C = 32, 320, 200, 400
    sizes = [20_000, 60_000] if quick else [20_000, 60_000, 200_000]
    rng = np.random.default_rng(0)

    composed = jax.jit(
        lambda dc, qc, w: jax.lax.top_k(score_codes(dc, qc, w), page))

    rows = []
    print(f"\n== kernel_scale (Q={Q} page={page} C={C} n={n_feat}) ==")
    for d in sizes:
        dc = jnp.asarray(rng.integers(-8, 8, size=(d, C)), jnp.int8)
        qc = jnp.asarray(rng.integers(-8, 8, size=(Q, C)), jnp.int8)
        w = jnp.asarray(rng.random((Q, C)), jnp.float32)
        V = jnp.asarray(rng.normal(size=(d, n_feat)), jnp.float32)
        qt = quantize_table(V)
        qv = jnp.asarray(rng.normal(size=(Q, n_feat)), jnp.float32)

        # analytic HBM traffic per query batch: every variant reads its
        # doc-side table once; ONLY the composed path also writes the
        # (Q, d) fp32 score matrix and reads it back for top_k
        score_mat = 2 * Q * d * 4
        variants = {
            "composed": (lambda: composed(dc, qc, w), d * C + score_mat),
            "fused": (lambda: fp_ops.fused_phase1(dc, qc, w, page=page),
                      d * C),
            "fused_int8": (lambda: fp_ops.fused_phase1_quant(
                qt.codes, qt.scale, qt.zero, qv, page=page),
                d * n_feat + 8 * d),
        }
        for name, (fn, nbytes) in variants.items():
            secs = _timed(fn)
            bound = nbytes / HBM_BW
            rows.append({
                "n_docs": d, "n_queries": Q, "page": page, "C": C,
                "n_features": n_feat, "variant": name,
                "wall_s": secs, "hbm_bytes": int(nbytes),
                "roofline_s": bound, "pct_roofline": bound / secs,
            })
            print(f"d={d:<7d} {name:10s} {secs * 1e3:8.1f}ms "
                  f"{nbytes / 2**20:8.1f}MiB")

    # the claim the bench exists to pin: at the LARGEST size the fused
    # kernel moves strictly fewer bytes AND finishes sooner
    big = max(sizes)
    by = {r["variant"]: r for r in rows if r["n_docs"] == big}
    assert by["fused"]["hbm_bytes"] < by["composed"]["hbm_bytes"]
    assert by["fused"]["wall_s"] < by["composed"]["wall_s"], (
        by["fused"]["wall_s"], by["composed"]["wall_s"])

    if json_path is None:
        json_path = os.path.join(os.path.dirname(__file__), "..",
                                 "artifacts", "BENCH_kernel_scale.json")
    with open(os.path.abspath(json_path), "w") as f:
        json.dump({"bench": "kernel_scale",
                   "hw_model": {"hbm_bw": HBM_BW, "peak_flops": PEAK_FLOPS},
                   "rows": rows}, f, indent=2)
    return rows


def main(full: bool = False):
    kernel_scale(quick=not full)
    for mesh in ["single_16x16", "multi_2x16x16"]:
        recs = load_records(mesh)
        if not recs:
            continue
        print(f"\n== roofline ({mesh}) ==")
        print(f"{'arch':28s} {'shape':15s} {'compute_s':>10s} {'memory_s':>10s} "
              f"{'coll_s':>10s} {'dom':>10s} {'useful':>7s} {'HBM_GiB':>8s}")
        for rec in recs:
            row = roofline_row(rec)
            uf = f"{row['useful_fraction']:.3f}" if row["useful_fraction"] else "   -"
            hbm = f"{row['hbm_gib']:.1f}" if row["hbm_gib"] is not None else "-"
            print(f"{row['arch']:28s} {row['shape']:15s} {row['t_compute_s']:10.3e} "
                  f"{row['t_memory_s']:10.3e} {row['t_collective_s']:10.3e} "
                  f"{row['dominant']:>10s} {uf:>7s} {hbm:>8s}")


if __name__ == "__main__":
    main()
