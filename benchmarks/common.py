"""Shared benchmark fixtures: corpus -> LSA -> index -> gold standard.

The paper's setup (§3) scaled to CPU: topic-mixture corpus standing in for
Wikipedia, LSA with ``--features`` (default 200; paper: 400 over 4.18M
docs), 1,000->--queries query docs, gold = brute-force cosine top-10.
Fixtures are cached under artifacts/ so the table/figure benches share one
build.
"""

from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import VectorIndex
from repro.data import make_corpus
from repro.lsa import build_lsa

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


class Fixture:
    def __init__(self, n_docs=20000, vocab=30000, topics=96, features=200,
                 n_queries=200, seed=0):
        os.makedirs(ART, exist_ok=True)
        tag = f"{n_docs}_{vocab}_{topics}_{features}_{seed}"
        cache = os.path.join(ART, f"bench_fixture_{tag}.npz")
        if os.path.exists(cache):
            z = np.load(cache)
            self.doc_vectors = jnp.asarray(z["doc_vectors"])
            self.doc_terms = z["doc_terms"]
            self.doc_tf = z["doc_tf"]
            self.vocab_size = int(z["vocab_size"])
        else:
            t0 = time.time()
            corpus = make_corpus(n_docs=n_docs, vocab_size=vocab, n_topics=topics,
                                 seed=seed)
            pipe = build_lsa(corpus, n_features=features)
            self.doc_vectors = pipe.doc_vectors
            self.doc_terms = corpus.doc_terms
            self.doc_tf = corpus.doc_tf
            self.vocab_size = corpus.vocab_size
            np.savez(cache, doc_vectors=np.asarray(self.doc_vectors),
                     doc_terms=corpus.doc_terms, doc_tf=corpus.doc_tf,
                     vocab_size=corpus.vocab_size)
            print(f"# fixture built in {time.time()-t0:.0f}s -> {cache}")
        self.n_docs = self.doc_vectors.shape[0]
        self.n_features = self.doc_vectors.shape[1]
        self.n_queries = n_queries
        rng = np.random.default_rng(seed + 1)
        self.query_ids = rng.choice(self.n_docs, size=n_queries, replace=False)
        self.queries = self.doc_vectors[self.query_ids]
        # Combined P1+I10 encoder: the bucket width has to match the corpus'
        # feature-magnitude scale (mean |x| ~ 1/sqrt(n_features) ~ 0.05 at
        # n=200).  P2 cells (0.01) are too fine -- measured P@10@page=640
        # drops from 0.95 to 0.28 (the encoder sweep that established this is
        # recorded in EXPERIMENTS.md §Quality).
        from repro.core import CombinedEncoder, IntervalEncoder, RoundingEncoder
        self.index = VectorIndex.build(
            self.doc_vectors,
            CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1)))
        self.gold_ids, self.gold_sims = self.index.gold_topk(self.queries, 10)


_FIXTURE = None


def fixture(**kw) -> Fixture:
    global _FIXTURE
    if _FIXTURE is None:
        _FIXTURE = Fixture(**kw)
    return _FIXTURE


def latency_percentiles(samples_s, keep_samples=False):
    """Per-query (or per-op) latency samples in seconds -> the tail block
    every BENCH_*.json row carries alongside its QPS keys: sample count +
    p50/p90/p99 in milliseconds (np.percentile, linear interpolation).
    ``keep_samples=True`` additionally embeds the raw samples (ms, in
    measurement order) for offline re-bucketing."""
    samples = np.asarray(list(samples_s), np.float64)
    out = {"n_samples": int(samples.size)}
    if samples.size == 0:
        out.update(p50_ms=None, p90_ms=None, p99_ms=None)
        return out
    p50, p90, p99 = np.percentile(samples, [50, 90, 99])
    out.update(p50_ms=float(p50 * 1e3), p90_ms=float(p90 * 1e3),
               p99_ms=float(p99 * 1e3))
    if keep_samples:
        out["samples_ms"] = [float(s * 1e3) for s in samples]
    return out


def timed(fn, *args, repeats=3, **kw):
    """-> (result, best seconds) with block_until_ready."""
    import jax
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best
