"""Train-step factory: microbatch gradient accumulation + AdamW update.

``make_train_step(loss_fn, cfg, accum)`` returns a jittable
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``.
With ``accum > 1`` the global batch is split on its leading axis and scanned;
XLA overlaps each microbatch's gradient ``psum`` with the next microbatch's
compute (async collectives), which is the standard DP comm/compute overlap.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .optimizer import (
    AdamWConfig,
    AdamWState,
    adafactor_update,
    adamw_update,
    global_norm,
)

__all__ = ["make_train_step"]


def _split_batch(batch, accum: int):
    """Split the global batch into ``accum`` microbatches, scan-ready.

    Reshape (B, ...) -> (B/accum, accum, ...) THEN swap to (accum, B/accum,
    ...): the microbatch rows stay contiguous *per device*, so the data-axis
    sharding of dim 0 survives as a sharding of dim 1 (a transpose of a
    sharding is metadata-only).  The naive ``reshape(accum, B/accum, ...)``
    mis-aligns device boundaries and GSPMD silently REPLICATES every
    microbatch (observed: +200 GiB/device in the dry-run memory analysis).
    """
    def f(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(b // accum, accum, *x.shape[1:]).swapaxes(0, 1)
    return jax.tree.map(f, batch)


def make_train_step(
    loss_fn: Callable,            # loss_fn(params, microbatch) -> scalar
    opt_cfg: AdamWConfig = AdamWConfig(),
    accum: int = 1,
    lr_schedule: Optional[Callable] = None,
    optimizer: str = "adamw",     # adamw | adafactor
):
    grad_fn = jax.value_and_grad(loss_fn)
    update = {"adamw": adamw_update, "adafactor": adafactor_update}[optimizer]

    def train_step(params, opt_state: AdamWState, batch):
        if accum == 1:
            loss, grads = grad_fn(params, batch)
        else:
            micro = _split_batch(batch, accum)

            def body(carry, mb):
                loss_acc, g_acc = carry
                l, g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + l, g_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero_g), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        lr_scale = lr_schedule(opt_state.step) if lr_schedule else 1.0
        new_params, new_state = update(grads, opt_state, params, opt_cfg, lr_scale)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "lr_scale": jnp.asarray(lr_scale, jnp.float32)}
        return new_params, new_state, metrics

    return train_step
