"""Elastic re-scaling: move (params, opt_state) between meshes.

Elasticity at pod scale = the ability to continue a run on a different device
count/topology (192 chips after losing a host; 2 pods after a scale-up).  In
GSPMD-land that is a pure re-layout problem: the logical pytree is unchanged,
only the shardings move.  ``reshard_tree`` re-places every leaf under the
target mesh+rule; device-count changes that divide the sharded axes need no
host round-trip (``jax.device_put`` moves shards directly); anything else
falls back to a host gather + re-scatter, which is exactly the
checkpoint-restore path (train/checkpoint.py) -- the two share semantics by
design: **elastic resize == checkpoint save + restore onto the new mesh**,
minus the disk.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["reshard_tree", "resize_data_axis"]


def reshard_tree(tree: Any, mesh: Mesh, rule: Callable[[tuple, Any], P]) -> Any:
    """Re-place every leaf on ``mesh`` with the PartitionSpec from ``rule``.

    rule(path, leaf) -> PartitionSpec.  Works across meshes of different
    sizes/shapes (the GSPMD resharding path; cross-mesh transfers fall back
    to host if needed).
    """
    def place(path, leaf):
        spec = rule(path, leaf)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(place, tree)


def resize_data_axis(tree: Any, old_mesh: Mesh, new_mesh: Mesh,
                     rule: Callable[[tuple, Any], P]) -> Any:
    """Continue a run on a resized mesh (e.g. 256 -> 192 chips).

    Shardings whose axes divide the new mesh move device-to-device; others
    bounce through host memory -- identical end state either way.
    """
    return reshard_tree(tree, new_mesh, rule)
