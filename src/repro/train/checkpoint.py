"""Sharded, atomic, async checkpointing (no external deps).

Layout:  <dir>/step_<N>/
            manifest.json          -- treedef + leaf names + shapes/dtypes
            shard<P>_leaf<i>.npy   -- per-host leaf payloads
A checkpoint is *complete* only once ``manifest.json`` exists (it is written
last, after an fsync'd tmp-dir rename), so a crash mid-write can never be
mistaken for a valid checkpoint -- restore scans for the newest complete
step.  ``AsyncCheckpointer`` double-buffers: the save runs on a background
thread over host copies so the train loop never blocks on disk.

On a multi-host pod each process saves only its addressable shards
(``process_index`` in the filename); this container is single-host so P=0,
but the layout and restore path are shard-aware.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "AsyncCheckpointer"]


def _leaf_paths(tree) -> list:
    leaves, _ = jax.tree_util.tree_flatten(tree)
    return leaves


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    proc = jax.process_index()
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{proc}"
    os.makedirs(tmp, exist_ok=True)
    meta = {"treedef": str(treedef), "n_leaves": len(leaves), "step": step,
            "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # npy has no bf16: store widened, restore casts back via the
            # reference tree's dtype (restore_checkpoint)
            arr = np.asarray(jax.numpy.asarray(leaf, dtype=jax.numpy.float32))
        np.save(os.path.join(tmp, f"shard{proc}_leaf{i}.npy"), arr)
        meta["leaves"].append({"i": i, "shape": list(arr.shape),
                               "dtype": logical_dtype})
    # manifest last; dir rename is atomic on POSIX
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith((".tmp0", ".tmp")):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like: Any, step: Optional[int] = None
                       ) -> Tuple[Any, Optional[int]]:
    """Restore into the structure of ``tree_like``; -> (tree, step|None)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return tree_like, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    proc = jax.process_index()
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"shard{proc}_leaf{i}.npy"))
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer with double buffering."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async write

        def _run():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and
            os.path.exists(os.path.join(self.ckpt_dir, n, "manifest.json"))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
