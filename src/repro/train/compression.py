"""Gradient compression for cross-pod data parallelism.

Two composable schemes (both standard large-scale tricks):

* **top-k sparsification with error feedback** (Deep Gradient Compression
  style): only the k largest-magnitude entries per leaf are exchanged; the
  residual is carried in an error-feedback buffer so the compression is
  unbiased over time.
* **int8 quantization** with per-leaf symmetric scale: 4x fewer bytes on the
  wire for the cross-pod all-reduce (the ``pod`` axis of the production mesh
  has the lowest bandwidth -- DCN, not ICI -- so this is where compression
  pays; see EXPERIMENTS.md §Perf).

``compressed_psum`` shows the intended collective usage under shard_map: the
quantized payload is what crosses the axis, dequantization happens after.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["topk_compress", "topk_decompress", "int8_quantize", "int8_dequantize",
           "ef_topk_step", "compressed_psum"]


class TopK(NamedTuple):
    values: jnp.ndarray   # (k,)
    indices: jnp.ndarray  # (k,) int32 into the flattened leaf
    shape: Any


def topk_compress(g: jnp.ndarray, ratio: float) -> TopK:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return TopK(values=flat[idx], indices=idx.astype(jnp.int32), shape=g.shape)


def topk_decompress(c: TopK) -> jnp.ndarray:
    import numpy as np

    size = int(np.prod(c.shape))
    flat = jnp.zeros((size,), c.values.dtype).at[c.indices].set(c.values)
    return flat.reshape(c.shape)


def ef_topk_step(g: jnp.ndarray, err: jnp.ndarray, ratio: float):
    """Error-feedback top-k: -> (sparse_grad_dense, new_err).

    sparse + err' == g + err exactly (nothing is lost, only delayed)."""
    corrected = g + err
    c = topk_compress(corrected, ratio)
    sparse = topk_decompress(c)
    return sparse, corrected - sparse


def int8_quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-quantized all-reduce over ``axis_name`` (use under shard_map).

    Each participant quantizes its shard-local gradient; int32 accumulation
    over the axis avoids overflow; scales are meaned.  Bytes on the wire:
    1/4 of f32 (plus one scalar per leaf).
    """
    q, scale = int8_quantize(g)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    scale_mean = jax.lax.psum(scale, axis_name) / n
    return q_sum.astype(jnp.float32) * scale_mean
