from .checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint
from .compression import compressed_psum, ef_topk_step, int8_dequantize, int8_quantize
from .grad import make_train_step
from .loop import TrainLoopConfig, run_train_loop
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update, cosine_schedule

__all__ = [
    "AsyncCheckpointer", "latest_step", "restore_checkpoint", "save_checkpoint",
    "compressed_psum", "ef_topk_step", "int8_dequantize", "int8_quantize",
    "make_train_step", "TrainLoopConfig", "run_train_loop",
    "AdamWConfig", "AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
]
