"""Fault-tolerant training loop: resume-from-checkpoint, straggler policy.

The loop is deliberately boring -- that is the fault-tolerance story:
* all mutable state is (params, opt_state, data_state); everything is
  checkpointed together, so a preempted run resumes bit-exactly from the
  last complete step (tests/test_train.py kills and resumes mid-run);
* per-step wall-clock is watched against a rolling straggler budget; a slow
  step (e.g. a failing host pre-eviction) triggers ``on_straggler`` (log /
  checkpoint-now / abort for the cluster manager to reschedule);
* data iterators are explicitly seedable + skippable so a restart replays
  the exact batch sequence (``data_state`` = number of consumed batches).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from .checkpoint import AsyncCheckpointer, restore_checkpoint

__all__ = ["TrainLoopConfig", "run_train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    log_every: int = 10
    straggler_factor: float = 5.0    # step slower than factor x rolling mean
    straggler_warmup: int = 8
    resume: bool = True


def run_train_loop(
    train_step: Callable,            # (params, opt_state, batch) -> (p, s, metrics)
    params: Any,
    opt_state: Any,
    make_batch: Callable[[int], Any],  # step index -> batch (seedable/skippable)
    cfg: TrainLoopConfig,
    on_straggler: Optional[Callable[[int, float], None]] = None,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
):
    ckpt = AsyncCheckpointer(cfg.ckpt_dir)
    start_step = 0
    if cfg.resume:
        state = {"params": params, "opt": opt_state}
        state, step = restore_checkpoint(cfg.ckpt_dir, state)
        if step is not None:
            params, opt_state = state["params"], state["opt"]
            start_step = step
    durations: list = []
    metrics = {}
    for step in range(start_step, cfg.total_steps):
        t0 = time.perf_counter()
        batch = make_batch(step)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0

        if len(durations) >= cfg.straggler_warmup:
            # median, not mean: the first (compile) step would otherwise
            # inflate the budget and mask real stragglers for ~32 steps
            typical = float(np.median(durations[-32:]))
            if dt > cfg.straggler_factor * typical and on_straggler is not None:
                on_straggler(step, dt / typical)
        durations.append(dt)

        if on_metrics is not None and step % cfg.log_every == 0:
            on_metrics(step, {k: float(v) for k, v in metrics.items()})
        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.total_steps:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    ckpt.wait()
    return params, opt_state, metrics
