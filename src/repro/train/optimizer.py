"""AdamW with global-norm clipping and cosine schedule, pure pytree ops.

Optimizer states mirror param shardings (and can additionally be sharded
ZeRO-1 style over the data axis via dist/sharding.py rules), so the dry-run
memory analysis accounts for them faithfully.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "AdafactorState", "adafactor_init", "adafactor_update",
           "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    cfg: AdamWConfig,
    lr_scale: jnp.ndarray | float = 1.0,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.nu, grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)


# --------------------------------------------------------------- Adafactor
# Factored second moments (Shazeer & Stern, arXiv:1804.04235), no momentum --
# the T5/PaLM memory recipe.  Required here to fit the 400B llama4-maverick
# optimizer state into v5e HBM (AdamW f32 moments alone would be ~12 GB/chip
# at 256-way sharding; factored states are ~params/d_ff).
class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any    # row second moment: shape[:-1]   (ndim>=2 leaves)
    vc: Any    # col second moment: shape[:-2] + (shape[-1],)
    v: Any     # full second moment for 0/1-D leaves


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    zr = lambda p: (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros((), jnp.float32))
    zc = lambda p: (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((), jnp.float32))
    zv = lambda p: (jnp.zeros((), jnp.float32) if _factored(p)
                    else jnp.zeros_like(p, dtype=jnp.float32))
    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(zr, params),
        vc=jax.tree.map(zc, params),
        v=jax.tree.map(zv, params),
    )


def adafactor_update(
    grads, state: AdafactorState, params, cfg: AdamWConfig,
    lr_scale: jnp.ndarray | float = 1.0,
) -> Tuple[Any, AdafactorState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8                    # Adafactor's schedule
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = cfg.lr * lr_scale

    def upd(p, g, vr, vc, v):
        g = g.astype(jnp.float32) * clip
        g2 = g * g + 1e-30
        if _factored(p):
            vr_n = beta2 * vr + (1 - beta2) * g2.mean(-1)
            vc_n = beta2 * vc + (1 - beta2) * g2.mean(-2)
            denom = (
                vr_n[..., None] * vc_n[..., None, :]
                / jnp.maximum(vr_n.mean(-1)[..., None, None], 1e-30)
            )
            u = g * jax.lax.rsqrt(denom + 1e-30)
            v_n = v
        else:
            v_n = beta2 * v + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v_n + 1e-30)
            vr_n, vc_n = vr, vc
        # update clipping (RMS(u) <= 1)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        new_p = (p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p)).astype(p.dtype)
        return new_p, vr_n, vc_n, v_n

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_vr = treedef.flatten_up_to(state.vr)
    flat_vc = treedef.flatten_up_to(state.vc)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_vr, flat_vc, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = AdafactorState(
        step=step,
        vr=jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        vc=jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        v=jax.tree_util.tree_unflatten(treedef, [o[3] for o in out]),
    )
    return new_params, new_state


def cosine_schedule(warmup: int, total: int, floor: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn
