import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both

The two XLA_FLAGS lines above run BEFORE any other import (jax locks device
count at first init); tests/benches never import this module, so they keep
seeing one device.  Per cell we write artifacts/dryrun/<mesh>/<arch>__<shape>.json
with cost_analysis (FLOPs / bytes), memory_analysis, the collective-byte
census (launch/hlo_analysis.py), and compile wall time.  Existing artifacts
are skipped unless --force (cells are independent; reruns are incremental).
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _analytic_arg_bytes(args, in_specs, mesh) -> int:
    """Per-device bytes of the inputs under their shardings (params+state+batch)."""
    total = 0
    flat_args = jax.tree_util.tree_leaves(args)
    flat_specs = jax.tree_util.tree_leaves(
        in_specs, is_leaf=lambda x: isinstance(x, P)
    )
    for a, s in zip(flat_args, flat_specs):
        size = np.prod(a.shape, dtype=np.int64) if a.shape else 1
        shard = 1
        for axes in s:
            if axes is None:
                continue
            for ax in (axes if isinstance(axes, tuple) else (axes,)):
                shard *= mesh.shape[ax]
        total += int(size) * a.dtype.itemsize // max(shard, 1)
    return total


def run_cell(cell, mesh, mesh_name: str, out_dir: str, force: bool = False,
             save_hlo: bool = False):
    from repro.launch.hlo_analysis import analyze_hlo

    path = os.path.join(out_dir, mesh_name, f"{cell.arch}__{cell.shape}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    in_sh = tuple(_shardings(s, mesh) for s in cell.in_specs)
    kwargs = {}
    if cell.out_specs is not None:
        kwargs["out_shardings"] = _shardings(cell.out_specs, mesh)

    from repro.dist.annotate import use_mesh

    t0 = time.perf_counter()
    with mesh, use_mesh(mesh):
        lowered = jax.jit(cell.fn, in_shardings=in_sh, **kwargs).lower(*cell.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    hl = analyze_hlo(hlo)   # loop-corrected flops/bytes/collectives
    if save_hlo:
        with open(path.replace(".json", ".hlo"), "w") as f:
            f.write(hlo)

    record = {
        "arch": cell.arch,
        "shape": cell.shape,
        "kind": cell.kind,
        "mesh": mesh_name,
        "mesh_shape": dict(mesh.shape),
        "note": cell.note,
        # loop-corrected (launch/hlo_analysis.py); per device per step
        "flops_per_device": hl["flops"],
        "dot_flops_per_device": hl["dot_flops"],
        "bytes_per_device": hl["bytes"],
        "collective_bytes_per_device": hl["collective_bytes"],
        "collective_breakdown": hl["collectives"],
        # raw XLA numbers (while bodies counted once -- kept for reference)
        "xla_flops_per_device": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "input_bytes_per_device": _analytic_arg_bytes(cell.args, cell.in_specs, mesh),
        "memory_analysis": mem_info,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_lines": hlo.count("\n"),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    from repro.configs import ALL_IDS, ARCH_IDS, arch_shapes, get_arch
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all' (10 assigned), or 'all+paper'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    if args.arch == "all":
        arch_ids = ARCH_IDS
    elif args.arch == "all+paper":
        arch_ids = ALL_IDS
    else:
        arch_ids = [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch_id in arch_ids:
        arch = get_arch(arch_id)
        shapes = arch_shapes(arch_id) if args.shape == "all" else [args.shape]
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi_2x16x16" if multi else "single_16x16"
                mesh = make_production_mesh(multi_pod=multi)
                cell = arch.cell(shape, mesh)
                if cell is None:
                    print(f"SKIP  {arch_id:28s} {shape:16s} {mesh_name} (by rule)")
                    continue
                try:
                    t0 = time.perf_counter()
                    rec = run_cell(cell, mesh, mesh_name, args.out,
                                   force=args.force, save_hlo=args.save_hlo)
                    print(f"OK    {arch_id:28s} {shape:16s} {mesh_name} "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll/dev={rec['collective_bytes_per_device']:.3e} "
                          f"({time.perf_counter()-t0:.0f}s)")
                except Exception as e:
                    failures.append((arch_id, shape, mesh_name, repr(e)))
                    print(f"FAIL  {arch_id:28s} {shape:16s} {mesh_name}: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled")


if __name__ == "__main__":
    main()
