"""Production meshes (brief-mandated): 16x16 single pod, 2x16x16 multi-pod.

A FUNCTION, not a module constant -- importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init; tests and
benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_shard_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist -- for tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


def make_shard_mesh(n_shards: int, n_replicas: int = 1):
    """Mesh for doc-sharded search: 1-D ``data`` (one doc-shard per device),
    or 2-D ``(data, replica)`` when ``n_replicas > 1`` (each doc-shard
    replicated across the ``replica`` axis, ES replica shards).

    Search has no tensor-parallel dimension -- every shard runs the whole
    two-phase pipeline over its own document range -- so the axes are pure
    serving axes: ``data`` partitions the corpus, ``replica`` multiplies
    QPS.  Use ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to fan
    a CPU host out into N virtual shard hosts.
    """
    need = n_shards * n_replicas
    devs = jax.devices()
    if need > len(devs):
        raise ValueError(
            f"{n_shards} shards x {n_replicas} replicas need {need} devices "
            f"but only {len(devs)} exist; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} "
            "before the first jax import")
    if n_replicas == 1:                      # keep the PR-1 1-D mesh contract
        return jax.make_mesh((n_shards,), ("data",), devices=devs[:need])
    return jax.make_mesh((n_shards, n_replicas), ("data", "replica"),
                         devices=devs[:need])
