"""Serving launcher: build a vector index and serve batched queries.

    PYTHONPATH=src python -m repro.launch.serve --docs 10000 --features 128 \
        --queries 256 --batch-size 32 [--shards 4 --replicas 2 --merge stream]

Stands up the paper's system end to end on local devices: synthetic corpus
-> LSA -> encoded index -> BatchedSearchEngine, then reports quality vs the
brute-force gold standard and effective latency/throughput.  ``--shards N``
doc-shards the index over an N-device ``data`` mesh (ES-style);
``--replicas R`` replicates every doc-shard R times on a ``(data, replica)``
mesh (queries round-robin across the replica groups -- ES replica shards);
``--merge stream`` streams per-shard candidate pages into the coordinating
merge instead of one blocking all-gather.  S*R virtual host devices are
forced when the platform has fewer.  (The pod-scale index layouts are
exercised by repro.launch.dryrun's vectordb-wiki cells.)
"""

from __future__ import annotations

import argparse
import sys
import time

# --shards x --replicas needs S*R host devices, and XLA_FLAGS must be set
# before the first jax import (which the repro.core import below triggers);
# malformed values fall through to argparse, which owns the error message
from repro.launch.hostdev import force_host_devices, peek_int_arg

force_host_devices(peek_int_arg(sys.argv, "--shards")
                   * max(peek_int_arg(sys.argv, "--replicas"), 1))

import numpy as np

from repro.core import (CombinedEncoder, IntervalEncoder, RoundingEncoder,
                        TrimFilter, VectorIndex, precision_at_k)
from repro.data import make_corpus
from repro.lsa import build_lsa
from repro.serve.engine import BatchedSearchEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=10000)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--page", type=int, default=320)
    ap.add_argument("--trim", type=float, default=0.05)
    ap.add_argument("--engine", default="codes",
                    choices=["codes", "postings", "onehot"])
    ap.add_argument("--shards", type=int, default=0,
                    help="doc-shard the index over N devices (0 = unsharded)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replicate each doc-shard R times (needs --shards; "
                         "queries round-robin across replica groups)")
    ap.add_argument("--merge", default=None,
                    choices=["gather", "stream"],
                    help="sharded merge transport (default: gather; stream = "
                         "ring-streamed per-shard pages)")
    args = ap.parse_args()
    if args.replicas > 1 and args.shards < 1:
        ap.error("--replicas needs --shards >= 1")
    if args.merge and args.shards < 1:
        ap.error("--merge needs --shards >= 1")

    print(f"building corpus ({args.docs} docs) + LSA-{args.features} ...")
    corpus = make_corpus(n_docs=args.docs, vocab_size=max(args.docs, 8000),
                         n_topics=64, seed=0)
    pipe = build_lsa(corpus, n_features=args.features)
    index = VectorIndex.build(
        pipe.doc_vectors,
        CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1)))

    rng = np.random.default_rng(1)
    qids = rng.choice(args.docs, size=args.queries, replace=False)
    queries = np.asarray(pipe.doc_vectors[qids])
    gold_ids, _ = index.gold_topk(pipe.doc_vectors[qids], 10)

    if args.shards > 0:
        from repro.launch.mesh import make_shard_mesh

        mesh = make_shard_mesh(args.shards, args.replicas)
        print(f"doc-sharding index over {args.shards} shard(s) "
              f"x {args.replicas} replica(s) ...")
        index = index.shard(mesh)

    engine = BatchedSearchEngine(
        index, batch_size=args.batch_size, k=10, page=args.page,
        trim=TrimFilter(args.trim) if args.trim else None, engine=args.engine,
        merge=args.merge)
    try:
        t0 = time.time()
        futs = [engine.submit(q) for q in queries]
        results = [f.result(timeout=120) for f in futs]
        dt = time.time() - t0
    finally:
        engine.close()

    import jax.numpy as jnp
    ids = jnp.asarray(np.stack([r[0] for r in results]))
    p10 = float(precision_at_k(ids, gold_ids).mean())
    print(f"served {args.queries} queries in {dt:.2f}s "
          f"({dt/args.queries*1e3:.1f} ms/query effective, "
          f"batch={args.batch_size}, engine={args.engine})")
    print(f"P@10 vs brute force: {p10:.3f} "
          f"(trim={args.trim}, page={args.page})")


if __name__ == "__main__":
    main()
