"""Serving launcher: build a vector index and serve batched queries.

    PYTHONPATH=src python -m repro.launch.serve --docs 10000 --features 128 \
        --queries 256 --batch-size 32 [--shards 4 --replicas 2 --merge stream] \
        [--ingest 1000] [--cluster [--fail-shard 0] [--auto-compact 0.2]]

Stands up the paper's system end to end on local devices: synthetic corpus
-> LSA -> encoded index -> BatchedSearchEngine, then reports quality vs the
brute-force gold standard and effective latency/throughput.  ``--shards N``
doc-shards the index over an N-device ``data`` mesh (ES-style; the index is
built ON the mesh by the one-program sharded build); ``--replicas R``
replicates every doc-shard R times on a ``(data, replica)`` mesh (queries
round-robin across the replica groups -- ES replica shards); ``--merge
stream`` streams per-shard candidate pages into the coordinating merge
instead of one blocking all-gather; ``--ingest M`` holds the last M docs
out of the build and hot-adds them through the live engine (ES append
segments), so the quality report covers docs that were never in the built
index.  S*R virtual host devices are forced when the platform has fewer.

Cluster control plane (:mod:`repro.cluster`): ``--cluster`` serves through
:class:`ClusterEngine` -- R independent per-replica-group batchers with
request-stream affinity instead of one batcher fronting the whole mesh.
``--fail-shard G`` then injects a failure into replica group G after the
first serving pass and re-serves the same queries: the run asserts the
failover results are bit-identical to the healthy cluster.  ``--auto-compact
T`` starts the background maintenance daemon with tombstone-ratio
threshold T, deletes enough docs to trip it, waits for the background
compaction, and re-serves to show quality is preserved.  (The pod-scale
index layouts are exercised by repro.launch.dryrun's vectordb-wiki cells.)

Durability (:mod:`repro.store`): ``--store DIR`` attaches a translog +
commit-point store -- every hot ingest/delete is fsync'd to the
write-ahead log before it acks (``--durability async`` relaxes to
buffered writes), and a baseline commit point is written at startup.
``--kill-and-recover`` then runs the acceptance scenario end to end:
after all serving passes it discards every in-memory index ("kill"),
crash-recovers from the store directory alone (latest commit + translog
replay, torn tails truncated), asserts the recovered index returns
BIT-IDENTICAL search results to the pre-kill live index, and re-serves
the query load through a fresh engine on the recovered state.

Observability (:mod:`repro.obs`): ``--stats-interval S`` samples every
request into a :class:`~repro.obs.tracing.Tracer`, prints an ES
``_cat``-style stats line every S seconds while serving, and ends with a
final stats + trace dump.  The run then asserts the reconciliation
contract: submitted == completed == queries issued (== the sum of
per-group completions under ``--cluster``), zero failures surfaced to
callers, and -- under ``--fail-shard`` -- exactly one health down
transition with at least one failover resubmit.  ``make smoke-obs``
drives both the healthy and the fail-shard variant.

Observability v2 (``make smoke-profile`` drives all four together):
``--profile`` re-serves the warmed queries with ES
``_search?profile=true``-style execution trees and asserts the
reconciliation contract -- each tree's phases tile its total exactly,
and the dispatch phase sums to the dispatch-latency histogram delta;
``--slow-threshold S`` attaches the tail-sampled slow log (S=0 asserts
100% capture); ``--fail-on-recompile`` watches jit compiles per (entry
point, abstract shape) and fails the run on ANY attributed compile after
the first pass marks steady state; ``--metrics-file PATH`` writes a
JSONL registry-snapshot history (the Prometheus text exposition comes
from the same exporter).  See docs/OBSERVABILITY.md for the ES mapping.

Observability v3 (``make smoke-health`` drives it): under ``--cluster
--fail-shard`` the run asserts the ES ``_cluster/health`` verdict walks
green -> yellow -> green across the injected failure and that the
transition ledger reconciles EXACTLY (one down event for the failed
group, counters match one-for-one); ``--diagnostics-on-exit DIR``
writes a one-call support-diagnostics bundle (stats + health + device
byte tables + compile/cost tables + slow log + metrics history) at the
end of the run and automatically at the moment a failover or
kill-and-recover fires.
"""

from __future__ import annotations

import argparse
import sys
import time

# --shards x --replicas needs S*R host devices, and XLA_FLAGS must be set
# before the first jax import (which the repro.core import below triggers);
# malformed values fall through to argparse, which owns the error message
from repro.launch.hostdev import force_host_devices, peek_int_arg

force_host_devices(peek_int_arg(sys.argv, "--shards")
                   * max(peek_int_arg(sys.argv, "--replicas"), 1))

import numpy as np

from repro.core import (CombinedEncoder, IntervalEncoder, RoundingEncoder,
                        TrimFilter, VectorIndex, precision_at_k)
from repro.data import make_corpus
from repro.lsa import build_lsa
from repro.serve.engine import BatchedSearchEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=10000)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--page", type=int, default=320)
    ap.add_argument("--trim", type=float, default=0.05)
    ap.add_argument("--engine", default="codes",
                    choices=["codes", "postings", "onehot"])
    ap.add_argument("--shards", type=int, default=0,
                    help="doc-shard the index over N devices (0 = unsharded)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replicate each doc-shard R times (needs --shards; "
                         "queries round-robin across replica groups)")
    ap.add_argument("--merge", default=None,
                    choices=["gather", "stream"],
                    help="sharded merge transport (default: gather; stream = "
                         "ring-streamed per-shard pages)")
    ap.add_argument("--ingest", type=int, default=0,
                    help="hold back N docs from the build and hot-add them "
                         "through the running engine (needs --shards)")
    ap.add_argument("--cluster", action="store_true",
                    help="serve through the cluster control plane: one "
                         "independent batcher per replica group, stream "
                         "affinity, failover routing (needs --shards)")
    ap.add_argument("--fail-shard", type=int, default=None, metavar="G",
                    help="inject a failure into replica group G after the "
                         "first pass and verify bit-identical failover "
                         "(needs --cluster and --replicas >= 2)")
    ap.add_argument("--auto-compact", type=float, default=None, metavar="T",
                    help="run the background maintenance daemon with "
                         "tombstone-ratio threshold T and demo an "
                         "auto-compaction (needs --cluster)")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="attach a durability store (write-ahead translog "
                         "+ commit points) under DIR (needs --shards)")
    ap.add_argument("--durability", default="request",
                    choices=["request", "async"],
                    help="translog fsync policy (request = fsync before "
                         "every ingest ack, the ES default)")
    ap.add_argument("--kill-and-recover", action="store_true",
                    help="after serving, discard the in-memory index, "
                         "crash-recover from --store alone, and assert "
                         "bit-identical search results")
    ap.add_argument("--stats-interval", type=float, default=None,
                    metavar="S",
                    help="print an ES _cat-style stats line every S seconds "
                         "plus a final stats + trace dump; the run then "
                         "asserts the counters reconcile exactly with the "
                         "queries issued (and that --fail-shard recorded "
                         "exactly one down transition)")
    ap.add_argument("--profile", action="store_true",
                    help="after the warm serving pass, re-serve every query "
                         "with _profile-style execution trees, assert each "
                         "tree's phases tile its total exactly and that the "
                         "dispatch phase reconciles with the dispatch "
                         "latency histogram, then print one tree plus "
                         "per-phase p50/p99")
    ap.add_argument("--slow-threshold", type=float, default=None,
                    metavar="S",
                    help="attach the tail-sampled slow log: every request "
                         "slower than S seconds (or failed) is captured at "
                         "100%% regardless of head sampling; S=0 captures "
                         "everything and the run asserts captured == seen")
    ap.add_argument("--metrics-file", default=None, metavar="PATH",
                    help="write a JSONL metrics-snapshot history to PATH "
                         "(one registry snapshot at each serving milestone "
                         "+ final) and print the final Prometheus text "
                         "exposition size")
    ap.add_argument("--diagnostics-on-exit", default=None, metavar="DIR",
                    help="write a one-call diagnostics bundle (stats, "
                         "cluster health, device/cost tables, slow log, "
                         "compile stats, metrics history) into DIR at the "
                         "end of the run -- and automatically at the moment "
                         "a --fail-shard failover or --kill-and-recover "
                         "teardown fires, so the bundle captures the state "
                         "an operator would want from the incident")
    ap.add_argument("--fail-on-recompile", action="store_true",
                    help="watch jit compiles per (entry point, abstract "
                         "shape); after the first serving pass marks steady "
                         "state, ANY further attributed compile fails the "
                         "run (incompatible with --auto-compact and "
                         "--kill-and-recover, whose post-warmup rebuilds "
                         "legitimately compile)")
    args = ap.parse_args()
    if args.replicas > 1 and args.shards < 1:
        ap.error("--replicas needs --shards >= 1")
    if args.merge and args.shards < 1:
        ap.error("--merge needs --shards >= 1")
    if args.ingest and args.shards < 1:
        ap.error("--ingest needs --shards >= 1 (plain VectorIndex is "
                 "immutable)")
    if not 0 <= args.ingest < args.docs:
        ap.error("--ingest must be in [0, --docs)")
    if args.cluster and args.shards < 1:
        ap.error("--cluster needs --shards >= 1")
    if args.fail_shard is not None:
        if not args.cluster or args.replicas < 2:
            ap.error("--fail-shard needs --cluster and --replicas >= 2 "
                     "(failover needs a surviving replica group)")
        if not 0 <= args.fail_shard < args.replicas:
            ap.error(f"--fail-shard must be in [0, {args.replicas})")
    if args.auto_compact is not None and not (args.cluster
                                              and 0 < args.auto_compact < 1):
        ap.error("--auto-compact needs --cluster and a threshold in (0, 1)")
    if args.store and args.shards < 1:
        ap.error("--store needs --shards >= 1 (durability serializes the "
                 "sharded index's canonical flat form)")
    if args.durability != "request" and not args.store:
        ap.error("--durability needs --store (there is no translog to "
                 "apply the policy to)")
    if args.kill_and_recover and not args.store:
        ap.error("--kill-and-recover needs --store")
    if args.stats_interval is not None and args.stats_interval <= 0:
        ap.error("--stats-interval must be positive")
    if args.slow_threshold is not None and args.slow_threshold < 0:
        ap.error("--slow-threshold must be >= 0")
    if args.fail_on_recompile and args.auto_compact is not None:
        ap.error("--fail-on-recompile is incompatible with --auto-compact: "
                 "post-warmup background merges legitimately compile")
    if args.fail_on_recompile and args.kill_and_recover:
        ap.error("--fail-on-recompile is incompatible with "
                 "--kill-and-recover: the post-warmup recovery rebuild "
                 "legitimately compiles")

    print(f"building corpus ({args.docs} docs) + LSA-{args.features} ...")
    corpus = make_corpus(n_docs=args.docs, vocab_size=max(args.docs, 8000),
                         n_topics=64, seed=0)
    pipe = build_lsa(corpus, n_features=args.features)
    encoder = CombinedEncoder(RoundingEncoder(1), IntervalEncoder(0.1))
    # gold standard is brute force over the FULL corpus -- including the
    # held-back docs the engine only ever sees through hot ingest -- and
    # needs no encoded index, only the normalized vectors
    import jax.numpy as jnp

    from repro.core.rerank import brute_force_topk, normalize

    rng = np.random.default_rng(1)
    qids = rng.choice(args.docs, size=args.queries, replace=False)
    queries = np.asarray(pipe.doc_vectors[qids])
    unit_vecs = normalize(jnp.asarray(pipe.doc_vectors, jnp.float32))
    gold_ids, _ = brute_force_topk(unit_vecs, unit_vecs[qids], 10)
    gold_ref = gold_ids            # rebound to the live gold after deletes

    if args.shards > 0:
        from repro.dist.shard_index import ShardedVectorIndex
        from repro.launch.mesh import make_shard_mesh

        mesh = make_shard_mesh(args.shards, args.replicas)
        built = args.docs - args.ingest
        print(f"on-device sharded build: {built} docs over {args.shards} "
              f"shard(s) x {args.replicas} replica(s) ...")
        index = ShardedVectorIndex.build_sharded(
            pipe.doc_vectors[:built], mesh, encoder=encoder)
    else:
        index = VectorIndex.build(pipe.doc_vectors, encoder)

    store = None
    if args.store:
        from repro.store import Store, latest_commit

        if latest_commit(args.store, validate=False) is not None:
            ap.error(f"--store {args.store} already holds a commit point; "
                     "this launcher always builds a fresh corpus, so point "
                     "it at a fresh directory")
        store = Store(args.store, durability=args.durability)
        print(f"durability store at {args.store} "
              f"(translog durability={args.durability}, "
              f"seqno={store.seqno})")

    common = dict(batch_size=args.batch_size, k=10, page=args.page,
                  trim=TrimFilter(args.trim) if args.trim else None,
                  engine=args.engine, merge=args.merge)
    tracer = None
    if args.stats_interval:
        from repro.obs import Tracer

        # sample every request: this launcher is a demo/acceptance run,
        # not a steady-state service, so full traces beat low overhead
        tracer = Tracer(capacity=64, sample=1.0)
        common["tracer"] = tracer
    slowlog = None
    if args.slow_threshold is not None:
        from repro.obs import SlowLog

        slowlog = SlowLog(threshold_s=args.slow_threshold, capacity=256)
        common["slowlog"] = slowlog
    watch = None
    if args.fail_on_recompile:
        from repro.obs import active_watch

        # the engines attribute their compiles to the process default
        # watch automatically; host-side analytics (the gold-standard
        # brute force above) stay <unattributed> and never count against
        # steady state
        watch = active_watch()
    exporter = None
    if args.metrics_file:
        from repro.obs import MetricsExporter, default_registry

        exporter = MetricsExporter(default_registry(),
                                   path=args.metrics_file)
    if args.cluster:
        from repro.cluster import ClusterEngine

        engine = ClusterEngine(index, auto_compact=args.auto_compact,
                               store=store, **common)
        n_streams = 4 * engine.n_groups
        submit = lambda i, q: engine.submit(q, stream=i % n_streams)
        print(f"cluster control plane: {engine.n_groups} replica-group "
              f"batcher(s), {n_streams} request streams")
    else:
        if store is not None:
            index = store.open_index(index)
        engine = BatchedSearchEngine(index, **common)
        submit = lambda i, q: engine.submit(q)

    def dump_diag(reason, eng=None):
        """Write one diagnostics bundle for the CURRENT engine (the
        ``engine`` local is rebound across kill/recover, and the closure
        follows it).  No-op unless --diagnostics-on-exit is set."""
        if not args.diagnostics_on_exit:
            return
        from repro.obs import write_diagnostics

        path = write_diagnostics(eng if eng is not None else engine,
                                 args.diagnostics_on_exit,
                                 exporter=exporter, reason=reason)
        print(f"diagnostics bundle ({reason}) -> {path}", flush=True)

    n_issued = 0
    stats_stop = None
    obs_final = lambda: None
    if args.stats_interval:
        import threading

        from repro.obs import format_stats_line

        stats_stop = threading.Event()
        periodic = engine                 # the engine the printer follows

        def _stats_loop():
            while not stats_stop.wait(args.stats_interval):
                try:
                    print(format_stats_line(periodic.stats()), flush=True)
                except Exception:  # noqa: BLE001 - engine mid-teardown
                    return

        threading.Thread(target=_stats_loop, daemon=True,
                         name="stats-printer").start()
        _obs_done = []

        def obs_final():
            """Stop the printer, dump final stats + traces, and assert
            the reconciliation contract: every query issued is accounted
            for exactly once, and an injected group failure shows up as
            exactly one down transition (THE failover event) plus at
            least one resubmit.  Runs once, BEFORE any kill/recover
            teardown so it sees the engine that served the load."""
            if _obs_done:
                return
            _obs_done.append(True)
            stats_stop.set()
            st = engine.stats()
            print("final " + format_stats_line(st), flush=True)
            req = st["requests"]
            assert req["submitted"] == n_issued, (req, n_issued)
            assert req["completed"] == n_issued, (req, n_issued)
            assert req["failed"] == 0, req
            if args.cluster:
                per_group = req["group_completed"]
                assert sum(per_group.values()) == n_issued, \
                    (per_group, n_issued)
                if args.fail_shard is not None:
                    h, r = st["health"], st["routing"]
                    assert h["down_transitions"] == 1, h
                    assert r["failover_resubmits"] >= 1, r
                    assert h["mark_ups"] + h["readmits"] >= 1, h
            ts = tracer.stats()
            print(f"traces: {ts['retained']} retained "
                  f"({ts['sampled']}/{ts['seen']} sampled)", flush=True)
            dump = tracer.dump()
            if dump:
                last = dump[-1]
                phases = ", ".join(
                    f"{s['name']}={s['duration_s'] * 1e3:.2f}ms"
                    for s in last["spans"] if s["duration_s"] is not None)
                print(f"last trace: {phases}", flush=True)
            print("stats: counters reconcile with the "
                  f"{n_issued} queries issued", flush=True)

    try:
        if args.ingest:
            t0 = time.time()
            first = engine.add_documents(pipe.doc_vectors[-args.ingest:])
            dt = time.time() - t0
            print(f"hot-added {args.ingest} docs (ids {first}.."
                  f"{first + args.ingest - 1}) in {dt*1e3:.1f} ms "
                  f"({args.ingest/dt:.0f} docs/s)")
        t0 = time.time()
        futs = [submit(i, q) for i, q in enumerate(queries)]
        n_issued += len(futs)
        results = [f.result(timeout=120) for f in futs]
        dt = time.time() - t0

        ids = jnp.asarray(np.stack([r[0] for r in results]))
        p10 = float(precision_at_k(ids, gold_ids).mean())
        print(f"served {args.queries} queries in {dt:.2f}s "
              f"({dt/args.queries*1e3:.1f} ms/query effective, "
              f"batch={args.batch_size}, engine={args.engine})")
        print(f"P@10 vs brute force: {p10:.3f} "
              f"(trim={args.trim}, page={args.page})")

        if exporter is not None:
            exporter.collect()
        if watch is not None:
            # everything the steady-state service needs is compiled by
            # the first pass; from here any attributed compile is a
            # recompile bug
            watch.mark_steady()
            print(f"compile watch: {watch.compiles_total} compile(s) "
                  "during warmup; steady state marked", flush=True)

        if args.profile:
            from repro.obs import format_profile_tree

            def _find(node, name):
                if node["name"] == name:
                    return node
                for c in node["children"]:
                    hit = _find(c, name)
                    if hit is not None:
                        return hit
                return None

            hist0 = engine.metrics.snapshot()["histograms"].get(
                "engine.dispatch.latency_s", {})
            sum0 = sum(v["sum"] for v in hist0.values())
            trees = []
            t0 = time.time()
            for i, q in enumerate(queries):
                if args.cluster:
                    _, _, tree = engine.profile(q, stream=i % n_streams)
                else:
                    _, _, tree = engine.search(q, profile=True)
                trees.append(tree)
            n_issued += len(trees)
            dt = time.time() - t0
            hist1 = engine.metrics.snapshot()["histograms"].get(
                "engine.dispatch.latency_s", {})
            sum1 = sum(v["sum"] for v in hist1.values())
            phases = {}
            disp_total = 0.0
            for tree in trees:
                q_node = _find(tree, "query")
                assert q_node is not None, tree
                kids = [c for c in q_node["children"]
                        if c["duration_s"] is not None]
                tiled = sum(c["duration_s"] for c in kids)
                assert abs(q_node["duration_s"] - tiled) < 1e-6, \
                    (q_node["duration_s"], tiled)
                disp = _find(tree, "dispatch")
                disp_total += disp["duration_s"]
                for c in kids + disp["children"]:
                    if c.get("duration_s") is not None:
                        phases.setdefault(c["name"], []).append(
                            c["duration_s"])
            # the pass is sequential, so each profiled request is its own
            # batch: the trees' dispatch phase must reconcile with the
            # dispatch-latency histogram delta (float addition error only)
            assert abs((sum1 - sum0) - disp_total) < 1e-6, \
                (sum1 - sum0, disp_total)
            print(f"profile: {len(trees)} trees in {dt:.2f}s -- phases "
                  "tile each total exactly; dispatch reconciles with the "
                  f"latency histogram ({disp_total * 1e3:.1f} ms)",
                  flush=True)
            print(format_profile_tree(trees[0]), flush=True)

            def _q(vals, frac):
                s = sorted(vals)
                return s[min(len(s) - 1, int(frac * len(s)))] * 1e3

            for name in sorted(phases):
                vals = phases[name]
                print(f"  phase {name:<12} p50={_q(vals, 0.5):8.3f}ms "
                      f"p99={_q(vals, 0.99):8.3f}ms  (n={len(vals)})",
                      flush=True)

        if args.fail_shard is not None:
            from repro.obs import format_health_line

            h0 = engine.cluster_health()
            assert h0["status"] == "green", h0
            gen0 = h0["generation"]
            engine.inject_failure(args.fail_shard)
            t0 = time.time()
            futs = [submit(i, q) for i, q in enumerate(queries)]
            n_issued += len(futs)
            down = [f.result(timeout=120) for f in futs]
            dt = time.time() - t0
            # the failpoint trips on first dispatch, failover routing
            # marks the group down mid-serve: health is yellow NOW (the
            # injected fault is a latent failure until traffic finds it,
            # exactly like a dying ES node)
            h1 = engine.cluster_health()
            assert h1["status"] == "yellow", h1
            assert args.fail_shard in h1["down"], h1
            print(format_health_line(h1), flush=True)
            same = all(np.array_equal(a[0], b[0])
                       and np.array_equal(a[1], b[1])
                       for a, b in zip(results, down))
            assert same, "failover results diverged from the healthy cluster"
            print(f"failover: injected failure into group {args.fail_shard}; "
                  f"re-served {args.queries} queries in {dt:.2f}s on "
                  f"groups {engine.health.up_groups()} -- results "
                  f"bit-identical to the healthy cluster")
            dump_diag("failover")
            # recovery: clear the fault and rejoin the group (two separate
            # events, like an ES node rejoin after the fault clears)
            engine.heal(args.fail_shard)
            engine.mark_up(args.fail_shard)
            # _cluster/health reconciliation: the verdict walked green ->
            # yellow -> green, and the transition ledger explains it
            # exactly -- one down event for the failed group since the
            # pre-injection generation, matched one-for-one by the
            # down_transitions counter, plus the recovery up/readmit
            h2 = engine.cluster_health()
            assert h2["status"] == "green", h2
            events = [e for e in h2["transitions"]
                      if e["generation"] > gen0]
            downs = [e for e in events if e["event"] == "down"]
            assert len(downs) == 1 and downs[0]["group"] == args.fail_shard, \
                events
            assert any(e["event"] in ("up", "readmit") for e in events), \
                events
            assert h2["counters"]["down_transitions"] == len(downs), h2
            print(format_health_line(h2) + "  (transitions reconcile: "
                  "green -> yellow -> green, 1 down event, counters match)",
                  flush=True)

        if args.auto_compact is not None:
            # the tombstone ratio is dead / docs-ever-assigned over the
            # WHOLE id space (built + hot-ingested), so size and draw the
            # victims from the whole space too or a big --ingest keeps the
            # ratio under the threshold forever
            n_del = int(min(0.9, 1.5 * args.auto_compact) * args.docs)
            pool = rng.permutation(np.setdiff1d(np.arange(args.docs), qids))
            victims = pool[:n_del]
            if len(victims) <= 1.2 * args.auto_compact * args.docs:
                ap.error("--auto-compact threshold unreachable: too few "
                         "deletable docs (raise --docs or lower --queries "
                         "or the threshold)")
            engine.delete(victims)
            target = max(1, len(engine.health.up_groups()))
            deadline = time.time() + 120
            while (engine.maintenance.compactions < target
                   and time.time() < deadline):
                time.sleep(0.05)
            n_compact = engine.maintenance.compactions
            assert n_compact, "background auto-compaction never fired"
            live_vecs = np.asarray(unit_vecs).copy()
            live_vecs[victims] = 0.0
            gold_live, _ = brute_force_topk(jnp.asarray(live_vecs),
                                            unit_vecs[qids], 10)
            gold_ref = gold_live
            futs = [submit(i, q) for i, q in enumerate(queries)]
            n_issued += len(futs)
            ids2 = jnp.asarray(
                np.stack([f.result(timeout=120)[0] for f in futs]))
            p10_live = float(precision_at_k(ids2, gold_live).mean())
            print(f"auto-compact: deleted {n_del} docs (ratio past "
                  f"{args.auto_compact}), background daemon compacted "
                  f"{n_compact} group(s); post-compact P@10 vs live gold: "
                  f"{p10_live:.3f}")

        if args.kill_and_recover:
            from repro.launch.mesh import make_shard_mesh
            from repro.store import recover

            # pre-kill reference on the live index, computed directly (no
            # batcher timing in the comparison); the recovered index is
            # rebuilt on the same mesh SHAPE, so parity is bit-exact at
            # any page, not only page >= n_docs
            live = (engine.group_index(0) if args.cluster
                    else engine.index)
            ref_ids, ref_scores = live.search(
                jnp.asarray(queries), k=10, page=args.page, engine=args.engine)
            ref_ids, ref_scores = np.asarray(ref_ids), np.asarray(ref_scores)
            n_ids_before = live.n_ids
            obs_final()                # before the kill: the counters and
            #                            traces belong to the dying engine
            dump_diag("kill-and-recover")
            engine.close()
            del live, index                         # "kill": drop the RAM copy
            t0 = time.time()
            mesh = (make_shard_mesh(args.shards) if args.cluster
                    else make_shard_mesh(args.shards, args.replicas))
            recovered, seq = recover(args.store, mesh)
            dt = time.time() - t0
            assert recovered.n_ids == n_ids_before, \
                (recovered.n_ids, n_ids_before)
            got_ids, got_scores = recovered.search(
                jnp.asarray(queries), k=10, page=args.page, engine=args.engine)
            assert np.array_equal(np.asarray(got_ids), ref_ids), \
                "recovered ids diverged from the pre-kill live index"
            assert np.array_equal(np.asarray(got_scores), ref_scores), \
                "recovered scores diverged from the pre-kill live index"
            print(f"kill-and-recover: crash-recovered {recovered.n_ids} "
                  f"docs from {args.store} (commit + translog replay to "
                  f"seq {seq}) in {dt:.2f}s -- search results BIT-IDENTICAL "
                  f"to the pre-kill live index")
            # and the recovered state serves: a fresh engine over it
            engine = BatchedSearchEngine(recovered, **common)
            t0 = time.time()
            futs = [engine.submit(q) for q in queries]
            ids3 = jnp.asarray(
                np.stack([f.result(timeout=120)[0] for f in futs]))
            dt = time.time() - t0
            p10_rec = float(precision_at_k(ids3, gold_ref).mean())
            print(f"re-served {args.queries} queries on the recovered "
                  f"index in {dt:.2f}s (P@10 {p10_rec:.3f})")
        obs_final()
        if slowlog is not None:
            ss = slowlog.stats()
            print(f"slowlog: {ss['captured']}/{ss['seen']} captured "
                  f"({ss['slow']} slow, {ss['errors']} errors, threshold "
                  f"{ss['threshold_s'] * 1e3:.0f}ms)", flush=True)
            if args.slow_threshold == 0:
                assert ss["captured"] == ss["seen"], ss
                print("slowlog: tail capture reconciles -- every request "
                      "captured at threshold 0", flush=True)
        if watch is not None:
            cs = watch.stats()
            print(f"recompile watch: {cs['compiles_total']} total, "
                  f"{cs['compiles_steady_state']} post-warmup across "
                  f"{len(cs['by_function'])} entry point(s)", flush=True)
            watch.check()        # raises on any steady-state recompile
            print("recompile watch: zero steady-state recompiles",
                  flush=True)
        if exporter is not None:
            exporter.collect()
            text = exporter.text()
            print(f"metrics: {len(exporter.history())} snapshot(s) -> "
                  f"{args.metrics_file}; prometheus exposition "
                  f"{len(text.splitlines())} lines", flush=True)
        dump_diag("exit")
    finally:
        if stats_stop is not None:
            stats_stop.set()
        engine.close()
        if slowlog is not None:
            slowlog.close()
        if store is not None:
            store.close()


if __name__ == "__main__":
    main()
