"""Fan one CPU host out into N virtual jax devices -- jax-free on purpose.

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` only takes effect
when set before the first jax import, so entry points call this at the very
top of the module, ahead of any repro/jax import.  Both CLI front-ends
(repro.launch.serve, benchmarks.shard_scale) share this one copy.
"""

from __future__ import annotations

import os

__all__ = ["force_host_devices", "peek_int_arg"]

_FLAG = "xla_force_host_platform_device_count"


def force_host_devices(n: int) -> None:
    """Request ``n`` virtual host devices; no-op for n <= 1 or when the
    flag is already present (an explicit user setting wins)."""
    if n > 1 and _FLAG not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + f" --{_FLAG}={n}").strip()


def peek_int_arg(argv, name: str) -> int:
    """Pre-argparse peek at an int option (``--opt N`` or ``--opt=N``);
    malformed or absent -> 0, leaving the error to argparse."""
    for i, a in enumerate(argv):
        try:
            if a == name:
                return int(argv[i + 1])
            if a.startswith(name + "="):
                return int(a.split("=", 1)[1])
        except (IndexError, ValueError):
            return 0
    return 0
