"""Loop-corrected static analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE, so a model
with a layer scan (and chunked-attention scans inside it) is undercounted by
orders of magnitude.  This module re-derives the roofline inputs from the
module text with call-graph multipliers:

* computations are parsed into per-op records (result/operand shapes via a
  per-computation symbol table);
* a multiplier is propagated from ENTRY through ``calls=`` / ``to_apply=`` /
  ``condition=`` / ``body=`` edges, with while bodies scaled by the loop trip
  count (recovered from the condition's ``constant(N)``);
* **flops**: exact ``2 * prod(result) * contracted`` for every ``dot``,
  plus 1 flop/element for arithmetic elementwise ops;
* **bytes**: HBM-boundary traffic -- for ops in non-fusion computations,
  result bytes + resolvable operand bytes (fusion internals excluded:
  they stay in registers/VMEM);
* **collective bytes** per kind (all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute), result-shape sized.

Validated against analytic 6ND for the LM train cells (tests/test_dryrun.py).
"""

from __future__ import annotations

import re
from collections import defaultdict, deque
from typing import Dict, List, NamedTuple, Optional, Tuple

__all__ = ["analyze_hlo", "collective_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "compare",
    "select", "and", "or", "xor", "not", "clamp", "convert", "cosine", "sine",
    "logistic", "log-plus-one", "exponential-minus-one",
}

_SHAPE_RE = re.compile(r"\b(%s)\[([0-9,]*)\]" % "|".join(DTYPE_BYTES))
_OPLINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


class Op(NamedTuple):
    name: str
    kind: str
    result_bytes: int
    result_elems: int
    operands: Tuple[str, ...]
    attrs: str


def _shape_info(type_text: str) -> Tuple[int, int]:
    """-> (bytes, elems) summed over all shapes in a (possibly tuple) type."""
    total_b = total_e = 0
    for m in _SHAPE_RE.finditer(type_text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        # headers sit at column 0: "%name (params...) -> type {" / "ENTRY %..."
        # (params lists may contain "/*index=N*/" comments, so don't key on "=")
        if (line[:1] in ("%", "E") and line.rstrip().endswith("{")
                and (line.startswith("%") or line.startswith("ENTRY"))):
            header = line.strip()
            is_entry = header.startswith("ENTRY")
            m = re.search(r"%([\w\.\-]+)", header)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if is_entry:
                    entry = cur
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _parse_ops(lines: List[str]) -> Tuple[List[Op], Dict[str, Tuple[int, int]]]:
    ops: List[Op] = []
    symbols: Dict[str, Tuple[int, int]] = {}
    for line in lines:
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPNAME_RE.search(rhs)
        if not om:
            continue
        kind = om.group(1)
        type_text = rhs[: om.start()]
        rb, re_ = _shape_info(type_text)
        symbols[name] = (rb, re_)
        args_attrs = rhs[om.end():]
        operands = tuple(_OPERAND_RE.findall(args_attrs.split("),")[0]))
        ops.append(Op(name, kind, rb, re_, operands, args_attrs))
    return ops, symbols


def _multipliers(comps, entry) -> Dict[str, float]:
    parsed = {n: _parse_ops(ls) for n, ls in comps.items()}
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    queue = deque([entry])
    visited_edges = set()
    while queue:
        cname = queue.popleft()
        m = mult[cname]
        ops, _ = parsed[cname]
        for op in ops:
            wm = _WHILE_RE.search(op.attrs)
            if op.kind == "while" and wm:
                cond, body = wm.group(1), wm.group(2)
                trip = 1
                for line in comps.get(cond, []):
                    for c in _CONST_RE.finditer(line):
                        trip = max(trip, int(c.group(1)))
                for target, f in ((cond, trip), (body, trip)):
                    key = (cname, op.name, target)
                    if key not in visited_edges:
                        visited_edges.add(key)
                        mult[target] += m * f
                        queue.append(target)
                continue
            for cm in _CALLS_RE.finditer(op.attrs):
                target = cm.group(1)
                key = (cname, op.name, target)
                if target in comps and key not in visited_edges:
                    visited_edges.add(key)
                    mult[target] += m
                    queue.append(target)
    return dict(mult)


def analyze_hlo(hlo: str) -> Dict:
    comps, entry = _split_computations(hlo)
    if entry is None:
        return {"flops": 0, "dot_flops": 0, "bytes": 0,
                "collectives": {}, "collective_bytes": 0}
    mult = _multipliers(comps, entry)
    parsed = {n: _parse_ops(ls) for n, ls in comps.items()}

    # which computations are fusion bodies (bytes counted at the call site)
    fusion_called = set()
    for n, (ops, _) in parsed.items():
        for op in ops:
            if op.kind == "fusion":
                for cm in _CALLS_RE.finditer(op.attrs):
                    fusion_called.add(cm.group(1))

    dot_flops = 0.0
    ew_flops = 0.0
    hbm_bytes = 0.0
    coll: Dict[str, float] = defaultdict(float)

    for cname, (ops, symbols) in parsed.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_called
        for op in ops:
            if op.kind == "dot":
                contract = 1
                lm_ = _LHS_CDIMS_RE.search(op.attrs)
                lhs_shape = None
                if op.operands:
                    # resolve lhs dims: re-find its defining line's shape dims
                    lhs_shape = _resolve_dims(comps[cname], op.operands[0])
                if lm_ and lhs_shape is not None:
                    for d in lm_.group(1).split(","):
                        if d:
                            contract *= lhs_shape[int(d)]
                dot_flops += m * 2.0 * op.result_elems * contract
            elif op.kind in _ELEMENTWISE:
                ew_flops += m * op.result_elems
            if in_fusion:
                continue  # internal traffic stays on-chip
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "bitcast", "tuple", "after-all"):
                continue
            for ckind in _COLLECTIVES:
                if op.kind.startswith(ckind):
                    if op.kind.endswith("-done"):
                        break
                    coll[ckind] += m * op.result_bytes
                    break
            opb = sum(symbols.get(o, (0, 0))[0] for o in op.operands)
            hbm_bytes += m * (op.result_bytes + opb)

    return {
        "flops": dot_flops + ew_flops,
        "dot_flops": dot_flops,
        "elementwise_flops": ew_flops,
        "bytes": hbm_bytes,
        "collectives": {k: v for k, v in coll.items()},
        "collective_bytes": sum(coll.values()),
    }


_DIMS_CACHE: Dict[int, Dict[str, Tuple[int, ...]]] = {}


def _resolve_dims(lines: List[str], name: str) -> Optional[Tuple[int, ...]]:
    key = id(lines)
    table = _DIMS_CACHE.get(key)
    if table is None:
        table = {}
        for line in lines:
            m = _OPLINE_RE.match(line)
            if not m:
                continue
            om = _OPNAME_RE.search(m.group(2))
            if not om:
                continue
            sm = _SHAPE_RE.search(m.group(2)[: om.start()])
            if sm:
                dims = tuple(int(d) for d in sm.group(2).split(",") if d)
                table[m.group(1)] = dims
        _DIMS_CACHE[key] = table
        if len(_DIMS_CACHE) > 64:
            _DIMS_CACHE.clear()
            _DIMS_CACHE[key] = table
    return table.get(name)


def collective_bytes(hlo: str) -> Tuple[Dict[str, int], int]:
    """Back-compat wrapper -> (per-kind totals, grand total)."""
    out = analyze_hlo(hlo)
    return out["collectives"], out["collective_bytes"]
