"""Training launcher: --arch <id> resolves the registry config and runs the
fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --ckpt-dir artifacts/ckpt_qwen2

``--smoke`` trains the arch's reduced config on local devices (CPU-friendly
end-to-end path: data -> step -> checkpoint -> resume).  Production pods use
the same code with the full config under `make_production_mesh()` (the
per-cell lowering of which is exercised by dryrun.py).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import GNNArch, LMArch, RecsysArch
from repro.data import lm_batch, random_graph, recsys_batch
from repro.train import (AdamWConfig, TrainLoopConfig, adamw_init,
                         cosine_schedule, make_train_step, run_train_loop)
from repro.train.optimizer import adafactor_init


def _smoke_setup(arch, arch_id: str, batch_size: int):
    rng = np.random.default_rng(0)
    if isinstance(arch, LMArch):
        from repro.models.transformer import model as lm

        cfg = arch.smoke()
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        loss = lambda p, b: lm.lm_loss(p, b, cfg)

        def make_batch(i):
            r = np.random.default_rng(i)
            b = lm_batch(r, batch_size, 32, cfg.vocab)
            return {k: jnp.asarray(v) for k, v in b.items()}

    elif isinstance(arch, GNNArch):
        import dataclasses

        from repro.models.gnn import gin

        cfg = dataclasses.replace(arch.cfg_for("full_graph_sm"), d_in=16,
                                  n_classes=4)
        params = gin.init_params(jax.random.PRNGKey(0), cfg)
        loss = lambda p, b: gin.node_loss(p, b, cfg)

        def make_batch(i):
            g = random_graph(np.random.default_rng(i), 128, 512, 16, 4)
            return {k: jnp.asarray(v) for k, v in g.items()}

    else:
        assert isinstance(arch, RecsysArch)
        from repro.models.recsys.models import bce_loss

        cfg = arch.smoke_cfg
        params = arch.init_fn(jax.random.PRNGKey(0), cfg)
        loss = lambda p, b: bce_loss(arch.forward_fn, p, b, cfg)

        def make_batch(i):
            r = np.random.default_rng(i)
            if arch.seq:
                b = recsys_batch(r, batch_size, 1, [cfg.item_vocab],
                                 seq_len=cfg.seq_len)
            else:
                b = recsys_batch(r, batch_size, cfg.n_sparse, cfg.vocab_sizes)
            return {k: jnp.asarray(v) for k, v in b.items()}

    return params, loss, make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices (required on CPU)")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if not args.smoke:
        raise SystemExit(
            "full-scale training needs a TPU pod; use --smoke here "
            "(the full configs are lowered+compiled by repro.launch.dryrun)")

    params, loss, make_batch = _smoke_setup(arch, args.arch, args.batch_size)
    optimizer = getattr(arch, "optimizer", "adamw")
    opt = (adamw_init if optimizer == "adamw" else adafactor_init)(params)
    step = jax.jit(make_train_step(
        loss, AdamWConfig(lr=args.lr), optimizer=optimizer,
        lr_schedule=cosine_schedule(warmup=max(args.steps // 10, 1),
                                    total=args.steps)))
    run_train_loop(
        step, params, opt, make_batch,
        TrainLoopConfig(total_steps=args.steps,
                        ckpt_dir=f"{args.ckpt_dir}_{args.arch}",
                        ckpt_every=args.ckpt_every, log_every=10),
        on_metrics=lambda s, m: print(f"step {s:5d} loss {m['loss']:.4f} "
                                      f"gnorm {m['grad_norm']:.2f}"),
        on_straggler=lambda s, r: print(f"!! straggler at step {s}: {r:.1f}x"),
    )
    print("done")


if __name__ == "__main__":
    main()
