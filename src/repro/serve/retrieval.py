"""Paper-integrated candidate retrieval for the recsys serving path.

``retrieval_cand`` (1 query x 1,000,000 candidates) IS the paper's workload:
instead of a brute-force (1M x D) dot per request, candidates are indexed
once with the paper's vector-to-code encoding, and each request runs the
two-phase search -- phase-1 code match over int8 codes (4x fewer bytes than
f32 embeddings, further reduced by query trim), phase-2 exact dot over the
``page`` survivors.  The batched-dot brute force is kept as the baseline the
benchmark compares against (same avg.diff/P@k metrics as the paper).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.codes import score_codes
from repro.core.encoding import Encoder, RoundingEncoder
from repro.core.filtering import TrimFilter, expand_mask, feature_mask
from repro.core.rerank import normalize, rerank_topk

__all__ = ["encode_candidates", "retrieval_step", "brute_force_retrieval"]


def encode_candidates(cand_vecs: jnp.ndarray, encoder: Encoder = RoundingEncoder(2)):
    """Index build: (N, D) candidate embeddings -> unit vectors + int codes."""
    v = normalize(cand_vecs.astype(jnp.float32))
    return v, encoder.encode(v)


@partial(jax.jit, static_argnames=("encoder", "page", "k", "trim_threshold"))
def retrieval_step(
    user_vec: jnp.ndarray,     # (Q, D) user-tower output
    cand_vecs: jnp.ndarray,    # (N, D) unit candidate vectors
    cand_codes: jnp.ndarray,   # (N, C) int codes (encode_candidates)
    encoder: Encoder = RoundingEncoder(2),
    page: int = 512,
    k: int = 100,
    trim_threshold: float = 0.05,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Two-phase retrieval -> (ids (Q, k), scores (Q, k))."""
    q = normalize(user_vec.astype(jnp.float32))
    qcodes = encoder.encode(q)
    mask = expand_mask(
        feature_mask(q, trim=TrimFilter(trim_threshold)), qcodes.shape[-1]
    )
    w = jnp.where(mask, 1.0, 0.0)
    scores1 = score_codes(cand_codes, qcodes, w)
    _, cand = jax.lax.top_k(scores1, page)
    return rerank_topk(cand_vecs, cand, q, k)


@partial(jax.jit, static_argnames=("k",))
def brute_force_retrieval(user_vec, cand_vecs, k: int = 100):
    q = normalize(user_vec.astype(jnp.float32))
    scores = q @ cand_vecs.T
    s, i = jax.lax.top_k(scores, k)
    return i, s
