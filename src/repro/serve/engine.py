"""Batched request serving for the vector-search index.

A real deployment fronts the TPU program with a request batcher: incoming
query vectors are buffered until ``max_batch`` or ``max_wait_s`` (whichever
first), padded to the compiled batch shape, executed as ONE jitted search,
and scattered back to their futures.  This mirrors the paper's observation
(Table 3) that parallel querying trades per-request latency for throughput --
here the trade is explicit: batch 1 = lowest latency, batch N = N-fold
throughput at ~constant step time (the TPU is batch-insensitive until the
code-match stream saturates HBM).

The engine is index-polymorphic: anything with the ``VectorIndex.search``
contract serves, in particular :class:`repro.dist.shard_index.
ShardedVectorIndex` -- one batcher then fronts a whole doc-sharded mesh
(the ES coordinating-node arrangement), and the per-request results are
bit-identical to the single-device index for ``page >= n_docs``.

Fronting a sharded index, each submitted batch runs the ES query/fetch
protocol end to end: per-shard phase-1 + local top-k under ``shard_map``,
then the coordinating merge.  ``merge="stream"`` makes that merge
asynchronous on-device -- per-shard candidate pages ring-rotate along the
``data`` axis and stream into the coordinator's running top-k, so the
communication of one shard's page overlaps the fold of the previous one
instead of a single blocking all-gather.  On a ``(data, replica)`` mesh
(``make_shard_mesh(shards, replicas)``) the batch itself round-robins
across replica groups, each holding a full copy of the corpus: R groups
answer Q/R queries apiece, multiplying QPS without touching quality.

Lifecycle: ``submit`` after ``close`` raises ``RuntimeError`` (the queue
has no worker to drain it); a search that raises inside the worker fails
only that batch's futures (``set_exception``) and the worker keeps
serving subsequent batches; ``close`` drains everything already queued
before returning.

**Hot ingest**: ``add_documents`` grows a sharded index ES-style (append
segments, :meth:`repro.dist.shard_index.ShardedVectorIndex.add_documents`)
and atomically swaps the new index in under the engine lock -- the batch
in flight finishes against the old index, every batch dequeued afterwards
sees the new documents.  ``delete`` tombstones the same way.  Ingest is a
control-plane operation: submits block for its (short) duration, which is
the ES refresh semantics.

**Hot swap**: ``swap_index(new, expected=old)`` is the compare-and-swap
the background maintenance daemon (:mod:`repro.cluster.maintenance`)
compacts through: the rebuild runs OUTSIDE the lock against a snapshot,
the swap takes the lock only for the pointer flip, and a concurrent
``add_documents``/``delete`` (which changes ``self.index``) makes the CAS
return False so the daemon retries against the fresh snapshot -- no
in-flight query is ever dropped and no ingest is ever lost.  The CAS also
carries the durability commit metadata: a
:class:`repro.store.durable.DurableIndex` rides through the swap with its
``translog_seq`` intact, so whoever wins the CAS hands the daemon a
consistent (state, translog position) pair to roll a commit point from.

``pending`` (queued + in-flight request count) is the router's load
signal for least-loaded spill across replica-group batchers
(:mod:`repro.cluster.router`).

**Observability** (:mod:`repro.obs`): the batcher records request
counters, batch occupancy, measured queue wait, and dispatch latency
into a :class:`~repro.obs.metrics.MetricsRegistry` (labelled ``group=g``
when fronting one replica group), and appends per-request spans --
queue wait, batch formation, device dispatch -- to any
:class:`~repro.obs.tracing.Trace` riding the submit.  All timestamps
are host-side, taken around the jitted program dispatch; the batch
deadline and the queue-wait spans share ONE clock read per dequeue, so
the batcher's accounting and the trace always agree on a wait.
``stats()`` is the ES ``_cat/thread_pool`` view of this batcher.
"""

from __future__ import annotations

import inspect
import threading
import time
from concurrent.futures import Future
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import TrimFilter, VectorIndex
from repro.obs.compile_watch import active_watch
from repro.obs.metrics import default_registry
from repro.obs.profile import ProfileNode
from repro.obs.slowlog import start_request_trace
from repro.obs.tracing import annotation

__all__ = ["BatchedSearchEngine"]


def _accepts_profile(index) -> bool:
    """Whether ``index.search`` takes the ``profile`` kwarg (the engine
    is index-polymorphic; test doubles and plain callables may not).
    ``**kwargs`` wrappers count -- they forward to an index that does."""
    try:
        params = inspect.signature(index.search).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtin search
        return False
    return "profile" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


class BatchedSearchEngine:
    def __init__(
        self,
        index: "VectorIndex | ShardedVectorIndex",  # noqa: F821 - any .search
        batch_size: int = 32,
        max_wait_s: float = 0.005,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = TrimFilter(0.05),
        engine: str = "codes",
        merge: Optional[str] = None,
        max_postings: "Optional[int | str]" = None,
        metrics=None,
        tracer=None,
        group: Optional[int] = None,
        donate_ingest: bool = False,
        slowlog=None,
        compile_watch=None,
    ):
        self.index = index
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.k, self.page, self.trim, self.engine = k, page, trim, engine
        # merge transport for sharded indexes ("gather" | "stream") and the
        # postings window ("auto" = size from the shard code distribution);
        # None omits the kwarg so plain VectorIndex keeps serving unchanged
        self.merge = merge
        self.max_postings = max_postings
        # opt-in buffer donation for hot ingest: add_documents may donate
        # the active append buffers to the update program -- but ONLY when
        # the current index is not the snapshot a batch is searching right
        # now (the worker records its snapshot in _serving under the lock;
        # donating a buffer a dispatched program still reads would be a
        # use-after-free)
        self.donate_ingest = donate_ingest
        self._serving = None
        # observability: metrics series carry the replica-group label when
        # this batcher fronts one group of a cluster; instruments are
        # cached here so the worker pays one lock-op per record, not a
        # registry lookup
        self.metrics = metrics if metrics is not None else default_registry()
        self.tracer = tracer
        # tail-based slow-query capture (repro.obs.slowlog): with one
        # attached, EVERY request carries a span skeleton and the slow
        # log's threshold decides retention at finish -- independent of
        # the tracer's head sampling
        self.slowlog = slowlog
        # recompile telemetry (repro.obs.compile_watch): the dispatch,
        # ingest, and delete seams run inside watch regions so any XLA
        # compile they trigger is attributed and counted
        self.compile_watch = (compile_watch if compile_watch is not None
                              else active_watch())
        self.group = group
        self._metric_labels = {} if group is None else {"group": group}
        lb = self._metric_labels
        self._c_submitted = self.metrics.counter(
            "engine.requests.submitted", **lb)
        self._c_completed = self.metrics.counter(
            "engine.requests.completed", **lb)
        self._c_failed = self.metrics.counter("engine.requests.failed", **lb)
        self._h_occupancy = self.metrics.histogram(
            "engine.batch.occupancy", **lb)
        self._h_wait = self.metrics.histogram("engine.queue.wait_s", **lb)
        self._h_dispatch = self.metrics.histogram(
            "engine.dispatch.latency_s", **lb)
        # which phase-1 path served each batch -- the fused-kernel rollout
        # counter (label = engine name, so a fleet-wide registry shows the
        # fused/composed mix at a glance)
        self._c_kernel_path = self.metrics.counter(
            "engine.kernel_path", engine=self.engine, **lb)
        self._lock = threading.Condition()
        # queue items: (query, future, enqueue timestamp, trace,
        # want_profile)
        self._queue: List[tuple] = []
        self._stop = False
        self._inflight = 0
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # ------------------------------------------------------------------ API
    def submit(self, query_vec: np.ndarray, trace=None,
               profile: bool = False) -> Future:
        """Queue one query -> Future of (ids, scores).  ``trace`` is an
        optional :class:`~repro.obs.Trace` the worker appends its spans
        to (the cluster router passes one down); without it, an engine
        constructed with a ``tracer``/``slowlog`` admits its own (head
        sampling for the tracer, a retained-on-slow skeleton for the
        slow log).  With ``profile=True`` the future resolves to
        ``(ids, scores, profile_dict)`` -- the per-phase execution tree
        (:mod:`repro.obs.profile`)."""
        fut: Future = Future()
        if trace is None:
            trace = start_request_trace(self.tracer, self.slowlog, "query")
            if trace:
                t = trace
                fut.add_done_callback(
                    lambda f: t.finish(
                        error=None if f.cancelled() or f.exception()
                        is None else repr(f.exception())))
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            self._queue.append((np.asarray(query_vec, np.float32), fut,
                                time.monotonic(), trace, profile))
            self._lock.notify()
        self._c_submitted.inc()
        return fut

    def search(self, query_vec: np.ndarray, timeout: float = 10.0,
               profile: bool = False):
        return self.submit(query_vec, profile=profile).result(
            timeout=timeout)

    @property
    def pending(self) -> int:
        """Queued + in-flight request count -- the cluster router's load
        signal for stream-affinity spill decisions."""
        with self._lock:
            return len(self._queue) + self._inflight

    def add_documents(self, vectors: np.ndarray) -> int:
        """Hot-add documents; returns the first global id assigned.

        The grown index (per-shard append segments) replaces ``self.index``
        atomically: in-flight batches finish on the old index, subsequent
        batches search the new docs.  Raises ``RuntimeError`` after
        ``close`` and ``TypeError`` for indexes without incremental ingest
        (plain :class:`VectorIndex` is immutable -- shard it first).

        With ``donate_ingest=True`` the update donates the old append
        buffers to the update program (zero steady-state allocations) --
        guarded by the serving snapshot: if the batch in flight is
        searching the CURRENT index, its buffers are still being read and
        donation is skipped for this call.
        """
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            add = getattr(self.index, "add_documents", None)
            if add is None:
                raise TypeError(
                    f"{type(self.index).__name__} does not support "
                    "incremental ingest; serve a ShardedVectorIndex")
            first_id = self.index.n_ids
            # donation is safe only when nothing else holds this index:
            # the engine owns the only reference unless the in-flight
            # batch snapshotted exactly this object
            donate = (self.donate_ingest
                      and self.index is not self._serving
                      and "donate" in inspect.signature(add).parameters)
            t0 = time.monotonic()
            with self.compile_watch.region(
                    "engine.ingest", sig=(np.asarray(vectors).shape,)):
                self.index = (add(vectors, donate=True) if donate
                              else add(vectors))
            latency = time.monotonic() - t0
        # ingest apply latency measured inside the lock -- this is the
        # stall submits see, the number the segment story exists to bound
        # (seals amortise; no per-op full rebuild)
        self.metrics.histogram("engine.ingest.latency_s",
                               **self._metric_labels).observe(latency)
        self.metrics.counter("engine.ingest.added_docs",
                             **self._metric_labels).inc(
            int(np.asarray(vectors).shape[0]))
        return first_id

    def delete(self, ids) -> None:
        """Hot-tombstone documents by global id: the pruned index swaps in
        under the engine lock (same semantics as :meth:`add_documents` --
        in-flight batches finish on the old index, later batches never see
        the dead docs).  Feeds ``index.tombstone_ratio``, the maintenance
        daemon's auto-compaction trigger."""
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            delete = getattr(self.index, "delete", None)
            if delete is None:
                raise TypeError(
                    f"{type(self.index).__name__} does not support "
                    "deletes; serve a ShardedVectorIndex")
            t0 = time.monotonic()
            with self.compile_watch.region(
                    "engine.delete", sig=(len(np.atleast_1d(ids)),)):
                self.index = delete(ids)
            latency = time.monotonic() - t0
        self.metrics.histogram("engine.ingest.latency_s",
                               **self._metric_labels).observe(latency)
        self.metrics.counter("engine.ingest.delete_ops",
                             **self._metric_labels).inc()

    def swap_index(self, new_index, expected=None) -> bool:
        """Atomically replace the served index (hot swap, no queries
        dropped).  With ``expected`` this is a compare-and-swap: the flip
        happens only while ``self.index is expected``, so a maintenance
        rebuild computed from a snapshot can never clobber a concurrent
        ingest -- it returns False and the caller retries on fresh state.
        """
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            if expected is not None and self.index is not expected:
                return False
            self.index = new_index
        self.metrics.counter("engine.swaps", **self._metric_labels).inc()
        return True

    def stats(self) -> dict:
        """ES ``_cat/thread_pool``-style snapshot of this batcher: queue
        depth, in-flight count, request counters, occupancy + queue-wait
        + dispatch-latency histograms, and the served index's doc/segment
        stats (see :func:`repro.obs.stats.engine_stats`)."""
        from repro.obs.stats import engine_stats

        return engine_stats(self)

    def node_stats(self) -> dict:
        """ES ``GET _nodes/stats``: per-device residency of the served
        index (see :func:`repro.obs.stats.node_stats`)."""
        from repro.obs.stats import node_stats

        return node_stats(self)

    def device_stats(self) -> dict:
        """Exact index-resident byte accounting for the served index --
        per leaf, per section, per device, reconciled against
        ``jax.live_arrays()`` (see :func:`repro.obs.device.
        device_bytes`)."""
        from repro.obs.device import device_bytes

        return device_bytes(self.index)

    def close(self):
        with self._lock:
            self._stop = True
            self._lock.notify()
        self._worker.join()

    # --------------------------------------------------------------- worker
    def _run(self):
        while True:
            with self._lock:
                # the batch deadline anchors to the OLDEST queued request's
                # enqueue time (a request waits at most max_wait_s before
                # dispatch), and each wake-up reads the clock ONCE -- the
                # old loop re-read time.monotonic() on every predicate
                # evaluation and anchored the deadline to worker wake-up,
                # so a request arriving into an idle worker could dispatch
                # immediately (deadline already stale) and the measured
                # wait was unknowable
                while len(self._queue) < self.batch_size and not self._stop:
                    now = time.monotonic()
                    if self._queue:
                        deadline = self._queue[0][2] + self.max_wait_s
                        if now >= deadline:
                            break
                        self._lock.wait(timeout=deadline - now)
                    else:
                        self._lock.wait(timeout=self.max_wait_s)
                if self._stop and not self._queue:
                    return
                t_deq = time.monotonic()
                batch = self._queue[: self.batch_size]
                del self._queue[: len(batch)]
                # snapshot under the lock: a hot swap after this point
                # applies to the NEXT batch, this one finishes on `index`.
                # _serving publishes the snapshot so a concurrent
                # donate-ingest knows these buffers are being read
                index = self.index
                self._serving = index if batch else None
                self._inflight = len(batch)
            if not batch:
                continue
            # one t_deq for the whole batch: the queue-wait each metric
            # and trace span reports is (t_deq - enqueue), same clock read;
            # one lock acquisition for the whole batch's waits
            self._h_wait.observe_many(
                [t_deq - it[2] for it in batch])
            self._h_occupancy.observe(len(batch) / self.batch_size)
            # a failing search must not kill the worker: every queued and
            # in-flight future would strand (resolve only by caller
            # timeout) -- fail this batch's futures, serve the next batch
            try:
                error = None
                prof = None
                t_dispatch = t_deq    # overwritten once the batch is built
                try:
                    qs = np.stack([it[0] for it in batch])
                    pad = self.batch_size - qs.shape[0]
                    if pad:
                        qs = np.concatenate(
                            [qs, np.zeros((pad, qs.shape[1]), qs.dtype)])
                    kwargs = {"merge": self.merge} if self.merge else {}
                    if self.max_postings is not None:
                        kwargs["max_postings"] = self.max_postings
                    if any(it[4] for it in batch):
                        # ONE dispatch subtree shared by every profiled
                        # request in the batch (they share the dispatch);
                        # the index annotates its phases into it when it
                        # supports the profile kwarg
                        prof = ProfileNode(
                            "dispatch", batch_size=len(batch),
                            engine=self.engine, k=self.k, page=self.page,
                            **({} if self.group is None
                               else {"group": self.group}))
                        if _accepts_profile(index):
                            kwargs["profile"] = prof
                    t_dispatch = time.monotonic()
                    with annotation("repro.engine.dispatch",
                                    self.tracer is not None
                                    and self.tracer.annotate):
                        with self.compile_watch.region(
                                "engine.dispatch",
                                sig=(qs.shape, str(qs.dtype), self.engine,
                                     self.k, self.page,
                                     self.merge or "gather")):
                            ids, scores = index.search(
                                jnp.asarray(qs), k=self.k, page=self.page,
                                trim=self.trim, engine=self.engine,
                                **kwargs,
                            )
                            ids, scores = np.asarray(ids), np.asarray(scores)
                except Exception as exc:  # noqa: BLE001 - fwd to futures
                    t_done = time.monotonic()
                    error = exc
                else:
                    t_done = time.monotonic()
                    if prof is not None:
                        prof.duration_s = t_done - t_dispatch
                self._h_dispatch.observe(t_done - t_dispatch)
                # record spans BEFORE resolving futures: resolving fires
                # the submitter's done-callback, which finishes the trace
                # -- and a slow log serializes the span list at finish
                # time (the tracer ring holds live traces, so it never
                # noticed ordering; the slow log does)
                for _, _, t_enq, tr, _ in batch:
                    if not tr:          # NULL_TRACE: skip the kwargs builds
                        continue
                    tr.span("queue_wait", t0=t_enq, t1=t_deq,
                            group=self.group)
                    tr.span("batch_form", t0=t_deq, t1=t_dispatch,
                            batch_size=len(batch), group=self.group)
                    tr.span("dispatch", t0=t_dispatch, t1=t_done,
                            group=self.group, batch_size=len(batch),
                            **({} if error is None
                               else {"error": repr(error)}))
                if error is not None:
                    for _, fut, _, _, _ in batch:
                        if not fut.done():
                            fut.set_exception(error)
                    self._c_failed.inc(len(batch))
                else:
                    for i, (_, fut, t_enq, _, want) in enumerate(batch):
                        if fut.done():      # caller may have cancelled
                            continue
                        if want:
                            # per-request root over the shared dispatch
                            # subtree; all phase bounds are SHARED clock
                            # reads, so queue_wait + batch_form + dispatch
                            # tile the total exactly
                            root = ProfileNode(
                                "query", t_done - t_enq,
                                engine=self.engine, k=self.k,
                                page=self.page,
                                **({} if self.group is None
                                   else {"group": self.group}))
                            root.child("queue_wait", t_deq - t_enq)
                            root.child("batch_form", t_dispatch - t_deq,
                                       batch_size=len(batch))
                            root.children.append(prof)
                            fut.set_result(
                                (ids[i], scores[i], root.to_dict()))
                        else:
                            fut.set_result((ids[i], scores[i]))
                    self._c_completed.inc(len(batch))
                    self._c_kernel_path.inc()   # one dispatch on `engine`
            finally:
                self._inflight = 0
                self._serving = None
