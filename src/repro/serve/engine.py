"""Batched request serving for the vector-search index.

A real deployment fronts the TPU program with a request batcher: incoming
query vectors are buffered until ``max_batch`` or ``max_wait_s`` (whichever
first), padded to the compiled batch shape, executed as ONE jitted search,
and scattered back to their futures.  This mirrors the paper's observation
(Table 3) that parallel querying trades per-request latency for throughput --
here the trade is explicit: batch 1 = lowest latency, batch N = N-fold
throughput at ~constant step time (the TPU is batch-insensitive until the
code-match stream saturates HBM).

The engine is index-polymorphic: anything with the ``VectorIndex.search``
contract serves, in particular :class:`repro.dist.shard_index.
ShardedVectorIndex` -- one batcher then fronts a whole doc-sharded mesh
(the ES coordinating-node arrangement), and the per-request results are
bit-identical to the single-device index for ``page >= n_docs``.

Fronting a sharded index, each submitted batch runs the ES query/fetch
protocol end to end: per-shard phase-1 + local top-k under ``shard_map``,
then the coordinating merge.  ``merge="stream"`` makes that merge
asynchronous on-device -- per-shard candidate pages ring-rotate along the
``data`` axis and stream into the coordinator's running top-k, so the
communication of one shard's page overlaps the fold of the previous one
instead of a single blocking all-gather.  On a ``(data, replica)`` mesh
(``make_shard_mesh(shards, replicas)``) the batch itself round-robins
across replica groups, each holding a full copy of the corpus: R groups
answer Q/R queries apiece, multiplying QPS without touching quality.

Lifecycle: ``submit`` after ``close`` raises ``RuntimeError`` (the queue
has no worker to drain it); a search that raises inside the worker fails
only that batch's futures (``set_exception``) and the worker keeps
serving subsequent batches; ``close`` drains everything already queued
before returning.

**Hot ingest**: ``add_documents`` grows a sharded index ES-style (append
segments, :meth:`repro.dist.shard_index.ShardedVectorIndex.add_documents`)
and atomically swaps the new index in under the engine lock -- the batch
in flight finishes against the old index, every batch dequeued afterwards
sees the new documents.  ``delete`` tombstones the same way.  Ingest is a
control-plane operation: submits block for its (short) duration, which is
the ES refresh semantics.

**Hot swap**: ``swap_index(new, expected=old)`` is the compare-and-swap
the background maintenance daemon (:mod:`repro.cluster.maintenance`)
compacts through: the rebuild runs OUTSIDE the lock against a snapshot,
the swap takes the lock only for the pointer flip, and a concurrent
``add_documents``/``delete`` (which changes ``self.index``) makes the CAS
return False so the daemon retries against the fresh snapshot -- no
in-flight query is ever dropped and no ingest is ever lost.  The CAS also
carries the durability commit metadata: a
:class:`repro.store.durable.DurableIndex` rides through the swap with its
``translog_seq`` intact, so whoever wins the CAS hands the daemon a
consistent (state, translog position) pair to roll a commit point from.

``pending`` (queued + in-flight request count) is the router's load
signal for least-loaded spill across replica-group batchers
(:mod:`repro.cluster.router`).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import TrimFilter, VectorIndex

__all__ = ["BatchedSearchEngine"]


class BatchedSearchEngine:
    def __init__(
        self,
        index: "VectorIndex | ShardedVectorIndex",  # noqa: F821 - any .search
        batch_size: int = 32,
        max_wait_s: float = 0.005,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = TrimFilter(0.05),
        engine: str = "codes",
        merge: Optional[str] = None,
        max_postings: "Optional[int | str]" = None,
    ):
        self.index = index
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.k, self.page, self.trim, self.engine = k, page, trim, engine
        # merge transport for sharded indexes ("gather" | "stream") and the
        # postings window ("auto" = size from the shard code distribution);
        # None omits the kwarg so plain VectorIndex keeps serving unchanged
        self.merge = merge
        self.max_postings = max_postings
        self._lock = threading.Condition()
        self._queue: List[Tuple[np.ndarray, Future]] = []
        self._stop = False
        self._inflight = 0
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # ------------------------------------------------------------------ API
    def submit(self, query_vec: np.ndarray) -> Future:
        fut: Future = Future()
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            self._queue.append((np.asarray(query_vec, np.float32), fut))
            self._lock.notify()
        return fut

    def search(self, query_vec: np.ndarray, timeout: float = 10.0):
        return self.submit(query_vec).result(timeout=timeout)

    @property
    def pending(self) -> int:
        """Queued + in-flight request count -- the cluster router's load
        signal for stream-affinity spill decisions."""
        with self._lock:
            return len(self._queue) + self._inflight

    def add_documents(self, vectors: np.ndarray) -> int:
        """Hot-add documents; returns the first global id assigned.

        The grown index (per-shard append segments) replaces ``self.index``
        atomically: in-flight batches finish on the old index, subsequent
        batches search the new docs.  Raises ``RuntimeError`` after
        ``close`` and ``TypeError`` for indexes without incremental ingest
        (plain :class:`VectorIndex` is immutable -- shard it first).
        """
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            add = getattr(self.index, "add_documents", None)
            if add is None:
                raise TypeError(
                    f"{type(self.index).__name__} does not support "
                    "incremental ingest; serve a ShardedVectorIndex")
            first_id = self.index.n_ids
            self.index = add(vectors)
            return first_id

    def delete(self, ids) -> None:
        """Hot-tombstone documents by global id: the pruned index swaps in
        under the engine lock (same semantics as :meth:`add_documents` --
        in-flight batches finish on the old index, later batches never see
        the dead docs).  Feeds ``index.tombstone_ratio``, the maintenance
        daemon's auto-compaction trigger."""
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            delete = getattr(self.index, "delete", None)
            if delete is None:
                raise TypeError(
                    f"{type(self.index).__name__} does not support "
                    "deletes; serve a ShardedVectorIndex")
            self.index = delete(ids)

    def swap_index(self, new_index, expected=None) -> bool:
        """Atomically replace the served index (hot swap, no queries
        dropped).  With ``expected`` this is a compare-and-swap: the flip
        happens only while ``self.index is expected``, so a maintenance
        rebuild computed from a snapshot can never clobber a concurrent
        ingest -- it returns False and the caller retries on fresh state.
        """
        with self._lock:
            if self._stop:
                raise RuntimeError("engine closed")
            if expected is not None and self.index is not expected:
                return False
            self.index = new_index
            return True

    def close(self):
        with self._lock:
            self._stop = True
            self._lock.notify()
        self._worker.join()

    # --------------------------------------------------------------- worker
    def _run(self):
        while True:
            with self._lock:
                deadline = time.monotonic() + self.max_wait_s
                while (len(self._queue) < self.batch_size and not self._stop
                       and (not self._queue or time.monotonic() < deadline)):
                    self._lock.wait(timeout=self.max_wait_s)
                if self._stop and not self._queue:
                    return
                batch = self._queue[: self.batch_size]
                del self._queue[: len(batch)]
                # snapshot under the lock: a hot swap after this point
                # applies to the NEXT batch, this one finishes on `index`
                index = self.index
                self._inflight = len(batch)
            if not batch:
                continue
            # a failing search must not kill the worker: every queued and
            # in-flight future would strand (resolve only by caller
            # timeout) -- fail this batch's futures, serve the next batch
            try:
                try:
                    qs = np.stack([q for q, _ in batch])
                    pad = self.batch_size - qs.shape[0]
                    if pad:
                        qs = np.concatenate(
                            [qs, np.zeros((pad, qs.shape[1]), qs.dtype)])
                    kwargs = {"merge": self.merge} if self.merge else {}
                    if self.max_postings is not None:
                        kwargs["max_postings"] = self.max_postings
                    ids, scores = index.search(
                        jnp.asarray(qs), k=self.k, page=self.page,
                        trim=self.trim, engine=self.engine, **kwargs,
                    )
                    ids, scores = np.asarray(ids), np.asarray(scores)
                except Exception as exc:  # noqa: BLE001 - fwd to futures
                    for _, fut in batch:
                        if not fut.done():
                            fut.set_exception(exc)
                    continue
                for i, (_, fut) in enumerate(batch):
                    if not fut.done():      # caller may have cancelled
                        fut.set_result((ids[i], scores[i]))
            finally:
                self._inflight = 0
