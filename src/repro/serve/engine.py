"""Batched request serving for the vector-search index.

A real deployment fronts the TPU program with a request batcher: incoming
query vectors are buffered until ``max_batch`` or ``max_wait_s`` (whichever
first), padded to the compiled batch shape, executed as ONE jitted search,
and scattered back to their futures.  This mirrors the paper's observation
(Table 3) that parallel querying trades per-request latency for throughput --
here the trade is explicit: batch 1 = lowest latency, batch N = N-fold
throughput at ~constant step time (the TPU is batch-insensitive until the
code-match stream saturates HBM).

The engine is index-polymorphic: anything with the ``VectorIndex.search``
contract serves, in particular :class:`repro.dist.shard_index.
ShardedVectorIndex` -- one batcher then fronts a whole doc-sharded mesh
(the ES coordinating-node arrangement), and the per-request results are
bit-identical to the single-device index for ``page >= n_docs``.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import TrimFilter, VectorIndex

__all__ = ["BatchedSearchEngine"]


class BatchedSearchEngine:
    def __init__(
        self,
        index: "VectorIndex | ShardedVectorIndex",  # noqa: F821 - any .search
        batch_size: int = 32,
        max_wait_s: float = 0.005,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = TrimFilter(0.05),
        engine: str = "codes",
    ):
        self.index = index
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.k, self.page, self.trim, self.engine = k, page, trim, engine
        self._lock = threading.Condition()
        self._queue: List[Tuple[np.ndarray, Future]] = []
        self._stop = False
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # ------------------------------------------------------------------ API
    def submit(self, query_vec: np.ndarray) -> Future:
        fut: Future = Future()
        with self._lock:
            self._queue.append((np.asarray(query_vec, np.float32), fut))
            self._lock.notify()
        return fut

    def search(self, query_vec: np.ndarray, timeout: float = 10.0):
        return self.submit(query_vec).result(timeout=timeout)

    def close(self):
        with self._lock:
            self._stop = True
            self._lock.notify()
        self._worker.join()

    # --------------------------------------------------------------- worker
    def _run(self):
        while True:
            with self._lock:
                deadline = time.monotonic() + self.max_wait_s
                while (len(self._queue) < self.batch_size and not self._stop
                       and (not self._queue or time.monotonic() < deadline)):
                    self._lock.wait(timeout=self.max_wait_s)
                if self._stop and not self._queue:
                    return
                batch = self._queue[: self.batch_size]
                del self._queue[: len(batch)]
            if not batch:
                continue
            qs = np.stack([q for q, _ in batch])
            pad = self.batch_size - qs.shape[0]
            if pad:
                qs = np.concatenate([qs, np.zeros((pad, qs.shape[1]), qs.dtype)])
            ids, scores = self.index.search(
                jnp.asarray(qs), k=self.k, page=self.page, trim=self.trim,
                engine=self.engine,
            )
            ids, scores = np.asarray(ids), np.asarray(scores)
            for i, (_, fut) in enumerate(batch):
                fut.set_result((ids[i], scores[i]))
