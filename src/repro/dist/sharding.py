"""Declarative parameter-sharding rules (GSPMD PartitionSpecs).

A *rule* is ``rule(path, leaf, mesh) -> PartitionSpec``; :func:`tree_specs`
maps one over a parameter tree.  Rules are divisibility-aware: every axis
placement checks that the dim divides the mesh axis and falls back to
replication (``None``) otherwise, so one rule serves every architecture on
every mesh -- the same posture as :func:`repro.dist.annotate.constrain`.

Layout conventions (the "index settings" of the training cluster):

* **FSDP** -- weight matrices shard their d_model-sized dim over ``data``.
* **TP**   -- attention shards the *head* dim over ``model`` (never d_head:
  a head is the atomic attention unit); dense/shared FFNs shard d_ff over
  ``model``; the unembed shards vocab over ``model``.
* **EP**   -- MoE expert weights shard the expert dim over ``model`` when it
  divides (expert parallelism), else fall back to TP over d_ff.
* **Embeddings** are never vocab-sharded (token gather stays shard-local).
* Vectors/scalars (norms, biases, routers) replicate.

Leading stacked-layer dims (from the ``lax.scan`` super-block vmap) are
always ``None``: layers are executed sequentially, not spatially.
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "DATA_AXIS",
    "MODEL_AXIS",
    "REPLICA_AXIS",
    "batch_axes",
    "tree_specs",
    "lm_param_spec",
    "lm_param_spec_inference",
    "generic_param_spec",
    "opt_state_spec",
]

DATA_AXIS = "data"
MODEL_AXIS = "model"
# serving-tier replication (ES replica shards): index leaves replicate across
# this axis, query batches round-robin over it -- a pure QPS axis, never a
# placement one, so no param-spec rule ever mentions it
REPLICA_AXIS = "replica"

# leaves replicate below this size under generic rules (a 16 MB f32 table);
# small weights cost more in collective latency than they save in HBM
_GENERIC_MIN_SIZE = 1 << 22


def batch_axes(mesh) -> tuple:
    """Every data-parallel mesh axis, outermost first (pod before data)."""
    return tuple(a for a in ("pod", DATA_AXIS) if a in mesh.axis_names)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key is not None:
            return str(key)
    return ""


def _axis_if(mesh, axis: str, dim_size: int):
    """``axis`` when it exists and divides ``dim_size``, else None."""
    if axis not in mesh.axis_names:
        return None
    n = int(mesh.shape[axis])
    return axis if dim_size % n == 0 and dim_size >= n else None


def lm_param_spec(path, leaf, mesh) -> P:
    """Sharding rule for the transformer LM parameter tree."""
    name = _leaf_name(path)
    s = leaf.shape
    data = lambda d: _axis_if(mesh, DATA_AXIS, s[d])
    model = lambda d: _axis_if(mesh, MODEL_AXIS, s[d])

    if name == "embed" and len(s) == 2:                  # (V, D)
        return P(None, data(1))                          # gather-safe: V whole
    if name == "unembed" and len(s) == 2:                # (D, V)
        return P(data(0), model(1))
    if name in ("wq", "wk", "wv") and len(s) == 4:       # (L, D, H|KV, dh)
        return P(None, data(1), model(2), None)
    if name == "wo" and len(s) == 4:                     # (L, H, dh, D)
        return P(None, model(1), None, data(3))
    if name in ("wg", "wu") and len(s) == 4:             # MoE (L, E, D, F)
        if model(1) is not None:                         # expert parallelism
            return P(None, MODEL_AXIS, None, data(3))
        return P(None, None, data(2), model(3))          # TP fallback
    if name == "wd" and len(s) == 4:                     # MoE (L, E, F, D)
        if model(1) is not None:
            return P(None, MODEL_AXIS, data(2), None)
        return P(None, None, model(2), data(3))
    if name in ("wg", "wu") and len(s) == 3:             # dense/shared (L, D, F)
        return P(None, data(1), model(2))
    if name == "wd" and len(s) == 3:                     # dense/shared (L, F, D)
        return P(None, model(1), data(2))
    return P()                                           # norms, biases, router


def lm_param_spec_inference(path, leaf, mesh) -> P:
    """TP-only variant for serving: weights stay resident (no per-layer FSDP
    all-gathers on the latency path); only ``model``-axis placements kept."""
    spec = lm_param_spec(path, leaf, mesh)
    return P(*(p if p == MODEL_AXIS else None for p in spec))


def generic_param_spec(path, leaf, mesh) -> P:
    """Family-agnostic rule (GNN / recsys): row-shard only leaves big enough
    to matter (embedding tables) over ``model``; replicate the rest."""
    s = leaf.shape
    if (len(s) >= 1 and int(np.prod(s)) >= _GENERIC_MIN_SIZE
            and _axis_if(mesh, MODEL_AXIS, s[0]) is not None):
        return P(MODEL_AXIS, *(None,) * (len(s) - 1))
    return P()


def opt_state_spec(param_spec: P, ndim: int, which: str) -> P:
    """Adafactor factored-stat specs: ``vr`` reduces away the last dim,
    ``vc`` the second-to-last; the surviving dims keep the param placement."""
    parts = list(param_spec) + [None] * (ndim - len(param_spec))
    if which == "vr":
        del parts[ndim - 1]
    elif which == "vc":
        del parts[ndim - 2]
    else:
        raise ValueError(f"unknown factored stat {which!r}")
    return P(*parts)


def tree_specs(tree, mesh, rule: Callable) -> "jax.tree_util.PyTreeDef":
    """Map ``rule`` over a parameter tree -> tree of PartitionSpecs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rule(path, leaf, mesh), tree
    )
