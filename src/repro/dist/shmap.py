"""Version-portable ``shard_map``.

jax >= 0.6 exposes ``jax.shard_map(..., axis_names=..., check_vma=...)``;
0.4.x has ``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)``
with the *complement* convention for partial-manual axes.  Callers say which
axes they want manual; the adapter speaks whichever dialect is present.

One deliberate degradation: 0.4.x partial-manual regions hard-crash XLA's
SPMD partitioner (``Check failed: target.IsManualSubgroup() ==
sharding().IsManualSubgroup()``), so on that branch the region is always
fully manual -- axes the caller wanted AUTO become unreferenced manual axes,
i.e. the computation replicates across them instead of staying sharded.
Correct, just less parallel; newer jax gets the real partial-manual form.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, mesh, in_specs, out_specs, manual_axes=None, check=False):
    """``shard_map`` manual over ``manual_axes`` (default: every mesh axis)."""
    manual = frozenset(manual_axes or mesh.axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check, axis_names=manual,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    # 0.4.x: full manual only (see module docstring)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check,
    )
