"""Doc-sharded two-phase vector search (the Elasticsearch scaling story).

:class:`ShardedVectorIndex` is :class:`repro.core.VectorIndex` split into
contiguous *doc-shards* along the mesh's ``data`` axis, one shard per
device.  A query runs the ES distributed query/fetch protocol:

1. **query phase** (per shard, under ``shard_map``): phase-1 scoring over
   the local codes/postings, local ``top_k(page)``, exact-cosine scoring of
   the local candidate page;
2. **merge phase**: per-shard candidate pages reach the coordinating
   reduce (ids are globalised by the shard's doc-id offset) and a global
   ``top_k(k)`` over the exact cosines picks the final hits.

Two merge transports implement step 2 (``search(..., merge=...)``):

* ``"gather"`` -- one blocking all-gather of every shard's page, then a
  flat global top-k (the PR-1 path; peak buffer ``S * page`` per query).
* ``"stream"`` -- candidate pages ring-rotate along the ``data`` axis
  (``ppermute``) and *stream* into a running top-k one shard at a time:
  the group coordinator (data index 0) folds pages in shard order, so
  communication of page ``t+1`` overlaps the merge of page ``t`` and the
  peak buffer is ``k + page`` regardless of shard count.  Tie-breaks
  replicate the flat gather's shard-major order, so both transports
  return identical hits.

**Replica tier** (ES replica shards): on a 2-D ``(data, replica)`` mesh
(:func:`repro.launch.mesh.make_shard_mesh` with ``n_replicas > 1``) every
index leaf is replicated across the ``replica`` axis -- R full copies of
the doc-sharded corpus.  Incoming query batches round-robin across replica
groups (the batch splits along ``replica`` in the ``shard_map`` in-spec),
each group runs the full query/fetch protocol against its own copy, and
per-replica results are bit-identical to the single-replica path: QPS
scales ~R x while quality is untouched (``page >= n_docs`` parity holds
per group).  Batches are zero-padded up to a multiple of R and the pad
rows sliced off after the merge, so they can never leak into results.

Because the merge ranks *exact* phase-2 cosines, ``page >= n_docs`` makes
the sharded search bit-identical to the single-device index: the same dot
products reach the same top-k.  Smaller pages change recall only through
per-shard candidate allocation (each shard contributes its own top
``page`` -- the same semantics as ES ``size`` fan-out).

IDF query weighting stays *global*: document frequencies are summed across
shards with a ``psum`` over ``data`` (integer-exact, identical in every
replica group), so trimming/weighting decisions are independent of both
the shard count and the replica count.

Ragged corpora pad each shard to a common length; padded rows carry a
never-matching sentinel code, score ``-inf`` in both phases, and can never
enter the merged top-k.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.encoding import Encoder
from repro.core.filtering import BestFilter, TrimFilter, expand_mask, feature_mask
from repro.core.postings import Postings, build_postings, idf_weights, lookup
from repro.core.rerank import normalize
from repro.core.search import _SENTINEL, VectorIndex, phase1_engine_scores

from .sharding import DATA_AXIS, REPLICA_AXIS

__all__ = ["ShardedVectorIndex"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedVectorIndex:
    """A :class:`VectorIndex` partitioned into per-device doc-shards.

    Array leaves carry an explicit leading shard dim (``n_shards`` first)
    and live sharded over the ``data`` mesh axis; each device holds one
    contiguous document range plus its local->global id ``offset``.
    """

    vectors: jnp.ndarray      # (S, dp, n) f32, unit rows; zero rows pad
    codes: jnp.ndarray        # (S, dp, C) int; sentinel rows pad
    post_docs: jnp.ndarray    # (S, C, dp) int32 per-shard posting order
    post_codes: jnp.ndarray   # (S, C, dp) sorted codes per shard
    offsets: jnp.ndarray      # (S,) int32 global id of each shard's doc 0
    counts: jnp.ndarray       # (S,) int32 real (unpadded) docs per shard
    encoder: Encoder
    mesh: Mesh
    n_docs: int               # global corpus size
    index_best: Optional[int]

    # -- pytree plumbing (mesh/encoder/sizes are static metadata) ----------
    def tree_flatten(self):
        children = (self.vectors, self.codes, self.post_docs,
                    self.post_codes, self.offsets, self.counts)
        return children, (self.encoder, self.mesh, self.n_docs, self.index_best)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # ------------------------------------------------------------ properties
    @property
    def n_shards(self) -> int:
        return self.vectors.shape[0]

    @property
    def n_replicas(self) -> int:
        if REPLICA_AXIS in self.mesh.axis_names:
            return int(self.mesh.shape[REPLICA_AXIS])
        return 1

    @property
    def docs_per_shard(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_features(self) -> int:
        return self.vectors.shape[2]

    # ----------------------------------------------------------------- build
    @classmethod
    def from_index(cls, index: VectorIndex, mesh: Mesh) -> "ShardedVectorIndex":
        """Partition an existing single-device index across ``mesh``'s
        ``data`` axis (contiguous ranges, ES-style doc-sharding).

        On a ``(data, replica)`` mesh every leaf's spec leaves the
        ``replica`` axis unmentioned, so ``NamedSharding`` replicates each
        doc-shard across it -- R identical serving copies of the corpus."""
        if DATA_AXIS not in mesh.axis_names:
            raise ValueError(f"mesh has no {DATA_AXIS!r} axis: {mesh.axis_names}")
        ns = int(mesh.shape[DATA_AXIS])
        n = index.n_docs
        if ns > n:
            raise ValueError(f"more shards ({ns}) than documents ({n})")
        dp = math.ceil(n / ns)
        pad = ns * dp - n

        vectors = np.asarray(index.vectors)
        codes = np.asarray(index.codes)
        sentinel = _SENTINEL[codes.dtype]
        vectors = np.concatenate(
            [vectors, np.zeros((pad, vectors.shape[1]), vectors.dtype)])
        codes = np.concatenate(
            [codes, np.full((pad, codes.shape[1]), sentinel, codes.dtype)])
        vectors = vectors.reshape(ns, dp, -1)
        codes = codes.reshape(ns, dp, -1)

        # per-shard inverted indexes: the sentinel sorts to the tail of every
        # posting list, so padded docs are invisible to range lookups
        post_docs, post_codes = [], []
        for s in range(ns):
            p = build_postings(jnp.asarray(codes[s]))
            post_docs.append(np.asarray(p.post_docs))
            post_codes.append(np.asarray(p.post_codes))

        offsets = (np.arange(ns) * dp).astype(np.int32)
        counts = np.clip(n - offsets, 0, dp).astype(np.int32)

        def put(x, spec):
            return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))

        row = P(DATA_AXIS, None, None)
        return cls(
            vectors=put(vectors, row),
            codes=put(codes, row),
            post_docs=put(np.stack(post_docs), row),
            post_codes=put(np.stack(post_codes), row),
            offsets=put(offsets, P(DATA_AXIS)),
            counts=put(counts, P(DATA_AXIS)),
            encoder=index.encoder,
            mesh=mesh,
            n_docs=n,
            index_best=index.index_best,
        )

    @classmethod
    def build(cls, vectors, mesh: Mesh, encoder=None, index_best=None):
        """Build + shard in one step (single-device build, then partition)."""
        kwargs = {} if encoder is None else {"encoder": encoder}
        return cls.from_index(
            VectorIndex.build(vectors, index_best=index_best, **kwargs), mesh)

    # ------------------------------------------------------------------ search
    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = None,
        best: Optional[BestFilter] = None,
        engine: str = "postings",
        weighting: str = "idf",
        max_postings: Optional[int] = None,
        merge: str = "gather",
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Distributed two-phase search -> (ids (Q,k), cosine scores (Q,k)).

        Same contract as :meth:`VectorIndex.search`; bit-identical to it
        when ``page >= n_docs``, for either ``merge`` transport
        (``"gather"`` = blocking all-gather, ``"stream"`` = ring-streamed
        per-shard pages) and any replica count -- queries round-robin
        across replica groups, each holding a full copy of the corpus.
        """
        if merge not in ("gather", "stream"):
            raise ValueError(f"unknown merge transport {merge!r}")
        queries = jnp.atleast_2d(queries)
        page = min(page, self.n_docs)
        k = min(k, page)
        page_loc = min(page, self.docs_per_shard)

        # round-robin over replica groups: the batch splits along the
        # replica axis, so pad it up to a multiple of R (pad rows are
        # sliced off below and can never reach a caller)
        n_q = queries.shape[0]
        q_pad = (-n_q) % self.n_replicas
        q = jnp.asarray(queries, jnp.float32)
        if q_pad:
            q = jnp.concatenate(
                [q, jnp.zeros((q_pad, q.shape[1]), jnp.float32)])
        q = normalize(q)
        qcodes = self.encoder.encode(q)
        mask = expand_mask(feature_mask(q, trim=trim, best=best),
                           qcodes.shape[-1])

        L = self.docs_per_shard if max_postings is None \
            else min(max_postings, self.docs_per_shard)
        gids, scores = _query_phase(
            self, q, qcodes, mask, page_loc=page_loc, engine=engine,
            weighting=weighting, max_postings=L,
            k=k if merge == "stream" else 0, merge=merge,
        )
        # drop replica-pad rows BEFORE the final reduce: the rescore inside
        # _merge_phase must run at the true (Q, k, n) shape -- the canonical
        # shape of exact_scores -- or pad rows would perturb the einsum
        # blocking and cost bit-parity with the single-device index
        if q_pad:
            gids, scores, q = gids[:n_q], scores[:n_q], q[:n_q]
        return _merge_phase(self.vectors, gids, scores, q, k=k)


def _merge_phase(vectors, gids, scores, q, *, k):
    """Coordinating-node reduce: global top-k over the exact cosines, then
    final scores recomputed at the (Q, k, n) shape shared with rerank_topk
    -- see exact_scores for why this gives bit-parity.  For the stream
    transport the inputs are already the merged (Q, k) page (sorted by
    score), so the top-k is an identity pass and only the rescore runs.

    The select + candidate-vector fetch run distributed (top-k and gather
    are exact, layout can't change a bit); the rescore einsum runs on the
    coordinating device with *unsharded* operands, because GSPMD blocks a
    sharded einsum differently per mesh shape -- rescoring in-mesh costs
    last-ulp parity between e.g. a 4x1 and a 2x4 layout of the same corpus.
    """
    top_ids, cvec = _merge_select(vectors, gids, scores, k=k)
    dev = jax.devices()[0]
    return top_ids, _rescore(jax.device_put(cvec, dev),
                             jax.device_put(q, dev))


@partial(jax.jit, static_argnames=("k",))
def _merge_select(vectors, gids, scores, *, k):
    _, pos = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(gids, pos, axis=1)
    flat_vectors = vectors.reshape(-1, vectors.shape[-1])
    return top_ids, flat_vectors[top_ids]           # (Q, k, n) hit vectors


@jax.jit
def _rescore(cvec, q):
    """exact_scores' canonical (Q, k, n) einsum over pre-fetched hits."""
    return jnp.einsum("qkn,qn->qk", cvec, q,
                      preferred_element_type=jnp.float32)


@partial(jax.jit, static_argnames=("page_loc", "engine", "weighting",
                                   "max_postings", "k", "merge"))
def _query_phase(sidx, q, qcodes, mask, *, page_loc, engine, weighting,
                 max_postings, k, merge):
    """Per-shard query phase under shard_map -> merge-ready candidates.

    ``merge="gather"``: returns global candidate ids (Q, S*page_loc) and
    their exact cosine scores (one all-gather; padded/invalid candidates
    are ``-inf``).  ``merge="stream"``: candidate pages ring-rotate along
    the ``data`` axis and fold into a running top-``k`` in shard order on
    each group's coordinator, which then broadcasts -- returns the merged
    (Q, k) ids/scores directly.  On a ``(data, replica)`` mesh the query
    batch additionally splits along ``replica`` (Q/R rows per group) and
    reassembles in the out-spec.
    """
    from .shmap import shard_map

    mesh = sidx.mesh
    dp = sidx.docs_per_shard
    enc = sidx.encoder
    n_docs = sidx.n_docs
    n_shards = sidx.n_shards

    def local(vec, codes, pdocs, pcodes, off, cnt, q, qcodes, mask):
        vec, codes = vec[0], codes[0]
        postings = Postings(pdocs[0], pcodes[0], dp)
        off, cnt = off[0], cnt[0]

        if weighting == "idf":
            lo, hi = jax.vmap(lambda c: lookup(postings, c))(qcodes)
            df = jax.lax.psum(hi - lo, DATA_AXIS)   # global df, integer-exact
            w = idf_weights(df, n_docs)
        elif weighting == "count":
            w = jnp.ones(qcodes.shape, jnp.float32)
        else:
            raise ValueError(f"unknown weighting {weighting!r}")
        w = jnp.where(mask, w, 0.0)

        s1 = phase1_engine_scores(codes, postings, qcodes, w, engine,
                                  max_postings, enc.max_abs_bucket)

        valid = jnp.arange(dp) < cnt                       # pads at the tail
        s1 = jnp.where(valid[None, :], s1, -jnp.inf)
        _, cand = jax.lax.top_k(s1, page_loc)              # (Q, page_loc)

        cvec = vec[cand]                                   # (Q, page_loc, n)
        s2 = jnp.einsum("qpn,qn->qp", cvec, q,
                        preferred_element_type=jnp.float32)
        s2 = jnp.where(cand < cnt, s2, -jnp.inf)
        gid = (cand + off).astype(jnp.int32)
        if merge == "gather":
            return gid, s2
        return _stream_merge_local(gid, s2, n_shards, k)

    row = P(DATA_AXIS, None, None)
    rep = REPLICA_AXIS in mesh.axis_names
    qaxis = REPLICA_AXIS if rep else None
    out = P(qaxis, DATA_AXIS) if merge == "gather" else P(qaxis, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(row, row, row, row, P(DATA_AXIS), P(DATA_AXIS),
                  P(qaxis, None), P(qaxis, None), P(qaxis, None)),
        out_specs=(out, out),
        check=False,
    )
    return fn(sidx.vectors, sidx.codes, sidx.post_docs, sidx.post_codes,
              sidx.offsets, sidx.counts, q, qcodes, mask)


def _stream_merge_local(gid, s2, n_shards, k):
    """Ring-streamed coordinator merge (runs inside the shard_map body).

    Pages rotate shard -> shard-1 along ``data``; after step t the device
    at data index i holds the page of shard (i+t) % S, so the group
    coordinator (data index 0) folds pages in shard order 0..S-1 -- the
    same shard-major tie-break order as the flat all-gather, which is what
    keeps the two transports bit-identical.  Each fold is a (k+page)-wide
    stable top-k, so communication of the next page overlaps the fold of
    the current one and peak memory stays k+page per query instead of
    S*page.  The coordinator's result is broadcast with a masked psum
    (every other device contributes zeros).

    Pre-merge ``-inf`` placeholder rows can never survive: ``k`` is
    clamped to ``page <= n_docs``, so at least ``k`` finite-score real
    candidates exist across the S pages and displace them.
    """
    acc_s = jnp.full((s2.shape[0], k), -jnp.inf, s2.dtype)
    acc_i = jnp.zeros((gid.shape[0], k), gid.dtype)
    perm = [(j, (j - 1) % n_shards) for j in range(n_shards)]
    for t in range(n_shards):
        cat_s = jnp.concatenate([acc_s, s2], axis=1)
        cat_i = jnp.concatenate([acc_i, gid], axis=1)
        acc_s, pos = jax.lax.top_k(cat_s, k)
        acc_i = jnp.take_along_axis(cat_i, pos, axis=1)
        if t < n_shards - 1:
            s2 = jax.lax.ppermute(s2, DATA_AXIS, perm)
            gid = jax.lax.ppermute(gid, DATA_AXIS, perm)
    lead = jax.lax.axis_index(DATA_AXIS) == 0
    acc_i = jax.lax.psum(jnp.where(lead, acc_i, 0), DATA_AXIS)
    acc_s = jax.lax.psum(jnp.where(lead, acc_s, 0.0), DATA_AXIS)
    return acc_i, acc_s
