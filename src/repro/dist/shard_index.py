"""Doc-sharded two-phase vector search (the Elasticsearch scaling story).

:class:`ShardedVectorIndex` is :class:`repro.core.VectorIndex` split into
contiguous *doc-shards* along the mesh's ``data`` axis, one shard per
device.  A query runs the ES distributed query/fetch protocol:

1. **query phase** (per shard, under ``shard_map``): phase-1 scoring over
   the local codes/postings, local ``top_k(page)``, exact-cosine scoring of
   the local candidate page;
2. **merge phase**: per-shard candidate pages reach the coordinating
   reduce (ids are globalised by the shard's doc-id offset) and a global
   ``top_k(k)`` over the exact cosines picks the final hits.

Two merge transports implement step 2 (``search(..., merge=...)``):

* ``"gather"`` -- one blocking all-gather of every shard's page, then a
  flat global top-k (the PR-1 path; peak buffer ``S * page`` per query).
* ``"stream"`` -- candidate pages ring-rotate along the ``data`` axis
  (``ppermute``) and *stream* into a running top-k one shard at a time:
  the group coordinator (data index 0) folds pages in shard order, so
  communication of page ``t+1`` overlaps the merge of page ``t`` and the
  peak buffer is ``k + page`` regardless of shard count.  Tie-breaks
  replicate the flat gather's shard-major order, so both transports
  return identical hits.

**Replica tier** (ES replica shards): on a 2-D ``(data, replica)`` mesh
(:func:`repro.launch.mesh.make_shard_mesh` with ``n_replicas > 1``) every
index leaf is replicated across the ``replica`` axis -- R full copies of
the doc-sharded corpus.  Incoming query batches round-robin across replica
groups (the batch splits along ``replica`` in the ``shard_map`` in-spec),
each group runs the full query/fetch protocol against its own copy, and
per-replica results are bit-identical to the single-replica path: QPS
scales ~R x while quality is untouched (``page >= n_docs`` parity holds
per group).  Batches are zero-padded up to a multiple of R and the pad
rows sliced off after the merge, so they can never leak into results.

Two control-plane entry points sit on top of the replica tier:

* :meth:`replica_group` makes the groups *addressable*: it views one
  replica column as an independent 1-D ``data``-mesh index (the leaves are
  already resident on that column's devices, so the re-put is free).  The
  cluster router (:mod:`repro.cluster.router`) fronts each group with its
  own request batcher, which is what lets concurrent QPS scale with R
  instead of materialising only inside a single batch.
* ``search(..., live_groups=...)`` is the *health-masked merge*: query
  blocks are assigned only to the named (healthy) replica columns, dead
  columns receive zero rows, and the out-rows of the live columns are
  gathered back into query order before the final rescore -- so a dead
  group's doc range is transparently served by the surviving replicas and
  the results match the healthy cluster (every group holds a full,
  bit-identical copy).

**On-device sharded build** (:meth:`ShardedVectorIndex.build_sharded`):
raw vectors are ``device_put`` straight onto the ``data`` axis and ONE
jitted SPMD program runs the whole pipeline per shard under ``shard_map``
-- normalize -> ``encoder.encode`` -> ``index_best`` sentinel masking ->
``build_postings`` -- so index construction scales with the mesh exactly
like search does.  :meth:`from_index` (partitioning an existing
single-device index) likewise rebuilds the per-shard posting lists in one
SPMD program; neither path loops over shards on the host.

**Incremental ingest** (the full Lucene segment story):

* :meth:`add_documents` appends new docs to a per-shard *active append
  buffer* (round-robin shard routing, monotonically growing global ids
  starting at ``n_docs``).  The buffer carries codes but no posting lists;
  its phase-1 scores come from a direct per-column bucket-equality match
  (the same score every engine computes) and its df joins the global psum
  through :func:`repro.core.postings.code_df`.
* Once the buffer reaches ``seal_threshold`` rows it SEALS into an
  immutable :class:`Segment` (a Lucene segment/generation): truncated to
  its exact width, with its own mini posting table for O(log G) df
  lookups, and a fresh active buffer opens.  Search scores base + N sealed
  generations + the active buffer under ONE jitted SPMD program with
  per-generation live masks -- candidate order is append order per shard,
  which keeps results bit-identical to the flat single-buffer path at
  every (k, page).
* :meth:`merge_segments` is the Lucene background merge: a contiguous run
  of sealed generations re-packs into one (tombstoned rows dropped and
  reclaimed, ids and vector bits preserved) -- the operation the cluster
  tier's ``TieredMergePolicy`` schedules off the query path, demoting full
  :meth:`compact` to a delete-pressure last resort.
* :meth:`delete` marks docs dead: the per-doc ``live`` mask goes False,
  the doc's codes become the sentinel, and the affected shards' posting
  lists are rebuilt in the same one-program SPMD argsort the build uses --
  so document frequencies are EXACT under tombstones (idf-sensitive
  engines score identically before and after :meth:`compact`), unlike
  Lucene's lazy semantics where df transiently counts deleted docs.  The
  ``live`` mask stays the source of truth for result eligibility.  Each
  shard's tombstone count is tracked host-side (``shard_tombstones``);
  ``tombstone_ratio`` is the worst per-shard dead fraction, the trigger
  the cluster maintenance daemon (:mod:`repro.cluster.maintenance`)
  watches for background auto-compaction.
* :meth:`compact` folds segments and tombstones back into a clean base by
  re-running the on-device sharded build over the live doc table.  Global
  ids are stable across compaction: dead ids simply stop existing (their
  rows become sentinel-coded padding).

BUILD/INGEST INVARIANTS (relied on throughout):

* *Sentinel-tail postings*: padded and tombstoned rows carry the
  never-matching sentinel code, which sorts to the tail of every posting
  list -- range lookups cannot reach them, and a legal query code can
  never equal the sentinel.
* *Unsharded final rescore*: reported scores always come from the
  canonical ``(Q, k, n)`` einsum with unsharded operands on the
  coordinating device (see ``_merge_phase``) -- GSPMD blocks a sharded
  einsum differently per mesh shape, which would cost last-ulp parity.
* *Segment/tombstone semantics*: empty segment slots and tombstones are
  sentinel-coded and ``live=False``; ``live`` is the source of truth for
  result eligibility.  When fewer than ``k`` live docs exist, unfillable
  result slots report ``(id=-1, score=-inf)``.

IDF query weighting stays *global*: document frequencies are summed across
shards with a ``psum`` over ``data`` (integer-exact, identical in every
replica group), so trimming/weighting decisions are independent of both
the shard count and the replica count.  ``N`` is the global id-space size
(``n_docs`` + docs ever appended), ES ``maxDoc`` style.

Ragged corpora pad each shard to a common length; padded rows carry a
never-matching sentinel code, score ``-inf`` in both phases, and can never
enter the merged top-k.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.encoding import Encoder, RoundingEncoder
from repro.obs.compile_watch import watch_region
from repro.core.filtering import (BestFilter, TrimFilter, expand_mask,
                                  feature_mask, index_best_codes)
from repro.core.postings import (Postings, build_postings, code_df,
                                 df_lookup, idf_weights)
from repro.core.quantize import quantize_rows
from repro.core.rerank import normalize
from repro.core.search import (_SENTINEL, FUSED_ENGINES, VectorIndex,
                               phase1_engine_scores)

from .sharding import DATA_AXIS, REPLICA_AXIS

__all__ = ["ShardedVectorIndex", "Segment", "DEFAULT_SEAL_THRESHOLD"]


def _put(mesh: Mesh, x, spec: P):
    return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))


_ROW = P(DATA_AXIS, None, None)
_VEC = P(DATA_AXIS, None)

# Active append buffers seal into an immutable Segment once they reach this
# many rows.  Below it a direct per-column bucket match over the buffer is
# cheaper than maintaining posting lists; past it the segment gets its own
# mini posting table for O(log G) df lookups.  None disables sealing (the
# pre-generational flat behaviour, which the parity tests pin against).
DEFAULT_SEAL_THRESHOLD = 256


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Segment:
    """One immutable sealed generation of appended docs (a Lucene segment).

    Sealed off the active append buffer once it outgrows the direct-match
    threshold: rows are truncated to their exact round-robin width and the
    segment gets its own mini posting table (the same one-program SPMD
    argsort the base build uses), so its document frequencies come from
    O(log G) posting-range lookups instead of an O(G * C) dense count.
    Phase-1 *scores* stay the direct bucket-equality match -- the identity
    every engine lowers to -- which is what keeps segmented search
    bit-identical to the flat append path at every (k, page).

    Segments are immutable in the Lucene sense: the only mutations are
    tombstoning through :meth:`ShardedVectorIndex.delete` (live -> False,
    sentinel codes, mini postings rebuilt so df stays exact) and wholesale
    replacement by :meth:`ShardedVectorIndex.merge_segments`.  ``n_rows``
    and ``tombstones`` are host-side ints (never cross jit) feeding the
    tiered merge policy's per-segment deleted-doc ratios.
    """

    vectors: jnp.ndarray     # (S, G, n) f32 unit rows; zero rows pad
    codes: jnp.ndarray       # (S, G, C) int; sentinel = dead/padding
    gids: jnp.ndarray        # (S, G) int32 global ids; -1 = padding
    live: jnp.ndarray        # (S, G) bool
    post_docs: jnp.ndarray   # (S, C, G) int32 mini posting order
    post_codes: jnp.ndarray  # (S, C, G) sorted codes per shard
    n_rows: int              # rows holding a doc (live or tombstoned)
    tombstones: int          # dead rows among n_rows

    def tree_flatten(self):
        children = (self.vectors, self.codes, self.gids, self.live,
                    self.post_docs, self.post_codes)
        return children, (self.n_rows, self.tombstones)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def width(self) -> int:
        """Per-shard slot width (= ceil(n_rows / n_shards) at seal/merge)."""
        return self.vectors.shape[1]

    @property
    def deleted_ratio(self) -> float:
        """Dead fraction of this segment's rows -- the per-segment signal
        the tiered merge policy consults (the whole-index
        ``tombstone_ratio`` can't see which generation the deletes hit)."""
        return self.tombstones / max(self.n_rows, 1)

    def quantized(self, mesh: Mesh):
        """Per-row int8 quantization of this segment's vectors for
        ``fused_int8`` phase-1 -- (codes (S,G,n) int8, scale (S,G),
        zero (S,G)), derived lazily and cached on the segment object
        (segments are immutable; tombstoning replaces the object but
        carries the cache, since the vector bits are untouched).
        Quantization is row-wise, so a row's int8 codes are identical
        here and in the flat append buffer -- the seg-vs-flat parity
        pin extends to the quantized engine for free."""
        cached = self.__dict__.get("_quant_cache")
        if cached is None:
            cached = _quantize_program(self.vectors, mesh=mesh)
            self.__dict__["_quant_cache"] = cached
        return cached


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedVectorIndex:
    """A :class:`VectorIndex` partitioned into per-device doc-shards.

    Array leaves carry an explicit leading shard dim (``n_shards`` first)
    and live sharded over the ``data`` mesh axis; each device holds one
    contiguous document range plus its local->global id ``offset``.  The
    ``seg_*`` leaves are the per-shard append segments of incremental
    ingest (width 0 for a freshly built index); ``live`` is the per-doc
    eligibility mask (False = pad or tombstone).
    """

    vectors: jnp.ndarray      # (S, dp, n) f32, unit rows; zero rows pad
    codes: jnp.ndarray        # (S, dp, C) int; sentinel rows pad/tombstone
    post_docs: jnp.ndarray    # (S, C, dp) int32 per-shard posting order
    post_codes: jnp.ndarray   # (S, C, dp) sorted codes per shard
    offsets: jnp.ndarray      # (S,) int32 global id of each shard's doc 0
    live: jnp.ndarray         # (S, dp) bool -- False = pad or tombstone
    seg_vectors: jnp.ndarray  # (S, G, n) f32 ACTIVE append-buffer vectors
    seg_codes: jnp.ndarray    # (S, G, C) int; sentinel = empty/tombstone
    seg_gids: jnp.ndarray     # (S, G) int32 global ids; -1 = never used
    seg_live: jnp.ndarray     # (S, G) bool
    segments: Tuple[Segment, ...]  # sealed generations, oldest first
    encoder: Encoder
    mesh: Mesh
    n_docs: int               # base id-space size (compaction folds segs in)
    index_best: Optional[int]
    n_appended: int = 0       # docs ever appended since the last compact
    shard_tombstones: Tuple[int, ...] = ()  # per-shard uncompacted deletes
    seal_threshold: Optional[int] = DEFAULT_SEAL_THRESHOLD
    seg_base: int = 0         # append counter at the active buffer's start
    active_tombstones: int = 0  # dead rows in the active buffer

    # -- pytree plumbing (mesh/encoder/sizes are static metadata) ----------
    def tree_flatten(self):
        children = (self.vectors, self.codes, self.post_docs,
                    self.post_codes, self.offsets, self.live,
                    self.seg_vectors, self.seg_codes, self.seg_gids,
                    self.seg_live, self.segments)
        return children, (self.encoder, self.mesh, self.n_docs,
                          self.index_best, self.n_appended,
                          self.shard_tombstones, self.seal_threshold,
                          self.seg_base, self.active_tombstones)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # ------------------------------------------------------------ properties
    @property
    def n_shards(self) -> int:
        return self.vectors.shape[0]

    @property
    def n_replicas(self) -> int:
        if REPLICA_AXIS in self.mesh.axis_names:
            return int(self.mesh.shape[REPLICA_AXIS])
        return 1

    @property
    def docs_per_shard(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_features(self) -> int:
        return self.vectors.shape[2]

    @property
    def seg_capacity(self) -> int:
        """ACTIVE append-buffer slots per shard (0 = no open buffer)."""
        return self.seg_vectors.shape[1]

    @property
    def n_ids(self) -> int:
        """Global id-space size: base docs + docs ever appended."""
        return self.n_docs + self.n_appended

    @property
    def n_tombstones(self) -> int:
        """Docs deleted since the last compaction (whole index)."""
        return sum(self.shard_tombstones)

    @property
    def n_segments(self) -> int:
        """Sealed generations currently serving alongside the base."""
        return len(self.segments)

    @property
    def n_active(self) -> int:
        """Docs in the active (unsealed) append buffer."""
        return self.n_appended - self.seg_base

    @property
    def segment_rows(self) -> int:
        """Rows held by sealed segments (tombstoned rows included)."""
        return sum(s.n_rows for s in self.segments)

    @property
    def n_reclaimed(self) -> int:
        """Appended rows dropped by segment merges since the last compact
        (they no longer occupy slots anywhere; their ids stay retired)."""
        return self.n_appended - self.n_active - self.segment_rows

    @staticmethod
    def _seg_slots_used(n_appended: int, ns: int) -> np.ndarray:
        """(S,) append-segment slots used per shard.  THE round-robin
        occupancy formula -- shared by ingest routing and the tombstone
        accounting so the two can never diverge."""
        used = np.full(ns, n_appended // ns, np.int64)
        used[: n_appended % ns] += 1
        return used

    @property
    def shard_populations(self) -> np.ndarray:
        """(S,) docs ever assigned to each shard (base + appended) -- a pure
        function of the contiguous base split and round-robin ingest
        routing, so no device readback."""
        ns, dp = self.n_shards, self.docs_per_shard
        base = np.clip(self.n_docs - np.arange(ns) * dp, 0, dp)
        app = self._seg_slots_used(self.n_active, ns)
        for s in self.segments:
            # each generation is round-robin within itself (sealed buffers
            # by construction, merged segments by re-packing), so the same
            # occupancy formula applies per segment
            app = app + self._seg_slots_used(s.n_rows, ns)
        return base + app

    @property
    def tombstone_ratio(self) -> float:
        """Worst per-shard dead fraction (ES ``deletes_pct_allowed`` style:
        deleted / docs-ever-assigned, per shard, max over shards) -- the
        signal the cluster maintenance daemon compares against its
        auto-compaction threshold."""
        if not any(self.shard_tombstones):
            return 0.0
        dead = np.asarray(self.shard_tombstones, np.float64)
        return float(np.max(dead / np.maximum(self.shard_populations, 1)))

    @property
    def max_df(self) -> int:
        """Longest live posting list over every (shard, column): the exact
        per-shard ``max_postings`` window -- sized from the shard's actual
        code distribution instead of the ``docs_per_shard`` worst case.
        Tombstone-free by construction (:meth:`delete` rebuilds postings,
        sentinels are excluded), cached per instance (every mutation
        returns a new index, so the cache can never go stale)."""
        cached = self.__dict__.get("_max_df_cache")
        if cached is None:
            cached = int(_max_df_program(
                self.post_codes, mesh=self.mesh,
                sentinel=int(_SENTINEL[self.codes.dtype])))
            self.__dict__["_max_df_cache"] = cached
        return cached

    # --------------------------------------------------- quantized tables
    # int8 per-row copies of the dense leaves for fused_int8 phase-1.
    # Pure per-row functions of the vector bits: never persisted (store
    # commits and crash recovery re-derive identical tables), identical
    # on every mesh shape, and cached per instance like max_df.  Deletes
    # do NOT invalidate them -- tombstones only flip live/codes, and dead
    # rows are -inf-masked before quantized scores can matter -- so the
    # mutation paths carry the caches forward wherever the underlying
    # vectors leaf is shared (_carry_quant).
    def _quant_base(self):
        """(codes (S,dp,n) int8, scale (S,dp), zero (S,dp)) of the base."""
        cached = self.__dict__.get("_quant_base_cache")
        if cached is None:
            cached = _quantize_program(self.vectors, mesh=self.mesh)
            self.__dict__["_quant_base_cache"] = cached
        return cached

    def _quant_active(self):
        """Quantized active append buffer (recomputed once per ingest
        batch -- the buffer is small and mutations return new instances)."""
        cached = self.__dict__.get("_quant_active_cache")
        if cached is None:
            cached = _quantize_program(self.seg_vectors, mesh=self.mesh)
            self.__dict__["_quant_active_cache"] = cached
        return cached

    def _carry_quant(self, out: "ShardedVectorIndex", base: bool = False,
                     active: bool = False) -> "ShardedVectorIndex":
        """Propagate quant caches to a derived index whose corresponding
        vectors leaves are unchanged (dataclasses.replace drops them)."""
        for flag, key in ((base, "_quant_base_cache"),
                          (active, "_quant_active_cache")):
            if flag and key in self.__dict__:
                out.__dict__[key] = self.__dict__[key]
        return out

    # -------------------------------------------------------- obs: residency
    def resident_leaves(self):
        """``(path, section, array)`` for every device-resident array this
        index holds -- the seam :func:`repro.obs.device.device_bytes` walks
        for exact byte accounting.  Crucially this includes the lazily
        derived quant-table caches (``_quant_base_cache`` /
        ``_quant_active_cache`` / per-segment ``_quant_cache``), which are
        real HBM residents but NOT pytree children, so a plain tree walk
        would under-report the index by the full int8 table size."""
        yield "vectors", "base", self.vectors
        yield "codes", "base", self.codes
        yield "post_docs", "base", self.post_docs
        yield "post_codes", "base", self.post_codes
        yield "offsets", "base", self.offsets
        yield "live", "base", self.live
        yield "seg_vectors", "active", self.seg_vectors
        yield "seg_codes", "active", self.seg_codes
        yield "seg_gids", "active", self.seg_gids
        yield "seg_live", "active", self.seg_live
        for i, seg in enumerate(self.segments):
            for nm in ("vectors", "codes", "gids", "live",
                       "post_docs", "post_codes"):
                yield f"segments[{i}].{nm}", "segments", getattr(seg, nm)
            q = seg.__dict__.get("_quant_cache")
            if q is not None:
                for nm, arr in zip(("codes", "scale", "zero"), q):
                    yield f"segments[{i}].quant.{nm}", "quant", arr
        for key, prefix in (("_quant_base_cache", "quant.base"),
                            ("_quant_active_cache", "quant.active")):
            q = self.__dict__.get(key)
            if q is not None:
                for nm, arr in zip(("codes", "scale", "zero"), q):
                    yield f"{prefix}.{nm}", "quant", arr

    # ------------------------------------------------------------- replicas
    def replica_group(self, g: int) -> "ShardedVectorIndex":
        """View replica group ``g`` as an independent index on the 1-D
        ``data`` sub-mesh of that replica column's devices.

        Every leaf is already replicated across the ``replica`` axis, so
        each column device holds its doc-shard outright and the re-put is
        a no-copy resharding.  The group index runs the plain 1-D search
        path (bit-identical to single-device for ``page >= n_docs``) and
        can be served, searched, and compacted independently of its
        siblings -- the unit the cluster router batches per-group."""
        R = self.n_replicas
        if not 0 <= g < R:
            raise ValueError(f"replica group must be in [0, {R}), got {g}")
        if R == 1:
            return self
        devs = np.asarray(self.mesh.devices)[:, g]
        sub = Mesh(devs, (DATA_AXIS,))
        put = lambda x, spec: jax.device_put(x, NamedSharding(sub, spec))
        return dataclasses.replace(
            self, mesh=sub,
            vectors=put(self.vectors, _ROW),
            codes=put(self.codes, _ROW),
            post_docs=put(self.post_docs, _ROW),
            post_codes=put(self.post_codes, _ROW),
            offsets=put(self.offsets, P(DATA_AXIS)),
            live=put(self.live, _VEC),
            seg_vectors=put(self.seg_vectors, _ROW),
            seg_codes=put(self.seg_codes, _ROW),
            seg_gids=put(self.seg_gids, _VEC),
            seg_live=put(self.seg_live, _VEC),
            segments=tuple(
                Segment(put(s.vectors, _ROW), put(s.codes, _ROW),
                        put(s.gids, _VEC), put(s.live, _VEC),
                        put(s.post_docs, _ROW), put(s.post_codes, _ROW),
                        s.n_rows, s.tombstones)
                for s in self.segments),
        )

    # -------------------------------------------------------- introspection
    def token_df(self, queries) -> jnp.ndarray:
        """Global per-token document frequencies, (Q, C) int32 -- EXACTLY
        what the query phase's idf weighting sees: per-shard base postings
        lookup + segment code match, psum over ``data``.  With the eager
        postings refresh in :meth:`delete` this counts live docs only, so
        it is invariant under :meth:`compact` -- the pin behind the
        "idf-sensitive engines score identically across compaction"
        guarantee (and a cheap cluster debugging probe)."""
        q = normalize(jnp.atleast_2d(jnp.asarray(queries, jnp.float32)))
        qcodes = self.encoder.encode(q)
        seg = self.seg_capacity > 0
        sealed = tuple((s.post_docs, s.post_codes) for s in self.segments)
        return _token_df_program(
            self.post_docs, self.post_codes,
            self.seg_codes if seg else None, sealed, qcodes, mesh=self.mesh)

    # ----------------------------------------------------------------- build
    @classmethod
    def _partition_geometry(cls, mesh: Mesh, n: int) -> Tuple[int, int, int]:
        if DATA_AXIS not in mesh.axis_names:
            raise ValueError(f"mesh has no {DATA_AXIS!r} axis: {mesh.axis_names}")
        ns = int(mesh.shape[DATA_AXIS])
        if ns > n:
            raise ValueError(f"more shards ({ns}) than documents ({n})")
        dp = math.ceil(n / ns)
        return ns, dp, ns * dp - n

    @classmethod
    def _offsets(cls, ns: int, dp: int) -> np.ndarray:
        return (np.arange(ns) * dp).astype(np.int32)

    @classmethod
    def _empty_segments(cls, mesh: Mesh, ns: int, n_feat: int, n_cols: int,
                        code_dtype):
        sentinel = _SENTINEL[np.dtype(code_dtype)]
        return (
            _put(mesh, jnp.zeros((ns, 0, n_feat), jnp.float32), _ROW),
            _put(mesh, jnp.full((ns, 0, n_cols), sentinel, code_dtype), _ROW),
            _put(mesh, jnp.full((ns, 0), -1, jnp.int32), _VEC),
            _put(mesh, jnp.zeros((ns, 0), bool), _VEC),
        )

    @classmethod
    def build_sharded(
        cls,
        vectors,
        mesh: Mesh,
        encoder: Encoder = RoundingEncoder(2),
        index_best: Optional[int] = None,
        *,
        live=None,
        seal_threshold: Optional[int] = DEFAULT_SEAL_THRESHOLD,
    ) -> "ShardedVectorIndex":
        """Build the index ON the mesh: one compiled SPMD program runs
        normalize -> encode -> ``index_best`` masking -> ``build_postings``
        per shard under ``shard_map`` -- no per-shard host loop, no host
        round-trip (device-resident ``vectors`` are resharded in place).

        Bit-identical to ``VectorIndex.build(vectors, ...)`` followed by
        :meth:`from_index` (pinned by tests/test_build_parity.py): every
        stage is row-wise, so per-shard blocks produce the same bits as the
        single-device whole.  ``live=False`` rows (used by :meth:`compact`
        to carry tombstones through a rebuild) become sentinel-coded,
        zero-vector padding in place.
        """
        v = jnp.asarray(vectors)
        if v.dtype != jnp.float32:
            v = v.astype(jnp.float32)
        if v.ndim != 2:
            raise ValueError(f"vectors must be 2-D, got shape {v.shape}")
        n, n_feat = v.shape
        ns, dp, pad = cls._partition_geometry(mesh, n)
        lv = (jnp.ones((n,), bool) if live is None
              else jnp.asarray(live, bool))
        if pad:
            v = jnp.concatenate([v, jnp.zeros((pad, n_feat), jnp.float32)])
            lv = jnp.concatenate([lv, jnp.zeros((pad,), bool)])
        raw = _put(mesh, v.reshape(ns, dp, n_feat), _ROW)
        lv = _put(mesh, lv.reshape(ns, dp), _VEC)

        with watch_region("build.program",
                          sig=(int(ns), int(dp), int(n_feat))):
            vecs, codes, pdocs, pcodes = _build_program(
                raw, lv, mesh=mesh, encoder=encoder, index_best=index_best)

        return cls(
            vectors=vecs,
            codes=codes,
            post_docs=pdocs,
            post_codes=pcodes,
            offsets=_put(mesh, cls._offsets(ns, dp), P(DATA_AXIS)),
            live=lv,
            encoder=encoder,
            mesh=mesh,
            n_docs=n,
            index_best=index_best,
            seal_threshold=seal_threshold,
            **cls._segments_kw(mesh, ns, n_feat, codes),
        )

    @classmethod
    def _segments_kw(cls, mesh, ns, n_feat, codes):
        sv, sc, sg, sl = cls._empty_segments(mesh, ns, n_feat,
                                             codes.shape[-1], codes.dtype)
        return {"seg_vectors": sv, "seg_codes": sc, "seg_gids": sg,
                "seg_live": sl, "segments": ()}

    @classmethod
    def from_index(cls, index: VectorIndex, mesh: Mesh, *,
                   seal_threshold: Optional[int] = DEFAULT_SEAL_THRESHOLD,
                   ) -> "ShardedVectorIndex":
        """Partition an existing single-device index across ``mesh``'s
        ``data`` axis (contiguous ranges, ES-style doc-sharding).  The
        per-shard posting lists are rebuilt in ONE compiled SPMD program
        (argsort per shard under ``shard_map``) -- not a host loop -- and
        device-resident leaves reshard without a host numpy round-trip.

        On a ``(data, replica)`` mesh every leaf's spec leaves the
        ``replica`` axis unmentioned, so ``NamedSharding`` replicates each
        doc-shard across it -- R identical serving copies of the corpus."""
        n = index.n_docs
        ns, dp, pad = cls._partition_geometry(mesh, n)

        vectors = jnp.asarray(index.vectors)
        codes = jnp.asarray(index.codes)
        sentinel = _SENTINEL[codes.dtype]
        if pad:
            vectors = jnp.concatenate(
                [vectors, jnp.zeros((pad, vectors.shape[1]), vectors.dtype)])
            codes = jnp.concatenate(
                [codes, jnp.full((pad, codes.shape[1]), sentinel, codes.dtype)])
        n_feat = vectors.shape[1]
        vectors = _put(mesh, vectors.reshape(ns, dp, n_feat), _ROW)
        codes = _put(mesh, codes.reshape(ns, dp, -1), _ROW)

        # per-shard inverted indexes in one SPMD program: the sentinel sorts
        # to the tail of every posting list, so padded docs are invisible to
        # range lookups
        with watch_region("build.postings", sig=tuple(codes.shape)):
            pdocs, pcodes = _postings_program(codes, mesh=mesh)

        offsets = cls._offsets(ns, dp)
        counts = np.clip(n - offsets, 0, dp)        # real rows per shard
        live = np.arange(dp)[None, :] < counts[:, None]
        return cls(
            vectors=vectors,
            codes=codes,
            post_docs=pdocs,
            post_codes=pcodes,
            offsets=_put(mesh, offsets, P(DATA_AXIS)),
            live=_put(mesh, live, _VEC),
            encoder=index.encoder,
            mesh=mesh,
            n_docs=n,
            index_best=index.index_best,
            seal_threshold=seal_threshold,
            **cls._segments_kw(mesh, ns, n_feat, codes),
        )

    @classmethod
    def build(cls, vectors, mesh: Mesh, encoder=None, index_best=None):
        """Build + shard in one step -- now the on-device sharded build
        (:meth:`build_sharded`); accepts device-resident vectors without a
        host numpy round-trip."""
        kwargs = {} if encoder is None else {"encoder": encoder}
        return cls.build_sharded(vectors, mesh, index_best=index_best,
                                 **kwargs)

    # ----------------------------------------------------------------- ingest
    def add_documents(self, vectors, *,
                      donate: bool = False) -> "ShardedVectorIndex":
        """Append new documents ES-style -> a new index sharing every
        unchanged leaf with ``self``.

        New docs are normalized/encoded on device, routed round-robin
        across shards, and written into per-shard append segments; global
        ids continue from ``n_ids`` (monotonic until :meth:`compact` folds
        segments into the base).  Segments are searched alongside the base
        (direct code match; no posting lists) until compaction.  Segment
        capacity grows geometrically and the query phase traces ``n_ids``
        as a runtime scalar, so an ingest stream recompiles the search
        program only O(log(appended)) times (for ``page < n_ids``), not
        per batch.

        The four active-buffer leaves update in ONE jitted program with
        explicit output shardings (no per-leaf device_put copies).  With
        ``donate=True`` the old buffers are additionally DONATED to that
        program -- zero new steady-state allocations -- which makes
        ``self`` unusable afterwards: only pass it when nothing else can
        be holding this index (the serve engine's opt-in hot-swap path
        proves that with its serving-snapshot guard).  Growth batches
        never donate: the concatenated temporaries are not committed to
        the output sharding, so XLA could not alias them anyway.
        """
        v = jnp.atleast_2d(jnp.asarray(vectors, jnp.float32))
        m = int(v.shape[0])
        if m == 0:
            return self
        if v.shape[1] != self.n_features:
            raise ValueError(
                f"expected {self.n_features}-feature vectors, got {v.shape}")
        v = normalize(v)
        codes = self.encoder.encode(v)
        sentinel = _SENTINEL[self.codes.dtype]
        if self.index_best is not None:
            codes = index_best_codes(v, codes, self.index_best, sentinel)

        ns, G = self.n_shards, self.seg_capacity
        # routing is strictly round-robin on the ACTIVE buffer's local
        # counter (n_appended - seg_base), so per-shard slot usage is a
        # pure function of the append history (tombstones keep their slot)
        # -- no device readback on the hot ingest path.  With no sealed
        # generations (seg_base == 0) this is the original global formula.
        n_act = self.n_active
        used = self._seg_slots_used(n_act, ns)
        shard_of = (n_act + np.arange(m)) % ns
        slot_of = used[shard_of] + np.arange(m) // ns
        need = int(slot_of.max()) + 1
        gids = (self.n_ids + np.arange(m)).astype(np.int32)

        svec, scod = self.seg_vectors, self.seg_codes
        sgid, sliv = self.seg_gids, self.seg_live
        grew = need > G
        if grew:
            # grow geometrically: search programs specialise on the segment
            # width, so exact-fit growth would recompile the whole SPMD
            # query phase per ingest batch -- doubling amortises that to
            # O(log(appended)) compiles (spare slots are sentinel-coded,
            # live=False, and invisible to every mask)
            grow = max(need, 2 * G, 8) - G
            n_feat, C = self.n_features, scod.shape[-1]
            svec = jnp.concatenate(
                [svec, jnp.zeros((ns, grow, n_feat), jnp.float32)], axis=1)
            scod = jnp.concatenate(
                [scod, jnp.full((ns, grow, C), sentinel, scod.dtype)], axis=1)
            sgid = jnp.concatenate(
                [sgid, jnp.full((ns, grow), -1, jnp.int32)], axis=1)
            sliv = jnp.concatenate(
                [sliv, jnp.zeros((ns, grow), bool)], axis=1)
        sh, sl = jnp.asarray(shard_of), jnp.asarray(slot_of)
        # growth batches skip donation: the concat temporaries above are
        # uncommitted, so the aliasing would be silently dropped anyway
        with watch_region("ingest.append",
                          sig=(int(m), int(svec.shape[1]), bool(grew))):
            svec, scod, sgid, sliv = _append_update(
                self.mesh, donate and not grew)(
                svec, scod, sgid, sliv, sh, sl, v,
                codes.astype(scod.dtype), jnp.asarray(gids))
        out = dataclasses.replace(
            self,
            seg_vectors=svec, seg_codes=scod, seg_gids=sgid, seg_live=sliv,
            n_appended=self.n_appended + m,
        )
        out = self._carry_quant(out, base=True)  # base leaves untouched
        if (out.seal_threshold is not None
                and out.n_active >= out.seal_threshold):
            out = out._seal_active()
        return out

    def _seal_active(self) -> "ShardedVectorIndex":
        """Seal the active append buffer into an immutable :class:`Segment`.

        The buffer is truncated to its exact round-robin width, gets its
        own mini posting table (the same one-program SPMD argsort the base
        build and :meth:`delete` use), and joins ``segments``; the next
        :meth:`add_documents` opens a fresh active buffer whose geometric
        growth ladder restarts from empty.  A pure function of the op
        history, so translog replay re-seals at identical boundaries.
        """
        ns = self.n_shards
        n_act = self.n_active
        if n_act == 0:
            return self
        w = int(self._seg_slots_used(n_act, ns).max())
        svec = _put(self.mesh, self.seg_vectors[:, :w], _ROW)
        scod = _put(self.mesh, self.seg_codes[:, :w], _ROW)
        sgid = _put(self.mesh, self.seg_gids[:, :w], _VEC)
        sliv = _put(self.mesh, self.seg_live[:, :w], _VEC)
        with watch_region("ingest.seal", sig=(int(w), ns)):
            pdocs, pcodes = _postings_program(scod, mesh=self.mesh)
        seg = Segment(svec, scod, sgid, sliv, pdocs, pcodes,
                      n_rows=n_act, tombstones=self.active_tombstones)
        # the sealed generation inherits the active buffer's quant cache
        # as its own (same vector bits; the seal is a truncating slice, and
        # quantization is row-wise) -- but only when widths already agree,
        # else let the segment re-derive lazily
        if ("_quant_active_cache" in self.__dict__
                and self.seg_capacity == w):
            seg.__dict__["_quant_cache"] = self.__dict__[
                "_quant_active_cache"]
        ev, ec, eg, el = self._empty_segments(
            self.mesh, ns, self.n_features, self.codes.shape[-1],
            self.codes.dtype)
        out = dataclasses.replace(
            self, segments=self.segments + (seg,),
            seg_vectors=ev, seg_codes=ec, seg_gids=eg, seg_live=el,
            seg_base=self.n_appended, active_tombstones=0)
        return self._carry_quant(out, base=True)

    def delete(self, ids) -> "ShardedVectorIndex":
        """Tombstone documents by global id -> a new index.

        The doc's ``live`` flag goes False and its codes become the
        sentinel, so the ``codes``/``onehot`` engines skip it outright and
        the ``live`` mask blocks it from every result page.  Base posting
        lists are REBUILT in the same one-program SPMD argsort the build
        uses (the sentinel sorts every tombstone to the list tails), so
        document frequencies are exact immediately -- idf weights, and
        therefore idf-sensitive phase-1 scores, are identical before and
        after :meth:`compact`.  That is stricter than Lucene (which lets
        df count deleted docs until a merge) at the cost of one argsort
        per delete batch -- a control-plane price, not a query-path one.
        Deleting an already-dead or padded id is a no-op for that id (and
        does not count toward ``shard_tombstones``).
        """
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        if ids.size == 0:
            return self
        if (ids < 0).any() or (ids >= self.n_ids).any():
            raise ValueError(
                f"ids must be in [0, {self.n_ids}), got {ids.min()}..{ids.max()}")
        sentinel = _SENTINEL[self.codes.dtype]
        dead = np.zeros(self.n_shards, np.int64)
        new = {}
        base = ids[ids < self.n_docs]
        if base.size:
            s, r = np.divmod(base, self.docs_per_shard)
            was_live = np.asarray(self.live)[s, r]
            np.add.at(dead, s[was_live], 1)
            s, r = jnp.asarray(s), jnp.asarray(r)
            new["live"] = _put(self.mesh, self.live.at[s, r].set(False), _VEC)
            new["codes"] = _put(self.mesh,
                                self.codes.at[s, r].set(sentinel), _ROW)
            # exact-df postings refresh: one SPMD argsort over the updated
            # codes drops the tombstones out of every posting list
            pdocs, pcodes = _postings_program(new["codes"], mesh=self.mesh)
            new["post_docs"], new["post_codes"] = pdocs, pcodes
        app = ids[ids >= self.n_docs]
        if app.size:
            segs = list(self.segments)
            seg_changed = False
            for i, seg in enumerate(segs):
                s, g = np.nonzero(np.isin(np.asarray(seg.gids), app))
                if s.size == 0:
                    continue
                was_live = np.asarray(seg.live)[s, g]
                np.add.at(dead, s[was_live], 1)
                n_new = int(was_live.sum())
                s, g = jnp.asarray(s), jnp.asarray(g)
                codes2 = _put(self.mesh,
                              seg.codes.at[s, g].set(sentinel), _ROW)
                live2 = _put(self.mesh, seg.live.at[s, g].set(False), _VEC)
                # exact df under tombstones, per generation: rebuild the
                # segment's mini posting table so the sentinel sorts its
                # dead rows past every legal lookup range
                pdocs, pcodes = _postings_program(codes2, mesh=self.mesh)
                segs[i] = Segment(seg.vectors, codes2, seg.gids, live2,
                                  pdocs, pcodes, seg.n_rows,
                                  seg.tombstones + n_new)
                if "_quant_cache" in seg.__dict__:
                    # same vectors leaf; dead rows are live-masked before
                    # quantized scores matter, so the table stays valid
                    segs[i].__dict__["_quant_cache"] = \
                        seg.__dict__["_quant_cache"]
                seg_changed = True
            if seg_changed:
                new["segments"] = tuple(segs)
            s, g = np.nonzero(np.isin(np.asarray(self.seg_gids), app))
            if s.size:
                was_live = np.asarray(self.seg_live)[s, g]
                np.add.at(dead, s[was_live], 1)
                new["active_tombstones"] = (self.active_tombstones
                                            + int(was_live.sum()))
                s, g = jnp.asarray(s), jnp.asarray(g)
                new["seg_live"] = _put(
                    self.mesh, self.seg_live.at[s, g].set(False), _VEC)
                new["seg_codes"] = _put(
                    self.mesh, self.seg_codes.at[s, g].set(sentinel), _ROW)
        old = (np.asarray(self.shard_tombstones, np.int64)
               if self.shard_tombstones else np.zeros(self.n_shards, np.int64))
        new["shard_tombstones"] = tuple(int(x) for x in old + dead)
        # deletes never touch a vectors leaf -- every quant table survives
        return self._carry_quant(dataclasses.replace(self, **new),
                                 base=True, active=True)

    def compact(self) -> "ShardedVectorIndex":
        """Fold append segments and tombstones back into a clean base by
        re-running the on-device sharded build over the live doc table.

        Global ids are STABLE: the new base spans ``[0, n_ids)`` in old-id
        order, with dead ids carried as sentinel-coded padding rows --
        posting lists are tombstone-free again and df is exact.  The new
        index has ``n_appended == 0`` and zero-width segments.
        """
        ns, dp, n_feat = self.n_shards, self.docs_per_shard, self.n_features
        flat_v = self.vectors.reshape(ns * dp, n_feat)[: self.n_docs]
        flat_l = self.live.reshape(ns * dp)[: self.n_docs]
        if self.n_appended:
            table_v = jnp.concatenate(
                [flat_v, jnp.zeros((self.n_appended, n_feat), jnp.float32)])
            table_l = jnp.concatenate(
                [flat_l, jnp.zeros((self.n_appended,), bool)])
            parts = [(s.gids, s.vectors, s.live) for s in self.segments]
            if self.seg_capacity:
                parts.append(
                    (self.seg_gids, self.seg_vectors, self.seg_live))
            # gids are unique across generations; rows merged away stay
            # unset (live False) -- their ids were already retired
            for sgid, svec, sliv in parts:
                sg = sgid.reshape(-1)
                idx = jnp.where(sg >= 0, sg, self.n_ids)  # never-used -> OOB
                table_v = table_v.at[idx].set(
                    svec.reshape(-1, n_feat), mode="drop")
                table_l = table_l.at[idx].set(sliv.reshape(-1), mode="drop")
        else:
            table_v, table_l = flat_v, flat_l
        return type(self).build_sharded(
            table_v, self.mesh, encoder=self.encoder,
            index_best=self.index_best, live=table_l,
            seal_threshold=self.seal_threshold)

    def merge_segments(self, start: int = 0,
                       count: Optional[int] = None) -> "ShardedVectorIndex":
        """Merge a contiguous run of sealed segments into one, dropping
        tombstoned rows (Lucene's background segment merge).

        Content-preserving, not a rebuild: surviving rows keep their unit
        vectors, codes, and global ids verbatim; they are re-packed
        round-robin in id order and the merged segment gets a fresh mini
        posting table.  Tombstones the run carried are RECLAIMED -- the
        per-shard ``shard_tombstones`` counters drop by exactly the dead
        rows merged away, so ``tombstone_ratio`` keeps meaning "deletes a
        compact could still fold".  Search results are bit-identical
        before and after for ``page >= n_ids``: removed rows were already
        ``-inf`` everywhere, and surviving rows keep their relative id
        order, so candidate tie-breaks cannot shift.

        Assembly is host-side gathers + ONE ``device_put`` per leaf --
        never a scatter from replica-replicated leaves (GSPMD reassembles
        such scatters with a double-counting cross-replica sum).
        """
        nseg = len(self.segments)
        if count is None:
            count = nseg - start
        if nseg == 0:
            raise ValueError("no sealed segments to merge")
        if not (0 <= start < nseg and count >= 1 and start + count <= nseg):
            raise ValueError(
                f"invalid merge range [{start}, {start + count}) "
                f"of {nseg} segments")
        run = self.segments[start:start + count]
        ns, n_feat = self.n_shards, self.n_features
        C = self.codes.shape[-1]
        sentinel = _SENTINEL[self.codes.dtype]

        keep_v, keep_c, keep_g = [], [], []
        dead_per_shard = np.zeros(ns, np.int64)
        for seg in run:
            sg = np.asarray(seg.gids)
            sl = np.asarray(seg.live)
            used = sg >= 0
            dead_per_shard += (used & ~sl).sum(axis=1)
            ks, kg = np.nonzero(used & sl)
            keep_g.append(sg[ks, kg])
            keep_v.append(np.asarray(seg.vectors)[ks, kg])
            keep_c.append(np.asarray(seg.codes)[ks, kg])
        gids = np.concatenate(keep_g)
        order = np.argsort(gids, kind="stable")   # id order = append order
        gids = gids[order]
        vecs = np.concatenate(keep_v)[order]
        codes = np.concatenate(keep_c)[order]
        n_live = int(gids.size)

        old = (np.asarray(self.shard_tombstones, np.int64)
               if self.shard_tombstones else np.zeros(ns, np.int64))
        stones = old - dead_per_shard
        stones_t = (tuple(int(x) for x in stones) if stones.any() else ())

        before, after = self.segments[:start], self.segments[start + count:]
        if n_live == 0:
            # every row in the run was dead: the generations just vanish
            return dataclasses.replace(
                self, segments=before + after, shard_tombstones=stones_t)

        w = -(-n_live // ns)
        mv = np.zeros((ns, w, n_feat), np.float32)
        mc = np.full((ns, w, C), sentinel, dtype=self.codes.dtype)
        mg = np.full((ns, w), -1, np.int32)
        ml = np.zeros((ns, w), bool)
        r = np.arange(n_live)
        sh, sl_ = r % ns, r // ns
        mv[sh, sl_] = vecs
        mc[sh, sl_] = codes
        mg[sh, sl_] = gids.astype(np.int32)
        ml[sh, sl_] = True
        dvec = _put(self.mesh, mv, _ROW)
        dcod = _put(self.mesh, mc, _ROW)
        dgid = _put(self.mesh, mg, _VEC)
        dliv = _put(self.mesh, ml, _VEC)
        with watch_region("merge.postings", sig=(int(w), ns)):
            pdocs, pcodes = _postings_program(dcod, mesh=self.mesh)
        merged = Segment(dvec, dcod, dgid, dliv, pdocs, pcodes,
                         n_rows=n_live, tombstones=0)
        return dataclasses.replace(
            self, segments=before + (merged,) + after,
            shard_tombstones=stones_t)

    # ------------------------------------------------------------------ search
    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = None,
        best: Optional[BestFilter] = None,
        engine: str = "postings",
        weighting: str = "idf",
        max_postings: "Optional[int | str]" = None,
        merge: str = "gather",
        live_groups: "Optional[Tuple[int, ...]]" = None,
        profile=None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Distributed two-phase search -> (ids (Q,k), cosine scores (Q,k)).

        Same contract as :meth:`VectorIndex.search`; bit-identical to it
        when ``page >= n_docs``, for either ``merge`` transport
        (``"gather"`` = blocking all-gather, ``"stream"`` = ring-streamed
        per-shard pages) and any replica count -- queries round-robin
        across replica groups, each holding a full copy of the corpus.
        After ingest/deletes the same protocol covers base + segments;
        result slots beyond the live doc count are ``(id=-1, score=-inf)``.

        ``max_postings="auto"`` sizes the postings window from the actual
        code distribution (:attr:`max_df`, the longest live posting list
        over every shard) -- exact like ``None``, but the window is the
        true maximum instead of the ``docs_per_shard`` worst case.

        ``live_groups`` is the failover mask: query blocks are assigned
        only to the named replica columns (dead columns get zero rows,
        which can never reach a caller) -- the health-masked merge the
        cluster control plane routes through when a group is down.

        ``profile`` is an optional :class:`~repro.obs.profile.
        ProfileNode` the phases annotate themselves into (encode,
        phase-1 with per-replica-group and per-generation candidate
        counts, merge select, rescore).  Phase boundaries are fenced
        with ``jax.block_until_ready`` -- host-side observation only,
        the computed values (and bit-parity) are untouched.
        """
        if merge not in ("gather", "stream"):
            raise ValueError(f"unknown merge transport {merge!r}")
        t_prof = time.monotonic() if profile is not None else 0.0
        R = self.n_replicas
        if live_groups is None:
            groups = tuple(range(R))
        else:
            groups = tuple(sorted({int(g) for g in live_groups}))
            if not groups or groups[0] < 0 or groups[-1] >= R:
                raise ValueError(
                    f"live_groups must be a non-empty subset of [0, {R}), "
                    f"got {live_groups}")
        U = len(groups)
        queries = jnp.atleast_2d(queries)
        page = min(page, self.n_ids)
        k = min(k, page)
        page_loc = min(page, self.docs_per_shard + self.seg_capacity
                       + sum(s.width for s in self.segments))

        # round-robin over the LIVE replica groups: the batch splits along
        # the replica axis, so pad it to U row-blocks and place block j in
        # live column groups[j]; down columns receive zero rows.  All pad
        # and dead-column rows are dropped again below, before the final
        # rescore, and can never reach a caller.
        n_q = queries.shape[0]
        B = -(-n_q // U)                    # rows per live group
        q = jnp.asarray(queries, jnp.float32)
        pad_real = U * B - n_q
        if pad_real:
            q = jnp.concatenate(
                [q, jnp.zeros((pad_real, q.shape[1]), jnp.float32)])
        if U < R:
            src = np.full(R * B, U * B, np.int64)       # OOB -> zero row
            for j, c in enumerate(groups):
                src[c * B:(c + 1) * B] = np.arange(j * B, (j + 1) * B)
            q = jnp.concatenate(
                [q, jnp.zeros((1, q.shape[1]), jnp.float32)])[jnp.asarray(src)]
        q = normalize(q)
        qcodes = self.encoder.encode(q)
        mask = expand_mask(feature_mask(q, trim=trim, best=best),
                           qcodes.shape[-1])
        if profile is not None:
            jax.block_until_ready((q, qcodes, mask))
            t_now = time.monotonic()
            profile.child("encode", t_now - t_prof,
                          n_queries=int(n_q), groups=U)
            t_prof = t_now

        if max_postings == "auto":
            max_postings = max(1, self.max_df)
        L = self.docs_per_shard if max_postings is None \
            else min(max_postings, self.docs_per_shard)
        seg = self.seg_capacity > 0
        sealed = tuple(
            (s.vectors, s.codes, s.gids, s.live, s.post_docs, s.post_codes)
            for s in self.segments)
        # fused_int8 scores every generation off its lazily derived int8
        # table (mixing quantized-cosine and idf-sum scales inside one
        # top_k would be meaningless); other engines pass no quant leaves
        quant = engine == "fused_int8"
        with watch_region(
                "search.query_phase",
                sig=(tuple(q.shape), engine, weighting, int(page_loc),
                     int(L), int(k) if merge == "stream" else 0, merge,
                     len(self.segments), bool(seg))):
            gids, scores = _query_phase(
                self.vectors, self.codes, self.post_docs, self.post_codes,
                self.offsets, self.live,
                self.seg_vectors if seg else None,
                self.seg_codes if seg else None,
                self.seg_gids if seg else None,
                self.seg_live if seg else None,
                sealed,
                self._quant_base() if quant else None,
                self._quant_active() if (quant and seg) else None,
                tuple(s.quantized(self.mesh) for s in self.segments)
                if quant else (),
                q, qcodes, mask, jnp.asarray(self.n_ids, jnp.int32),
                mesh=self.mesh, max_abs_bucket=self.encoder.max_abs_bucket,
                page_loc=page_loc, engine=engine, weighting=weighting,
                max_postings=L, k=k if merge == "stream" else 0, merge=merge,
            )
        # drop replica-pad and dead-column rows BEFORE the final reduce: the
        # rescore inside _merge_phase must run at the true (Q, k, n) shape
        # -- the canonical shape of exact_scores -- or pad rows would
        # perturb the einsum blocking and cost bit-parity with the
        # single-device index
        if U < R:
            sel = jnp.asarray(np.concatenate(
                [np.arange(c * B, (c + 1) * B) for c in groups])[:n_q])
            gids, scores, q = gids[sel], scores[sel], q[sel]
        elif pad_real:
            gids, scores, q = gids[:n_q], scores[:n_q], q[:n_q]
        if profile is not None:
            jax.block_until_ready((gids, scores))
            t_now = time.monotonic()
            kernel = engine if engine in FUSED_ENGINES else "composed"
            node = profile.child(
                "phase1", t_now - t_prof, engine=engine, kernel=kernel,
                page=int(page), page_loc=int(page_loc), k=int(k),
                merge=merge)
            t_prof = t_now
            # per-replica-group children: padded row-block j of the batch
            # ran on live column groups[j]
            for j, c in enumerate(groups):
                nq_j = max(0, min(n_q, (j + 1) * B) - j * B)
                if nq_j:
                    node.child(f"group{c}", n_queries=int(nq_j))
            # per-generation candidate counts, resolved host-side by gid
            # membership (profile mode only -- this is a device readback)
            gh = np.asarray(gids)
            valid = gh[gh >= 0]
            node.attrs["candidates"] = int(valid.size)
            node.child("base", rows=int(self.n_docs),
                       candidates=int((valid < self.n_docs).sum()))
            appended = valid[valid >= self.n_docs]
            for gi, s in enumerate(self.segments):
                sg = np.asarray(s.gids).ravel()
                node.child(f"gen{gi}", rows=int(s.n_rows),
                           tombstones=int(s.tombstones),
                           candidates=int(np.isin(
                               appended, sg[sg >= 0]).sum()))
            if seg and self.n_active:
                ag = np.asarray(self.seg_gids).ravel()
                node.child("active", rows=int(self.n_active),
                           tombstones=int(self.active_tombstones),
                           candidates=int(np.isin(
                               appended, ag[ag >= 0]).sum()))
        return _merge_phase(self, gids, scores, q, k=k, profile=profile)


@partial(jax.jit, static_argnames=("mesh", "encoder", "index_best"))
def _build_program(raw, live, *, mesh, encoder, index_best):
    """THE on-device build: one SPMD program, whole pipeline per shard.

    Every stage is row-wise (normalize, encode, best-mask) or
    column-independent over the local rows (the posting argsort), so each
    shard's block produces bit-identical results to the same rows inside a
    single-device build -- which is exactly the parity the property suite
    pins.  ``live=False`` rows (pads, carried tombstones) become zero
    vectors with sentinel codes, sorting to the tail of every posting list.
    """
    from .shmap import shard_map

    def local(vec, lv):
        vec, lv = vec[0], lv[0]
        v = normalize(vec)
        v = jnp.where(lv[:, None], v, 0.0)
        codes = encoder.encode(v)
        sentinel = _SENTINEL[codes.dtype]
        if index_best is not None:
            codes = index_best_codes(v, codes, index_best, sentinel)
        codes = jnp.where(lv[:, None], codes,
                          jnp.asarray(sentinel, codes.dtype))
        p = build_postings(codes)
        return v[None], codes[None], p.post_docs[None], p.post_codes[None]

    fn = shard_map(local, mesh=mesh, in_specs=(_ROW, _VEC),
                   out_specs=(_ROW, _ROW, _ROW, _ROW), check=False)
    return fn(raw, live)


@functools.lru_cache(maxsize=None)
def _append_update(mesh: Mesh, donate: bool):
    """The fused append-update program for the ingest hot path.

    All four active-buffer leaves scatter-update in ONE jitted program
    with explicit output shardings -- replacing four eager ``.at[].set``
    + ``device_put`` pairs (eight buffer allocations per batch) with a
    single XLA computation (four allocations, or ZERO with donation:
    ``donate=True`` aliases each input buffer to its output, so the
    update happens in place).  Scatter targets here are the data-sharded
    seg leaves, never anything replica-replicated-only, so the GSPMD
    scatter hazard (see merge_segments) does not apply.  Cached per
    (mesh, donate); jit caches per batch shape inside.
    """
    row = NamedSharding(mesh, _ROW)
    vec = NamedSharding(mesh, _VEC)

    def upd(svec, scod, sgid, sliv, sh, sl, v, c, g):
        return (svec.at[sh, sl].set(v),
                scod.at[sh, sl].set(c),
                sgid.at[sh, sl].set(g),
                sliv.at[sh, sl].set(True))

    return jax.jit(upd,
                   donate_argnums=(0, 1, 2, 3) if donate else (),
                   out_shardings=(row, row, vec, vec))


@partial(jax.jit, static_argnames=("mesh",))
def _quantize_program(vectors, *, mesh):
    """Per-shard int8 row quantization in one SPMD program: (S, W, n) f32
    -> (codes (S, W, n) int8, scale (S, W), zero (S, W)).  Row-wise, so
    per-shard blocks quantize to the same bits as the rows would anywhere
    else -- mesh shape and generation layout can't change a code."""
    from .shmap import shard_map

    def local(v):
        q8, sc, zp = quantize_rows(v[0])
        return q8[None], sc[None], zp[None]

    fn = shard_map(local, mesh=mesh, in_specs=(_ROW,),
                   out_specs=(_ROW, _VEC, _VEC), check=False)
    return fn(vectors)


@partial(jax.jit, static_argnames=("mesh",))
def _postings_program(codes, *, mesh):
    """Per-shard posting-list build in one SPMD program (from_index path:
    codes already exist, only the argsort runs per shard)."""
    from .shmap import shard_map

    def local(c):
        p = build_postings(c[0])
        return p.post_docs[None], p.post_codes[None]

    fn = shard_map(local, mesh=mesh, in_specs=(_ROW,),
                   out_specs=(_ROW, _ROW), check=False)
    return fn(codes)


def _merge_phase(sidx, gids, scores, q, *, k, profile=None):
    """Coordinating-node reduce: global top-k over the exact cosines, then
    final scores recomputed at the (Q, k, n) shape shared with rerank_topk
    -- see exact_scores for why this gives bit-parity.  For the stream
    transport the inputs are already the merged (Q, k) page (sorted by
    score), so the top-k is an identity pass and only the rescore runs.

    The select + candidate-vector fetch run distributed (top-k and gather
    are exact, layout can't change a bit); the rescore einsum runs on the
    coordinating device with *unsharded* operands, because GSPMD blocks a
    sharded einsum differently per mesh shape -- rescoring in-mesh costs
    last-ulp parity between e.g. a 4x1 and a 2x4 layout of the same corpus.

    Result slots whose merged score is -inf (fewer than k live candidates)
    report id -1 and keep score -inf through the rescore.
    """
    t_prof = time.monotonic() if profile is not None else 0.0
    seg_parts = tuple((s.vectors, s.gids) for s in sidx.segments)
    if sidx.n_appended and sidx.seg_capacity:
        seg_parts += ((sidx.seg_vectors, sidx.seg_gids),)
    with watch_region("search.merge_select",
                      sig=(tuple(gids.shape), int(k), len(seg_parts))):
        if seg_parts:
            top_ids, cvec = _merge_select_seg(
                sidx.vectors, seg_parts, gids, scores, k=k,
                n_docs=sidx.n_docs)
        else:
            # no appended rows anywhere (fresh index, or every appended
            # row was merged away dead): candidates are base gids only
            top_ids, cvec = _merge_select(sidx.vectors, gids, scores, k=k)
    if profile is not None:
        jax.block_until_ready((top_ids, cvec))
        t_now = time.monotonic()
        profile.child("merge_select", t_now - t_prof, k=int(k),
                      generations=len(seg_parts))
        t_prof = t_now
    dev = jax.devices()[0]
    cvec_d = jax.device_put(cvec, dev)
    q_d = jax.device_put(q, dev)
    ids_d = jax.device_put(top_ids, dev)
    with watch_region("search.rescore", sig=(tuple(q.shape), int(k))):
        out = _rescore(cvec_d, q_d, ids_d)
    if profile is not None:
        jax.block_until_ready(out)
        profile.child("rescore", time.monotonic() - t_prof, k=int(k))
    return top_ids, out


@partial(jax.jit, static_argnames=("k",))
def _merge_select(vectors, gids, scores, *, k):
    top_s, pos = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(gids, pos, axis=1)
    top_ids = jnp.where(jnp.isneginf(top_s), -1, top_ids)
    flat_vectors = vectors.reshape(-1, vectors.shape[-1])
    cvec = flat_vectors[jnp.maximum(top_ids, 0)]    # (Q, k, n) hit vectors
    return top_ids, cvec


@partial(jax.jit, static_argnames=("k", "n_docs"))
def _merge_select_seg(vectors, seg_parts, gids, scores, *, k, n_docs):
    """Merge select over base + appended generations.

    ``seg_parts`` is a tuple of ``(vectors (S, G, n), gids (S, G))`` pairs
    -- the sealed segments plus the active buffer.  Pure gathers only (no
    scatter): base hits fetch from the flat base by gid = flat row;
    appended hits (gid >= ``n_docs``) resolve their slot by gid equality
    within each generation (gids are unique across generations) and fold
    in with a ``where``.  Scatter-built lookup tables are unsafe here --
    on a replicated ``(data, replica)`` layout GSPMD reassembles a
    scattered table with a cross-replica sum that double-counts the base
    rows.  The fold is PER generation on purpose: concatenating two
    generations' (data-sharded, replica-replicated) leaves and gathering
    from the concatenation miscompiles the same way on a replica mesh
    (the gathered row comes back as a cross-replica combination that
    matches no source row), while single-layout gathers stay exact.
    """
    top_s, pos = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(gids, pos, axis=1)
    top_ids = jnp.where(jnp.isneginf(top_s), -1, top_ids)
    n_feat = vectors.shape[-1]
    flat = vectors.reshape(-1, n_feat)              # rows [0, S*dp)
    cvec = flat[jnp.clip(top_ids, 0, flat.shape[0] - 1)]
    for v, g in seg_parts:
        sg = g.reshape(-1)
        sv = v.reshape(-1, n_feat)
        match = top_ids[:, :, None] == sg[None, None, :]
        slot = jnp.argmax(match, axis=-1)
        found = match.any(axis=-1)
        cvec = jnp.where(found[..., None], sv[slot], cvec)
    return top_ids, cvec                            # (Q, k, n) hit vectors


@jax.jit
def _rescore(cvec, q, top_ids):
    """exact_scores' canonical (Q, k, n) einsum over pre-fetched hits;
    unfillable (id -1) slots stay -inf instead of a junk-row cosine."""
    s = jnp.einsum("qkn,qn->qk", cvec, q,
                   preferred_element_type=jnp.float32)
    return jnp.where(top_ids < 0, -jnp.inf, s)


@partial(jax.jit, static_argnames=("mesh", "max_abs_bucket", "page_loc",
                                   "engine", "weighting", "max_postings",
                                   "k", "merge"))
def _query_phase(vectors, codes, post_docs, post_codes, offsets, live,
                 seg_vectors, seg_codes, seg_gids, seg_live, sealed,
                 base_quant, act_quant, sealed_quant,
                 q, qcodes, mask, n_ids, *, mesh, max_abs_bucket, page_loc,
                 engine, weighting, max_postings, k, merge):
    """Per-shard query phase under shard_map -> merge-ready candidates.

    ``merge="gather"``: returns global candidate ids (Q, S*page_loc) and
    their exact cosine scores (one all-gather; padded/invalid candidates
    are ``-inf``).  ``merge="stream"``: candidate pages ring-rotate along
    the ``data`` axis and fold into a running top-``k`` in shard order on
    each group's coordinator, which then broadcasts -- returns the merged
    (Q, k) ids/scores directly.  On a ``(data, replica)`` mesh the query
    batch additionally splits along ``replica`` (Q/R rows per group) and
    reassembles in the out-spec.

    Appended docs live in generations: ``sealed`` is a tuple of
    ``(vectors, codes, gids, live, post_docs, post_codes)`` leaf-tuples --
    one per sealed :class:`Segment` -- and ``seg_*`` is the active append
    buffer (``None`` when empty).  Every generation scores by direct
    per-column bucket equality (the identity every engine lowers to, which
    is what pins bit-parity with the flat path), but *df* comes from each
    sealed segment's mini posting table (``df_lookup``, integer-exact and
    equal to the dense count) while the active buffer still uses
    ``code_df``.  Candidate order is base, then generations oldest-first,
    then the active buffer -- per shard that is exactly append order, the
    same tie-break order as the flat buffer, so ``top_k`` stability makes
    the candidate pages match the pre-generational program bit for bit.

    Takes leaves, not the index pytree, and the id-space size ``n_ids`` as
    a TRACED scalar: repeated ingest batches that stay within the segment
    capacity then hit this jit's cache (same shapes, same treedef) instead
    of recompiling the SPMD program per ``add_documents``; seals and
    merges change the treedef and recompile O(maintenance events) times.

    The ``fused``/``fused_int8`` engines replace the dense-scores +
    ``top_k`` pair with the fused kernel's streamed selection over the
    BASE (top ``min(page_loc, dp)`` of the base always covers every base
    candidate the composed top-k could pick), then one top-k over [base
    page | generation scores] in the same concat-index space -- identical
    candidates, same downstream gather/rescore.  ``fused_int8`` scores
    every generation off the per-row int8 tables (``*_quant`` args,
    ``None``/empty for other engines) and reads no tokens, so the idf
    psum is skipped entirely.
    """
    from .shmap import shard_map

    dp = vectors.shape[1]
    G = 0 if seg_vectors is None else seg_vectors.shape[1]
    n_shards = vectors.shape[0]
    n_sealed = len(sealed)
    widths = tuple(t[0].shape[1] for t in sealed)
    quant = engine == "fused_int8"

    def local(*args):
        vec, codes, pdocs, pcodes, off, lv = args[:6]
        rest = args[6:]
        if G:
            svec, scod, sgid, sliv = (x[0] for x in rest[:4])
            rest = rest[4:]
        segs = [tuple(x[0] for x in rest[i * 6:(i + 1) * 6])
                for i in range(n_sealed)]
        rest = rest[n_sealed * 6:]
        if quant:
            bq8, bsc, bzp = (x[0] for x in rest[:3])
            rest = rest[3:]
            if G:
                aq8, asc, azp = (x[0] for x in rest[:3])
                rest = rest[3:]
            seg_quants = [tuple(x[0] for x in rest[i * 3:(i + 1) * 3])
                          for i in range(n_sealed)]
            rest = rest[n_sealed * 3:]
        q, qcodes, mask, n_ids = rest
        vec, codes, lv = vec[0], codes[0], lv[0]
        postings = Postings(pdocs[0], pcodes[0], dp)
        off = off[0]

        if quant:
            w = None    # token-free engine: no df psum, no idf weights
        elif weighting == "idf":
            df = df_lookup(postings, qcodes)
            for i, (_, _, _, _, spd, spc) in enumerate(segs):
                # sealed generations answer df off their mini posting
                # lists: integer-equal to the dense code_df count, O(log G)
                df = df + df_lookup(Postings(spd, spc, widths[i]), qcodes)
            if G:
                df = df + code_df(scod, qcodes)
            df = jax.lax.psum(df, DATA_AXIS)        # global df, integer-exact
            w = idf_weights(df, n_ids)
        elif weighting == "count":
            w = jnp.ones(qcodes.shape, jnp.float32)
        else:
            raise ValueError(f"unknown weighting {weighting!r}")
        if w is not None:
            w = jnp.where(mask, w, 0.0)

        def seg_scores(sc, sl):
            # generation phase 1: direct bucket-equality match (the
            # identity every engine lowers); sentinel slots never match
            # but mask them anyway -- liveness must not hinge on codes
            eq = (qcodes[:, None, :] == sc[None, :, :]).astype(jnp.int8)
            s_seg = jnp.einsum("qgc,qc->qg", eq, w,
                               preferred_element_type=jnp.float32)
            return jnp.where(sl[None, :], s_seg, -jnp.inf)

        def seg_scores_fused(sc, sl):
            # the fused branch scores generations with the SAME ordered
            # column fold the kernel uses for the base (ref.match_scores),
            # so every doc's phase-1 bits are identical across the seg and
            # flat layouts -- the einsum form above reduces in a
            # shape-dependent order and would wobble the last ulp
            from repro.kernels.fused_phase1.ref import match_scores

            return jnp.where(sl[None, :], match_scores(sc, qcodes, w),
                             -jnp.inf)

        def seg_scores_quant(t, sl):
            # generation phase 1 under fused_int8: the same per-row
            # affine-int8 score the base kernel computes -- quantization
            # is row-wise, so a row scores identically in a sealed
            # generation and in the flat buffer (the parity pin)
            s8, ssc, szp = t
            raw = jnp.einsum("qn,gn->qg", q, s8.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            s_seg = raw * ssc[None, :] + qsum * szp[None, :]
            return jnp.where(sl[None, :], s_seg, -jnp.inf)

        if engine in FUSED_ENGINES:
            # fused selection: the kernel streams the base and returns its
            # top min(page_loc, dp) directly -- a superset of every base
            # candidate the composed top-k could select -- then ONE top-k
            # merges it with the (small) generation scores in the same
            # concat-index space [base | sealed... | active] the composed
            # path uses.  Stable top-k order matches the composed concat
            # (base entries keep ascending-id tie order and precede
            # generation entries), so `cand` is identical wherever scores
            # are finite; -inf slots differ only in unspecified ids, which
            # the live mask turns into (id=-1, -inf) either way.
            from repro.kernels.fused_phase1 import ops as fp_ops

            p_base = min(page_loc, dp)
            if quant:
                qsum = jnp.sum(q, axis=-1, keepdims=True)
                s_b, ids_b = fp_ops.fused_phase1_quant(
                    bq8, bsc, bzp, q, page=p_base, live=lv)
            else:
                s_b, ids_b = fp_ops.fused_phase1(
                    codes, qcodes, w, page=p_base, live=lv)
            parts_s, parts_i = [s_b], [ids_b]
            gen_off = dp
            gen_sc = ([seg_scores_quant(seg_quants[i], segs[i][3])
                       for i in range(n_sealed)] if quant else
                      [seg_scores_fused(segs[i][1], segs[i][3])
                       for i in range(n_sealed)])
            for i, sc_i in enumerate(gen_sc):
                parts_s.append(sc_i)
                parts_i.append(gen_off + jax.lax.broadcasted_iota(
                    jnp.int32, sc_i.shape, 1))
                gen_off += widths[i]
            if G:
                sc_a = (seg_scores_quant((aq8, asc, azp), sliv) if quant
                        else seg_scores_fused(scod, sliv))
                parts_s.append(sc_a)
                parts_i.append(gen_off + jax.lax.broadcasted_iota(
                    jnp.int32, sc_a.shape, 1))
            if len(parts_s) == 1:
                cand = ids_b                        # p_base == page_loc
            else:
                cat_s = jnp.concatenate(parts_s, axis=1)
                cat_i = jnp.concatenate(parts_i, axis=1)
                _, pos = jax.lax.top_k(cat_s, page_loc)
                cand = jnp.take_along_axis(cat_i, pos, axis=1)
        else:
            s1 = phase1_engine_scores(codes, postings, qcodes, w, engine,
                                      max_postings, max_abs_bucket)
            s1 = jnp.where(lv[None, :], s1, -jnp.inf)  # pads/tombstones out
            parts = [s1]
            parts += [seg_scores(sc_, sl_) for _, sc_, _, sl_, _, _ in segs]
            if G:
                parts.append(seg_scores(scod, sliv))
            s1 = (parts[0] if len(parts) == 1
                  else jnp.concatenate(parts, axis=1))
            _, cand = jax.lax.top_k(s1, page_loc)   # (Q, page_loc)

        if segs or G:
            vparts = [vec] + [t[0] for t in segs]
            lparts = [lv] + [t[3] for t in segs]
            gparts = ([off + jnp.arange(dp, dtype=jnp.int32)]
                      + [t[2] for t in segs])
            if G:
                vparts.append(svec)
                lparts.append(sliv)
                gparts.append(sgid)
            vec_all = jnp.concatenate(vparts, axis=0)
            live_all = jnp.concatenate(lparts)
            gid_all = jnp.concatenate(gparts)
        else:
            vec_all, live_all = vec, lv
        cvec = vec_all[cand]                        # (Q, page_loc, n)
        s2 = jnp.einsum("qpn,qn->qp", cvec, q,
                        preferred_element_type=jnp.float32)
        s2 = jnp.where(live_all[cand], s2, -jnp.inf)
        gid = (gid_all[cand] if (segs or G)
               else (cand + off).astype(jnp.int32))
        if merge == "gather":
            return gid, s2
        return _stream_merge_local(gid, s2, n_shards, k)

    rep = REPLICA_AXIS in mesh.axis_names
    qaxis = REPLICA_AXIS if rep else None
    args = [vectors, codes, post_docs, post_codes, offsets, live]
    specs = [_ROW, _ROW, _ROW, _ROW, P(DATA_AXIS), _VEC]
    if G:
        args += [seg_vectors, seg_codes, seg_gids, seg_live]
        specs += [_ROW, _ROW, _VEC, _VEC]
    for sv_, sc_, sg_, sl_, spd_, spc_ in sealed:
        args += [sv_, sc_, sg_, sl_, spd_, spc_]
        specs += [_ROW, _ROW, _VEC, _VEC, _ROW, _ROW]
    if quant:
        args += list(base_quant)
        specs += [_ROW, _VEC, _VEC]
        if G:
            args += list(act_quant)
            specs += [_ROW, _VEC, _VEC]
        for t in sealed_quant:
            args += list(t)
            specs += [_ROW, _VEC, _VEC]
    args += [q, qcodes, mask, n_ids]
    specs += [P(qaxis, None)] * 3 + [P()]
    out = P(qaxis, DATA_AXIS) if merge == "gather" else P(qaxis, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=tuple(specs),
        out_specs=(out, out),
        check=False,
    )
    return fn(*args)


def _stream_merge_local(gid, s2, n_shards, k):
    """Ring-streamed coordinator merge (runs inside the shard_map body).

    Pages rotate shard -> shard-1 along ``data``; after step t the device
    at data index i holds the page of shard (i+t) % S, so the group
    coordinator (data index 0) folds pages in shard order 0..S-1 -- the
    same shard-major tie-break order as the flat all-gather, which is what
    keeps the two transports bit-identical.  Each fold is a (k+page)-wide
    stable top-k, so communication of the next page overlaps the fold of
    the current one and peak memory stays k+page per query instead of
    S*page.  The coordinator's result is broadcast with a masked psum
    (every other device contributes zeros).

    Pre-merge ``-inf`` placeholder rows surface only when fewer than ``k``
    live candidates exist across the S pages (possible after deletes);
    the merge select downstream reports those slots as (id=-1, -inf).
    """
    acc_s = jnp.full((s2.shape[0], k), -jnp.inf, s2.dtype)
    acc_i = jnp.zeros((gid.shape[0], k), gid.dtype)
    perm = [(j, (j - 1) % n_shards) for j in range(n_shards)]
    for t in range(n_shards):
        cat_s = jnp.concatenate([acc_s, s2], axis=1)
        cat_i = jnp.concatenate([acc_i, gid], axis=1)
        acc_s, pos = jax.lax.top_k(cat_s, k)
        acc_i = jnp.take_along_axis(cat_i, pos, axis=1)
        if t < n_shards - 1:
            s2 = jax.lax.ppermute(s2, DATA_AXIS, perm)
            gid = jax.lax.ppermute(gid, DATA_AXIS, perm)
    lead = jax.lax.axis_index(DATA_AXIS) == 0
    acc_i = jax.lax.psum(jnp.where(lead, acc_i, 0), DATA_AXIS)
    acc_s = jax.lax.psum(jnp.where(lead, acc_s, 0.0), DATA_AXIS)
    return acc_i, acc_s


@partial(jax.jit, static_argnames=("mesh", "sentinel"))
def _max_df_program(post_codes, *, mesh, sentinel):
    """Longest live posting list over every (shard, column) -> scalar.

    Per shard the posting codes are already sorted per column, so a run of
    equal values IS a posting list: segment-count the runs, read each
    position's run length back, mask the sentinel tail, and pmax across
    shards.  This is the exact ``max_postings`` window -- every legal
    posting range fits -- computed from the shard's real code
    distribution instead of the ``docs_per_shard`` worst case.
    """
    from .shmap import shard_map

    d = post_codes.shape[-1]

    def local(pc):
        x = pc[0]                                   # (C, d) sorted rows

        def run_max(row):
            change = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32),
                 (row[1:] != row[:-1]).astype(jnp.int32)])
            gid = jnp.cumsum(change)
            counts = jax.ops.segment_sum(
                jnp.ones((d,), jnp.int32), gid, num_segments=d)
            return jnp.max(jnp.where(row != sentinel, counts[gid], 0))

        return jax.lax.pmax(jnp.max(jax.vmap(run_max)(x)), DATA_AXIS)

    fn = shard_map(local, mesh=mesh, in_specs=(_ROW,), out_specs=P(),
                   check=False)
    return fn(post_codes)


@partial(jax.jit, static_argnames=("mesh",))
def _token_df_program(post_docs, post_codes, seg_codes, sealed, qcodes, *,
                      mesh):
    """Global per-token df, the query phase's idf input verbatim: per-shard
    postings range lookup (base + each sealed generation's mini posting
    table) plus the active buffer's code match, psum over ``data``.
    ``sealed`` is a tuple of (post_docs, post_codes) pairs.  Queries are
    replicated (df is identical in every replica group)."""
    from .shmap import shard_map

    dp = post_codes.shape[-1]
    G = seg_codes is not None
    n_sealed = len(sealed)
    widths = tuple(pc.shape[-1] for _, pc in sealed)

    def local(*args):
        pd, pc = args[0], args[1]
        rest = args[2:]
        if G:
            sc = rest[0][0]
            rest = rest[1:]
        seg_posts = [(rest[2 * i][0], rest[2 * i + 1][0])
                     for i in range(n_sealed)]
        qc = rest[2 * n_sealed]
        df = df_lookup(Postings(pd[0], pc[0], dp), qc)
        for i, (spd, spc) in enumerate(seg_posts):
            df = df + df_lookup(Postings(spd, spc, widths[i]), qc)
        if G:
            df = df + code_df(sc, qc)
        return jax.lax.psum(df, DATA_AXIS)

    args = [post_docs, post_codes] + ([seg_codes] if G else [])
    specs = [_ROW, _ROW] + ([_ROW] if G else [])
    for spd_, spc_ in sealed:
        args += [spd_, spc_]
        specs += [_ROW, _ROW]
    args += [qcodes]
    specs += [P(None, None)]
    fn = shard_map(local, mesh=mesh, in_specs=tuple(specs),
                   out_specs=P(None, None), check=False)
    return fn(*args)
