"""Doc-sharded two-phase vector search (the Elasticsearch scaling story).

:class:`ShardedVectorIndex` is :class:`repro.core.VectorIndex` split into
contiguous *doc-shards* along the mesh's ``data`` axis, one shard per
device.  A query runs the ES distributed query/fetch protocol:

1. **query phase** (per shard, under ``shard_map``): phase-1 scoring over
   the local codes/postings, local ``top_k(page)``, exact-cosine scoring of
   the local candidate page;
2. **merge phase**: candidates all-gather to every device (ids are
   globalised by the shard's doc-id offset) and a global ``top_k(k)`` over
   the exact cosines picks the final hits -- the coordinating node's reduce.

Because the merge ranks *exact* phase-2 cosines, ``page >= n_docs`` makes
the sharded search bit-identical to the single-device index: the same dot
products reach the same top-k.  Smaller pages change recall only through
per-shard candidate allocation (each shard contributes its own top
``page`` -- the same semantics as ES ``size`` fan-out).

IDF query weighting stays *global*: document frequencies are summed across
shards with a ``psum`` (integer-exact), so trimming/weighting decisions are
independent of the shard count.

Ragged corpora pad each shard to a common length; padded rows carry a
never-matching sentinel code, score ``-inf`` in both phases, and can never
enter the merged top-k.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.encoding import Encoder
from repro.core.filtering import BestFilter, TrimFilter, expand_mask, feature_mask
from repro.core.postings import Postings, build_postings, idf_weights, lookup
from repro.core.rerank import exact_scores, normalize
from repro.core.search import _SENTINEL, VectorIndex, phase1_engine_scores

from .sharding import DATA_AXIS

__all__ = ["ShardedVectorIndex"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedVectorIndex:
    """A :class:`VectorIndex` partitioned into per-device doc-shards.

    Array leaves carry an explicit leading shard dim (``n_shards`` first)
    and live sharded over the ``data`` mesh axis; each device holds one
    contiguous document range plus its local->global id ``offset``.
    """

    vectors: jnp.ndarray      # (S, dp, n) f32, unit rows; zero rows pad
    codes: jnp.ndarray        # (S, dp, C) int; sentinel rows pad
    post_docs: jnp.ndarray    # (S, C, dp) int32 per-shard posting order
    post_codes: jnp.ndarray   # (S, C, dp) sorted codes per shard
    offsets: jnp.ndarray      # (S,) int32 global id of each shard's doc 0
    counts: jnp.ndarray       # (S,) int32 real (unpadded) docs per shard
    encoder: Encoder
    mesh: Mesh
    n_docs: int               # global corpus size
    index_best: Optional[int]

    # -- pytree plumbing (mesh/encoder/sizes are static metadata) ----------
    def tree_flatten(self):
        children = (self.vectors, self.codes, self.post_docs,
                    self.post_codes, self.offsets, self.counts)
        return children, (self.encoder, self.mesh, self.n_docs, self.index_best)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # ------------------------------------------------------------ properties
    @property
    def n_shards(self) -> int:
        return self.vectors.shape[0]

    @property
    def docs_per_shard(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_features(self) -> int:
        return self.vectors.shape[2]

    # ----------------------------------------------------------------- build
    @classmethod
    def from_index(cls, index: VectorIndex, mesh: Mesh) -> "ShardedVectorIndex":
        """Partition an existing single-device index across ``mesh``'s
        ``data`` axis (contiguous ranges, ES-style doc-sharding)."""
        if DATA_AXIS not in mesh.axis_names:
            raise ValueError(f"mesh has no {DATA_AXIS!r} axis: {mesh.axis_names}")
        ns = int(mesh.shape[DATA_AXIS])
        n = index.n_docs
        if ns > n:
            raise ValueError(f"more shards ({ns}) than documents ({n})")
        dp = math.ceil(n / ns)
        pad = ns * dp - n

        vectors = np.asarray(index.vectors)
        codes = np.asarray(index.codes)
        sentinel = _SENTINEL[codes.dtype]
        vectors = np.concatenate(
            [vectors, np.zeros((pad, vectors.shape[1]), vectors.dtype)])
        codes = np.concatenate(
            [codes, np.full((pad, codes.shape[1]), sentinel, codes.dtype)])
        vectors = vectors.reshape(ns, dp, -1)
        codes = codes.reshape(ns, dp, -1)

        # per-shard inverted indexes: the sentinel sorts to the tail of every
        # posting list, so padded docs are invisible to range lookups
        post_docs, post_codes = [], []
        for s in range(ns):
            p = build_postings(jnp.asarray(codes[s]))
            post_docs.append(np.asarray(p.post_docs))
            post_codes.append(np.asarray(p.post_codes))

        offsets = (np.arange(ns) * dp).astype(np.int32)
        counts = np.clip(n - offsets, 0, dp).astype(np.int32)

        def put(x, spec):
            return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))

        row = P(DATA_AXIS, None, None)
        return cls(
            vectors=put(vectors, row),
            codes=put(codes, row),
            post_docs=put(np.stack(post_docs), row),
            post_codes=put(np.stack(post_codes), row),
            offsets=put(offsets, P(DATA_AXIS)),
            counts=put(counts, P(DATA_AXIS)),
            encoder=index.encoder,
            mesh=mesh,
            n_docs=n,
            index_best=index.index_best,
        )

    @classmethod
    def build(cls, vectors, mesh: Mesh, encoder=None, index_best=None):
        """Build + shard in one step (single-device build, then partition)."""
        kwargs = {} if encoder is None else {"encoder": encoder}
        return cls.from_index(
            VectorIndex.build(vectors, index_best=index_best, **kwargs), mesh)

    # ------------------------------------------------------------------ search
    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = None,
        best: Optional[BestFilter] = None,
        engine: str = "postings",
        weighting: str = "idf",
        max_postings: Optional[int] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Distributed two-phase search -> (ids (Q,k), cosine scores (Q,k)).

        Same contract as :meth:`VectorIndex.search`; bit-identical to it
        when ``page >= n_docs``.
        """
        queries = jnp.atleast_2d(queries)
        page = min(page, self.n_docs)
        k = min(k, page)
        page_loc = min(page, self.docs_per_shard)

        q = normalize(jnp.asarray(queries, jnp.float32))
        qcodes = self.encoder.encode(q)
        mask = expand_mask(feature_mask(q, trim=trim, best=best),
                           qcodes.shape[-1])

        L = self.docs_per_shard if max_postings is None \
            else min(max_postings, self.docs_per_shard)
        gids, scores = _query_phase(
            self, q, qcodes, mask, page_loc=page_loc, engine=engine,
            weighting=weighting, max_postings=L,
        )
        return _merge_phase(self.vectors, gids, scores, q, k=k)


@partial(jax.jit, static_argnames=("k",))
def _merge_phase(vectors, gids, scores, q, *, k):
    """Coordinating-node reduce: global top-k over the gathered exact
    cosines, then final scores recomputed at the (Q, k, n) shape shared
    with rerank_topk -- see exact_scores for why this gives bit-parity."""
    _, pos = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(gids, pos, axis=1)
    flat_vectors = vectors.reshape(-1, vectors.shape[-1])
    return top_ids, exact_scores(flat_vectors, top_ids, q)


@partial(jax.jit,
         static_argnames=("page_loc", "engine", "weighting", "max_postings"))
def _query_phase(sidx, q, qcodes, mask, *, page_loc, engine, weighting,
                 max_postings):
    """Per-shard query phase under shard_map -> gathered candidates.

    Returns global candidate ids (Q, S*page_loc) and their exact cosine
    scores; padded/invalid candidates are ``-inf``.
    """
    from .shmap import shard_map

    mesh = sidx.mesh
    dp = sidx.docs_per_shard
    enc = sidx.encoder
    n_docs = sidx.n_docs

    def local(vec, codes, pdocs, pcodes, off, cnt, q, qcodes, mask):
        vec, codes = vec[0], codes[0]
        postings = Postings(pdocs[0], pcodes[0], dp)
        off, cnt = off[0], cnt[0]

        if weighting == "idf":
            lo, hi = jax.vmap(lambda c: lookup(postings, c))(qcodes)
            df = jax.lax.psum(hi - lo, DATA_AXIS)   # global df, integer-exact
            w = idf_weights(df, n_docs)
        elif weighting == "count":
            w = jnp.ones(qcodes.shape, jnp.float32)
        else:
            raise ValueError(f"unknown weighting {weighting!r}")
        w = jnp.where(mask, w, 0.0)

        s1 = phase1_engine_scores(codes, postings, qcodes, w, engine,
                                  max_postings, enc.max_abs_bucket)

        valid = jnp.arange(dp) < cnt                       # pads at the tail
        s1 = jnp.where(valid[None, :], s1, -jnp.inf)
        _, cand = jax.lax.top_k(s1, page_loc)              # (Q, page_loc)

        cvec = vec[cand]                                   # (Q, page_loc, n)
        s2 = jnp.einsum("qpn,qn->qp", cvec, q,
                        preferred_element_type=jnp.float32)
        s2 = jnp.where(cand < cnt, s2, -jnp.inf)
        gid = (cand + off).astype(jnp.int32)
        return gid, s2

    row = P(DATA_AXIS, None, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(row, row, row, row, P(DATA_AXIS), P(DATA_AXIS),
                  P(None, None), P(None, None), P(None, None)),
        out_specs=(P(None, DATA_AXIS), P(None, DATA_AXIS)),
        check=False,
    )
    return fn(sidx.vectors, sidx.codes, sidx.post_docs, sidx.post_codes,
              sidx.offsets, sidx.counts, q, qcodes, mask)
