"""Mesh-scoped activation annotations.

``use_mesh(mesh)`` installs a mesh for the enclosing scope; ``constrain``
then maps *logical* axis names ("batch", "model", "vocab", ...) onto the
installed mesh's axes via ``with_sharding_constraint``.  Outside any mesh
scope every call is the identity, so model code is annotation-transparent:
the same forward function runs on 1 CPU device and on a 2x16x16 pod.

Logical names resolve as

* ``"batch"``  -> every data-parallel axis present (``("pod", "data")``)
* ``"vocab"``  -> the tensor-parallel axis (an alias of ``"model"``: the
  unembed projection shards its output over the same axis as the heads)
* anything else -> the mesh axis of that name, if present

and any dimension whose size does not divide the resolved axis product is
dropped to ``None`` (replicated) rather than erroring -- the rule that lets
one annotation serve every architecture/mesh pairing.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["use_mesh", "current_mesh", "constrain"]

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    """The innermost mesh installed by :func:`use_mesh`, or None."""
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install ``mesh`` as the ambient mesh for :func:`constrain`."""
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def _resolve(name, mesh) -> Optional[tuple]:
    if name is None:
        return None
    if name == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    elif name == "vocab":
        axes = ("model",) if "model" in mesh.axis_names else ()
    else:
        axes = (name,) if name in mesh.axis_names else ()
    return axes or None


def constrain(x, *axis_names):
    """``with_sharding_constraint(x, P(*axis_names))`` against the ambient
    mesh; identity when no mesh is installed.  Indivisible dims drop to
    replicated, so the constraint can never be unsatisfiable."""
    mesh = current_mesh()
    if mesh is None:
        return x
    parts = []
    for dim, name in enumerate(axis_names):
        axes = _resolve(name, mesh)
        if axes is not None:
            n = int(np.prod([mesh.shape[a] for a in axes]))
            if n <= 1 or x.shape[dim] % n != 0:
                axes = None
        parts.append(None if axes is None
                     else (axes[0] if len(axes) == 1 else axes))
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))
