"""Distribution subsystem: mesh annotations, sharding rules, doc-sharded search.

The paper's production story is an Elasticsearch cluster: one logical index
split into *doc-shards*, each shard scored independently, per-shard top
candidates merged by the coordinating node.  This package is that story
re-expressed over a JAX device mesh -- every piece maps onto an ES concept:

===========================  ====================================================
this package                 Elasticsearch analogue
===========================  ====================================================
:mod:`~repro.dist.annotate`  node roles / routing awareness -- ``use_mesh``
                             installs the cluster topology; ``constrain`` pins an
                             activation to a shard layout the way ES routing
                             pins a document to a shard (and silently no-ops on
                             a single node, so all code runs on 1 CPU device).
:mod:`~repro.dist.sharding`  the index-settings layer (``number_of_shards``,
                             per-field routing): declarative *rules* mapping a
                             parameter tree onto mesh axes, replicating anything
                             that does not divide evenly -- the same way ES
                             refuses to split a shard below one Lucene segment.
:mod:`~repro.dist.shard_index`  the doc-shards themselves.
                             :class:`ShardedVectorIndex` partitions vectors,
                             codes and posting lists into contiguous document
                             ranges (one per ``data``-axis device), runs
                             phase-1 scoring + local ``top_k(page)`` per shard
                             under ``shard_map`` (the per-shard query phase),
                             and merges candidates globally by exact cosine
                             (the coordinating node's reduce) -- either one
                             blocking all-gather or a ring-streamed fold.
``replica`` mesh axis        replica shards: on a ``(data, replica)`` mesh the
                             index leaves replicate across ``replica`` and
                             query batches round-robin over the replica
                             groups -- R full serving copies, ~R x QPS, zero
                             quality change.
===========================  ====================================================

Global document ids are ``local_id + shard_offset``, mirroring how ES derives
a hit's identity from ``(shard, doc)``.  For ``page >= n_docs`` the sharded
search is bit-identical to single-device :meth:`VectorIndex.search` -- the
merge sees every document's exact cosine, so sharding is purely a throughput
axis, never a quality trade.
"""

from repro.dist.annotate import constrain, current_mesh, use_mesh
from repro.dist.sharding import (
    DATA_AXIS,
    MODEL_AXIS,
    REPLICA_AXIS,
    batch_axes,
    generic_param_spec,
    lm_param_spec,
    lm_param_spec_inference,
    opt_state_spec,
    tree_specs,
)

__all__ = [
    "constrain",
    "current_mesh",
    "use_mesh",
    "DATA_AXIS",
    "MODEL_AXIS",
    "REPLICA_AXIS",
    "batch_axes",
    "generic_param_spec",
    "lm_param_spec",
    "lm_param_spec_inference",
    "opt_state_spec",
    "tree_specs",
]
