"""The paper's contribution: semantic vector encoding + two-phase search."""

from .encoding import CombinedEncoder, IntervalEncoder, RoundingEncoder
from .filtering import BestFilter, TrimFilter
from .metrics import avg_diff, ndcg_k, precision_at_k
from .mlt import MLTIndex
from .rerank import brute_force_topk, normalize, rerank_topk
from .search import SearchParams, VectorIndex

__all__ = [
    "CombinedEncoder",
    "IntervalEncoder",
    "RoundingEncoder",
    "BestFilter",
    "TrimFilter",
    "MLTIndex",
    "VectorIndex",
    "SearchParams",
    "avg_diff",
    "ndcg_k",
    "precision_at_k",
    "brute_force_topk",
    "normalize",
    "rerank_topk",
]
