"""Per-row int8 scalar quantization of the dense vector table.

The paper's tunable speed/quality knob pushed down to the numeric level:
phase-1 candidate selection can run against an int8 copy of the (d, n)
vector table -- 4x fewer bytes streamed from HBM -- while the final page is
ALWAYS rescored against the exact fp32 vectors (the canonical (Q, k, n)
einsum in :mod:`repro.core.rerank` -- the last-ulp parity invariant is
untouched, so quantization can only change *which* candidates reach the
rescore, never the reported score of a hit).

Scheme: asymmetric per-row affine quantization.  For each row ``v``::

    zero  = (max(v) + min(v)) / 2
    scale = max(max(v) - min(v), eps) / 254
    q     = clip(round((v - zero) / scale), -127, 127)  int8

so the dequantized row is ``q * scale + zero`` with per-element error
``<= scale / 2`` (the row's extremes land exactly on +-127; no clipping in
exact arithmetic).  All-zero rows (shard padding) quantize to exactly
``q = 0, zero = 0``.

Because quantization is a pure per-row function of the row's bits, a row
quantizes to identical int8 codes wherever it lives -- single device, any
mesh shape, base table or sealed segment, before or after a crash-recovery
rebuild.  That is what lets the sharded/segmented paths derive quantized
tables lazily per leaf (nothing is persisted) while keeping seg-vs-flat
bit-parity.

The phase-1 score against dequantized rows never materializes them::

    q . (a * scale + zero) = scale * (q . a) + zero * sum(q)

one int8-read matmul plus a rank-1 correction (:func:`quantized_scores`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "QMAX",
    "QuantizedTable",
    "quantize_rows",
    "dequantize_rows",
    "quantize_table",
    "quantized_scores",
]

QMAX = 127          # symmetric int8 code range [-127, 127]
_EPS = 1e-8         # degenerate (constant) rows get this range


def quantize_rows(v: jnp.ndarray, eps: float = _EPS):
    """Quantize ``(..., n)`` f32 rows -> (codes int8, scale, zero).

    ``scale``/``zero`` have shape ``(...,)`` (one pair per row).  Row-wise
    and deterministic: quantizing any sub-batch of rows yields the same
    bits as quantizing them inside a larger table (pinned by tests).
    """
    v = jnp.asarray(v, jnp.float32)
    lo = jnp.min(v, axis=-1, keepdims=True)
    hi = jnp.max(v, axis=-1, keepdims=True)
    zero = (hi + lo) * 0.5
    scale = jnp.maximum(hi - lo, eps) / (2.0 * QMAX)
    q = jnp.clip(jnp.round((v - zero) / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale[..., 0], zero[..., 0]


def dequantize_rows(codes: jnp.ndarray, scale: jnp.ndarray,
                    zero: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct f32 rows; per-element error ``<= scale / 2`` per row."""
    return (codes.astype(jnp.float32) * scale[..., None]
            + zero[..., None])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTable:
    """int8 copy of a (d, n) vector table + per-row affine params."""

    codes: jnp.ndarray    # (d, n) int8
    scale: jnp.ndarray    # (d,) f32
    zero: jnp.ndarray     # (d,) f32

    def tree_flatten(self):
        return (self.codes, self.scale, self.zero), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def nbytes_codes(self) -> int:
        return self.codes.size  # int8: one byte per element


def quantize_table(vectors: jnp.ndarray) -> QuantizedTable:
    return QuantizedTable(*quantize_rows(vectors))


def quantized_scores(
    codes: jnp.ndarray,      # (d, n) int8
    scale: jnp.ndarray,      # (d,) f32
    zero: jnp.ndarray,       # (d,) f32
    queries: jnp.ndarray,    # (Q, n) f32
    qsum: jnp.ndarray = None,  # (Q, 1) precomputed sum(queries, -1)
) -> jnp.ndarray:
    """(Q, d) phase-1 scores against the dequantized rows, computed as
    ``scale * (codes . query) + zero * sum(query)`` -- the dequantized
    table is never materialized.  The composed jnp reference for the
    ``fused_int8`` engine (kernels/fused_phase1/ref.py wraps this)."""
    if qsum is None:
        qsum = jnp.sum(queries, axis=-1, keepdims=True)
    raw = jnp.einsum("qn,dn->qd", queries, codes.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return raw * scale[None, :] + qsum * zero[None, :]
