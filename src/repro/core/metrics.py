"""Evaluation metrics from paper §3.1: Precision@k, nDCG_k, avg. diff."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["precision_at_k", "ndcg_k", "avg_diff"]


def precision_at_k(retrieved: jnp.ndarray, gold: jnp.ndarray) -> jnp.ndarray:
    """Fraction of gold ids present in retrieved ids (both (Q, k))."""
    hit = (retrieved[:, :, None] == gold[:, None, :]).any(-1)  # (Q, k)
    return hit.mean(-1)


def ndcg_k(retrieved_sims: jnp.ndarray, gold_sims: jnp.ndarray) -> jnp.ndarray:
    """nDCG_k with graded relevance = cosine similarity to the query.

    ``retrieved_sims``: (Q, k) cosine of the retrieved docs, in rank order.
    ``gold_sims``: (Q, k) cosine of the ideal (gold) docs, in rank order.
    """
    k = retrieved_sims.shape[-1]
    discounts = 1.0 / jnp.log2(jnp.arange(2, k + 2).astype(jnp.float32))
    dcg = (retrieved_sims * discounts).sum(-1)
    idcg = (gold_sims * discounts).sum(-1)
    return dcg / jnp.maximum(idcg, 1e-12)


def avg_diff(retrieved_sims: jnp.ndarray, gold_sims: jnp.ndarray) -> jnp.ndarray:
    """Mean loss between ideal and actual cosine similarities of the top k."""
    return (gold_sims - retrieved_sims).mean(-1)
