"""Phase 2: exact cosine re-ranking of phase-1 candidates (paper §2.2).

All vectors are unit-normalised at index build, so cosine == dot.  Because of
re-ranking, phase-1 *rank positions* are irrelevant -- only membership of the
gold documents in the candidate page matters (paper §3.1 note); the tests pin
this exactness property (``page >= n_docs`` => identical to brute force).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["normalize", "exact_scores", "rerank_topk", "brute_force_topk"]


def normalize(x: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def exact_scores(vectors: jnp.ndarray, ids: jnp.ndarray,
                 queries: jnp.ndarray) -> jnp.ndarray:
    """Exact cosines of the selected ids, (Q, k) from a (Q, k, n) einsum.

    Final reported scores always come from THIS shape, regardless of how the
    candidates were scored during selection -- the einsum's reduction
    blocking depends on the candidate-page shape, so recomputing at the
    fixed (Q, k, n) shape is what keeps single-device and doc-sharded
    search bit-identical (dist/shard_index.py merges through it too).
    """
    return jnp.einsum("qkn,qn->qk", vectors[ids], queries,
                      preferred_element_type=jnp.float32)


@partial(jax.jit, static_argnames=("k",))
def rerank_topk(
    vectors: jnp.ndarray,    # (d, n) unit-normalised index vectors
    cand_ids: jnp.ndarray,   # (Q, page) int32 phase-1 candidates
    queries: jnp.ndarray,    # (Q, n) unit-normalised queries
    k: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact cosine top-k among the candidates -> (ids (Q,k), scores (Q,k))."""
    cand = vectors[cand_ids]                            # (Q, page, n)
    scores = jnp.einsum(
        "qpn,qn->qp", cand, queries, preferred_element_type=jnp.float32
    )
    _, top_pos = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(cand_ids, top_pos, axis=1)
    return top_ids, exact_scores(vectors, top_ids, queries)


@partial(jax.jit, static_argnames=("k", "block"))
def brute_force_topk(
    vectors: jnp.ndarray,   # (d, n)
    queries: jnp.ndarray,   # (Q, n)
    k: int,
    block: int = 8192,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The paper's naive baseline: one linear scan, O(nd) (gold standard).

    ``k`` clamps to the corpus size (same contract as ``VectorIndex.search``):
    without it, ``k > d`` rows would pad the result with ``(id 0, -inf)``
    junk that silently poisons any recall computed against it."""
    d, n = vectors.shape
    k = min(k, d)
    Q = queries.shape[0]
    pad = (-d) % block
    padded = jnp.pad(vectors, ((0, pad), (0, 0)))
    nb = padded.shape[0] // block
    blocks = padded.reshape(nb, block, n)

    def body(carry, inp):
        best_s, best_i = carry
        blk, base = inp
        s = queries @ blk.T                              # (Q, block)
        ids = base + jnp.arange(block, dtype=jnp.int32)
        valid = ids < d
        s = jnp.where(valid[None, :], s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, (Q, block))], axis=1)
        ts, tp = jax.lax.top_k(cat_s, k)
        ti = jnp.take_along_axis(cat_i, tp, axis=1)
        return (ts, ti), None

    init = (
        jnp.full((Q, k), -jnp.inf, jnp.float32),
        jnp.zeros((Q, k), jnp.int32),
    )
    bases = (jnp.arange(nb) * block).astype(jnp.int32)
    (best_s, best_i), _ = jax.lax.scan(body, init, (blocks, bases))
    return best_i, best_s
