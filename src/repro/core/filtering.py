"""High-pass filters (paper §2.2.2): *trim* and *best*.

Both produce a boolean *feature mask* over the **original** feature axis of a
vector; :func:`expand_mask` tiles it to the code-column axis of an encoder
(identity for single encoders, 2x tile for :class:`CombinedEncoder`).

The paper applies filters to the *query* (always legal, choosable per request
-- its §5 "pleasant practical consequence") and optionally to the *index*
(``best`` at index time).  Both paths are supported by
:class:`repro.core.search.VectorIndex`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp

__all__ = ["TrimFilter", "BestFilter", "Filter", "feature_mask", "expand_mask",
           "index_best_codes"]


@dataclasses.dataclass(frozen=True)
class TrimFilter:
    """Keep features with ``|x_j| >= threshold`` (paper: 0.05 / 0.10 / 0.20)."""

    threshold: float = 0.05

    def mask(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.abs(x) >= self.threshold


@dataclasses.dataclass(frozen=True)
class BestFilter:
    """Keep only the ``m`` features with the largest ``|x_j|``."""

    m: int = 90

    def mask(self, x: jnp.ndarray) -> jnp.ndarray:
        n = x.shape[-1]
        if self.m >= n:
            return jnp.ones(x.shape, dtype=bool)
        a = jnp.abs(x)
        # threshold = m-th largest magnitude; ties broken by index via top_k's
        # deterministic ordering on the magnitude values.
        kth = jnp.sort(a, axis=-1)[..., n - self.m]
        keep = a >= kth[..., None]
        # in case of ties producing > m survivors, drop the lowest-index extras
        # deterministically so |mask| == m exactly.
        order = jnp.argsort(jnp.argsort(-a, axis=-1, stable=True), axis=-1)
        return keep & (order < self.m)


Filter = Union[TrimFilter, BestFilter]


def feature_mask(
    x: jnp.ndarray,
    trim: Optional[TrimFilter] = None,
    best: Optional[BestFilter] = None,
) -> jnp.ndarray:
    """Combined boolean mask on the feature axis (AND of the active filters)."""
    m = jnp.ones(x.shape, dtype=bool)
    if trim is not None:
        m = m & trim.mask(x)
    if best is not None:
        m = m & best.mask(x)
    return m


def index_best_codes(
    vectors: jnp.ndarray, codes: jnp.ndarray, m: int, sentinel: int
) -> jnp.ndarray:
    """Index-side *best* filter: code columns of non-best features take the
    never-matching ``sentinel`` code, dropping them from every posting list.

    The single implementation shared by ``VectorIndex.build`` and the
    on-device sharded build (:mod:`repro.dist.shard_index`): both paths must
    produce bit-identical codes, so the masking lives here, once.  Pure
    row-wise jnp -- safe under ``jit``/``shard_map``.
    """
    mask = expand_mask(feature_mask(vectors, best=BestFilter(m)), codes.shape[-1])
    return jnp.where(mask, codes, jnp.asarray(sentinel, codes.dtype))


def expand_mask(mask: jnp.ndarray, n_columns: int) -> jnp.ndarray:
    """Tile a feature mask to an encoder's code-column axis."""
    n = mask.shape[-1]
    if n_columns == n:
        return mask
    if n_columns % n != 0:
        raise ValueError(f"n_columns={n_columns} not a multiple of n={n}")
    reps = n_columns // n
    return jnp.concatenate([mask] * reps, axis=-1)
