"""Public two-phase search API (paper §2.2): VectorIndex.

    idx = VectorIndex.build(vectors, encoder=RoundingEncoder(2))
    ids, sims = idx.search(queries, k=10, page=320, trim=TrimFilter(0.05))

Phase 1 retrieves ``page`` candidates with one of the engines

* ``postings``   -- paper-faithful inverted index (:mod:`repro.core.postings`)
* ``codes``      -- TPU-native code-match streaming (:mod:`repro.core.codes`)
* ``onehot``     -- MXU matmul over the one-hot token vocabulary
* ``codes_pallas`` -- the code_match Pallas kernel (full score matrix)
* ``fused``      -- fused Pallas kernel: code-match scoring + running
  top-``page`` in one pass, no (Q, n_docs) score matrix
  (:mod:`repro.kernels.fused_phase1`)
* ``fused_int8`` -- the fused kernel over the int8 per-row quantized copy
  of the dense table (:mod:`repro.core.quantize`, derived lazily and
  cached per index instance) -- phase-1 selection only

and phase 2 re-ranks them by exact cosine (:mod:`repro.core.rerank`) --
for every engine, including the quantized one, so reported scores are
always exact fp32.
Filtering (trim/best) is query-side by default -- choosable per request, the
paper's §5 recommendation -- with optional index-side ``best`` at build time.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .codes import score_codes, score_onehot
from .encoding import Encoder, RoundingEncoder
from .filtering import (
    BestFilter,
    TrimFilter,
    expand_mask,
    feature_mask,
    index_best_codes,
)
from .postings import (
    Postings,
    build_postings,
    idf_weights,
    lookup,
    score_postings_batch,
)
from .quantize import QuantizedTable, quantize_table
from .rerank import brute_force_topk, normalize, rerank_topk

__all__ = ["VectorIndex", "SearchParams", "phase1_engine_scores",
           "FUSED_ENGINES"]

# engines that fuse phase-1 scoring with candidate selection: they return
# the candidate page directly instead of a dense (Q, d) score matrix, so
# they dispatch around phase1_engine_scores (in both VectorIndex.search
# and the per-shard query phase in repro.dist.shard_index)
FUSED_ENGINES = ("fused", "fused_int8")

_SENTINEL = {  # never-matching code per dtype (outside any bucket range)
    jnp.int8.dtype: 127,
    jnp.int16.dtype: 32767,
    jnp.int32.dtype: 2**31 - 1,
}


def phase1_engine_scores(
    codes: jnp.ndarray,            # (d, C) document codes
    postings: Postings,
    qcodes: jnp.ndarray,           # (Q, C)
    col_weights: jnp.ndarray,      # (Q, C), 0 where the token is filtered
    engine: str,
    max_postings: Optional[int],
    max_abs_bucket: int,
) -> jnp.ndarray:
    """Phase-1 scores (Q, d) under the chosen engine.

    The single engine-dispatch point: both the single-device
    :meth:`VectorIndex.phase1_scores` and the per-shard query phase in
    :mod:`repro.dist.shard_index` go through here, so a new engine is
    automatically available (and parity-testable) in both.
    """
    if engine == "postings":
        L = postings.n_docs if max_postings is None else max_postings
        return score_postings_batch(
            postings,
            qcodes,
            col_weights > 0,
            max_postings=L,
            weighting="count",   # weights already folded into col_weights
            col_weights=col_weights,
        )
    if engine == "codes":
        return score_codes(codes, qcodes, col_weights)
    if engine == "codes_pallas":
        from repro.kernels.code_match import ops as cm_ops

        return cm_ops.code_match(codes, qcodes, col_weights)
    if engine == "onehot":
        return score_onehot(codes, qcodes, col_weights, max_abs_bucket)
    raise ValueError(f"unknown engine {engine!r}")


@dataclasses.dataclass(frozen=True)
class SearchParams:
    k: int = 10
    page: int = 320
    trim: Optional[TrimFilter] = None
    best: Optional[BestFilter] = None
    engine: str = "postings"  # postings|codes|onehot|codes_pallas|fused|fused_int8
    weighting: str = "idf"         # idf | count
    max_postings: Optional[int] = None  # None -> exact (= n_docs)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VectorIndex:
    """Immutable two-phase search index over unit-normalised vectors."""

    vectors: jnp.ndarray           # (d, n) f32, unit rows
    codes: jnp.ndarray             # (d, C) int
    postings: Postings
    encoder: Encoder
    index_best: Optional[int]      # index-side 'best' filter used at build

    # -- pytree plumbing (lets the whole index cross jit/shard boundaries) --
    def tree_flatten(self):
        return (self.vectors, self.codes, self.postings), (self.encoder, self.index_best)

    @classmethod
    def tree_unflatten(cls, aux, children):
        vectors, codes, postings = children
        encoder, index_best = aux
        return cls(vectors, codes, postings, encoder, index_best)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        vectors: jnp.ndarray,
        encoder: Encoder = RoundingEncoder(2),
        index_best: Optional[int] = None,
    ) -> "VectorIndex":
        vectors = normalize(jnp.asarray(vectors, jnp.float32))
        codes = encoder.encode(vectors)
        if index_best is not None:
            codes = index_best_codes(
                vectors, codes, index_best, _SENTINEL[codes.dtype])
        postings = build_postings(codes)
        return cls(vectors, codes, postings, encoder, index_best)

    @property
    def n_docs(self) -> int:
        return self.vectors.shape[0]

    @property
    def n_features(self) -> int:
        return self.vectors.shape[1]

    @property
    def quantized(self) -> QuantizedTable:
        """int8 per-row quantized copy of ``vectors`` for ``fused_int8``
        phase-1 selection.  Derived lazily (a pure function of the vector
        bits -- never persisted; recovered indexes re-derive identical
        tables) and cached per instance: every mutation path returns a
        new index, so the cache can never go stale (the ``max_df``
        pattern in dist/shard_index)."""
        cached = self.__dict__.get("_quant_cache")
        if cached is None:
            cached = quantize_table(self.vectors)
            self.__dict__["_quant_cache"] = cached
        return cached

    # ---------------------------------------------------------- query encode
    def encode_queries(
        self,
        queries: jnp.ndarray,
        trim: Optional[TrimFilter],
        best: Optional[BestFilter],
        weighting: str,
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """-> (queries_normalised (Q,n), qcodes (Q,C), col_weights (Q,C))."""
        q = normalize(jnp.asarray(queries, jnp.float32))
        qcodes = self.encoder.encode(q)
        mask = expand_mask(feature_mask(q, trim=trim, best=best), qcodes.shape[-1])
        if weighting == "idf":
            lo, hi = jax.vmap(lambda qc: lookup(self.postings, qc))(qcodes)
            w = idf_weights(hi - lo, self.postings.n_docs)
        elif weighting == "count":
            w = jnp.ones(qcodes.shape, jnp.float32)
        else:
            raise ValueError(f"unknown weighting {weighting!r}")
        return q, qcodes, jnp.where(mask, w, 0.0)

    # ----------------------------------------------------------------- phase 1
    def phase1_scores(
        self,
        qcodes: jnp.ndarray,
        col_weights: jnp.ndarray,
        engine: str,
        max_postings: Optional[int],
    ) -> jnp.ndarray:
        return phase1_engine_scores(
            self.codes, self.postings, qcodes, col_weights, engine,
            max_postings, self.encoder.max_abs_bucket,
        )

    # ------------------------------------------------------------------ search
    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        page: int = 320,
        trim: Optional[TrimFilter] = None,
        best: Optional[BestFilter] = None,
        engine: str = "postings",
        weighting: str = "idf",
        max_postings: Optional[int] = None,
        profile=None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Two-phase search -> (ids (Q,k), cosine scores (Q,k)).

        The ``fused``/``fused_int8`` engines select the candidate page in
        one kernel pass (repro.kernels.fused_phase1) instead of
        materializing phase-1 scores; ``fused`` is bit-identical to
        ``codes`` selection, ``fused_int8`` trades candidate recall for
        4x fewer phase-1 bytes.  Phase 2 is the same exact-fp32 rerank
        for every engine.  ``fused_int8`` reads no tokens, so
        trim/best/weighting do not apply to it.

        ``profile`` is an optional :class:`repro.obs.profile.ProfileNode`
        that receives encode / phase1 / rescore children with host-side
        wall times (``jax.block_until_ready`` fences between phases; the
        fences change only *when* results are observed, never their
        values, so bit-parity pins hold with profiling on).
        """
        queries = jnp.atleast_2d(queries)
        page = min(page, self.n_docs)
        k = min(k, page)
        t_prof = time.monotonic() if profile is not None else 0.0
        if engine in FUSED_ENGINES:
            from repro.kernels.fused_phase1 import ops as fp_ops

            if engine == "fused":
                q, qcodes, w = self.encode_queries(
                    queries, trim, best, weighting)
                if profile is not None:
                    jax.block_until_ready((q, qcodes, w))
                    t_now = time.monotonic()
                    profile.child("encode", t_now - t_prof,
                                  n_queries=int(q.shape[0]))
                    t_prof = t_now
                _, cand = fp_ops.fused_phase1(self.codes, qcodes, w,
                                              page=page)
            else:
                q = normalize(jnp.asarray(queries, jnp.float32))
                if profile is not None:
                    jax.block_until_ready(q)
                    t_now = time.monotonic()
                    profile.child("encode", t_now - t_prof,
                                  n_queries=int(q.shape[0]))
                    t_prof = t_now
                qt = self.quantized
                _, cand = fp_ops.fused_phase1_quant(
                    qt.codes, qt.scale, qt.zero, q, page=page)
            if profile is not None:
                jax.block_until_ready(cand)
                t_now = time.monotonic()
                profile.child("phase1", t_now - t_prof, engine=engine,
                              kernel=engine, page=int(page), k=int(k),
                              candidates=int(cand.size))
                t_prof = t_now
            ids, scores = rerank_topk(self.vectors, cand, q, k)
            if profile is not None:
                jax.block_until_ready((ids, scores))
                profile.child("rescore", time.monotonic() - t_prof,
                              k=int(k))
            return ids, scores
        q, qcodes, w = self.encode_queries(queries, trim, best, weighting)
        if profile is not None:
            jax.block_until_ready((q, qcodes, w))
            t_now = time.monotonic()
            profile.child("encode", t_now - t_prof,
                          n_queries=int(q.shape[0]))
            t_prof = t_now
        scores1 = self.phase1_scores(qcodes, w, engine, max_postings)
        _, cand = jax.lax.top_k(scores1, page)                  # (Q, page)
        if profile is not None:
            jax.block_until_ready(cand)
            t_now = time.monotonic()
            profile.child("phase1", t_now - t_prof, engine=engine,
                          kernel="composed", page=int(page), k=int(k),
                          candidates=int(cand.size))
            t_prof = t_now
        ids, scores = rerank_topk(self.vectors, cand, q, k)
        if profile is not None:
            jax.block_until_ready((ids, scores))
            profile.child("rescore", time.monotonic() - t_prof, k=int(k))
        return ids, scores

    # ------------------------------------------------------------------- shard
    def shard(self, mesh) -> "ShardedVectorIndex":  # noqa: F821 (lazy import)
        """Partition this index into per-device doc-shards over ``mesh``'s
        ``data`` axis -> :class:`repro.dist.shard_index.ShardedVectorIndex`
        (same ``search`` contract; bit-identical for ``page >= n_docs``)."""
        from repro.dist.shard_index import ShardedVectorIndex

        return ShardedVectorIndex.from_index(self, mesh)

    def gold_topk(self, queries: jnp.ndarray, k: int = 10):
        """Paper's gold standard: brute-force cosine scan over all vectors.

        ``k`` clamps to ``n_docs``, matching :meth:`search`'s
        ``k = min(k, page) <= n_docs`` -- a corpus can't yield more hits
        than it has documents."""
        q = normalize(jnp.atleast_2d(jnp.asarray(queries, jnp.float32)))
        return brute_force_topk(self.vectors, q, min(k, self.n_docs))
