"""Vector -> integer-code encoders (paper §2.2.1).

The paper encodes each feature value of a dense semantic vector into a string
"feature token".  A token is fully determined by the pair

    (column j, bucket b)

where ``b`` is an integer quantization of the feature value.  All engines in
this package operate on the integer *code matrix* directly; the exact
paper-format strings are only materialized by :mod:`repro.core.tokens` (for
interop with a real fulltext engine and for the paper-example tests).

Three encoders are provided, mirroring the paper:

* :class:`RoundingEncoder`  -- ``P<p>``: round to ``p`` decimals.
* :class:`IntervalEncoder`  -- ``I<1/w>``: floor-quantize into width-``w`` bins.
* :class:`CombinedEncoder`  -- union of both token sets (codes concatenated
  along the column axis; columns ``[0, n)`` are the rounding part and columns
  ``[n, 2n)`` the interval part).

Every encoder maps ``x : (..., n) float`` -> ``codes : (..., n_columns) int``,
with ``n_columns == n`` (single) or ``2n`` (combined).  Codes use the smallest
signed integer dtype that can represent the encoder's bucket range for
unit-normalised inputs (|x| <= 1), which is what makes the TPU ``codes``
engine byte-efficient (int8 for the paper's default settings).
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax.numpy as jnp
import numpy as np

__all__ = [
    "RoundingEncoder",
    "IntervalEncoder",
    "CombinedEncoder",
    "Encoder",
    "smallest_int_dtype",
]


def smallest_int_dtype(max_abs: int) -> np.dtype:
    """Smallest signed integer dtype holding values in [-max_abs, max_abs]."""
    if max_abs <= 127:
        return np.dtype(np.int8)
    if max_abs <= 32767:
        return np.dtype(np.int16)
    return np.dtype(np.int32)


@dataclasses.dataclass(frozen=True)
class RoundingEncoder:
    """Paper's *rounding* scheme ``P<precision>``.

    ``bucket = round(x * 10**precision)`` -- e.g. precision=2 maps 0.12 -> 12,
    -0.13 -> -13, 0.065 -> 7 (ties-to-even is NOT used; the paper rounds
    half-away-from-zero as ordinary decimal rounding does).
    """

    precision: int = 2

    @property
    def scale(self) -> int:
        return 10 ** self.precision

    @property
    def scheme_id(self) -> str:
        return f"P{self.precision}"

    @property
    def max_abs_bucket(self) -> int:
        # unit-normalised features are in [-1, 1]
        return self.scale

    @property
    def code_dtype(self) -> np.dtype:
        return smallest_int_dtype(self.max_abs_bucket)

    def n_columns(self, n_features: int) -> int:
        return n_features

    def encode(self, x: jnp.ndarray) -> jnp.ndarray:
        scaled = x * self.scale
        # round half away from zero (decimal-style), not jnp.round's
        # ties-to-even: floor(|v| + 0.5) * sign(v).
        b = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
        return b.astype(self.code_dtype)

    def column_feature(self, n_features: int) -> np.ndarray:
        """Original feature index of every code column."""
        return np.arange(n_features)

    def decode_center(self, codes: jnp.ndarray) -> jnp.ndarray:
        """Representative value of a bucket (for reconstruction tests)."""
        return codes.astype(jnp.float32) / self.scale


@dataclasses.dataclass(frozen=True)
class IntervalEncoder:
    """Paper's *interval* scheme ``I<round(1/width)>``.

    ``bucket = floor(x / width)`` -- e.g. width=0.1 maps 0.12 -> 1 (interval
    starting at 0.1), -0.13 -> -2 (interval starting at -0.2), 0.065 -> 0.
    """

    width: float = 0.1

    @property
    def scheme_id(self) -> str:
        return f"I{round(1.0 / self.width)}"

    @property
    def max_abs_bucket(self) -> int:
        return int(np.ceil(1.0 / self.width)) + 1

    @property
    def code_dtype(self) -> np.dtype:
        return smallest_int_dtype(self.max_abs_bucket)

    def n_columns(self, n_features: int) -> int:
        return n_features

    def encode(self, x: jnp.ndarray) -> jnp.ndarray:
        b = jnp.floor(x / self.width)
        return b.astype(self.code_dtype)

    def column_feature(self, n_features: int) -> np.ndarray:
        return np.arange(n_features)

    def decode_center(self, codes: jnp.ndarray) -> jnp.ndarray:
        return (codes.astype(jnp.float32) + 0.5) * self.width


@dataclasses.dataclass(frozen=True)
class CombinedEncoder:
    """Paper's *combined* scheme: rounding and interval tokens together."""

    rounding: RoundingEncoder = RoundingEncoder(3)
    interval: IntervalEncoder = IntervalEncoder(0.2)

    @property
    def scheme_id(self) -> str:
        return f"{self.rounding.scheme_id}+{self.interval.scheme_id}"

    @property
    def max_abs_bucket(self) -> int:
        return max(self.rounding.max_abs_bucket, self.interval.max_abs_bucket)

    @property
    def code_dtype(self) -> np.dtype:
        return smallest_int_dtype(self.max_abs_bucket)

    def n_columns(self, n_features: int) -> int:
        return 2 * n_features

    def encode(self, x: jnp.ndarray) -> jnp.ndarray:
        dt = self.code_dtype
        r = self.rounding.encode(x).astype(dt)
        i = self.interval.encode(x).astype(dt)
        return jnp.concatenate([r, i], axis=-1)

    def column_feature(self, n_features: int) -> np.ndarray:
        f = np.arange(n_features)
        return np.concatenate([f, f])

    def decode_center(self, codes: jnp.ndarray) -> jnp.ndarray:  # pragma: no cover
        raise NotImplementedError("combined codes have no single center")


Encoder = Union[RoundingEncoder, IntervalEncoder, CombinedEncoder]
