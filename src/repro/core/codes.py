"""TPU-native phase-1 engines over the integer code matrix (beyond-paper).

Identity this rests on (see DESIGN.md §2): a feature-token match is exactly a
per-column bucket equality, so the paper's inverted-index score is

    score(q, d) = sum_j  w[q, j] * [qcodes[q, j] == doc_codes[d, j]]

Two lowerings:

* ``codes``  -- stream the (d, C) int8/int16 code matrix block-by-block and
  compare against the (trimmed) query codes.  Regular memory access, no
  gathers; the Pallas kernel :mod:`repro.kernels.code_match` is the TPU fast
  path, this module's ``score_codes`` is the jnp reference/CPU path.
* ``onehot`` -- expand codes into a {0,1} int8 matrix over the
  (column x bucket) token vocabulary and lower phase 1 to an actual MXU
  matmul ``Q1 @ D1.T``.  This is literally the CSC/inverted-index identity:
  D1's columns ARE the posting lists.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["score_codes", "score_onehot", "onehot_expand"]


def _pad_rows(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    d = x.shape[0]
    pad = (-d) % multiple
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


@partial(jax.jit, static_argnames=("block",))
def score_codes(
    doc_codes: jnp.ndarray,   # (d, C) int
    qcodes: jnp.ndarray,      # (Q, C) int
    col_weights: jnp.ndarray,  # (Q, C) f32 -- 0 where the query token is filtered
    block: int = 2048,
) -> jnp.ndarray:
    """Masked quantized-Hamming scores (Q, d), blocked over documents."""
    d, C = doc_codes.shape
    padded = _pad_rows(doc_codes, block)
    nb = padded.shape[0] // block
    blocks = padded.reshape(nb, block, C)

    def body(_, blk):
        eq = (qcodes[:, None, :] == blk[None, :, :]).astype(jnp.int8)  # (Q, blk, C)
        s = jnp.einsum(
            "qbc,qc->qb", eq, col_weights, preferred_element_type=jnp.float32
        )
        return _, s

    _, out = jax.lax.scan(body, None, blocks)        # (nb, Q, block)
    out = jnp.moveaxis(out, 1, 0).reshape(qcodes.shape[0], nb * block)
    return out[:, :d]


def onehot_expand(codes: jnp.ndarray, max_abs_bucket: int) -> jnp.ndarray:
    """(d, C) int codes -> (d, C * B) int8 one-hot token matrix.

    B = 2 * max_abs_bucket + 1 buckets per column; out-of-range codes clip to
    the boundary buckets (unit-normalised vectors never hit the clip).
    """
    B = 2 * max_abs_bucket + 1
    idx = jnp.clip(codes.astype(jnp.int32) + max_abs_bucket, 0, B - 1)  # (d, C)
    oh = jax.nn.one_hot(idx, B, dtype=jnp.int8)                          # (d, C, B)
    return oh.reshape(codes.shape[0], -1)


@partial(jax.jit, static_argnames=("max_abs_bucket",))
def score_onehot(
    doc_codes: jnp.ndarray,    # (d, C) int
    qcodes: jnp.ndarray,       # (Q, C) int
    col_weights: jnp.ndarray,  # (Q, C) f32
    max_abs_bucket: int,
) -> jnp.ndarray:
    """Phase-1 scores as an MXU matmul over the one-hot token vocabulary."""
    B = 2 * max_abs_bucket + 1
    D1 = onehot_expand(doc_codes, max_abs_bucket)             # (d, C*B) int8
    Q1 = onehot_expand(qcodes, max_abs_bucket).astype(jnp.float32)
    Q1 = Q1.reshape(qcodes.shape[0], qcodes.shape[1], B) * col_weights[..., None]
    Q1 = Q1.reshape(qcodes.shape[0], -1)                      # (Q, C*B) f32
    return jax.lax.dot_general(
        Q1,
        D1,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
