"""Paper-faithful inverted index over feature tokens.

This is the literal Lucene/Elasticsearch retrieval algorithm (paper §2.3,
"high-pass filtering" complexity analysis) re-expressed with fixed shapes so
it jits:

* **build** -- for every code column the documents are sorted by bucket value;
  a "posting list" for token ``(column j, bucket b)`` is then the contiguous
  range of the sorted order whose codes equal ``b``.  Finding it is a binary
  search, ``O(log j)``, exactly the paper's term-dictionary lookup.
* **score** -- for every surviving query token we fetch its posting range and
  scatter-add the token weight into a dense score accumulator
  (``jax.ops.segment_sum`` = the hash-map accumulator of the paper), then
  take the top-``page`` candidates.

Shapes are static: per-column gathers read a fixed window of
``max_postings`` entries (masked beyond the true range).  ``max_postings >=
n_docs`` makes the engine exact; smaller values trade recall for speed the
same way a real engine's early-termination does.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Postings", "build_postings", "lookup", "idf_weights",
           "score_postings", "code_df", "df_lookup"]


class Postings(NamedTuple):
    """Inverted index: per column, doc ids sorted by their bucket code."""

    post_docs: jnp.ndarray   # (C, d) int32 -- doc ids, sorted by code per column
    post_codes: jnp.ndarray  # (C, d) intN  -- the sorted codes themselves
    n_docs: int


def build_postings(codes: jnp.ndarray) -> Postings:
    """codes: (d, C) -> Postings.  Pure JAX; runs under jit."""
    d, _ = codes.shape
    order = jnp.argsort(codes, axis=0, stable=True)          # (d, C)
    sorted_codes = jnp.take_along_axis(codes, order, axis=0)  # (d, C)
    return Postings(
        post_docs=order.T.astype(jnp.int32),
        post_codes=sorted_codes.T,
        n_docs=d,
    )


def _searchsorted_row(row: jnp.ndarray, value: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    lo = jnp.searchsorted(row, value, side="left")
    hi = jnp.searchsorted(row, value, side="right")
    return lo, hi


def lookup(postings: Postings, qcodes: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Binary-search every query token's posting range.

    qcodes: (C,) -> (lo, hi) each (C,).  ``hi - lo`` is the document frequency
    of the token (paper's ``l``).
    """
    lo, hi = jax.vmap(_searchsorted_row)(postings.post_codes, qcodes)
    return lo, hi


def code_df(codes: jnp.ndarray, qcodes: jnp.ndarray) -> jnp.ndarray:
    """Per-token document frequency against a raw ``(d, C)`` code matrix.

    The segment-side analogue of :func:`lookup`'s ``hi - lo``: append
    segments (incremental ingest, :mod:`repro.dist.shard_index`) carry no
    posting lists, so their df contribution is a direct per-column bucket
    equality count.  Sentinel-coded rows (empty slots, tombstones) can never
    equal a legal query code and contribute zero automatically.

    qcodes: (Q, C) -> (Q, C) int32 counts.
    """
    return jnp.sum(qcodes[:, None, :] == codes[None, :, :], axis=1,
                   dtype=jnp.int32)


def df_lookup(postings: Postings, qcodes: jnp.ndarray) -> jnp.ndarray:
    """Batched document frequencies straight off the posting lists.

    qcodes: (Q, C) -> (Q, C) int32; per token the count is ``hi - lo`` of
    :func:`lookup`'s range.  Integer-exact and therefore bit-identical to
    :func:`code_df` over the same code matrix (tombstones and padding carry
    the sentinel, which sorts past every legal range), but O(log d) per
    token instead of O(d) -- the df path sealed append segments switch to
    once they carry their own mini posting tables
    (:class:`repro.dist.shard_index.Segment`).
    """
    lo, hi = jax.vmap(lambda c: lookup(postings, c))(qcodes)
    return (hi - lo).astype(jnp.int32)


def idf_weights(df: jnp.ndarray, n_docs: int) -> jnp.ndarray:
    """Lucene-style idf:  ln(1 + (N - df + 0.5) / (df + 0.5))."""
    df = df.astype(jnp.float32)
    return jnp.log1p((n_docs - df + 0.5) / (df + 0.5))


@partial(jax.jit, static_argnames=("max_postings", "weighting"))
def score_postings(
    postings: Postings,
    qcodes: jnp.ndarray,       # (C,) query bucket codes
    col_mask: jnp.ndarray,     # (C,) bool -- surviving query tokens
    max_postings: int,
    weighting: str = "idf",    # "idf" | "count"
    col_weights: Optional[jnp.ndarray] = None,  # optional extra per-column weight
) -> jnp.ndarray:
    """Dense scores (d,) via posting-list traversal + scatter-add."""
    C, d = postings.post_codes.shape
    lo, hi = lookup(postings, qcodes)
    df = hi - lo
    if weighting == "idf":
        w = idf_weights(df, postings.n_docs)
    elif weighting == "count":
        w = jnp.ones((C,), jnp.float32)
    else:
        raise ValueError(f"unknown weighting {weighting!r}")
    if col_weights is not None:
        w = w * col_weights
    w = jnp.where(col_mask, w, 0.0)

    # fixed-size posting window per column (masked beyond the true range)
    pos = lo[:, None] + jnp.arange(max_postings)[None, :]          # (C, L)
    valid = pos < hi[:, None]
    pos = jnp.minimum(pos, d - 1)
    docs = jnp.take_along_axis(postings.post_docs, pos, axis=1)    # (C, L)
    contrib = jnp.where(valid, w[:, None], 0.0)                    # (C, L)
    scores = jax.ops.segment_sum(
        contrib.reshape(-1), docs.reshape(-1).astype(jnp.int32), num_segments=d
    )
    return scores


def score_postings_batch(
    postings: Postings,
    qcodes: jnp.ndarray,      # (Q, C)
    col_mask: jnp.ndarray,    # (Q, C)
    max_postings: int,
    weighting: str = "idf",
    col_weights: Optional[jnp.ndarray] = None,  # (Q, C) or None
) -> jnp.ndarray:
    """Batched scoring: (Q, d)."""
    fn = lambda qc, cm, cw: score_postings(
        postings, qc, cm, max_postings, weighting, cw
    )
    if col_weights is None:
        return jax.vmap(lambda qc, cm: fn(qc, cm, None))(qcodes, col_mask)
    return jax.vmap(fn)(qcodes, col_mask, col_weights)
