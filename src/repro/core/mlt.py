"""More-Like-This (MLT) baseline (paper §3.1 / Table 4).

The paper compares against Elasticsearch's native MLT query: the raw article
*text* is indexed, and a query document's ``max_query_terms`` highest-tf-idf
terms form a boolean OR query scored by the engine's default term weighting.
We reproduce that algorithm over bag-of-words corpora: an inverted index over
*terms* (not feature tokens), query-term selection by tf-idf, and
presence x idf x log-tf scoring.  Unlike the encoded-vector method there is
no phase-2 re-rank -- MLT's own top-k is the result, exactly as evaluated in
the paper.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["MLTIndex"]


class _TermPostings(NamedTuple):
    sorted_terms: jnp.ndarray  # (nnz,) int32 term ids, ascending
    sorted_docs: jnp.ndarray   # (nnz,) int32 doc ids
    sorted_tf: jnp.ndarray     # (nnz,) f32 term frequency in that doc
    idf: jnp.ndarray           # (vocab,) f32
    n_docs: int


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MLTIndex:
    """Term-space fulltext index with a More-Like-This query API."""

    postings: _TermPostings
    doc_terms: jnp.ndarray    # (d, T) int32 padded with -1
    doc_tf: jnp.ndarray       # (d, T) f32

    def tree_flatten(self):
        return (self.postings, self.doc_terms, self.doc_tf), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def build(cls, doc_terms: jnp.ndarray, doc_tf: jnp.ndarray, vocab_size: int) -> "MLTIndex":
        """doc_terms: (d, T) padded term ids (-1 = pad), doc_tf: (d, T) counts."""
        d, T = doc_terms.shape
        terms = doc_terms.reshape(-1).astype(jnp.int32)
        docs = jnp.repeat(jnp.arange(d, dtype=jnp.int32), T)
        tf = doc_tf.reshape(-1).astype(jnp.float32)
        # push pads to the end by mapping -1 -> vocab_size
        key = jnp.where(terms < 0, vocab_size, terms)
        order = jnp.argsort(key, stable=True)
        sorted_terms = key[order]
        sorted_docs = docs[order]
        sorted_tf = tf[order]
        df = jax.ops.segment_sum(
            (terms >= 0).astype(jnp.float32), jnp.maximum(key, 0), num_segments=vocab_size + 1
        )[:vocab_size]
        idf = jnp.log1p((d - df + 0.5) / (df + 0.5))
        return cls(_TermPostings(sorted_terms, sorted_docs, sorted_tf, idf, d),
                   doc_terms, doc_tf)

    # ------------------------------------------------------------------ query
    def more_like_this(
        self,
        query_terms: jnp.ndarray,   # (Q, T) padded term ids (-1 = pad)
        query_tf: jnp.ndarray,      # (Q, T)
        max_query_terms: int = 25,
        k: int = 10,
        max_postings: int = 4096,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (ids (Q, k), mlt scores (Q, k))."""
        return _mlt(self, query_terms, query_tf, max_query_terms, k, max_postings)


@partial(jax.jit, static_argnames=("max_query_terms", "k", "max_postings"))
def _mlt(index: MLTIndex, query_terms, query_tf, max_query_terms, k, max_postings):
    p = index.postings
    nnz = p.sorted_terms.shape[0]
    d = index.doc_terms.shape[0]  # static (shape-derived), jit-safe

    def one(qt, tf):
        valid = qt >= 0
        tid = jnp.maximum(qt, 0)
        # MLT term selection: top terms of the query doc by tf-idf
        tfidf = jnp.where(valid, (1.0 + jnp.log1p(tf)) * p.idf[tid], -jnp.inf)
        sel_w, sel_pos = jax.lax.top_k(tfidf, min(max_query_terms, qt.shape[0]))
        sel_terms = tid[sel_pos]
        sel_valid = jnp.isfinite(sel_w)

        lo = jnp.searchsorted(p.sorted_terms, sel_terms, side="left")
        hi = jnp.searchsorted(p.sorted_terms, sel_terms, side="right")
        pos = lo[:, None] + jnp.arange(max_postings)[None, :]
        in_range = (pos < hi[:, None]) & sel_valid[:, None]
        pos = jnp.minimum(pos, nnz - 1)
        docs = p.sorted_docs[pos]
        tf_hit = p.sorted_tf[pos]
        w = p.idf[sel_terms][:, None] * (1.0 + jnp.log1p(tf_hit))
        contrib = jnp.where(in_range, w, 0.0)
        scores = jax.ops.segment_sum(
            contrib.reshape(-1), docs.reshape(-1), num_segments=d
        )
        return jax.lax.top_k(scores, k)

    scores, ids = jax.vmap(one)(query_terms, query_tf)
    return ids, scores
