"""Materialize paper-format string feature tokens (paper §2.2.1).

Only used for interop (feeding a real fulltext engine) and for tests that pin
the exact examples from the paper; all internal engines operate on integer
codes.  Token grammar (no special characters, per the paper's footnote 1):

    <feature><scheme><value>
    value   := 'i' ['neg'] digits ['d' digits]     # 'd' is the decimal point

Examples from the paper, all reproduced by the tests:

* rounding P2 of [0.12, -0.13, 0.065] -> ['0P2i0d12', '1P2ineg0d13', '2P2i0d07']
* interval I10 of the same          -> ['0I10i0d1', '1I10ineg0d2', '2I10i0d0']
* combined P3+I5                    -> ['0P3i0d120', '1P3ineg0d130',
                                        '2P3i0d065', '0I5i0d0',
                                        '1I5ineg0d2', '2I5i0d0']
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from .encoding import CombinedEncoder, Encoder, IntervalEncoder, RoundingEncoder
from .filtering import BestFilter, TrimFilter, feature_mask

__all__ = ["encode_value", "tokens_for_vector", "token"]


def encode_value(text: str) -> str:
    """'0.12' -> 'i0d12'; '-0.2' -> 'ineg0d2' (paper's sign/point escaping)."""
    out = text
    neg = out.startswith("-")
    if neg:
        out = out[1:]
    out = out.replace(".", "d")
    return "i" + ("neg" if neg else "") + out


def _strip_trailing_zeros(text: str) -> str:
    """Strip trailing zeros but keep at least one fractional digit
    (the paper prints the 0.0 interval start as 'd0', e.g. '2I10i0d0')."""
    if "." in text:
        text = text.rstrip("0")
        if text.endswith("."):
            text += "0"
    if text in ("-0.0", "-0"):
        text = "0.0"
    return text


def _interval_start_str(bucket: int, width: float) -> str:
    # bucket b covers [b*width, (b+1)*width); the paper names the interval by
    # its start, printed minimally ('0d1' for 0.1, '0d0' for 0.0).
    start = bucket * width
    # avoid float noise: print with enough decimals then strip
    txt = _strip_trailing_zeros(f"{start:.6f}")
    return txt


def token(feature: int, scheme_id: str, value_text: str) -> str:
    return f"{feature}{scheme_id}{encode_value(value_text)}"


def tokens_for_vector(
    x: np.ndarray,
    encoder: Encoder,
    trim: Optional[TrimFilter] = None,
    best: Optional[BestFilter] = None,
) -> List[str]:
    """Paper-format tokens for one vector, with optional high-pass filtering."""
    x = np.asarray(x)
    if x.ndim != 1:
        raise ValueError("tokens_for_vector expects a single vector")
    mask = np.asarray(feature_mask(x, trim=trim, best=best))

    if isinstance(encoder, CombinedEncoder):
        return tokens_for_vector(x, encoder.rounding, trim, best) + tokens_for_vector(
            x, encoder.interval, trim, best
        )

    out: List[str] = []
    if isinstance(encoder, RoundingEncoder):
        codes = np.asarray(encoder.encode(x)).astype(np.int64)
        for j in range(x.shape[0]):
            if not mask[j]:
                continue
            val = codes[j] / encoder.scale
            out.append(token(j, encoder.scheme_id, f"{val:.{encoder.precision}f}"))
    elif isinstance(encoder, IntervalEncoder):
        codes = np.asarray(encoder.encode(x)).astype(np.int64)
        for j in range(x.shape[0]):
            if not mask[j]:
                continue
            out.append(
                token(j, encoder.scheme_id, _interval_start_str(int(codes[j]), encoder.width))
            )
    else:  # pragma: no cover
        raise TypeError(f"unknown encoder {encoder!r}")
    return out
