"""Deterministic synthetic corpora with topic structure.

The paper evaluates on 4.18M Wikipedia articles.  In this CPU container we
reproduce the paper's *claims* on a topic-mixture corpus: every document draws
a sparse Dirichlet mixture over ``n_topics`` latent topics, each topic being a
Zipf-ish distribution over its own vocabulary slice (plus a shared background
slice).  This yields exactly the structure LSA exploits -- documents about the
same topics become near neighbours in the latent space -- so quality curves
(P@10 / nDCG / avg.diff vs page, trim, best) behave like the paper's.

Also hosts synthetic batch generators for the assigned-architecture smoke
tests (LM token streams, recsys click batches, random graphs).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TopicCorpus", "make_corpus", "lm_batch", "recsys_batch", "random_graph"]


@dataclasses.dataclass(frozen=True)
class TopicCorpus:
    doc_terms: np.ndarray   # (d, T) int32 padded with -1
    doc_tf: np.ndarray      # (d, T) f32 counts (0 where pad)
    vocab_size: int
    n_topics: int
    doc_topics: np.ndarray  # (d, n_topics) f32 -- the true mixtures (for tests)


def make_corpus(
    n_docs: int = 5000,
    vocab_size: int = 20000,
    n_topics: int = 50,
    doc_len: int = 120,
    max_unique: int = 96,
    alpha: float = 0.08,
    background_frac: float = 0.15,
    seed: int = 0,
) -> TopicCorpus:
    """Topic-mixture bag-of-words corpus, padded to ``max_unique`` terms/doc."""
    rng = np.random.default_rng(seed)
    n_bg = int(vocab_size * background_frac)
    topic_vocab = vocab_size - n_bg
    per_topic = topic_vocab // n_topics

    # Zipf weights within each topic's slice and the background slice
    zipf = 1.0 / np.arange(1, per_topic + 1) ** 1.1
    zipf /= zipf.sum()
    bg_zipf = 1.0 / np.arange(1, n_bg + 1) ** 1.05
    bg_zipf /= bg_zipf.sum()

    mixtures = rng.dirichlet(np.full(n_topics, alpha), size=n_docs).astype(np.float32)

    doc_terms = np.full((n_docs, max_unique), -1, np.int32)
    doc_tf = np.zeros((n_docs, max_unique), np.float32)
    for i in range(n_docs):
        # topic tokens
        k_topics = rng.choice(n_topics, size=doc_len, p=mixtures[i])
        offs = rng.choice(per_topic, size=doc_len, p=zipf)
        toks = n_bg + k_topics * per_topic + offs
        # background tokens (~25% of doc length)
        n_b = max(1, doc_len // 4)
        toks = np.concatenate([toks, rng.choice(n_bg, size=n_b, p=bg_zipf)])
        uniq, counts = np.unique(toks, return_counts=True)
        if uniq.shape[0] > max_unique:
            top = np.argsort(-counts)[:max_unique]
            uniq, counts = uniq[top], counts[top]
        doc_terms[i, : uniq.shape[0]] = uniq
        doc_tf[i, : uniq.shape[0]] = counts
    return TopicCorpus(doc_terms, doc_tf, vocab_size, n_topics, mixtures)


# ---------------------------------------------------------------- model batches
def lm_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int):
    tokens = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
    return {"tokens": tokens, "labels": np.roll(tokens, -1, axis=1)}


def recsys_batch(rng: np.random.Generator, batch: int, n_sparse: int, vocabs, seq_len: int = 0):
    out = {
        "sparse_ids": np.stack(
            [rng.integers(0, v, size=batch, dtype=np.int32) for v in vocabs], axis=1
        ),
        "dense": rng.normal(size=(batch, 13)).astype(np.float32),
        "label": rng.integers(0, 2, size=(batch,)).astype(np.float32),
    }
    if seq_len:
        out["hist_ids"] = rng.integers(0, vocabs[0], size=(batch, seq_len), dtype=np.int32)
        out["hist_mask"] = (rng.random((batch, seq_len)) < 0.9).astype(np.float32)
        out["target_id"] = rng.integers(0, vocabs[0], size=(batch,), dtype=np.int32)
    return out


def random_graph(rng: np.random.Generator, n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int = 8):
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    return {
        "x": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "edge_src": src,
        "edge_dst": dst,
        "labels": rng.integers(0, n_classes, size=n_nodes, dtype=np.int32),
        "label_mask": (rng.random(n_nodes) < 0.3).astype(np.float32),
    }
