from .synthetic import TopicCorpus, lm_batch, make_corpus, random_graph, recsys_batch

__all__ = ["TopicCorpus", "make_corpus", "lm_batch", "recsys_batch", "random_graph"]
