"""Shard-local MoE dispatch (EXPERIMENTS.md §Perf A5): the structural fix.

The sort-based dispatch in moe.py permutes tokens with data-dependent
indices; GSPMD cannot prove locality, so it replicates the (T, D) token
buffers across the data axis (the dominant memory term of the llama4 train
cell, immune to sharding constraints -- iteration A4).

Here the dispatch runs under ``shard_map`` (via :mod:`repro.dist.shmap`),
manual over the data axes with the model axis AUTO on jax >= 0.6 (on 0.4.x
the adapter degrades to fully-manual -- partial-manual regions hard-crash
that SPMD partitioner -- so expert weights replicate across ``model``
there): every data shard sorts and buckets ONLY its local tokens into a
local capacity buffer (E, C_local, D), computes its experts, and combines
locally.  Token
buffers never cross data shards; the only cross-shard traffic is the
explicit FSDP all-gather of the expert weights' d_ff slices -- exactly what
GSPMD's FSDP inserts for the dense layers anyway.

Scope: the expert-parallel layout (E divisible by the model axis, llama4).
Archs on the TP-inside-experts fallback (mixtral) keep the global path.
Trade-off vs the global dispatch: capacity is per-shard, so overflow drops
tokens per shard rather than globally -- standard GShard 'local group'
semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.annotate import current_mesh

from .moe import _moe_ffn_chunk

__all__ = ["moe_ffn_local"]

# manual(data)-axis view of the per-layer expert weight shardings
# (dist.sharding.lm_param_spec EP branch, minus the leading stacked dim,
# minus the auto model axis):
_WSPEC = (None, None, "data")    # wg/wu (E, D, F): F is the FSDP dim
_WDSPEC = (None, "data", None)   # wd (E, F, D)
_SSPEC = ("data", None)          # shared wg/wu (D, F*): D is the FSDP dim
_SDSPEC = (None, "data")         # shared wd (F*, D)


def _gather_leaf(leaf, spec, data_axes):
    # gather in f32: the BACKWARD of a bf16 all_gather is a bf16 psum, which
    # crashes XLA-CPU's AllReducePromotion pass (minimal repro in
    # EXPERIMENTS.md A5).  Costs 2x on gather bytes in this measurement;
    # on a real TPU backend the bf16 gather works and halves the traffic.
    out = leaf.astype(jnp.float32) if leaf.dtype == jnp.bfloat16 else leaf
    for dim, names in enumerate(spec):
        if names is None:
            continue
        for name in (names if isinstance(names, tuple) else (names,)):
            if name in data_axes:
                out = jax.lax.all_gather(out, name, axis=dim, tiled=True)
    return out.astype(leaf.dtype)


def moe_ffn_local(p, x, top_k, capacity_factor=1.25, act="silu",
                  token_chunk: int = 0):
    """Drop-in for moe_ffn with shard-local dispatch.  Falls back to the
    global path when no mesh is installed (unit tests, single host)."""
    mesh = current_mesh()
    if mesh is None:
        return _moe_ffn_chunk(p, x, top_k, capacity_factor, act)

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local(p_loc, x_loc):
        pw = {
            "router": p_loc["router"],
            "wg": _gather_leaf(p_loc["wg"], _WSPEC, data_axes),
            "wu": _gather_leaf(p_loc["wu"], _WSPEC, data_axes),
            "wd": _gather_leaf(p_loc["wd"], _WDSPEC, data_axes),
        }
        if "shared" in p_loc:
            pw["shared"] = {
                "wg": _gather_leaf(p_loc["shared"]["wg"], _SSPEC, data_axes),
                "wu": _gather_leaf(p_loc["shared"]["wu"], _SSPEC, data_axes),
                "wd": _gather_leaf(p_loc["shared"]["wd"], _SDSPEC, data_axes),
            }
        # full-f32 region: ANY bf16 collective (fwd or transposed bwd) in a
        # manual region crashes XLA-CPU's AllReducePromotion; f32 is the
        # measurable-on-CPU configuration (bytes 2x pessimistic, noted).
        xdt = x_loc.dtype
        pw = jax.tree.map(lambda t: t.astype(jnp.float32), pw)
        y, aux = _moe_ffn_chunk(pw, x_loc.astype(jnp.float32), top_k,
                                capacity_factor, act, annotate=False)
        y = y.astype(xdt)
        # NB: no pmean here -- a scalar all-reduce inside this manual region
        # trips XLA-CPU's AllReducePromotion pass (hard crash); per-shard aux
        # values are averaged outside instead.
        return y, aux[None]

    in_specs = (
        {
            "router": P(),
            "wg": P(*_WSPEC), "wu": P(*_WSPEC), "wd": P(*_WDSPEC),
            **({"shared": {"wg": P(*_SSPEC), "wu": P(*_SSPEC),
                           "wd": P(*_SDSPEC)}} if "shared" in p else {}),
        },
        P(data_axes, None),
    )
    from repro.dist.shmap import shard_map

    fn = shard_map(
        local, mesh=mesh, in_specs=in_specs,
        out_specs=(P(data_axes, None), P(data_axes)),
        manual_axes=frozenset(data_axes), check=False,
    )
    y, aux_shards = fn({k: p[k] for k in in_specs[0]}, x)
    return y, aux_shards.mean()
