"""Attention for the LM family: GQA + RoPE + pattern masks, memory-efficient.

Supports the four layer kinds needed by the assigned archs:

* ``full``    -- causal full attention (qwen2, gemma2 global, llama4 global)
* ``swa``     -- sliding-window attention (mixtral, starcoder2, gemma2 local)
* ``chunked`` -- chunked-local attention (llama4 iRoPE local layers: tokens
  attend only within their ``window``-sized chunk)

Prefill/training uses a **streaming-softmax two-level scan** (outer map over
query chunks, inner scan over KV chunks with running (max, sum, acc)) so the
(S x S) score matrix is never materialised -- required to lower the 32k
prefill and 4k train shapes at pod scale.  Decode attends one query position
against the cache directly (O(S) per step).  Logit softcapping (gemma2) is
``cap * tanh(s / cap)`` applied pre-mask.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["rope", "attention", "decode_attention", "LayerKind"]

NEG_INF = -1e30


class LayerKind(NamedTuple):
    attn: str          # full | swa | chunked
    use_rope: bool
    moe: bool


# --------------------------------------------------------------------- RoPE
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh), positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                                 # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _softcap(s: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return cap * jnp.tanh(s / cap)
    return s


def _mask_bias(qpos, kpos, kind: str, window: int) -> jnp.ndarray:
    """(Cq, Ckv) additive bias: 0 where attending is allowed, -inf otherwise."""
    q = qpos[:, None]
    k = kpos[None, :]
    ok = k <= q                       # causal
    if kind == "swa" and window > 0:
        ok = ok & (k > q - window)
    elif kind == "chunked" and window > 0:
        ok = ok & ((k // window) == (q // window))
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ----------------------------------------------------- streaming chunked attn
@functools.partial(
    jax.jit, static_argnames=("kind", "window", "softcap", "q_chunk", "kv_chunk")
)
def attention(
    q: jnp.ndarray,   # (B, S, H, dh)
    k: jnp.ndarray,   # (B, S, KV, dh)
    v: jnp.ndarray,   # (B, S, KV, dh)
    kind: str = "full",
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)
    nq, nkv = S // q_chunk, S // kv_chunk

    kc = k.reshape(B, nkv, kv_chunk, KV, dh)
    vc = v.reshape(B, nkv, kv_chunk, KV, dh)
    qr = q.reshape(B, nq, q_chunk, H, dh)

    def one_q_chunk(args):
        qi, qblk = args                                 # (B, q_chunk, H, dh)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            acc, m, l = carry
            kj, kblk, vblk = inp                        # (B, kv_chunk, KV, dh)
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            kfull = jnp.repeat(kblk, G, axis=2)         # (B, kv_chunk, H, dh)
            vfull = jnp.repeat(vblk, G, axis=2)
            s = jnp.einsum(
                "bqhd,bchd->bhqc", qblk, kfull, preferred_element_type=jnp.float32
            ) * scale
            s = _softcap(s, softcap)
            s = s + _mask_bias(qpos, kpos, kind, window)[None, None]
            m_new = jnp.maximum(m, s.max(-1))           # (B, H, q_chunk)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum(
                "bhqc,bchd->bqhd", p.astype(vfull.dtype), vfull,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
            return (acc_new, m_new, l_new), None

        init = (
            jnp.zeros((B, q_chunk, H, dh), jnp.float32),
            jnp.full((B, H, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, H, q_chunk), jnp.float32),
        )
        ks = jnp.arange(nkv)
        # scan-over-checkpoint: the backward recomputes each chunk's
        # probabilities instead of stacking (nq, nkv, B, H, Cq, Ckv) f32
        # residuals -- the flash-attention memory profile (dry-run memory
        # analysis showed 28 GiB/device residual stacks without this).
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step), init,
            (ks, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))
        )
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q.dtype)                      # (B, q_chunk, H, dh)

    outs = jax.lax.map(one_q_chunk, (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, dh)


# ---------------------------------------------- context-parallel attention
@functools.partial(
    jax.jit, static_argnames=("kind", "window", "softcap", "q_chunk", "kv_chunk")
)
def attention_seq_parallel(
    q: jnp.ndarray,   # (B, S, H, dh)
    k: jnp.ndarray,
    v: jnp.ndarray,
    kind: str = "full",
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 256,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Streaming-softmax attention with the q-chunk axis VECTORIZED (not
    scanned) and constrained to the ``model`` mesh axis -- context
    parallelism.  This is the TP story for archs whose head count does not
    divide the model axis (llama4: 40 heads on a 16-way axis): instead of
    replicating attention 16x, each model shard owns S/16 query positions;
    K/V are all-gathered per layer (bf16, cheap relative to score compute).
    """
    from repro.dist.annotate import constrain

    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq, nkv = S // q_chunk, S // kv_chunk

    qr = q.reshape(B, nq, q_chunk, H, dh)
    qr = constrain(qr, "batch", "model", None, None, None)
    qpos = jnp.arange(S).reshape(nq, q_chunk)
    kc = jnp.moveaxis(k.reshape(B, nkv, kv_chunk, KV, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nkv, kv_chunk, KV, dh), 1, 0)

    def kv_step(carry, inp):
        acc, m, l = carry
        kj, kblk, vblk = inp
        kpos = kj * kv_chunk + jnp.arange(kv_chunk)
        kfull = jnp.repeat(kblk, G, axis=2)
        vfull = jnp.repeat(vblk, G, axis=2)
        s = jnp.einsum("bnqhd,bchd->bnhqc", qr, kfull,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, softcap)
        bias = jax.vmap(lambda qp: _mask_bias(qp, kpos, kind, window))(qpos)
        s = s + bias[None, :, None]                     # (B, nq, H, Cq, Ckv)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bnhqc,bchd->bnqhd", p.astype(vfull.dtype), vfull,
                        preferred_element_type=jnp.float32)
        acc_new = acc * jnp.moveaxis(corr, 2, 3)[..., None] + pv
        return (acc_new, m_new, l_new), None

    init = (
        jnp.zeros((B, nq, q_chunk, H, dh), jnp.float32),
        jnp.full((B, nq, H, q_chunk), NEG_INF, jnp.float32),
        jnp.zeros((B, nq, H, q_chunk), jnp.float32),
    )
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(kv_step), init, (jnp.arange(nkv), kc, vc))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 1, 3, 2)[..., None]
    out = constrain(out.astype(q.dtype), "batch", "model", None, None, None)
    return out.reshape(B, S, H, dh)


# ------------------------------------------------------------- decode (S_q=1)
def decode_attention(
    q: jnp.ndarray,        # (B, 1, H, dh)
    k_cache: jnp.ndarray,  # (B, S_c, KV, dh)
    v_cache: jnp.ndarray,  # (B, S_c, KV, dh)
    kv_pos: jnp.ndarray,   # (S_c,) int32 absolute positions, -1 = empty slot
    cur_pos: jnp.ndarray,  # () int32 position of the query token
    kind: str = "full",
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    B, _, H, dh = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qh = q[:, 0].reshape(B, KV, G, dh)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qh, k_cache, preferred_element_type=jnp.float32
    ) * scale
    s = _softcap(s, softcap)
    ok = (kv_pos >= 0) & (kv_pos <= cur_pos)
    if kind == "swa" and window > 0:
        ok = ok & (kv_pos > cur_pos - window)
    elif kind == "chunked" and window > 0:
        ok = ok & ((kv_pos // window) == (cur_pos // window))
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, dh).astype(q.dtype)
