"""Decoder-only LM covering the five assigned transformer architectures.

One parameterised implementation; heterogeneity (attention pattern, MoE
cadence) is expressed as a *sub-layer period*: layers are grouped into
``n_layers / period`` identical super-blocks that are ``lax.scan``-ned (small
HLO, fast pod-scale compiles), each containing ``period`` distinct sub-layers
(e.g. llama4: 3 chunked-local + 1 global-NoPE, MoE on every 2nd).

Param/compute dtypes: f32 master params, bf16 matmul compute, f32 softmax /
loss reductions (see models/common.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.dist.annotate import constrain

from ..common import CDTYPE, dense_init, embed_init, rms_norm, softmax_xent
from .attention import LayerKind, attention, decode_attention, rope
from .moe import moe_ffn, moe_init

__all__ = ["LMConfig", "init_params", "forward", "lm_loss", "prefill", "serve_step",
           "init_cache"]


# ---------------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_every: int = 1          # MoE on layers where (i % moe_every) == moe_every-1
    moe_shared: int = 0
    capacity_factor: float = 1.25
    # attention pattern
    attn_pattern: str = "full"  # full | swa | alt_local_global | chunked_global4
    window: int = 0
    softcap_attn: float = 0.0
    softcap_final: float = 0.0
    qkv_bias: bool = False
    tied_embeddings: bool = False
    embed_scale: bool = False   # gemma-style sqrt(d_model) embedding multiplier
    rope_theta: float = 10000.0
    act: str = "silu"
    # chunking for memory-efficient attention
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # memory policy
    param_dtype: str = "float32"   # "bfloat16" for 400B-class archs
    cache_update: str = "slice"    # "masked" when the cache seq dim is sharded
    moe_token_chunk: int = 32768   # MoE dispatch-buffer bound (tokens)
    moe_dispatch: str = "global"   # "local" = shard-local dispatch (shard_map)
    # context parallelism: shard the q-chunk axis over "model" -- the TP
    # story for archs whose head count does not divide the model axis
    seq_parallel_attn: bool = False

    def sub_kinds(self) -> List[LayerKind]:
        if self.attn_pattern == "full":
            attns = [("full", True)]
        elif self.attn_pattern == "swa":
            attns = [("swa", True)]
        elif self.attn_pattern == "alt_local_global":
            attns = [("swa", True), ("full", True)]
        elif self.attn_pattern == "chunked_global4":
            attns = [("chunked", True)] * 3 + [("full", False)]  # iRoPE: global=NoPE
        else:
            raise ValueError(self.attn_pattern)
        moe_period = self.moe_every if self.moe_experts else 1
        period = math.lcm(len(attns), moe_period)
        kinds = []
        for i in range(period):
            a, use_rope = attns[i % len(attns)]
            is_moe = bool(self.moe_experts) and (i % moe_period == moe_period - 1)
            kinds.append(LayerKind(attn=a, use_rope=use_rope, moe=is_moe))
        return kinds

    @property
    def period(self) -> int:
        return len(self.sub_kinds())

    @property
    def n_super(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    def cache_len(self, kind: LayerKind, max_seq: int) -> int:
        if kind.attn in ("swa", "chunked") and 0 < self.window < max_seq:
            return self.window
        return max_seq

    def param_count(self) -> int:
        """Total parameter count (for 6ND roofline math)."""
        p = self.vocab * self.d_model * (1 if self.tied_embeddings else 2)
        for kind in self.sub_kinds():
            attn = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
                + self.n_heads * self.d_head * self.d_model
            if kind.moe:
                ffn = self.moe_experts * 3 * self.d_model * self.d_ff \
                    + self.d_model * self.moe_experts \
                    + self.moe_shared * 3 * self.d_model * self.d_ff
            else:
                ffn = 3 * self.d_model * self.d_ff
            p += (attn + ffn + 2 * self.d_model) * self.n_super
        return p

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        if not self.moe_experts:
            return self.param_count()
        p = self.vocab * self.d_model * (1 if self.tied_embeddings else 2)
        for kind in self.sub_kinds():
            attn = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
                + self.n_heads * self.d_head * self.d_model
            if kind.moe:
                ffn = (self.moe_top_k + self.moe_shared) * 3 * self.d_model * self.d_ff
            else:
                ffn = 3 * self.d_model * self.d_ff
            p += (attn + ffn) * self.n_super
        return p


# ------------------------------------------------------------------------ init
def _init_sublayer(key, cfg: LMConfig, kind: LayerKind):
    ks = jax.random.split(key, 8)
    H, KV, dh, D, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model, cfg.d_ff
    p: Dict[str, Any] = {
        "ln1": jnp.zeros((D,), jnp.float32),
        "ln2": jnp.zeros((D,), jnp.float32),
        "wq": dense_init(ks[0], (D, H, dh)),
        "wk": dense_init(ks[1], (D, KV, dh)),
        "wv": dense_init(ks[2], (D, KV, dh)),
        "wo": dense_init(ks[3], (H, dh, D), scale=1.0 / math.sqrt(H * dh)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, dh), jnp.float32)
        p["bk"] = jnp.zeros((KV, dh), jnp.float32)
        p["bv"] = jnp.zeros((KV, dh), jnp.float32)
    if kind.moe:
        p["moe"] = moe_init(ks[4], D, F, cfg.moe_experts, cfg.moe_shared)
    else:
        p["ffn"] = {
            "wg": dense_init(ks[5], (D, F)),
            "wu": dense_init(ks[6], (D, F)),
            "wd": dense_init(ks[7], (F, D)),
        }
    return p


def init_params(key, cfg: LMConfig):
    kinds = cfg.sub_kinds()
    keys = jax.random.split(key, cfg.period + 2)
    blocks = {}
    for p_i, kind in enumerate(kinds):
        sub_keys = jax.random.split(keys[p_i], cfg.n_super)
        blocks[f"sub{p_i}"] = jax.vmap(
            lambda k: _init_sublayer(k, cfg, kind)
        )(sub_keys)
    params = {
        "embed": embed_init(keys[-1], (cfg.vocab, cfg.d_model)),
        "blocks": blocks,
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tied_embeddings:
        params["unembed"] = dense_init(keys[-2], (cfg.d_model, cfg.vocab))
    if cfg.param_dtype != "float32":
        dt = jnp.dtype(cfg.param_dtype)
        params = jax.tree.map(lambda x: x.astype(dt), params)
    return params


# -------------------------------------------------------------------- sublayer
def _qkv(p, h, cfg: LMConfig):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    return q, k, v


def _ffn_or_moe(p, h, cfg: LMConfig, kind: LayerKind):
    B, S, D = h.shape
    if kind.moe:
        if cfg.moe_dispatch == "local":
            from .moe_local import moe_ffn_local

            y, aux = moe_ffn_local(
                p["moe"], h.reshape(B * S, D), cfg.moe_top_k,
                cfg.capacity_factor, cfg.act,
            )
        else:
            y, aux = moe_ffn(
                p["moe"], h.reshape(B * S, D), cfg.moe_top_k,
                cfg.capacity_factor, cfg.act, token_chunk=cfg.moe_token_chunk,
            )
        return y.reshape(B, S, D), aux
    f = p["ffn"]
    from ..common import act_fn

    act = act_fn(cfg.act)
    y = act(h @ f["wg"].astype(h.dtype)) * (h @ f["wu"].astype(h.dtype))
    return (y @ f["wd"].astype(h.dtype)), jnp.float32(0.0)


def _sublayer_full(p, h, cfg: LMConfig, kind: LayerKind, positions):
    """Training/prefill sub-layer over the full sequence.

    Activation constraints pin batch on the data axes and heads on the model
    axis (dropped automatically where indivisible): without them, GSPMD
    resolves the FSDP-weight-vs-batch conflict on the ``data`` axis by
    ALL-GATHERING ACTIVATIONS instead of weights (observed: every score
    buffer batch-replicated, +120 GiB/device)."""
    h = constrain(h, "batch", None, None)
    x = rms_norm(h, p["ln1"])
    q, k, v = _qkv(p, x, cfg)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    if kind.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if cfg.seq_parallel_attn:
        from .attention import attention_seq_parallel

        o = attention_seq_parallel(
            q, k, v,
            kind=kind.attn, window=cfg.window, softcap=cfg.softcap_attn,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
    else:
        o = attention(
            q, k, v,
            kind=kind.attn, window=cfg.window, softcap=cfg.softcap_attn,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
    o = constrain(o, "batch", None, "model", None)
    h = h + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(h.dtype))
    h = constrain(h, "batch", None, None)
    x = rms_norm(h, p["ln2"])
    y, aux = _ffn_or_moe(p, x, cfg, kind)
    return h + constrain(y, "batch", None, None), aux, (k, v)


# ------------------------------------------------------------------- forward
def forward(params, tokens, cfg: LMConfig, collect_cache_len: int = 0,
            last_only: bool = False):
    """-> (logits, aux_loss, caches|None).  tokens: (B, S) int32.

    ``last_only`` skips the unembed for all but the final position (serving
    prefill never needs the (B, S, V) logits tensor)."""
    B, S = tokens.shape
    kinds = cfg.sub_kinds()
    h = params["embed"].astype(CDTYPE)[tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), CDTYPE)
    positions = jnp.arange(S)[None, :]

    def super_block(carry, block_params):
        h, aux = carry
        caches = {}
        for p_i, kind in enumerate(kinds):
            h, a, (k, v) = _sublayer_full(
                block_params[f"sub{p_i}"], h, cfg, kind, positions
            )
            aux = aux + a
            if collect_cache_len:
                L = cfg.cache_len(kind, collect_cache_len)
                caches[f"sub{p_i}"] = {
                    "k": k[:, S - L:] if L < S else _pad_cache(k, L),
                    "v": v[:, S - L:] if L < S else _pad_cache(v, L),
                    "pos": (jnp.arange(L) + (S - L)) if L < S
                           else _pad_pos(S, L),
                }
        return (h, aux), caches

    block_fn = jax.checkpoint(super_block)
    (h, aux), caches = jax.lax.scan(block_fn, (h, jnp.float32(0.0)), params["blocks"])
    if last_only:
        h = h[:, -1:]
    h = rms_norm(h, params["ln_f"])
    unembed = (params["embed"].T if cfg.tied_embeddings else params["unembed"])
    logits = h @ unembed.astype(h.dtype)
    logits = constrain(logits, "batch", None, "vocab")
    if cfg.softcap_final:
        logits = cfg.softcap_final * jnp.tanh(logits / cfg.softcap_final)
    return logits, aux, (caches if collect_cache_len else None)


def _pad_cache(k, L):
    B, S = k.shape[0], k.shape[1]
    if L == S:
        return k
    return jnp.pad(k, ((0, 0), (0, L - S), (0, 0), (0, 0)))


def _pad_pos(S, L):
    pos = jnp.arange(L, dtype=jnp.int32)
    return jnp.where(pos < S, pos, -1)


def lm_loss(params, batch, cfg: LMConfig, aux_coef: float = 0.01):
    logits, aux, _ = forward(params, batch["tokens"], cfg)
    mask = jnp.ones_like(batch["labels"], jnp.float32)
    # last position predicts a rolled token; mask it out
    mask = mask.at[:, -1].set(0.0)
    return softmax_xent(logits, batch["labels"], mask) + aux_coef * aux


# ------------------------------------------------------------------- serving
def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=CDTYPE):
    kinds = cfg.sub_kinds()
    cache = {}
    for p_i, kind in enumerate(kinds):
        L = cfg.cache_len(kind, max_seq)
        cache[f"sub{p_i}"] = {
            "k": jnp.zeros((cfg.n_super, batch, L, cfg.n_kv_heads, cfg.d_head), dtype),
            "v": jnp.zeros((cfg.n_super, batch, L, cfg.n_kv_heads, cfg.d_head), dtype),
            "pos": jnp.full((cfg.n_super, L), -1, jnp.int32),
        }
    return cache


def prefill(params, tokens, cfg: LMConfig, max_seq: int):
    """Prefill: forward + cache build -> (last-position logits, caches)."""
    logits, _, caches = forward(
        params, tokens, cfg, collect_cache_len=max_seq, last_only=True
    )
    return logits, caches


def serve_step(params, cache, tokens, cur_pos, cfg: LMConfig):
    """One decode step.  tokens: (B, 1); cur_pos: () int32 absolute position.

    -> (logits (B, 1, V), updated cache).  Caches are ring buffers: slot =
    pos % cache_len, so SWA/chunked layers stay O(window) at any context.
    """
    kinds = cfg.sub_kinds()
    B = tokens.shape[0]
    h = params["embed"].astype(CDTYPE)[tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), CDTYPE)
    positions = jnp.full((B, 1), cur_pos)

    def super_block(h, xs):
        block_params, block_cache = xs
        new_cache = {}
        for p_i, kind in enumerate(kinds):
            p = block_params[f"sub{p_i}"]
            c = block_cache[f"sub{p_i}"]
            x = rms_norm(h, p["ln1"])
            q, k, v = _qkv(p, x, cfg)
            if kind.use_rope:
                q = rope(q, positions, cfg.rope_theta)
                k = rope(k, positions, cfg.rope_theta)
            L = c["k"].shape[1]
            slot = (cur_pos % L).astype(jnp.int32)
            if cfg.cache_update == "masked":
                # select-based ring write: O(L) bytes but no dynamic index on
                # a sharded dim -- used when the cache seq axis is sharded
                # (long_500k: 524288-slot cache over the data axis).
                sel = (jnp.arange(L) == slot)
                k_cache = jnp.where(sel[None, :, None, None], k, c["k"])
                v_cache = jnp.where(sel[None, :, None, None], v, c["v"])
                kv_pos = jnp.where(sel, cur_pos.astype(jnp.int32), c["pos"])
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(c["k"], k, slot, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(c["v"], v, slot, axis=1)
                kv_pos = jax.lax.dynamic_update_slice_in_dim(
                    c["pos"], cur_pos[None].astype(jnp.int32), slot, axis=0
                )
            o = decode_attention(
                q, k_cache, v_cache, kv_pos, cur_pos,
                kind=kind.attn, window=cfg.window, softcap=cfg.softcap_attn,
            )
            h = h + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(h.dtype))
            x2 = rms_norm(h, p["ln2"])
            y, _ = _ffn_or_moe(p, x2, cfg, kind)
            h = h + y
            new_cache[f"sub{p_i}"] = {"k": k_cache, "v": v_cache, "pos": kv_pos}
        return h, new_cache

    h, new_cache = jax.lax.scan(super_block, h, (params["blocks"], cache))
    h = rms_norm(h, params["ln_f"])
    unembed = (params["embed"].T if cfg.tied_embeddings else params["unembed"])
    logits = h @ unembed.astype(h.dtype)
    if cfg.softcap_final:
        logits = cfg.softcap_final * jnp.tanh(logits / cfg.softcap_final)
    return logits, new_cache
