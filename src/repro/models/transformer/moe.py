"""Mixture-of-Experts FFN with sort-based dispatch (static shapes, no (T,E,C)
one-hot dispatch einsum -- see DESIGN.md: the GShard dispatch tensor is
quadratic waste at pod scale, the sort+scatter path is O(T*k) and lowers to
gather/scatter/sort ops XLA shards cleanly).

Routing: top-k softmax (renormalised over the chosen experts -- Mixtral
style; llama4's top-1 is the k=1 special case).  Capacity per expert is
``ceil(T*k/E * capacity_factor)``; overflow tokens are dropped (their
combine weight is zero), underflow slots compute on zeros.  A Switch-style
load-balancing auxiliary loss is returned for the trainer.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn", "moe_init"]

from ..common import act_fn, dense_init


def moe_init(key, d_model: int, d_ff: int, n_experts: int, n_shared: int = 0):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts)),
        "wg": dense_init(ks[1], (n_experts, d_model, d_ff)),
        "wu": dense_init(ks[2], (n_experts, d_model, d_ff)),
        "wd": dense_init(ks[3], (n_experts, d_ff, d_model)),
    }
    if n_shared:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": dense_init(kk[0], (d_model, n_shared * d_ff)),
            "wu": dense_init(kk[1], (d_model, n_shared * d_ff)),
            "wd": dense_init(kk[2], (n_shared * d_ff, d_model)),
        }
    return p


def moe_ffn(
    p,
    x: jnp.ndarray,            # (T, D) token-major
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    token_chunk: int = 32768,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (y (T, D), aux_loss scalar).

    Tokens are processed in ``token_chunk`` scan slices: the dispatch
    buffers scale with the chunk, not the full (batch x seq) -- a 32k-token
    prefill would otherwise build a (E, T*k*cf/E, D) buffer per layer
    (observed 64+ GiB/device for mixtral prefill_32k)."""
    T = x.shape[0]
    if T > token_chunk and T % token_chunk == 0:
        nb = T // token_chunk
        xb = x.reshape(nb, token_chunk, -1)

        def body(aux, xc):
            y, a = _moe_ffn_chunk(p, xc, top_k, capacity_factor, act)
            return aux + a, y

        aux, yb = jax.lax.scan(body, jnp.float32(0.0), xb)
        return yb.reshape(T, -1), aux / nb
    return _moe_ffn_chunk(p, x, top_k, capacity_factor, act)


def _moe_ffn_chunk(p, x, top_k, capacity_factor, act, annotate=True):
    T, D = x.shape
    E = p["router"].shape[1]
    f = act_fn(act)

    logits = (x.astype(jnp.float32)) @ p["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)                 # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e fraction_routed_e * mean_prob_e
    me = probs.mean(0)                                           # (E,)
    assign = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32).mean(0)
    aux = E * jnp.sum(assign * me)

    # ---- sort-based dispatch --------------------------------------------
    C = max(1, math.ceil(T * top_k / E * capacity_factor))
    e_flat = idx.reshape(-1)                                     # (T*k,)
    tok_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    order = jnp.argsort(e_flat)                                  # stable-enough
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank = jnp.arange(T * top_k) - start[e_sorted]
    keep = rank < C
    slot = jnp.where(keep, e_sorted * C + rank, E * C)           # E*C = drop bin

    from repro.dist.annotate import constrain

    # NOTE: these constraints pin the intended token sharding of the
    # permuted buffers, but measured (EXPERIMENTS.md §Perf A4) they do NOT
    # stop GSPMD replicating the data-dependent gather/scatter -- the real
    # fix is shard-local dispatch + explicit all-to-all under shard_map,
    # logged as the next iteration.
    cst = constrain if annotate else (lambda t, *a: t)
    xg = cst(x[tok_sorted], "batch", None)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(
        xg, mode="drop"
    )[: E * C].reshape(E, C, D)
    # expert-shard the dispatch buffer: tokens-sharded -> expert-sharded is
    # the MoE all-to-all; without the constraint the buffer replicates.
    buf = cst(buf, "expert", None, None)

    # ---- expert FFNs (batched over E) -----------------------------------
    h = f(
        jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    ) * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    out_buf = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype),
                         p["wd"].astype(x.dtype),
                         preferred_element_type=jnp.float32)     # (E, C, D)
    out_buf = cst(out_buf, "expert", None, None)

    # ---- combine ---------------------------------------------------------
    y_sorted = cst(
        jnp.where(
            keep[:, None],
            out_buf.reshape(E * C, D)[jnp.minimum(slot, E * C - 1)],
            0.0,
        ),
        "batch", None,
    )
    gates_sorted = gate_vals.reshape(-1)[order][:, None]
    y_flat = jnp.zeros((T * top_k, D), jnp.float32).at[order].set(
        y_sorted * gates_sorted
    )
    y = cst(y_flat.reshape(T, top_k, D).sum(1).astype(x.dtype),
            "batch", None)

    if "shared" in p:
        sp = p["shared"]
        sh = f(x @ sp["wg"].astype(x.dtype)) * (x @ sp["wu"].astype(x.dtype))
        y = y + (sh @ sp["wd"].astype(x.dtype)).astype(x.dtype)
    return y, aux
