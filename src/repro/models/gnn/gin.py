"""GIN (Graph Isomorphism Network, arXiv:1810.00826) -- gin-tu config.

Message passing is implemented with the JAX-native scatter primitive
(``jax.ops.segment_sum`` over an edge list) -- THE sparse-aggregation
substrate this brief calls out (no SpMM in JAX; BCOO is not used).  Three
execution shapes:

* node classification on one big (padded) graph -- full_graph_sm/ogb_products
* sampled-subgraph training (neighbor sampler in sampler.py) -- minibatch_lg
* batched small graphs with sum-readout graph classification -- molecule

GIN update: ``h_i <- MLP_l((1 + eps_l) * h_i + sum_{j in N(i)} h_j)`` with a
learnable eps (gin-tu: eps=learnable, aggregator=sum, 5 layers, d_hidden 64).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from ..common import mlp_apply, mlp_init, softmax_xent

__all__ = ["GINConfig", "init_params", "node_forward", "graph_forward",
           "node_loss", "graph_loss"]


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 1433
    n_classes: int = 16
    learn_eps: bool = True


def init_params(key, cfg: GINConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        din = cfg.d_in if i == 0 else cfg.d_hidden
        layers.append({
            "mlp": mlp_init(keys[i], [din, 2 * cfg.d_hidden, cfg.d_hidden]),
            "eps": jnp.zeros((), jnp.float32),
        })
    return {
        "layers": layers,
        "readout": mlp_init(keys[-1], [cfg.d_hidden, cfg.n_classes]),
    }


def _aggregate(h: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, n: int):
    """sum_{j in N(i)} h_j via gather + segment_sum; -1 edges are padding."""
    valid = (src >= 0) & (dst >= 0)
    msg = jnp.where(valid[:, None], h[jnp.maximum(src, 0)], 0.0)
    return jax.ops.segment_sum(msg, jnp.where(valid, dst, n), num_segments=n + 1)[:n]


def node_forward(params, x, edge_src, edge_dst, cfg: GINConfig):
    """x: (N, F); edges: (E,) src/dst int32 (-1 pad) -> (N, n_classes)."""
    n = x.shape[0]
    h = x
    for layer in params["layers"]:
        agg = _aggregate(h, edge_src, edge_dst, n)
        eps = layer["eps"] if cfg.learn_eps else 0.0
        h = mlp_apply(layer["mlp"], (1.0 + eps) * h + agg, act="relu", final_act=True)
    return mlp_apply(params["readout"], h)


def node_loss(params, batch: Dict, cfg: GINConfig):
    logits = node_forward(params, batch["x"], batch["edge_src"], batch["edge_dst"], cfg)
    return softmax_xent(logits, batch["labels"], batch["label_mask"])


def graph_forward(params, x, edge_src, edge_dst, node_mask, cfg: GINConfig):
    """Batched small graphs: x (B, N, F), edges (B, E) -> (B, n_classes)."""
    def one(xi, si, di, mi):
        n = xi.shape[0]
        h = xi
        for layer in params["layers"]:
            agg = _aggregate(h, si, di, n)
            eps = layer["eps"] if cfg.learn_eps else 0.0
            h = mlp_apply(layer["mlp"], (1.0 + eps) * h + agg, act="relu",
                          final_act=True)
        pooled = (h * mi[:, None]).sum(0)           # sum readout
        return mlp_apply(params["readout"], pooled)

    return jax.vmap(one)(x, edge_src, edge_dst, node_mask)


def graph_loss(params, batch: Dict, cfg: GINConfig):
    logits = graph_forward(params, batch["x"], batch["edge_src"],
                           batch["edge_dst"], batch["node_mask"], cfg)
    return softmax_xent(logits, batch["labels"])
