"""Host-side uniform neighbor sampler (GraphSAGE-style) for minibatch_lg.

Produces fixed-shape padded subgraph blocks (XLA needs static shapes): for
fanouts ``(f1, f2)`` and ``B`` seed nodes the block holds at most
``B + B*f1 + B*f1*f2`` nodes.  The sampler runs on host numpy from a CSR
adjacency (the data-pipeline side of the system); the device-side train step
consumes the padded block like any other graph.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = ["CSRGraph", "build_csr", "sample_block", "block_capacity"]


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)
    feats: np.ndarray    # (N, F)
    labels: np.ndarray   # (N,)


def build_csr(n_nodes: int, edge_src: np.ndarray, edge_dst: np.ndarray,
              feats: np.ndarray, labels: np.ndarray) -> CSRGraph:
    order = np.argsort(edge_dst, kind="stable")
    src_sorted = edge_src[order]
    dst_sorted = edge_dst[order]
    indptr = np.searchsorted(dst_sorted, np.arange(n_nodes + 1))
    return CSRGraph(indptr.astype(np.int64), src_sorted.astype(np.int32),
                    feats, labels)


def block_capacity(batch_nodes: int, fanouts: Tuple[int, ...]) -> Tuple[int, int]:
    """-> (max_nodes, max_edges) of a sampled block."""
    n, nodes, edges = batch_nodes, batch_nodes, 0
    for f in fanouts:
        edges += n * f
        n = n * f
        nodes += n
    return nodes, edges


def sample_block(
    g: CSRGraph, seeds: np.ndarray, fanouts: Tuple[int, ...],
    rng: np.random.Generator,
) -> Dict[str, np.ndarray]:
    """Uniform k-hop neighbor sampling -> padded block arrays.

    Returns locally-indexed ``edge_src/edge_dst`` (-1 padded), node features
    ``x`` for all block nodes, seed ``labels`` and a ``seed_mask``.
    """
    max_nodes, max_edges = block_capacity(len(seeds), fanouts)
    node_ids = list(seeds)
    local = {int(s): i for i, s in enumerate(seeds)}
    e_src, e_dst = [], []
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = g.indptr[u], g.indptr[u + 1]
            deg = hi - lo
            if deg == 0:
                continue
            picks = g.indices[lo + rng.integers(0, deg, size=min(f, int(deg)))]
            for v in picks:
                v = int(v)
                if v not in local:
                    local[v] = len(node_ids)
                    node_ids.append(v)
                    nxt.append(v)
                e_src.append(local[v])
                e_dst.append(local[u])
        frontier = nxt

    node_ids = np.asarray(node_ids[:max_nodes], np.int64)
    n, e = len(node_ids), len(e_src)
    x = np.zeros((max_nodes, g.feats.shape[1]), g.feats.dtype)
    x[:n] = g.feats[node_ids]
    src = np.full(max_edges, -1, np.int32)
    dst = np.full(max_edges, -1, np.int32)
    src[:e] = np.asarray(e_src[:max_edges], np.int32)
    dst[:e] = np.asarray(e_dst[:max_edges], np.int32)
    labels = np.zeros(max_nodes, np.int32)
    labels[: len(seeds)] = g.labels[seeds]
    mask = np.zeros(max_nodes, np.float32)
    mask[: len(seeds)] = 1.0
    return {"x": x, "edge_src": src, "edge_dst": dst,
            "labels": labels, "label_mask": mask}
