"""The four assigned recsys architectures: xDeepFM, AutoInt, DIN, BST.

Shared anatomy (kernel_taxonomy §B.6): sparse embedding tables (the hot
path -- see embedding.py) -> feature-interaction op -> small MLP -> logit.
Per-model interaction:

* xDeepFM  [arXiv:1803.05170] -- CIN: layered outer-product + 1x1-conv
  compress, sum-pool per layer, plus a deep MLP branch and a linear branch.
* AutoInt  [arXiv:1810.11921] -- multi-head self-attention over the 39 field
  embeddings with residuals.
* DIN      [arXiv:1706.06978] -- target attention over the user's behaviour
  history through the (hist, target, hist-target, hist*target) MLP.
* BST      [arXiv:1905.06874] -- one transformer block over the behaviour
  sequence + target item, then a deep MLP.

Every model also exposes ``user_embedding`` (its natural user representation)
so the paper's encoded-vector search can serve as its candidate-retrieval
phase (``retrieval_cand`` shape; see repro/serve/retrieval.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import dense_init, embed_init, mlp_apply, mlp_init, sigmoid_bce
from .embedding import field_lookup, field_offsets, flat_table_init

__all__ = [
    "XDeepFMConfig", "AutoIntConfig", "DINConfig", "BSTConfig",
    "xdeepfm_init", "xdeepfm_forward", "autoint_init", "autoint_forward",
    "din_init", "din_forward", "bst_init", "bst_forward", "bce_loss",
    "xdeepfm_user_embedding", "autoint_user_embedding",
    "din_user_embedding", "bst_user_embedding",
]


# ============================================================== xDeepFM (CIN)
@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    mlp: Tuple[int, ...] = (400, 400)
    field_vocab: int = 100_000
    n_dense: int = 13

    @property
    def vocab_sizes(self):
        return [self.field_vocab] * self.n_sparse


def xdeepfm_init(key, cfg: XDeepFMConfig):
    ks = jax.random.split(key, 6)
    m, D = cfg.n_sparse, cfg.embed_dim
    cin_ws = []
    h_prev = m
    kc = jax.random.split(ks[1], len(cfg.cin_layers))
    for k, h in zip(kc, cfg.cin_layers):
        cin_ws.append(dense_init(k, (h_prev * m, h)))
        h_prev = h
    return {
        "table": flat_table_init(ks[0], cfg.vocab_sizes, D),
        "linear": embed_init(ks[2], (int(np.sum(cfg.vocab_sizes)),)),
        "cin": cin_ws,
        "mlp": mlp_init(ks[3], [m * D + cfg.n_dense, *cfg.mlp, 1]),
        "cin_out": dense_init(ks[4], (int(np.sum(cfg.cin_layers)), 1)),
        "bias": jnp.zeros((), jnp.float32),
    }


def xdeepfm_forward(params, batch: Dict, cfg: XDeepFMConfig):
    offs = jnp.asarray(field_offsets(cfg.vocab_sizes))
    x0 = field_lookup(params["table"], batch["sparse_ids"], offs)    # (B, m, D)
    B, m, D = x0.shape

    # CIN: X^k[b,h,d] = sum_{i,j} W^k[i*m+j, h] X^{k-1}[b,i,d] X^0[b,j,d]
    xs, pooled = x0, []
    for W in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xs, x0)                      # (B,Hk-1,m,D)
        z = z.reshape(B, -1, D)
        xs = jax.nn.relu(jnp.einsum("bpd,ph->bhd", z, W))
        pooled.append(xs.sum(-1))                                    # (B, Hk)
    cin_feat = jnp.concatenate(pooled, axis=-1)
    cin_logit = cin_feat @ params["cin_out"]

    deep_in = jnp.concatenate([x0.reshape(B, m * D), batch["dense"]], axis=-1)
    deep_logit = mlp_apply(params["mlp"], deep_in, act="relu")

    flat_ids = batch["sparse_ids"] + offs[None, :].astype(batch["sparse_ids"].dtype)
    lin_logit = jnp.take(params["linear"], flat_ids, axis=0).sum(-1, keepdims=True)

    return (cin_logit + deep_logit + lin_logit)[:, 0] + params["bias"]


def xdeepfm_user_embedding(params, batch, cfg: XDeepFMConfig):
    offs = jnp.asarray(field_offsets(cfg.vocab_sizes))
    x0 = field_lookup(params["table"], batch["sparse_ids"], offs)
    return x0.mean(axis=1)                                           # (B, D)


# ================================================================== AutoInt
@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    field_vocab: int = 100_000
    n_dense: int = 13

    @property
    def vocab_sizes(self):
        return [self.field_vocab] * self.n_sparse


def autoint_init(key, cfg: AutoIntConfig):
    ks = jax.random.split(key, 3 + cfg.n_attn_layers)
    d_in = cfg.embed_dim
    layers = []
    for i in range(cfg.n_attn_layers):
        kk = jax.random.split(ks[2 + i], 4)
        layers.append({
            "wq": dense_init(kk[0], (d_in, cfg.n_heads, cfg.d_attn)),
            "wk": dense_init(kk[1], (d_in, cfg.n_heads, cfg.d_attn)),
            "wv": dense_init(kk[2], (d_in, cfg.n_heads, cfg.d_attn)),
            "wres": dense_init(kk[3], (d_in, cfg.n_heads * cfg.d_attn)),
        })
        d_in = cfg.n_heads * cfg.d_attn
    return {
        "table": flat_table_init(ks[0], cfg.vocab_sizes, cfg.embed_dim),
        "attn": layers,
        "out": dense_init(ks[1], (cfg.n_sparse * d_in + cfg.n_dense, 1)),
        "bias": jnp.zeros((), jnp.float32),
    }


def autoint_forward(params, batch: Dict, cfg: AutoIntConfig):
    offs = jnp.asarray(field_offsets(cfg.vocab_sizes))
    h = field_lookup(params["table"], batch["sparse_ids"], offs)     # (B, m, D)
    for layer in params["attn"]:
        q = jnp.einsum("bmd,dhk->bmhk", h, layer["wq"])
        k = jnp.einsum("bmd,dhk->bmhk", h, layer["wk"])
        v = jnp.einsum("bmd,dhk->bmhk", h, layer["wv"])
        s = jnp.einsum("bmhk,bnhk->bhmn", q, k) / jnp.sqrt(float(cfg.d_attn))
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhmn,bnhk->bmhk", a, v)
        o = o.reshape(*h.shape[:2], -1)
        h = jax.nn.relu(o + h @ layer["wres"])
    B = h.shape[0]
    feat = jnp.concatenate([h.reshape(B, -1), batch["dense"]], axis=-1)
    return (feat @ params["out"])[:, 0] + params["bias"]


def autoint_user_embedding(params, batch, cfg: AutoIntConfig):
    offs = jnp.asarray(field_offsets(cfg.vocab_sizes))
    return field_lookup(params["table"], batch["sparse_ids"], offs).mean(1)


# ===================================================================== DIN
@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    item_vocab: int = 1_000_000
    n_dense: int = 13


def din_init(key, cfg: DINConfig):
    ks = jax.random.split(key, 4)
    D = cfg.embed_dim
    return {
        "items": embed_init(ks[0], (cfg.item_vocab, D)),
        "attn_mlp": mlp_init(ks[1], [4 * D, *cfg.attn_mlp, 1]),
        "mlp": mlp_init(ks[2], [2 * D + cfg.n_dense, *cfg.mlp, 1]),
        "bias": jnp.zeros((), jnp.float32),
    }


def din_attention(params, hist, target, mask):
    """DIN local activation unit -> weighted-sum interest (B, D)."""
    B, L, D = hist.shape
    t = jnp.broadcast_to(target[:, None, :], (B, L, D))
    feat = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)   # (B, L, 4D)
    w = mlp_apply(params["attn_mlp"], feat, act="relu")[..., 0]      # (B, L)
    w = jnp.where(mask > 0, w, 0.0)  # paper: no softmax; masked weights
    return (hist * w[..., None]).sum(1)


def din_forward(params, batch: Dict, cfg: DINConfig):
    hist = jnp.take(params["items"], batch["hist_ids"], axis=0)      # (B, L, D)
    target = jnp.take(params["items"], batch["target_id"], axis=0)   # (B, D)
    interest = din_attention(params, hist, target, batch["hist_mask"])
    feat = jnp.concatenate([interest, target, batch["dense"]], axis=-1)
    return mlp_apply(params["mlp"], feat, act="relu")[:, 0] + params["bias"]


def din_user_embedding(params, batch, cfg: DINConfig):
    hist = jnp.take(params["items"], batch["hist_ids"], axis=0)
    target = jnp.take(params["items"], batch["target_id"], axis=0)
    return din_attention(params, hist, target, batch["hist_mask"])


# ===================================================================== BST
@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp: Tuple[int, ...] = (1024, 512, 256)
    item_vocab: int = 1_000_000
    n_dense: int = 13

    @property
    def d_head(self):
        return self.embed_dim // self.n_heads


def bst_init(key, cfg: BSTConfig):
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    D = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        kk = jax.random.split(ks[3 + i], 6)
        blocks.append({
            "wq": dense_init(kk[0], (D, cfg.n_heads, cfg.d_head)),
            "wk": dense_init(kk[1], (D, cfg.n_heads, cfg.d_head)),
            "wv": dense_init(kk[2], (D, cfg.n_heads, cfg.d_head)),
            "wo": dense_init(kk[3], (cfg.n_heads * cfg.d_head, D)),
            "ff1": dense_init(kk[4], (D, 4 * D)),
            "ff2": dense_init(kk[5], (4 * D, D)),
            "ln1": jnp.zeros((D,)), "ln2": jnp.zeros((D,)),
        })
    return {
        "items": embed_init(ks[0], (cfg.item_vocab, D)),
        "pos": embed_init(ks[1], (cfg.seq_len + 1, D)),
        "blocks": blocks,
        "mlp": mlp_init(ks[2], [(cfg.seq_len + 1) * D + cfg.n_dense, *cfg.mlp, 1]),
        "bias": jnp.zeros((), jnp.float32),
    }


def _bst_encode(params, batch, cfg: BSTConfig):
    from ..common import rms_norm

    hist = jnp.take(params["items"], batch["hist_ids"], axis=0)      # (B, L, D)
    target = jnp.take(params["items"], batch["target_id"], axis=0)   # (B, D)
    seq = jnp.concatenate([hist, target[:, None, :]], axis=1)        # (B, L+1, D)
    seq = seq + params["pos"][None]
    mask = jnp.concatenate(
        [batch["hist_mask"], jnp.ones_like(batch["hist_mask"][:, :1])], axis=1
    )
    for blk in params["blocks"]:
        x = rms_norm(seq, blk["ln1"])
        q = jnp.einsum("bld,dhk->blhk", x, blk["wq"])
        k = jnp.einsum("bld,dhk->blhk", x, blk["wk"])
        v = jnp.einsum("bld,dhk->blhk", x, blk["wv"])
        s = jnp.einsum("blhk,bmhk->bhlm", q, k) / jnp.sqrt(float(cfg.d_head))
        s = jnp.where(mask[:, None, None, :] > 0, s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhlm,bmhk->blhk", a, v).reshape(*seq.shape[:2], -1)
        seq = seq + o @ blk["wo"]
        x = rms_norm(seq, blk["ln2"])
        seq = seq + jax.nn.relu(x @ blk["ff1"]) @ blk["ff2"]
    return seq, mask


def bst_forward(params, batch: Dict, cfg: BSTConfig):
    seq, _ = _bst_encode(params, batch, cfg)
    B = seq.shape[0]
    feat = jnp.concatenate([seq.reshape(B, -1), batch["dense"]], axis=-1)
    return mlp_apply(params["mlp"], feat, act="relu")[:, 0] + params["bias"]


def bst_user_embedding(params, batch, cfg: BSTConfig):
    seq, mask = _bst_encode(params, batch, cfg)
    return (seq * mask[..., None]).sum(1) / jnp.maximum(
        mask.sum(-1, keepdims=True), 1e-9
    )


# ---------------------------------------------------------------------- loss
def bce_loss(forward_fn, params, batch, cfg):
    return sigmoid_bce(forward_fn(params, batch, cfg), batch["label"])
