"""Embedding substrate for recsys: EmbeddingBag built from JAX primitives.

JAX has no native ``nn.EmbeddingBag`` -- this module IS that layer (brief:
"implement EmbeddingBag with ``jnp.take`` + ``jax.ops.segment_sum``; this is
part of the system").  Tables are stored as one flat ``(sum_f V_f, D)``
matrix with per-field offsets so the row axis shards cleanly over the
``model`` mesh axis (row-sharded embedding = the standard DLRM layout).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..common import embed_init

__all__ = ["flat_table_init", "field_lookup", "embedding_bag", "field_offsets"]


def field_offsets(vocab_sizes: Sequence[int]) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int32)


def flat_table_init(key, vocab_sizes: Sequence[int], dim: int):
    total = int(np.sum(vocab_sizes))
    return embed_init(key, (total, dim))


def field_lookup(table: jnp.ndarray, ids: jnp.ndarray, offsets: jnp.ndarray):
    """Single-hot per-field lookup: ids (B, F) -> (B, F, D)."""
    flat = ids + offsets[None, :].astype(ids.dtype)
    return jnp.take(table, flat, axis=0)


def embedding_bag(
    table: jnp.ndarray,      # (V, D)
    ids: jnp.ndarray,        # (B, L) int32 (multi-hot bag; -1 or masked = pad)
    weights: jnp.ndarray,    # (B, L) f32 per-sample weights / mask
    mode: str = "sum",       # sum | mean
) -> jnp.ndarray:
    """torch.nn.EmbeddingBag equivalent: ragged gather + weighted reduce."""
    g = jnp.take(table, jnp.maximum(ids, 0), axis=0)          # (B, L, D)
    w = jnp.where(ids >= 0, weights, 0.0)
    out = (g * w[..., None]).sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-9)
    return out


def embedding_bag_segment(
    table: jnp.ndarray,       # (V, D)
    flat_ids: jnp.ndarray,    # (nnz,) int32
    segment_ids: jnp.ndarray,  # (nnz,) int32 bag index per id
    n_bags: int,
    weights: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """CSR-style EmbeddingBag: gather rows then segment_sum into bags."""
    g = jnp.take(table, jnp.maximum(flat_ids, 0), axis=0)
    if weights is not None:
        g = g * weights[:, None]
    g = jnp.where((flat_ids >= 0)[:, None], g, 0.0)
    return jax.ops.segment_sum(g, segment_ids, num_segments=n_bags)
