"""Shared model primitives: init helpers, norms, activations, losses."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "embed_init", "rms_norm", "layer_norm", "act_fn",
    "softmax_xent", "sigmoid_bce", "mlp_init", "mlp_apply",
]

PDTYPE = jnp.float32   # parameter dtype (f32 master copies)
CDTYPE = jnp.bfloat16  # compute dtype


def dense_init(key, shape, scale: float | None = None, dtype=PDTYPE):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = 1.0 / jnp.sqrt(fan_in)
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=PDTYPE):
    return jax.random.normal(key, shape, dtype) * 0.02


def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, gamma, beta, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


def act_fn(name: str) -> Callable:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "tanh": jnp.tanh}[name]


def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy; logits upcast to f32 for the reduction.

    The gold logit is extracted with a one-hot contraction, NOT
    ``take_along_axis``: a gather over a model-sharded vocab axis forces
    GSPMD to replicate the full (B, S, V) logits on every device (found via
    dry-run memory_analysis: +100 GiB/device at 150k vocab), while the
    one-hot product reduces over the sharded axis with a single psum."""
    from repro.dist.annotate import constrain

    spec = ["batch"] + [None] * (logits.ndim - 2) + ["vocab"]
    logits = constrain(logits.astype(jnp.float32), *spec)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = constrain(
        jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype), *spec
    )
    gold = (logits * onehot).sum(-1)
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def sigmoid_bce(logits, labels):
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def mlp_init(key, dims, bias=True, dtype=PDTYPE):
    """dims = [in, h1, ..., out] -> list of {'w','b'} layers."""
    layers = []
    keys = jax.random.split(key, len(dims) - 1)
    for k, din, dout in zip(keys, dims[:-1], dims[1:]):
        layer = {"w": dense_init(k, (din, dout), dtype=dtype)}
        if bias:
            layer["b"] = jnp.zeros((dout,), dtype)
        layers.append(layer)
    return layers


def mlp_apply(layers, x, act="relu", final_act=False):
    f = act_fn(act)
    for i, layer in enumerate(layers):
        x = x @ layer["w"].astype(x.dtype)
        if "b" in layer:
            x = x + layer["b"].astype(x.dtype)
        if i < len(layers) - 1 or final_act:
            x = f(x)
    return x
