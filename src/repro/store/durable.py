"""Store façade + the write-through index wrapper.

:class:`Store` owns one durability directory (translog generations +
commit points) -- the per-index analogue of an ES data path.
:class:`DurableIndex` is the write-through discipline: it wraps a
:class:`ShardedVectorIndex` so that every ``add_documents``/``delete``
hits the translog (fsync per the store's durability policy) BEFORE the
caller is acked -- exactly ES ``index.translog.durability=request``
semantics, and in ES's order: the op applies to the in-memory index
FIRST and is logged only once it succeeded, so a malformed op that
raises (wrong feature count, out-of-range id) is never logged and can
never poison a later recovery replay.  A crash between apply and log
loses only an unacked op -- the recovered state is exactly the acked
history.

``DurableIndex`` follows the repo's immutable-index idiom (every mutator
returns a new wrapper sharing the store), and carries ``translog_seq`` --
the seqno of the last op folded into this state.  That attribute is the
*commit metadata* that rides through ``BatchedSearchEngine.swap_index``:
the maintenance daemon's compact-and-CAS produces a new wrapper whose
``translog_seq`` still names the exact translog position its state
covers, so the daemon can roll a commit point for the swapped index
without any engine-level bookkeeping -- a racing ingest simply produces
a later state with a later seqno, and whichever (state, seq) pair wins
the CAS is the consistent pair that gets committed.

``compact()`` and ``merge_segments()`` intentionally do NOT log:
maintenance changes no acked content (ids and df are preserved), so
recovery replaying the same ops over the pre-maintenance commit reaches
the same search state -- translog replay re-runs the identical
``add_documents`` history, which re-seals segments at identical
boundaries (sealing is a pure function of the op history).  Commit right
after a maintenance pass (the daemon does) to re-anchor recovery on the
folded form and let the replayed translog trim.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Optional, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.obs.metrics import default_registry

from .recovery import recover
from .snapshot import latest_commit, write_commit
from .translog import Translog

__all__ = ["Store", "DurableIndex"]


class Store:
    """One durability directory: translog writer + commit points.

    ``commit`` and ``recover``/``recover_index`` serialize on an internal
    lock: a commit's translog trim unlinks generation files, which must
    never race a recovery scan that just listed them (the maintenance
    daemon commits from its own thread while ``ClusterEngine.
    restore_group`` recovers under the cluster's control-plane lock --
    two locks, one store, hence the store owns the mutual exclusion).

    **Observability**: commit and recovery wall times + counts record
    into ``metrics`` (a cluster the store attaches to shares its
    registry in), and :meth:`stats` is the ES ``_stats/translog`` view --
    translog seqno/generation/on-disk bytes, newest commit
    generation/seq, commit + recovery timings.
    """

    def __init__(self, path: str, durability: str = "request",
                 metrics=None):
        os.makedirs(path, exist_ok=True)
        self.path = path
        self.translog = Translog(path, durability=durability)
        self.metrics = metrics if metrics is not None else default_registry()
        self._lock = threading.Lock()

    @property
    def seqno(self) -> int:
        return self.translog.seqno

    @property
    def durability(self) -> str:
        return self.translog.durability

    def commit(self, index, seq: Optional[int] = None) -> int:
        """Write a commit point for ``index`` (covering ``seq``, default
        the index's own ``translog_seq``), then roll the translog onto a
        fresh generation and trim generations the commit covers."""
        if seq is None:
            seq = getattr(index, "translog_seq", None)
            if seq is None:
                raise ValueError(
                    "index carries no translog_seq; pass seq= explicitly")
        t0 = time.monotonic()
        stats: dict = {}
        with self._lock:
            # seq-only lookup: no point CRC-validating the fallback's data
            # here -- a corrupt fallback only makes the trim retain more
            prev = latest_commit(self.path, validate=False)
            # blob GC runs inside write_commit, under this lock -- mutually
            # exclusive with recover_index, so a restore in progress can
            # never have a referenced blob deleted under it
            gen = write_commit(self.path, index, seq, stats)
            self.translog.roll()
            # retain translog back to the FALLBACK commit (the previous
            # one): if this commit's data file tears later, recovery falls
            # back to `prev` and still needs the ops between the two
            # commit points
            self.translog.trim(prev.seq if prev is not None else 0)
        self.metrics.counter("store.commits").inc()
        self.metrics.histogram("store.commit.duration_s").observe(
            time.monotonic() - t0)
        # the O(changed) evidence: bytes actually written vs the bytes the
        # commit references (unchanged content-addressed blobs are shared)
        self.metrics.counter("store.commit.bytes_written").inc(
            stats["bytes_written"])
        self.metrics.gauge("store.commit.last_bytes_written").set(
            stats["bytes_written"])
        self.metrics.gauge("store.commit.last_bytes_total").set(
            stats["bytes_total"])
        return gen

    def has_commit(self) -> bool:
        # existence check only -- no point streaming a full-corpus CRC
        return latest_commit(self.path, validate=False) is not None

    def recover_index(self, mesh: Mesh):
        """Crash-recover onto ``mesh`` -> (raw index, seqno), serialized
        against concurrent commits (whose translog trim would otherwise
        unlink generation files out from under the replay scan)."""
        t0 = time.monotonic()
        with self._lock:
            out = recover(self.path, mesh)
        self.metrics.counter("store.recoveries").inc()
        self.metrics.histogram("store.recovery.duration_s").observe(
            time.monotonic() - t0)
        return out

    def recover(self, mesh: Mesh) -> "Tuple[DurableIndex, int]":
        """Crash-recover onto ``mesh`` -> (write-through wrapped index,
        seqno).  The wrapper's ``translog_seq`` resumes at the recovered
        position, so the next ingest logs at the right offset."""
        index, seq = self.recover_index(mesh)
        return DurableIndex(index, self, seq=seq), seq

    def open_index(self, index, *, allow_existing: bool = False,
                   ) -> "DurableIndex":
        """Wrap a freshly built ``index`` for serving through this store
        and write its baseline commit point (a translog is only
        replayable on top of a commit).

        A store that ALREADY holds history refuses (``ValueError``):
        silently pairing a new index with an old commit would make every
        later recovery/restore_group replay a different corpus than the
        one being served.  Restarting on existing state is
        :meth:`recover`'s job -- its result is already wrapped and needs
        no ``open_index``.  ``allow_existing=True`` opts out for callers
        that KNOW the index equals the stored state (a fresh baseline
        commit is then written on top, which is always consistent)."""
        if not allow_existing and (self.has_commit() or self.seqno):
            raise ValueError(
                f"store {self.path!r} already holds history (commit or "
                "translog ops); recover(mesh) instead of open_index, or "
                "pass allow_existing=True if this index provably equals "
                "the stored state")
        wrapped = DurableIndex(index, self, seq=self.seqno)
        self.commit(wrapped)
        return wrapped

    def stats(self) -> dict:
        """ES ``_stats/translog``-style snapshot: translog seqno /
        generation / retained on-disk bytes, newest commit
        generation/seq, commit + recovery counts and wall-time
        histograms (see :func:`repro.obs.stats.store_stats`)."""
        from repro.obs.stats import store_stats

        return store_stats(self)

    def close(self) -> None:
        self.translog.close()


class DurableIndex:
    """Write-through wrapper: translog first, memory second.

    Transparent for reads (attribute access proxies to the wrapped index,
    so engines/benches/daemons see ``search``/``n_ids``/
    ``tombstone_ratio``/... unchanged); the three mutators return a new
    wrapper sharing the store, with ``translog_seq`` advanced past the
    logged op.
    """

    def __init__(self, inner, store: Store, seq: Optional[int] = None):
        self.inner = inner
        self.store = store
        self.translog_seq = store.seqno if seq is None else seq

    def add_documents(self, vectors) -> "DurableIndex":
        # apply first (validation lives there), then log the exact float32
        # array that was applied -- replay re-runs the identical
        # normalize/encode for bit-exact recovery, and an op that raised
        # is never logged (it must not resurface at recovery)
        v = np.asarray(vectors, np.float32)
        new = self.inner.add_documents(v)
        seq = self.store.translog.add(v)
        return DurableIndex(new, self.store, seq)

    def delete(self, ids) -> "DurableIndex":
        arr = np.atleast_1d(np.asarray(ids, np.int64))
        new = self.inner.delete(arr)
        seq = self.store.translog.delete(arr)
        return DurableIndex(new, self.store, seq)

    def compact(self) -> "DurableIndex":
        # not logged: content-preserving (see module docstring)
        return DurableIndex(self.inner.compact(), self.store,
                            self.translog_seq)

    def merge_segments(self, start: int = 0, count=None) -> "DurableIndex":
        # not logged, same reasoning as compact: a merge drops only
        # already-dead rows, so replaying the acked ops over the
        # pre-merge commit reaches the same search state
        return DurableIndex(self.inner.merge_segments(start, count),
                            self.store, self.translog_seq)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"DurableIndex(seq={self.translog_seq}, "
                f"store={self.store.path!r}, inner={self.inner!r})")
