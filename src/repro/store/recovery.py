"""Crash recovery: latest commit point + translog replay.

The ES shard-recovery sequence (``index.recovery`` after a node restart):
open the newest Lucene commit, then replay every translog operation past
the commit's sequence number.  Here the same two phases run against the
store directory:

1. :func:`repro.store.snapshot.latest_commit` picks the newest commit
   whose manifest and data checksum verify (falling back to earlier
   generations past a torn newest commit);
2. :func:`repro.store.translog.read_ops` replays records with
   ``seq > commit.seq`` -- torn tails are truncated, checksummed records
   are applied through the SAME ``add_documents``/``delete`` code paths
   the live ingest ran.  Replay re-runs the identical normalize/encode
   computation on the identical logged inputs -- and re-SEALS append
   segments at identical boundaries, because sealing is a pure function
   of the op history -- which is why the recovered index is not merely
   equivalent but *bit-identical* in search to the index that was lost
   (pinned by tests/test_store.py at every ingest/delete/merge/compact
   stage boundary, all engines, 1/4/4x2 meshes).

The commit side is O(changed): content-addressed blobs mean recovery
reads (and ``restore_group`` ships) only the parts the newest commit
actually references -- unchanged segments restore from blobs written
generations ago.

A commit gap (oldest surviving translog record is newer than
``commit.seq + 1``) raises :class:`TranslogCorruptedError` rather than
silently recovering a hole in the acked history.
"""

from __future__ import annotations

from typing import Tuple

from jax.sharding import Mesh

from repro.dist.shard_index import ShardedVectorIndex

from .snapshot import latest_commit, restore
from .translog import OP_ADD, OP_DELETE, TranslogCorruptedError, read_ops

__all__ = ["recover", "NoCommitError"]


class NoCommitError(FileNotFoundError):
    """The store directory holds no valid commit point to recover from."""


def recover(store_dir: str, mesh: Mesh) -> Tuple[ShardedVectorIndex, int]:
    """Rebuild the index from disk onto ``mesh`` -> (index, last seqno).

    The mesh may differ from the writer's (see
    :func:`repro.store.snapshot.restore`); the returned seqno is what a
    new commit covering this state should record.
    """
    commit = latest_commit(store_dir)
    if commit is None:
        raise NoCommitError(f"no valid commit point in {store_dir!r}")
    index = restore(commit, mesh)
    seq = commit.seq
    for rec_seq, op, payload in read_ops(store_dir, after_seq=seq,
                                         truncate_torn=True):
        if op == OP_ADD:
            index = index.add_documents(payload)
        elif op == OP_DELETE:
            index = index.delete(payload)
        else:
            raise TranslogCorruptedError(
                f"unknown translog op {op} at seq {rec_seq}")
        seq = rec_seq
    return index, seq
