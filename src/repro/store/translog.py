"""Write-ahead translog for ingest durability (the ES transaction log).

Elasticsearch acks an index/delete request only after the operation is in
the shard's *translog* (``index.translog.durability``), because the Lucene
segments it will eventually live in are flushed far less often.  This
module is that log for the sharded vector index: an append-only file of
framed, checksummed, sequence-numbered records -- one per
``add_documents``/``delete`` operation -- fsync'd per a configurable
durability policy, written after the op applied in memory but BEFORE the
caller is acked (ES's order: a raising op is never logged, see
:class:`repro.store.durable.DurableIndex`).

One deliberate deviation from ES: the log is *operation*-scoped, not
per-shard.  ES needs a log per shard because each shard is an independent
Lucene index with independent routing; here ingest routing is a pure
function of the global append counter (round-robin, see
``ShardedVectorIndex._seg_slots_used``), so replaying the single global
operation stream reproduces every shard's state bit for bit -- on ANY
shard count, which is what lets a commit written on an SxR mesh restore
onto a different mesh shape.

On-disk layout (ES translog generations): ``translog-<gen>.log`` files,
each ``MAGIC + version`` then records

    [crc32 u32][seq u64][op u8][payload_len u32][payload bytes]

where ``crc32`` covers everything after itself.  A *torn tail* (crash
mid-append: short header, short payload, or checksum mismatch at the end
of the newest generation) is detected and truncated on recovery; a bad
record anywhere else is real corruption and raises
:class:`TranslogCorruptedError`.  Commits roll the writer onto a fresh
generation and delete generations wholly covered by the commit point
(:meth:`Translog.roll` / :meth:`Translog.trim` -- ES
``translog.retention`` after a flush).

Durability policies (ES ``index.translog.durability``):

* ``"request"`` (default) -- flush + fsync before the append returns: an
  acked op survives a process kill AND a power loss.
* ``"async"`` -- buffered write only; fsync happens at ``sync``/``roll``/
  ``close``.  An acked op survives a process kill (the OS holds the
  bytes) but a power loss may lose the tail -- the replay path treats the
  missing tail as torn and recovers to the last durable prefix.
"""

from __future__ import annotations

import io
import os
import re
import struct
import threading
import zlib
from typing import Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["Translog", "TranslogCorruptedError", "OP_ADD", "OP_DELETE",
           "read_ops"]

_MAGIC = b"RTLG"
_VERSION = 1
_HEADER = _MAGIC + bytes([_VERSION])
_BASE = struct.Struct("<Q")              # header trailer: base seqno -- the
#   seq of the last record BEFORE this generation, so an empty rolled
#   generation still anchors the writer's next seqno after a trim (the ES
#   translog.ckpt checkpoint, folded into the file header)
_REC = struct.Struct("<IQBI")            # crc32, seq, op, payload_len
_GEN_RE = re.compile(r"^translog-(\d{8})\.log$")

OP_ADD = 1                               # payload: (m, n_feat) f32 vectors
OP_DELETE = 2                            # payload: (m,) i64 global ids

_DURABILITIES = ("request", "async")


class TranslogCorruptedError(RuntimeError):
    """A record failed its checksum somewhere OTHER than the torn tail of
    the newest generation (which is a normal crash artefact and silently
    truncated) -- the log cannot be trusted past this point."""


def _encode(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, np.ascontiguousarray(arr), allow_pickle=False)
    return buf.getvalue()


def _decode(payload: bytes) -> np.ndarray:
    return np.load(io.BytesIO(payload), allow_pickle=False)


def _fsync_dir(path: str) -> None:
    """Persist directory entries: a created (or unlinked) generation file
    is durable only once its dirent is -- fsync of the file alone does
    not survive a power loss of the directory block."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _gen_path(dirpath: str, gen: int) -> str:
    return os.path.join(dirpath, f"translog-{gen:08d}.log")


def _list_generations(dirpath: str) -> List[int]:
    gens = []
    for name in os.listdir(dirpath):
        m = _GEN_RE.match(name)
        if m:
            gens.append(int(m.group(1)))
    return sorted(gens)


def _gen_base(path: str) -> int:
    """The generation's base seqno (last seq issued before it opened)."""
    with open(path, "rb") as f:
        header = f.read(len(_HEADER) + _BASE.size)
    if len(header) < len(_HEADER) + _BASE.size or \
            header[: len(_HEADER)] != _HEADER:
        raise TranslogCorruptedError(f"{path}: bad translog header")
    return _BASE.unpack_from(header, len(_HEADER))[0]


def _read_gen(path: str, *, tolerate_torn: bool,
              truncate: bool) -> Iterator[Tuple[int, int, bytes]]:
    """Yield ``(seq, op, payload)`` from one generation file.

    A torn tail (short/garbled trailing record) is tolerated only when
    ``tolerate_torn`` -- and physically truncated when ``truncate`` -- so
    that the invariant "damage only ever sits at the very end of the
    newest generation" survives the repair."""
    _gen_base(path)                                 # header sanity
    with open(path, "rb") as f:
        f.seek(len(_HEADER) + _BASE.size)
        torn_at: Optional[int] = None
        while True:
            pos = f.tell()
            head = f.read(_REC.size)
            if not head:
                return                              # clean EOF
            if len(head) < _REC.size:
                torn_at = pos
                break
            crc, seq, op, plen = _REC.unpack(head)
            payload = f.read(plen)
            if len(payload) < plen or crc != zlib.crc32(head[4:] + payload):
                torn_at = pos
                break
            yield seq, op, payload
    if not tolerate_torn:
        raise TranslogCorruptedError(
            f"{path}: corrupt record at byte {torn_at} (not the newest "
            "generation's tail -- refusing to replay past it)")
    if truncate:
        with open(path, "r+b") as f:
            f.truncate(torn_at)


def _scan(dirpath: str, *, truncate_torn: bool,
          ) -> Iterator[Tuple[int, int, bytes]]:
    """Every record across all generations, in order, with consecutive
    records checked for seqno contiguity (appends are strictly sequential,
    and trims only ever remove a covered PREFIX of generations, so any
    in-stream gap is corruption)."""
    gens = _list_generations(dirpath)
    prev = None
    for i, gen in enumerate(gens):
        last = i == len(gens) - 1
        path = _gen_path(dirpath, gen)
        try:
            _gen_base(path)
        except TranslogCorruptedError:
            if last:
                # torn HEADER (crash mid-roll, before the first record):
                # an empty newest generation -- the previous generations
                # still hold the whole durable history
                return
            raise
        for seq, op, payload in _read_gen(
                path, tolerate_torn=last, truncate=last and truncate_torn):
            if prev is not None and seq != prev + 1:
                raise TranslogCorruptedError(
                    f"translog gap: seq {prev} followed by {seq} in "
                    f"generation {gen}")
            prev = seq
            yield seq, op, payload


def read_ops(dirpath: str, after_seq: int = 0, *, truncate_torn: bool = True,
             ) -> Iterator[Tuple[int, int, np.ndarray]]:
    """Replay ``(seq, op, payload array)`` for every record with
    ``seq > after_seq``, generations in order.

    The first record past ``after_seq`` must be ``after_seq + 1`` unless
    its predecessors are still on disk -- a hole between the commit point
    and the replayable history means a lost generation and raises
    :class:`TranslogCorruptedError` (replaying around it would silently
    diverge from the acked history).  Only the newest generation may carry
    a torn tail; it is truncated in place when ``truncate_torn`` (the
    crash-recovery default).
    """
    first = True
    for seq, op, payload in _scan(dirpath, truncate_torn=truncate_torn):
        if first and seq > after_seq + 1:
            raise TranslogCorruptedError(
                f"translog gap: oldest record on disk is seq {seq} but the "
                f"commit point covers only up to {after_seq}")
        first = False
        if seq <= after_seq:
            continue
        yield seq, op, _decode(payload)


class Translog:
    """Append-only writer over the generation files in ``dirpath``.

    Opening recovers crash state first (truncates the newest generation's
    torn tail, re-reads the last durable seqno) and then starts a FRESH
    generation, so the writer never appends into a file another process's
    crash may have damaged mid-record.  Thread-safe: appends serialize on
    an internal lock (the engine lock already serializes ingest, this is
    defence in depth for direct users).
    """

    def __init__(self, dirpath: str, durability: str = "request"):
        if durability not in _DURABILITIES:
            raise ValueError(
                f"durability must be one of {_DURABILITIES}, got "
                f"{durability!r}")
        os.makedirs(dirpath, exist_ok=True)
        self.dirpath = dirpath
        self.durability = durability
        self._lock = threading.Lock()
        self._seq = 0
        gens = _list_generations(dirpath)
        if gens:
            # a torn HEADER on the newest generation is a crash mid-roll
            # artifact: no record can exist past an incomplete header, so
            # DELETE the file.  Merely skipping it would brick the log:
            # once this writer's new generation holds records, the torn
            # file would no longer be "newest" and every later scan would
            # treat its bad header as hard corruption.
            newest = _gen_path(dirpath, gens[-1])
            try:
                _gen_base(newest)
            except TranslogCorruptedError:
                os.remove(newest)
                _fsync_dir(dirpath)
                gens.pop()
        if gens:
            # establish the durable seqno; the newest generation's torn
            # TAIL (if any) is truncated as a side effect
            for seq, _, _ in _scan(dirpath, truncate_torn=True):
                self._seq = seq
            # an empty (just-rolled, trimmed) generation anchors the seqno
            # through its header base instead of through records
            self._seq = max(self._seq, _gen_base(_gen_path(dirpath,
                                                           gens[-1])))
        self._gen = (gens[-1] + 1) if gens else 1
        self._file = self._open_gen()

    def _open_gen(self):
        f = open(_gen_path(self.dirpath, self._gen), "ab")
        f.write(_HEADER + _BASE.pack(self._seq))
        f.flush()
        os.fsync(f.fileno())
        _fsync_dir(self.dirpath)    # the dirent too, or "request"-durable
        #                             records could vanish with the file
        return f

    # ------------------------------------------------------------------ API
    @property
    def seqno(self) -> int:
        """Last assigned sequence number (0 = nothing ever logged)."""
        with self._lock:
            return self._seq

    @property
    def generation(self) -> int:
        return self._gen

    def append(self, op: int, arr: np.ndarray) -> int:
        """Frame + append one record; returns its sequence number.  Under
        ``durability="request"`` the record is fsync'd before this
        returns -- the caller may ack."""
        payload = _encode(arr)
        with self._lock:
            if self._file.closed:
                raise RuntimeError("translog closed")
            self._seq += 1
            body = struct.pack("<QBI", self._seq, op, len(payload)) + payload
            self._file.write(struct.pack("<I", zlib.crc32(body)) + body)
            self._file.flush()
            if self.durability == "request":
                os.fsync(self._file.fileno())
            return self._seq

    def add(self, vectors) -> int:
        """Log an ``add_documents`` op (the RAW input vectors: replay runs
        the identical normalize/encode the live ingest ran, which is what
        makes recovery bit-exact)."""
        return self.append(OP_ADD, np.asarray(vectors, np.float32))

    def delete(self, ids) -> int:
        return self.append(OP_DELETE, np.asarray(ids, np.int64))

    def sync(self) -> None:
        with self._lock:
            if not self._file.closed:
                self._file.flush()
                os.fsync(self._file.fileno())

    def roll(self) -> int:
        """Fsync + close the current generation and start a fresh one (ES
        rolls the translog generation at every flush/commit)."""
        with self._lock:
            self._file.flush()
            os.fsync(self._file.fileno())
            self._file.close()
            self._gen += 1
            self._file = self._open_gen()
            return self._gen

    def trim(self, upto_seq: int) -> int:
        """Delete non-current generations whose every record is covered by
        a commit point at ``upto_seq``; returns files removed.  Trailing
        generations are never skipped past a retained one, so the on-disk
        set stays a contiguous suffix of history."""
        removed = 0
        with self._lock:
            for gen in _list_generations(self.dirpath):
                if gen == self._gen:
                    continue
                path = _gen_path(self.dirpath, gen)
                try:
                    seqs = [s for s, _, _ in _read_gen(
                        path, tolerate_torn=False, truncate=False)]
                except TranslogCorruptedError:
                    break                # damaged: keep for forensics
                if seqs and max(seqs) > upto_seq:
                    break                # first uncovered generation: stop
                os.remove(path)
                removed += 1
            if removed:
                _fsync_dir(self.dirpath)
        return removed

    def close(self) -> None:
        with self._lock:
            if not self._file.closed:
                self._file.flush()
                os.fsync(self._file.fileno())
                self._file.close()
