"""Durability subsystem: translog, commit points, crash recovery.

The paper's pitch is that a vector database hosted in a fulltext engine
inherits Elasticsearch's "robustness, stability, scalability" (Rygl et
al. 2017; Lin et al. 2023 make the same argument for Lucene).  Before
this package the reproduction was memory-only -- a process restart lost
every index, ingest, and compaction, and PR 4's failover survived a dead
replica group only because the data still lived in RAM on its siblings.
This package is the missing durability pillar.  Every component maps
onto an ES/Lucene concept:

===============================  ==========================================
this package                     Elasticsearch / Lucene analogue
===============================  ==========================================
:class:`Translog`                the shard transaction log
(:mod:`~repro.store.translog`)   (``index.translog``): framed, crc32'd,
                                 sequence-numbered add/delete records,
                                 fsync'd per ``durability`` ("request" =
                                 fsync before ack, "async" = buffered);
                                 generation files rolled at each commit
                                 and trimmed once covered.  Deviation:
                                 operation-scoped, not per-shard --
                                 round-robin ingest routing is a pure
                                 function of the append counter, so one
                                 global op stream reproduces every shard
                                 (on any mesh shape) bit for bit.
commit points                    a Lucene commit (``segments_N``) run
(:mod:`~repro.store.snapshot`)   through the ES *incremental snapshot*
                                 model: the index splits into
                                 content-addressed blob files (base
                                 vectors / base state / active buffer /
                                 one per sealed segment) named by a
                                 digest of their bytes, so a part
                                 unchanged since the last commit is
                                 *referenced again* instead of
                                 rewritten -- commits and
                                 ``restore_group`` are O(changed), not
                                 O(index).  The manifest's atomic rename
                                 IS the commit; ``latest_commit`` falls
                                 back a generation when any referenced
                                 blob is damaged; retention GC deletes
                                 only blobs NO retained manifest
                                 references (never the fallback's), under
                                 the store lock so an in-progress restore
                                 cannot lose a blob.  ``restore``
                                 re-partitions onto ANY mesh shape -- ES
                                 snapshot/restore into a differently
                                 sized cluster -- scatter-free (host
                                 assembly + one device_put per leaf; a
                                 device scatter onto replica-replicated
                                 leaves hits the GSPMD cross-replica
                                 double-count, the ``_merge_select_seg``
                                 gotcha).
:func:`recover`                  peer-less shard recovery: open the
(:mod:`~repro.store.recovery`)   newest commit, truncate the translog's
                                 torn tail, replay ops past the commit's
                                 seqno through the live ingest code paths
                                 -- the recovered index is bit-identical
                                 in search to the lost one.
:class:`Store` /                 the shard data path + the write-through
:class:`DurableIndex`            discipline: apply in memory, translog
(:mod:`~repro.store.durable`)    append (fsync per policy), THEN ack --
                                 an acked op survives the process, and a
                                 raising op is never logged (it cannot
                                 poison recovery); ``translog_seq`` rides
                                 each immutable index state through hot
                                 swaps as the commit metadata.
===============================  ==========================================

Wiring: :class:`~repro.cluster.maintenance.MaintenanceDaemon` (given a
``store``) rolls a commit point after each successful background
compaction and trims the replayed translog;
:meth:`~repro.cluster.router.ClusterEngine.restore_group` re-admits a
downed replica group from disk; ``repro.launch.serve --store DIR
[--kill-and-recover]`` demos kill -> recover -> bit-parity end to end.
"""

from repro.store.durable import DurableIndex, Store
from repro.store.recovery import NoCommitError, recover
from repro.store.snapshot import (CommitPoint, latest_commit, restore,
                                  write_commit)
from repro.store.translog import (OP_ADD, OP_DELETE, Translog,
                                  TranslogCorruptedError, read_ops)

__all__ = [
    "Store", "DurableIndex", "Translog", "TranslogCorruptedError",
    "CommitPoint", "write_commit", "latest_commit", "restore", "recover",
    "NoCommitError", "read_ops", "OP_ADD", "OP_DELETE",
]
