"""Commit points: content-addressed incremental snapshots of the index.

The Lucene side of durability, now with the ES *incremental snapshot*
model.  A commit point is a generation-numbered manifest
(``commit-<gen>.json``) whose atomic rename IS the commit -- a crash
mid-write leaves no manifest, so the previous commit stays authoritative
-- plus a set of **content-addressed blob files** the manifest references:

* ``seg-<digest>.seg`` -- one deterministic RSEG container per index
  *part*: the base vectors, the base search state (codes + live), the
  active append buffer, and one blob per sealed
  :class:`~repro.dist.shard_index.Segment`.  The file name is a digest of
  the blob bytes, so a part whose content did not change since the last
  commit hashes to the SAME file and is simply *referenced again* instead
  of rewritten -- commits are O(changed parts), not O(index), exactly how
  an ES snapshot reuses unchanged Lucene segment files across snapshots.
  Determinism is why ``np.savez`` is NOT used here: zipfile stamps
  timestamps into member headers, so equal arrays would produce unequal
  bytes and break the content addressing.  RSEG is magic + a
  ``sort_keys`` JSON array directory + raw C-order array bytes: equal
  arrays <=> equal bytes.
* ``commit-<gen>.json`` -- the manifest: translog seqno covered,
  geometry + segment metadata, encoder parameters, and per-blob
  ``{file, crc32, bytes}`` entries.  :func:`latest_commit` walks
  generations newest-first and returns the first whose manifest AND every
  referenced blob checksum verify, so a torn newest commit falls back to
  the previous one.

**Retention + GC**: :func:`write_commit` keeps the newest two manifests
(current + fallback, so a torn newest data file can still recover) and
then deletes every ``seg-*.seg`` not referenced by ANY retained manifest.
The GC set is the union over retained manifests -- a blob the fallback
commit still references is never deleted, however old.  Callers that
interleave GC with recovery (the :class:`~repro.store.durable.Store`)
serialize both on one lock, so a restore in progress can never have a
referenced blob unlinked under it.

:func:`restore` rebuilds a device-resident :class:`ShardedVectorIndex`:

* host-numpy assembly + ONE ``device_put`` per leaf -- **scatter-free by
  construction**.  This matters on a ``(data, replica)`` mesh: building a
  device table with scatter (``.at[].set``) from replica-replicated
  operands makes GSPMD reassemble the scatter with a cross-replica sum
  that double-counts rows (the ``_merge_select_seg`` gotcha, see
  ROADMAP).
* on the writer's own shard count every stored leaf restores
  bit-identically (blobs hold the per-shard layouts verbatim).  On a
  different shard count, rows re-place by the same deterministic rules
  ingest/merge used: active rows by their append offset
  (``gid - n_docs - seg_base``), sealed-segment rows by gid rank,
  round-robin -- search parity at ``page >= n_ids`` holds on any mesh.
* per-shard posting lists (base and per-segment mini tables) are rebuilt
  with the same one-program SPMD argsort (``_postings_program``) the live
  index uses, so they are bit-identical to the committed index's on the
  same mesh shape.

``shard_tombstones`` is exact on a same-shard-count restore; restoring to
a different shard count redistributes the writer's TOTAL round-robin
(per-shard deletion history is advisory maintenance pressure, not search
state -- the live masks and sentinel codes in the snapshot are the search
truth and restore exactly).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import re
import struct
import zlib
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.encoding import (CombinedEncoder, Encoder, IntervalEncoder,
                                 RoundingEncoder)
from repro.core.search import _SENTINEL
from repro.dist.shard_index import (Segment, ShardedVectorIndex,
                                    _postings_program, _put, _ROW, _VEC)
from repro.dist.sharding import DATA_AXIS

__all__ = ["CommitPoint", "write_commit", "latest_commit", "restore",
           "encoder_meta", "encoder_from_meta"]

_FORMAT_VERSION = 2
_MANIFEST_RE = re.compile(r"^commit-(\d{8})\.json$")
_BLOB_RE = re.compile(r"^seg-[0-9a-f]{16}\.seg$")
_BLOB_MAGIC = b"RSEG"
_RETAINED_COMMITS = 2      # current + one fallback (ES keeps the previous
#                            segments_N for exactly this torn-file case)


# --------------------------------------------------------- encoder (de)ser
def encoder_meta(enc: Encoder) -> dict:
    if isinstance(enc, RoundingEncoder):
        return {"type": "rounding", "precision": enc.precision}
    if isinstance(enc, IntervalEncoder):
        return {"type": "interval", "width": enc.width}
    if isinstance(enc, CombinedEncoder):
        return {"type": "combined", "rounding": encoder_meta(enc.rounding),
                "interval": encoder_meta(enc.interval)}
    raise TypeError(f"cannot serialize encoder {type(enc).__name__}")


def encoder_from_meta(meta: dict) -> Encoder:
    kind = meta.get("type")
    if kind == "rounding":
        return RoundingEncoder(int(meta["precision"]))
    if kind == "interval":
        return IntervalEncoder(float(meta["width"]))
    if kind == "combined":
        return CombinedEncoder(encoder_from_meta(meta["rounding"]),
                               encoder_from_meta(meta["interval"]))
    raise ValueError(f"unknown encoder meta {meta!r}")


# ------------------------------------------------------------ fs plumbing
from .translog import _fsync_dir  # noqa: E402 - one dirent-durability impl


def _write_atomic(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


def _crc32_file(path: str, chunk: int = 1 << 20) -> int:
    """Streaming crc32 -- the snapshot can be the whole corpus, so never
    pull it into memory just to checksum it."""
    crc = 0
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return crc
            crc = zlib.crc32(block, crc)


def _manifest_path(store_dir: str, gen: int) -> str:
    return os.path.join(store_dir, f"commit-{gen:08d}.json")


def _list_commits(store_dir: str):
    gens = []
    for name in os.listdir(store_dir):
        m = _MANIFEST_RE.match(name)
        if m:
            gens.append(int(m.group(1)))
    return sorted(gens)


# ------------------------------------------------------ RSEG blob container
def _pack_blob(arrays: dict) -> bytes:
    """Named numpy arrays -> one deterministic byte string.

    Layout: ``RSEG`` magic, little-endian u32 header length, a
    ``sort_keys``/no-whitespace JSON directory of ``{name, dtype, shape}``
    entries (insertion order preserved -- it indexes the payload), then
    each array's raw C-order bytes.  No timestamps, no compression, no
    alignment padding: equal arrays produce equal bytes, which is the
    whole content-addressing contract.
    """
    entries, payload = [], []
    for name, arr in arrays.items():
        a = np.ascontiguousarray(arr)
        entries.append({"name": name, "dtype": np.dtype(a.dtype).str,
                        "shape": list(a.shape)})
        payload.append(a.tobytes())
    header = json.dumps({"version": 1, "arrays": entries}, sort_keys=True,
                        separators=(",", ":")).encode()
    return b"".join([_BLOB_MAGIC, struct.pack("<I", len(header)), header]
                    + payload)


def _unpack_blob(path: str) -> dict:
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] != _BLOB_MAGIC:
        raise ValueError(f"{path!r} is not an RSEG blob")
    (hlen,) = struct.unpack("<I", blob[4:8])
    directory = json.loads(blob[8:8 + hlen])
    out, off = {}, 8 + hlen
    for e in directory["arrays"]:
        dt, shape = np.dtype(e["dtype"]), tuple(e["shape"])
        n = dt.itemsize * int(np.prod(shape, dtype=np.int64))
        out[e["name"]] = np.frombuffer(
            blob, dtype=dt, count=int(np.prod(shape, dtype=np.int64)),
            offset=off).reshape(shape)
        off += n
    return out


def _write_blob(store_dir: str, arrays: dict, stats: dict) -> dict:
    """Write (or re-reference) one content-addressed blob -> its manifest
    entry.  An existing file with the same digest name and byte length IS
    this content (digest collisions at equal length are out of scope for
    a 128-bit truncated sha256) -- the write is skipped and only
    ``bytes_total`` grows, which is the entire sharing mechanism."""
    blob = _pack_blob(arrays)
    name = f"seg-{hashlib.sha256(blob).hexdigest()[:16]}.seg"
    path = os.path.join(store_dir, name)
    stats["bytes_total"] += len(blob)
    if not (os.path.exists(path) and os.path.getsize(path) == len(blob)):
        _write_atomic(path, blob)
        stats["bytes_written"] += len(blob)
        stats["blobs_written"] += 1
    return {"file": name, "crc32": zlib.crc32(blob), "bytes": len(blob)}


def _referenced_blobs(meta: dict) -> set:
    files = meta.get("files", {})
    refs = {e["file"] for k, e in files.items()
            if k != "segments" and e is not None}
    refs.update(e["file"] for e in files.get("segments", ()))
    return refs


@dataclasses.dataclass(frozen=True)
class CommitPoint:
    """One verified commit: manifest dict + the store directory holding
    the content-addressed blobs it references."""

    generation: int
    seq: int
    meta: dict
    data_path: str            # the store directory


# ----------------------------------------------------------------- commit
def write_commit(store_dir: str, index: ShardedVectorIndex, seq: int,
                 stats: Optional[dict] = None) -> int:
    """Snapshot ``index`` as the next commit generation covering translog
    seqno ``seq``; returns the generation number.

    Every blob lands (fsync'd, or is already on disk from an earlier
    generation -- the content-addressed sharing) before the manifest, and
    the manifest rename is the commit: interrupted writes are invisible to
    :func:`latest_commit`.  Cost is O(changed parts): the base vectors
    blob rewrites only after a compact, the base state only after base
    deletes, a sealed segment's blob only after deletes hit it, and the
    active-buffer blob per append batch -- unchanged parts re-reference
    their existing file.  ``stats`` (optional dict) receives
    ``bytes_written`` / ``bytes_total`` / ``blobs_written`` for the
    benchmarks that measure the O(changed) claim instead of asserting it.
    """
    os.makedirs(store_dir, exist_ok=True)
    ns, dp = index.n_shards, index.docs_per_shard
    nf, n_docs = index.n_features, index.n_docs
    n_act = index.n_active
    if stats is None:
        stats = {}
    stats.update(bytes_written=0, bytes_total=0, blobs_written=0)

    files = {
        "base_vectors": _write_blob(store_dir, {
            "vectors": np.asarray(index.vectors).reshape(ns * dp, nf)
            [:n_docs]}, stats),
        "base_state": _write_blob(store_dir, {
            "codes": np.asarray(index.codes).reshape(ns * dp, -1)[:n_docs],
            "live": np.asarray(index.live).reshape(ns * dp)[:n_docs],
        }, stats),
        "active": None,
        "segments": [],
    }
    if n_act:
        j = np.arange(n_act)
        sg = np.asarray(index.seg_gids)
        if not np.array_equal(sg[j % ns, j // ns],
                              n_docs + index.seg_base + j):
            raise ValueError(
                "active-buffer gids violate round-robin routing -- "
                "refusing to write a snapshot that would not restore "
                "bit-identically")
        # the FULL (S, G) leaves, spare sentinel slots included: a
        # same-mesh restore then reproduces the leaf bits exactly, and
        # the blob only changes when the buffer content does
        files["active"] = _write_blob(store_dir, {
            "vectors": np.asarray(index.seg_vectors),
            "codes": np.asarray(index.seg_codes),
            "gids": sg,
            "live": np.asarray(index.seg_live),
        }, stats)
    for s in index.segments:
        entry = _write_blob(store_dir, {
            "vectors": np.asarray(s.vectors),
            "codes": np.asarray(s.codes),
            "gids": np.asarray(s.gids),
            "live": np.asarray(s.live),
        }, stats)
        entry.update(n_rows=s.n_rows, tombstones=s.tombstones)
        files["segments"].append(entry)

    gens = _list_commits(store_dir)
    gen = (gens[-1] + 1) if gens else 1
    manifest = {
        "format_version": _FORMAT_VERSION,
        "generation": gen,
        "seq": int(seq),
        "n_docs": n_docs,
        "n_appended": index.n_appended,
        "seg_base": index.seg_base,
        "active_tombstones": index.active_tombstones,
        "n_features": nf,
        "code_columns": int(index.codes.shape[-1]),
        "writer_shards": ns,
        "seal_threshold": index.seal_threshold,
        "seg_capacity": index.seg_capacity,
        "shard_tombstones": [int(t) for t in (index.shard_tombstones
                                              or (0,) * ns)],
        "index_best": index.index_best,
        "encoder": encoder_meta(index.encoder),
        "files": files,
        "bytes_written": stats["bytes_written"],
        "bytes_total": stats["bytes_total"],
    }
    _write_atomic(_manifest_path(store_dir, gen),
                  json.dumps(manifest, indent=1).encode())
    _gc_commits(store_dir)
    return gen


def _gc_commits(store_dir: str) -> None:
    """Retention + blob GC: keep the newest ``_RETAINED_COMMITS``
    manifests, then delete every ``seg-*.seg`` no retained manifest
    references.

    The live set is the UNION over retained manifests -- a blob shared
    with (or only referenced by) the fallback commit survives, however
    many generations ago it was written.  A retained manifest that fails
    to parse contributes nothing to the live set but also aborts the
    sweep: deleting blobs while a manifest is unreadable could strand the
    one commit recovery will fall back to.  Callers racing recovery must
    hold the store lock around the whole commit (``Store.commit`` does) --
    that is the GC-safety contract for in-progress ``restore_group``.
    """
    gens = _list_commits(store_dir)
    for old in gens[:-_RETAINED_COMMITS]:
        try:
            os.remove(_manifest_path(store_dir, old))
        except OSError:
            pass
    live: set = set()
    for gen in gens[-_RETAINED_COMMITS:]:
        try:
            with open(_manifest_path(store_dir, gen)) as f:
                live |= _referenced_blobs(json.load(f))
        except (OSError, ValueError):
            return                       # unreadable manifest: skip the GC
    for name in os.listdir(store_dir):
        if _BLOB_RE.match(name) and name not in live:
            try:
                os.remove(os.path.join(store_dir, name))
            except OSError:
                pass


def latest_commit(store_dir: str, *,
                  validate: bool = True) -> Optional[CommitPoint]:
    """Newest commit whose manifest parses AND (with ``validate``, the
    default) whose referenced blobs all match their checksums; earlier
    generations are the fallback (ES keeps the previous ``segments_N``
    for exactly this reason).  None if no valid commit.
    ``validate=False`` skips the per-blob CRCs -- for seq-only lookups
    (e.g. the commit retention bookkeeping) where a full-corpus read per
    call would be pure waste."""
    if not os.path.isdir(store_dir):
        return None
    for gen in reversed(_list_commits(store_dir)):
        try:
            with open(_manifest_path(store_dir, gen)) as f:
                meta = json.load(f)
            if meta.get("format_version") != _FORMAT_VERSION:
                continue
            entries = ([meta["files"][k] for k in ("base_vectors",
                                                   "base_state", "active")
                        if meta["files"][k] is not None]
                       + list(meta["files"]["segments"]))
            ok = True
            for e in entries:
                path = os.path.join(store_dir, e["file"])
                if validate:
                    ok = (os.path.getsize(path) == e["bytes"]
                          and _crc32_file(path) == e["crc32"])
                else:
                    ok = os.path.exists(path)
                if not ok:
                    break
            if not ok:
                continue
        except (OSError, ValueError, KeyError):
            continue
        return CommitPoint(generation=gen, seq=int(meta["seq"]), meta=meta,
                           data_path=store_dir)
    return None


# ---------------------------------------------------------------- restore
def restore(commit: CommitPoint, mesh: Mesh) -> ShardedVectorIndex:
    """Rebuild a device-resident index from ``commit`` on ``mesh``.

    On the writer's own shard count the stored per-shard layouts reload
    verbatim, so every leaf is bit-identical to the committed index's.  A
    different shard count re-places rows host-side by the deterministic
    rules ingest/merge used (active rows by append offset, sealed rows by
    gid rank, round-robin) and places each leaf with one ``device_put``
    (scatter-free -- see module docstring for the replica-mesh GSPMD
    gotcha); postings (base + per-segment mini tables) are rebuilt by the
    same SPMD argsort the live paths use.  On any shape, search results
    match at ``page >= n_ids``.
    """
    meta = commit.meta
    store_dir = commit.data_path
    files = meta["files"]
    blob = lambda entry: _unpack_blob(os.path.join(store_dir, entry["file"]))
    base_vectors = blob(files["base_vectors"])["vectors"]
    base_state = blob(files["base_state"])
    base_codes, base_live = base_state["codes"], base_state["live"]

    n_docs, n_app = int(meta["n_docs"]), int(meta["n_appended"])
    seg_base = int(meta["seg_base"])
    n_act = n_app - seg_base
    nf, C = int(meta["n_features"]), int(meta["code_columns"])
    encoder = encoder_from_meta(meta["encoder"])
    cdtype = base_codes.dtype
    sentinel = _SENTINEL[jnp.dtype(cdtype)]
    ns, dp, pad = ShardedVectorIndex._partition_geometry(mesh, n_docs)
    same_shards = ns == int(meta["writer_shards"])

    vec = np.zeros((ns * dp, nf), np.float32)
    vec[:n_docs] = base_vectors
    codes = np.full((ns * dp, C), sentinel, cdtype)
    codes[:n_docs] = base_codes
    live = np.zeros((ns * dp,), bool)
    live[:n_docs] = base_live

    vectors = _put(mesh, vec.reshape(ns, dp, nf), _ROW)
    codes = _put(mesh, codes.reshape(ns, dp, C), _ROW)
    live = _put(mesh, live.reshape(ns, dp), _VEC)
    pdocs, pcodes = _postings_program(codes, mesh=mesh)

    # ----- active append buffer
    if files["active"] is not None and same_shards:
        act = blob(files["active"])        # leaf-level bit-identity
        sv, sc = act["vectors"], act["codes"]
        sg, sl = act["gids"], act["live"]
    else:
        if n_act:
            act = blob(files["active"])
            # a fresh geometric ladder, as one add_documents from empty
            # would allocate; spare slots are sentinel-coded and invisible
            cap = max(math.ceil(n_act / ns), 8)
        else:
            cap = 0
        sv = np.zeros((ns, cap, nf), np.float32)
        sc = np.full((ns, cap, C), sentinel, cdtype)
        sg = np.full((ns, cap), -1, np.int32)
        sl = np.zeros((ns, cap), bool)
        if n_act:
            rows = act["gids"].reshape(-1) >= 0
            gids = act["gids"].reshape(-1)[rows]
            # active rows re-place by append offset: the j-th doc appended
            # since the last seal sits in slot j // S of shard j % S
            j = gids - n_docs - seg_base
            s, g = j % ns, j // ns
            sv[s, g] = act["vectors"].reshape(-1, nf)[rows]
            sc[s, g] = act["codes"].reshape(-1, C)[rows]
            sg[s, g] = gids.astype(np.int32)
            sl[s, g] = act["live"].reshape(-1)[rows]

    # ----- sealed segments
    segments = []
    for e in files["segments"]:
        part = blob(e)
        if same_shards:
            mv, mc = part["vectors"], part["codes"]
            mg, ml = part["gids"], part["live"]
        else:
            rows = part["gids"].reshape(-1) >= 0
            gids = part["gids"].reshape(-1)[rows]
            order = np.argsort(gids, kind="stable")
            # sealed rows re-place by gid rank -- the rule both sealing
            # (contiguous gids) and merging (id-order re-pack) produce
            w = -(-int(e["n_rows"]) // ns)
            mv = np.zeros((ns, w, nf), np.float32)
            mc = np.full((ns, w, C), sentinel, cdtype)
            mg = np.full((ns, w), -1, np.int32)
            ml = np.zeros((ns, w), bool)
            r = np.arange(gids.size)
            s, g = r % ns, r // ns
            mv[s, g] = part["vectors"].reshape(-1, nf)[rows][order]
            mc[s, g] = part["codes"].reshape(-1, C)[rows][order]
            mg[s, g] = gids[order].astype(np.int32)
            ml[s, g] = part["live"].reshape(-1)[rows][order]
        dcod = _put(mesh, mc, _ROW)
        spd, spc = _postings_program(dcod, mesh=mesh)
        segments.append(Segment(
            _put(mesh, mv, _ROW), dcod, _put(mesh, mg, _VEC),
            _put(mesh, ml, _VEC), spd, spc,
            n_rows=int(e["n_rows"]), tombstones=int(e["tombstones"])))

    stones = [int(t) for t in meta["shard_tombstones"]]
    if not same_shards:
        total = sum(stones)                 # advisory: exact total, even
        stones = [total // ns + (i < total % ns) for i in range(ns)]
    if not any(stones):
        stones = []                         # the fresh-index spelling

    seal = meta["seal_threshold"]
    return ShardedVectorIndex(
        vectors=vectors,
        codes=codes,
        post_docs=pdocs,
        post_codes=pcodes,
        offsets=_put(mesh, ShardedVectorIndex._offsets(ns, dp),
                     P(DATA_AXIS)),
        live=live,
        seg_vectors=_put(mesh, sv, _ROW),
        seg_codes=_put(mesh, sc, _ROW),
        seg_gids=_put(mesh, sg, _VEC),
        seg_live=_put(mesh, sl, _VEC),
        segments=tuple(segments),
        encoder=encoder,
        mesh=mesh,
        n_docs=n_docs,
        index_best=meta["index_best"],
        n_appended=n_app,
        shard_tombstones=tuple(stones),
        seal_threshold=None if seal is None else int(seal),
        seg_base=seg_base,
        active_tombstones=int(meta["active_tombstones"]),
    )
