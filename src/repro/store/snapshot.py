"""Commit points: immutable on-disk snapshots of the sharded index.

The Lucene side of durability.  A *commit point* is what ES calls the
``segments_N`` file a Lucene commit writes: an immutable, checksummed
snapshot of every live segment plus a generation-numbered manifest whose
atomic rename IS the commit -- a crash mid-write leaves no manifest, so
the previous commit point stays authoritative and recovery never sees a
half-written index.  Here:

* ``segments-<gen>.npz`` -- the index state in *canonical flat form*
  (base vectors/codes/live over ``[0, n_docs)`` in global-id order, and
  the append segments flattened to append order), NOT the per-device
  leaves.  The flat form is mesh-shape-free, which is what lets
  :func:`restore` rebuild the index on a mesh with a different shard or
  replica count than the writer's (ES snapshot/restore into a differently
  sized cluster).  Written to a temp file, fsync'd, then renamed.
* ``commit-<gen>.json`` -- the manifest: translog seqno the snapshot
  covers, geometry, encoder parameters, a crc32 of the data file.
  Written last via fsync'd temp file + ``os.replace`` (the atomic
  rename); :func:`latest_commit` walks generations newest-first and
  returns the first one whose manifest AND data checksum verify, so a
  corrupt newest commit falls back to the previous one instead of
  failing recovery.

:func:`restore` rebuilds a device-resident :class:`ShardedVectorIndex`:

* the flat arrays are padded/partitioned for the TARGET mesh geometry
  entirely in host numpy and placed with ONE ``device_put`` per leaf --
  **scatter-free by construction**.  This matters on a ``(data,
  replica)`` mesh: building a device table with scatter (``.at[].set``)
  from replica-replicated operands makes GSPMD reassemble the scatter
  with a cross-replica sum that double-counts rows (the
  ``_merge_select_seg`` gotcha, see ROADMAP) -- host-side assembly +
  device_put has no device scatter to mis-partition, on any mesh shape.
* per-shard posting lists are rebuilt with the same one-program SPMD
  argsort (``_postings_program``) that ``build``/``delete`` use, so the
  restored postings are bit-identical to the live index's on the same
  mesh shape -- and searches are bit-identical on ANY mesh shape at
  ``page >= n_docs`` (the repo-wide mesh-parity invariant).
* append segments re-place by the same round-robin routing formula
  ingest used (slot ``j // S`` of shard ``j % S`` for the ``j``-th doc
  appended since the last compaction) -- deterministic routing is what
  makes the flat form sufficient.

``shard_tombstones`` is exact on a same-shard-count restore; restoring to
a different shard count redistributes the writer's TOTAL round-robin
(per-shard deletion history is advisory maintenance pressure, not search
state -- the live masks and sentinel codes in the snapshot are the search
truth and restore exactly).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import zlib
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.encoding import (CombinedEncoder, Encoder, IntervalEncoder,
                                 RoundingEncoder)
from repro.core.search import _SENTINEL
from repro.dist.shard_index import (ShardedVectorIndex, _postings_program,
                                    _put, _ROW, _VEC)
from repro.dist.sharding import DATA_AXIS

__all__ = ["CommitPoint", "write_commit", "latest_commit", "restore",
           "encoder_meta", "encoder_from_meta"]

_FORMAT_VERSION = 1
_MANIFEST_RE = re.compile(r"^commit-(\d{8})\.json$")


# --------------------------------------------------------- encoder (de)ser
def encoder_meta(enc: Encoder) -> dict:
    if isinstance(enc, RoundingEncoder):
        return {"type": "rounding", "precision": enc.precision}
    if isinstance(enc, IntervalEncoder):
        return {"type": "interval", "width": enc.width}
    if isinstance(enc, CombinedEncoder):
        return {"type": "combined", "rounding": encoder_meta(enc.rounding),
                "interval": encoder_meta(enc.interval)}
    raise TypeError(f"cannot serialize encoder {type(enc).__name__}")


def encoder_from_meta(meta: dict) -> Encoder:
    kind = meta.get("type")
    if kind == "rounding":
        return RoundingEncoder(int(meta["precision"]))
    if kind == "interval":
        return IntervalEncoder(float(meta["width"]))
    if kind == "combined":
        return CombinedEncoder(encoder_from_meta(meta["rounding"]),
                               encoder_from_meta(meta["interval"]))
    raise ValueError(f"unknown encoder meta {meta!r}")


# ------------------------------------------------------------ fs plumbing
from .translog import _fsync_dir  # noqa: E402 - one dirent-durability impl


def _write_atomic(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


def _crc32_file(path: str, chunk: int = 1 << 20) -> int:
    """Streaming crc32 -- the snapshot can be the whole corpus, so never
    pull it into memory just to checksum it."""
    crc = 0
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return crc
            crc = zlib.crc32(block, crc)


def _manifest_path(store_dir: str, gen: int) -> str:
    return os.path.join(store_dir, f"commit-{gen:08d}.json")


def _data_name(gen: int) -> str:
    return f"segments-{gen:08d}.npz"


def _list_commits(store_dir: str):
    gens = []
    for name in os.listdir(store_dir):
        m = _MANIFEST_RE.match(name)
        if m:
            gens.append(int(m.group(1)))
    return sorted(gens)


@dataclasses.dataclass(frozen=True)
class CommitPoint:
    """One verified commit: manifest dict + the path of its data file."""

    generation: int
    seq: int
    meta: dict
    data_path: str


# ----------------------------------------------------------------- commit
def write_commit(store_dir: str, index: ShardedVectorIndex, seq: int) -> int:
    """Snapshot ``index`` as the next commit generation covering translog
    seqno ``seq``; returns the generation number.

    The data file lands (fsync'd) before the manifest, and the manifest
    rename is the commit -- interrupted writes are invisible to
    :func:`latest_commit`.  The snapshot stores canonical flat arrays
    (see module docstring), so any live index whose search state is equal
    produces an equal snapshot regardless of its mesh shape.
    """
    os.makedirs(store_dir, exist_ok=True)
    ns, dp = index.n_shards, index.docs_per_shard
    nf, n_docs = index.n_features, index.n_docs
    n_app = index.n_appended
    arrays = {
        "base_vectors": np.asarray(index.vectors).reshape(ns * dp, nf)
        [:n_docs],
        "base_codes": np.asarray(index.codes).reshape(
            ns * dp, -1)[:n_docs],
        "base_live": np.asarray(index.live).reshape(ns * dp)[:n_docs],
    }
    if n_app:
        j = np.arange(n_app)
        s, g = j % ns, j // ns
        sg = np.asarray(index.seg_gids)
        if not np.array_equal(sg[s, g], n_docs + j):
            raise ValueError(
                "segment gids violate round-robin routing -- refusing to "
                "write a snapshot that would not restore bit-identically")
        arrays["seg_vectors"] = np.asarray(index.seg_vectors)[s, g]
        arrays["seg_codes"] = np.asarray(index.seg_codes)[s, g]
        arrays["seg_live"] = np.asarray(index.seg_live)[s, g]

    gens = _list_commits(store_dir)
    gen = (gens[-1] + 1) if gens else 1
    data_path = os.path.join(store_dir, _data_name(gen))
    tmp = data_path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, data_path)
    _fsync_dir(store_dir)

    # one sequential re-read of the bytes just written (page-cache hot);
    # checksumming DURING the write does not compose with np.savez --
    # zipfile seeks back to patch member headers on seekable files, which
    # invalidates any crc accumulated over the write stream
    crc = _crc32_file(data_path)
    manifest = {
        "format_version": _FORMAT_VERSION,
        "generation": gen,
        "seq": int(seq),
        "n_docs": n_docs,
        "n_appended": n_app,
        "n_features": nf,
        "code_columns": int(index.codes.shape[-1]),
        "writer_shards": ns,
        "seg_capacity": index.seg_capacity,
        "shard_tombstones": [int(t) for t in (index.shard_tombstones
                                              or (0,) * ns)],
        "index_best": index.index_best,
        "encoder": encoder_meta(index.encoder),
        "data_file": _data_name(gen),
        "data_crc32": crc,
    }
    _write_atomic(_manifest_path(store_dir, gen),
                  json.dumps(manifest, indent=1).encode())
    # deletion policy: keep this commit plus one fallback (the ES default
    # keeps only the latest; we keep two so a torn newest data file can
    # still recover), prune older generations
    for old in _list_commits(store_dir)[:-2]:
        for path in (_manifest_path(store_dir, old),
                     os.path.join(store_dir, _data_name(old))):
            try:
                os.remove(path)
            except OSError:
                pass
    return gen


def latest_commit(store_dir: str, *,
                  validate: bool = True) -> Optional[CommitPoint]:
    """Newest commit whose manifest parses AND (with ``validate``, the
    default) whose data file matches its checksum; earlier generations
    are the fallback (ES keeps the previous ``segments_N`` for exactly
    this reason).  None if no valid commit.  ``validate=False`` skips the
    streaming data-file CRC -- for seq-only lookups (e.g. the commit
    retention bookkeeping) where a full-corpus read per call would be
    pure waste."""
    if not os.path.isdir(store_dir):
        return None
    for gen in reversed(_list_commits(store_dir)):
        try:
            with open(_manifest_path(store_dir, gen)) as f:
                meta = json.load(f)
            data_path = os.path.join(store_dir, meta["data_file"])
            if validate and _crc32_file(data_path) != meta["data_crc32"]:
                continue
            if not validate and not os.path.exists(data_path):
                continue
        except (OSError, ValueError, KeyError):
            continue
        return CommitPoint(generation=gen, seq=int(meta["seq"]), meta=meta,
                           data_path=data_path)
    return None


# ---------------------------------------------------------------- restore
def restore(commit: CommitPoint, mesh: Mesh) -> ShardedVectorIndex:
    """Rebuild a device-resident index from ``commit`` on ``mesh``.

    The target mesh may have a different shard/replica count than the
    writer's: leaves are re-partitioned host-side from the canonical flat
    arrays and placed with one ``device_put`` each (scatter-free -- see
    module docstring for the replica-mesh GSPMD gotcha), and postings are
    rebuilt by the same SPMD argsort the live build uses.  On the
    writer's own mesh shape every leaf is bit-identical to the index that
    was committed; on any shape, search results match at
    ``page >= n_docs``.
    """
    meta = commit.meta
    with np.load(commit.data_path) as z:
        base_vectors = z["base_vectors"]
        base_codes = z["base_codes"]
        base_live = z["base_live"]
        seg = "seg_vectors" in z.files
        if seg:
            seg_vectors, seg_codes = z["seg_vectors"], z["seg_codes"]
            seg_live = z["seg_live"]

    n_docs, n_app = int(meta["n_docs"]), int(meta["n_appended"])
    nf, C = int(meta["n_features"]), int(meta["code_columns"])
    encoder = encoder_from_meta(meta["encoder"])
    sentinel = _SENTINEL[jnp.dtype(base_codes.dtype)]
    ns, dp, pad = ShardedVectorIndex._partition_geometry(mesh, n_docs)

    vec = np.zeros((ns * dp, nf), np.float32)
    vec[:n_docs] = base_vectors
    codes = np.full((ns * dp, C), sentinel, base_codes.dtype)
    codes[:n_docs] = base_codes
    live = np.zeros((ns * dp,), bool)
    live[:n_docs] = base_live

    vectors = _put(mesh, vec.reshape(ns, dp, nf), _ROW)
    codes = _put(mesh, codes.reshape(ns, dp, C), _ROW)
    live = _put(mesh, live.reshape(ns, dp), _VEC)
    pdocs, pcodes = _postings_program(codes, mesh=mesh)

    if n_app and ns == int(meta["writer_shards"]):
        cap = int(meta["seg_capacity"])     # leaf-level bit-identity
    elif n_app:
        # a fresh geometric ladder, as one add_documents from empty would
        # allocate; spare slots are sentinel-coded and invisible
        cap = max(math.ceil(n_app / ns), 8)
    else:
        cap = 0
    sv = np.zeros((ns, cap, nf), np.float32)
    sc = np.full((ns, cap, C), sentinel, base_codes.dtype)
    sg = np.full((ns, cap), -1, np.int32)
    sl = np.zeros((ns, cap), bool)
    if n_app:
        j = np.arange(n_app)
        s, g = j % ns, j // ns
        sv[s, g] = seg_vectors
        sc[s, g] = seg_codes
        sg[s, g] = (n_docs + j).astype(np.int32)
        sl[s, g] = seg_live

    stones = [int(t) for t in meta["shard_tombstones"]]
    if ns != int(meta["writer_shards"]):
        total = sum(stones)                 # advisory: exact total, even
        stones = [total // ns + (i < total % ns) for i in range(ns)]
    if not any(stones):
        stones = []                         # the fresh-index spelling

    return ShardedVectorIndex(
        vectors=vectors,
        codes=codes,
        post_docs=pdocs,
        post_codes=pcodes,
        offsets=_put(mesh, ShardedVectorIndex._offsets(ns, dp),
                     P(DATA_AXIS)),
        live=live,
        seg_vectors=_put(mesh, sv, _ROW),
        seg_codes=_put(mesh, sc, _ROW),
        seg_gids=_put(mesh, sg, _VEC),
        seg_live=_put(mesh, sl, _VEC),
        encoder=encoder,
        mesh=mesh,
        n_docs=n_docs,
        index_best=meta["index_best"],
        n_appended=n_app,
        shard_tombstones=tuple(stones),
    )
