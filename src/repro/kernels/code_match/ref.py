"""Pure-jnp oracle for the code_match kernel."""

from __future__ import annotations

import jax.numpy as jnp


def code_match_ref(
    doc_codes: jnp.ndarray,    # (d, C) int
    qcodes: jnp.ndarray,       # (Q, C) int
    col_weights: jnp.ndarray,  # (Q, C) f32
) -> jnp.ndarray:
    eq = qcodes[:, None, :] == doc_codes[None, :, :]      # (Q, d, C)
    return jnp.sum(jnp.where(eq, col_weights[:, None, :], 0.0), axis=-1)
