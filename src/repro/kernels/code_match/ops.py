"""Public jit'd wrapper around the code_match Pallas kernel.

Handles padding to block multiples and backend selection: on TPU the compiled
kernel runs natively; elsewhere ``interpret=True`` executes the same kernel
body on CPU (used by the test-suite sweeps), unless the problem is large, in
which case the jnp reference path (same math, XLA-fused) is used for speed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_C, DEFAULT_BLOCK_D, DEFAULT_BLOCK_Q, code_match_pallas
from .ref import code_match_ref

_INTERPRET_ELEMENT_LIMIT = 1 << 22  # interpret mode is python-speed; cap it


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def code_match(
    doc_codes: jnp.ndarray,
    qcodes: jnp.ndarray,
    col_weights: jnp.ndarray,
    block_q: int = DEFAULT_BLOCK_Q,
    block_d: int = DEFAULT_BLOCK_D,
    block_c: int = DEFAULT_BLOCK_C,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """out (Q, d): weighted code-equality scores; see kernel.py."""
    d, C = doc_codes.shape
    Q = qcodes.shape[0]

    on_tpu = _on_tpu()
    if not on_tpu and not force_pallas:
        work = Q * d * C
        if work > _INTERPRET_ELEMENT_LIMIT:
            return code_match_ref(doc_codes, qcodes, col_weights)

    block_q = min(block_q, max(Q, 1))
    block_d = min(block_d, max(d, 1))
    pad_q = (-Q) % block_q
    pad_d = (-d) % block_d
    qc = jnp.pad(qcodes, ((0, pad_q), (0, 0)))
    w = jnp.pad(col_weights, ((0, pad_q), (0, 0)))
    dc = jnp.pad(doc_codes, ((0, pad_d), (0, 0)))
    out = code_match_pallas(
        dc, qc, w,
        block_q=block_q, block_d=block_d, block_c=block_c,
        interpret=not on_tpu,
    )
    return out[:Q, :d]
