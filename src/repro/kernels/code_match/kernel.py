"""Pallas TPU kernel for phase-1 code-match scoring.

Computes ``out[q, d] = sum_c w[q, c] * (qcodes[q, c] == doc_codes[d, c])`` --
the paper's inverted-index score re-expressed as a masked quantized-Hamming
similarity (DESIGN.md §2).

TPU mapping: the (d, C) int8/int16 code matrix streams HBM -> VMEM in
``(BLOCK_D, C)`` tiles; queries and weights for a ``(BLOCK_Q, C)`` tile stay
resident.  The equality-compare + weighted reduce is VPU work (equality has
no MXU form), vectorised over the 8x128 lanes; the C axis is walked in
``BLOCK_C`` chunks so the (BLOCK_Q, BLOCK_D, BLOCK_C) compare cube stays
within VMEM.  Arithmetic intensity is ~2 flop/byte at int8, so the kernel is
memory-bound by construction -- the win over phase-1 on raw f32 vectors is
exactly the 4x byte reduction of int8 codes (plus query-side trim zeroing
whole columns, which XLA cannot exploit but the postings engine and the
column-gather pre-pass can; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 8
DEFAULT_BLOCK_D = 512
DEFAULT_BLOCK_C = 128


def _code_match_kernel(q_ref, w_ref, d_ref, o_ref, *, block_c: int):
    """One (BLOCK_Q, BLOCK_D) output tile."""
    n_cols = q_ref.shape[-1]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for c0 in range(0, n_cols, block_c):  # static unroll: n_cols is compile-time
        qc = q_ref[:, c0 : c0 + block_c]          # (BQ, BC) int
        dc = d_ref[:, c0 : c0 + block_c]          # (BD, BC) int
        w = w_ref[:, c0 : c0 + block_c]           # (BQ, BC) f32
        eq = qc[:, None, :] == dc[None, :, :]     # (BQ, BD, BC) bool
        acc = acc + jnp.sum(jnp.where(eq, w[:, None, :], 0.0), axis=-1)
    o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_d", "block_c", "interpret"),
)
def code_match_pallas(
    doc_codes: jnp.ndarray,   # (d, C) int
    qcodes: jnp.ndarray,      # (Q, C) int
    col_weights: jnp.ndarray,  # (Q, C) f32
    block_q: int = DEFAULT_BLOCK_Q,
    block_d: int = DEFAULT_BLOCK_D,
    block_c: int = DEFAULT_BLOCK_C,
    interpret: bool = False,
) -> jnp.ndarray:
    """Padded-shape Pallas call; use :mod:`.ops` for the public wrapper."""
    d, C = doc_codes.shape
    Q = qcodes.shape[0]
    assert Q % block_q == 0 and d % block_d == 0, (Q, d, block_q, block_d)

    grid = (Q // block_q, d // block_d)
    kernel = functools.partial(_code_match_kernel, block_c=min(block_c, C))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, C), lambda i, j: (i, 0)),   # qcodes
            pl.BlockSpec((block_q, C), lambda i, j: (i, 0)),   # weights
            pl.BlockSpec((block_d, C), lambda i, j: (j, 0)),   # doc codes
        ],
        out_specs=pl.BlockSpec((block_q, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, d), jnp.float32),
        interpret=interpret,
    )(qcodes, col_weights, doc_codes)
