"""Pallas TPU kernels for the two-phase search hot path.

Every kernel package follows the same layout -- ``kernel.py`` (the padded
``pallas_call`` + kernel body), ``ops.py`` (the public wrapper: compiled on
TPU, ``interpret=True`` on CPU for small problems, an XLA-fused jnp or
streaming-scan fallback for large ones), ``ref.py`` (the pure-jnp oracle
the parity suite pins against).  Inventory:

* ``code_match``  -- phase-1 scoring tile: ``out[q, d] = sum_c w[q, c] *
  (qcodes[q, c] == doc_codes[d, c])``, the paper's inverted-index score as
  a masked quantized-Hamming similarity.  VPU work, memory-bound; the
  ``codes_pallas`` engine dispatches here.  Emits the full (Q, d) score
  matrix (block-chunked C reduction, so parity is approximate at 1e-5).
* ``rerank_topk`` -- phase-2 exact cosine re-rank of a candidate page via
  MXU matmul tiles; final scores always come from the canonical
  ``(Q, k, n)`` einsum in :mod:`repro.core.rerank` (the last-ulp parity
  contract shared with the sharded merge).
* ``bucketize``   -- fused normalize + quantize encode used at
  build/ingest: one HBM pass instead of normalize -> rounds -> casts.
* ``fused_phase1`` -- THE query hot path (ROADMAP fused-path item):
  phase-1 scoring and the running top-``page`` selection in ONE kernel.
  Tiling: grid (Q/BQ, d/BD) with the doc axis minor; each step scores a
  (BQ, BD) tile -- fp32 weighted code equality (``fused`` engine) or int8
  quantized dot + per-row affine correction (``fused_int8`` engine, table
  from :mod:`repro.core.quantize`) -- and folds it into a (BQ, page)
  accumulator kept in the revisited output block: ``top_k(concat([acc,
  tile]))``.  Stable top-k makes the streamed fold bit-equivalent to one
  global top-k, and the C reduction is unchunked, so the fp32 path is
  BIT-identical to the composed reference while never materializing the
  (Q, d) score matrix in HBM (the composed path writes + re-reads it --
  2*Q*d*4 bytes that dominate at scale; see BENCH_kernel_scale.json).

Why the final rescore stays fp32 and unsharded: quantization and fusion
only pick WHICH candidates reach phase 2 -- reported scores always come
from the exact (Q, k, n) einsum with unsharded operands on the
coordinating device.  That keeps recall the only quality variable (the
paper's knob), and keeps every mesh shape / engine / quantization setting
bit-identical in reported scores for the hits they agree on.
"""
