"""Pallas TPU kernel for phase-2 candidate scoring (exact cosine).

``scores[q, p] = sum_n cand[q, p, n] * query[q, n]`` over unit-normalised
vectors -- a batched (page x n) @ (n,) matvec.  Tiles the page axis so each
(BLOCK_P, n) candidate slab sits in VMEM and lowers the contraction to an MXU
dot.  Top-k selection stays outside the kernel (``jax.lax.top_k``): k is tiny
(<= 10) and selection is latency-, not bandwidth-, bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_P = 256


def _rerank_kernel(c_ref, q_ref, o_ref):
    # c_ref: (1, BLOCK_P, n); q_ref: (1, n); o_ref: (1, BLOCK_P)
    cand = c_ref[0]                       # (BLOCK_P, n)
    q = q_ref[0]                          # (n,)
    o_ref[0, :] = jax.lax.dot_general(
        cand, q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def rerank_scores_pallas(
    cand_vecs: jnp.ndarray,  # (Q, P, n) f32 gathered candidates
    queries: jnp.ndarray,    # (Q, n) f32
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = False,
) -> jnp.ndarray:
    Q, P, n = cand_vecs.shape
    assert P % block_p == 0, (P, block_p)
    grid = (Q, P // block_p)
    return pl.pallas_call(
        _rerank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_p, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, P), jnp.float32),
        interpret=interpret,
    )(cand_vecs, queries)
