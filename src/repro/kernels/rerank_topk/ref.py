"""Pure-jnp oracle for the rerank_topk kernel."""

from __future__ import annotations

import jax.numpy as jnp


def rerank_scores_ref(cand_vecs: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """(Q, P, n), (Q, n) -> (Q, P) exact cosine (inputs unit-normalised)."""
    return jnp.einsum(
        "qpn,qn->qp", cand_vecs, queries, preferred_element_type=jnp.float32
    )
