"""Public wrapper: fused phase-2 rerank (gather -> Pallas scores -> top-k)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_P, rerank_scores_pallas
from .ref import rerank_scores_ref

_INTERPRET_ELEMENT_LIMIT = 1 << 22


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rerank_scores(
    cand_vecs: jnp.ndarray,
    queries: jnp.ndarray,
    block_p: int = DEFAULT_BLOCK_P,
    force_pallas: bool = False,
) -> jnp.ndarray:
    Q, P, n = cand_vecs.shape
    on_tpu = _on_tpu()
    if not on_tpu and not force_pallas and Q * P * n > _INTERPRET_ELEMENT_LIMIT:
        return rerank_scores_ref(cand_vecs, queries)
    block_p = min(block_p, P)
    pad_p = (-P) % block_p
    cv = jnp.pad(cand_vecs, ((0, 0), (0, pad_p), (0, 0)))
    out = rerank_scores_pallas(cv, queries, block_p=block_p, interpret=not on_tpu)
    return out[:, :P]


def rerank_topk(
    vectors: jnp.ndarray,    # (d, n) index vectors, unit rows
    cand_ids: jnp.ndarray,   # (Q, page) int32
    queries: jnp.ndarray,    # (Q, n) unit rows
    k: int,
    block_p: int = DEFAULT_BLOCK_P,
    force_pallas: bool = False,
):
    """Kernelized equivalent of :func:`repro.core.rerank.rerank_topk`.

    Selection runs on the Pallas scores; the returned scores are recomputed
    through :func:`repro.core.rerank.exact_scores` at the (Q, k, n) shape --
    the same final-score contract as the core and doc-sharded paths, so the
    three implementations stay exactly comparable."""
    from repro.core.rerank import exact_scores

    cand = vectors[cand_ids]
    scores = rerank_scores(cand, queries, block_p=block_p, force_pallas=force_pallas)
    _, top_pos = jax.lax.top_k(scores, k)
    top_ids = jnp.take_along_axis(cand_ids, top_pos, axis=1)
    return top_ids, exact_scores(vectors, top_ids, queries)
