"""Pure-jnp oracles for the fused phase-1 kernel.

Each oracle is the COMPOSED path the fused kernel replaces: materialize the
full (Q, d) phase-1 score matrix, mask dead rows, then one global stable
``top_k(page)``.  The fused kernel must match these bit-exactly in fp32
(scores always; ids wherever the score is finite -- see ops.py for the
-inf-slot contract).

:func:`match_scores` is the ONE scoring expression the whole fp32 family
shares (this oracle, the Pallas kernel body, the streaming fallback, and
the sharded generation scorer): select then a MANUAL pairwise-tree sum
over the code columns, zero-padded to a power of two.  Every tree step is
an elementwise add of two halves, so the reduction order is a pure
function of C -- the bits cannot depend on how the doc or query axis is
tiled.  A ``jnp.sum`` over C does NOT have that property: XLA picks the
reduction order per tensor shape, and blocked vs full scoring then
disagrees in the last ulp for some (tile, C) combinations.  (Zero-padding
is exact: scores are sums of non-negative weights, and x + 0.0 == x for
every such float.)  The tree also benches slightly faster than the
where/sum form at the stream tile size.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize import quantized_scores


def match_scores(doc_codes: jnp.ndarray,    # (d, C) int
                 qcodes: jnp.ndarray,       # (Q, C) int
                 col_weights: jnp.ndarray,  # (Q, C) f32
                 ) -> jnp.ndarray:
    """Code-match scores (Q, d): select the matching weights, then sum
    the C axis with a fixed pairwise tree.  Bit-invariant to doc/query
    tiling (see module doc)."""
    x = jnp.where(qcodes[:, None, :] == doc_codes[None, :, :],
                  col_weights[:, None, :], 0.0)          # (Q, d, C)
    n = x.shape[-1]
    p2 = 1 << max(n - 1, 0).bit_length()                 # next power of two
    if p2 != n:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, p2 - n)))
    while x.shape[-1] > 1:
        h = x.shape[-1] // 2
        x = x[..., :h] + x[..., h:]
    return x[..., 0]


def _mask_topk(scores: jnp.ndarray, live: Optional[jnp.ndarray],
               page: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if live is not None:
        scores = jnp.where(live[None, :], scores, -jnp.inf)
    top_s, top_i = jax.lax.top_k(scores, page)
    return top_s, top_i.astype(jnp.int32)


def fused_phase1_ref(
    doc_codes: jnp.ndarray,    # (d, C) int
    qcodes: jnp.ndarray,       # (Q, C) int
    col_weights: jnp.ndarray,  # (Q, C) f32
    page: int,
    live: Optional[jnp.ndarray] = None,   # (d,) bool
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Composed fp32 reference: code_match scores -> mask -> top_k(page)."""
    scores = match_scores(doc_codes, qcodes, col_weights)
    return _mask_topk(scores, live, page)


def fused_phase1_quant_ref(
    qcodes8: jnp.ndarray,     # (d, n) int8 quantized rows
    scale: jnp.ndarray,       # (d,) f32
    zero: jnp.ndarray,        # (d,) f32
    queries: jnp.ndarray,     # (Q, n) f32
    page: int,
    live: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Composed int8 reference: quantized_scores -> mask -> top_k(page)."""
    qsum = jnp.sum(queries, axis=-1, keepdims=True)
    scores = quantized_scores(qcodes8, scale, zero, queries, qsum=qsum)
    return _mask_topk(scores, live, page)
