"""Fused phase-1 Pallas kernel: tiled scoring + running top-k in one pass.

The composed hot path scores every document, writes the full (Q, d) score
matrix to HBM, reads it back for ``top_k(page)``, and throws it away --
2 x Q x d x 4 bytes of HBM traffic that dwarfs the code table itself once
d is large.  This kernel keeps a running top-``page`` accumulator in the
revisited output block instead, so the score matrix never exists:

* grid = (Q / BLOCK_Q, d / BLOCK_D), with the DOC axis as the minor
  (fastest-moving) grid dimension -- for a fixed query tile the kernel
  walks every doc tile in order, and the output BlockSpec ignores the doc
  index, so the same (BLOCK_Q, page) scores/ids block stays resident in
  VMEM across the whole doc sweep (the standard revisited-accumulator
  pattern);
* each step scores one (BLOCK_Q, BLOCK_D) tile -- weighted code equality
  in fp32 mode, the int8 dot + per-row affine correction in quantized
  mode -- masks dead rows to -inf, and folds the tile into the
  accumulator as ``top_k(concat([acc, tile]), page)``;
* stable ``top_k`` makes the streamed fold EQUIVALENT to one global
  top-k: ties prefer earlier concat positions, accumulator entries hold
  earlier doc ids than any tile entry, and within a tile ids ascend -- so
  the selected ids and scores are bit-identical to the composed
  reference (per-cell scores are untouched by the fold; only selection
  is streamed).

The C (code-column) reduction is the shared fixed pairwise tree from
ref.py (``match_scores``): its order is a pure function of C, so the
per-cell bits are identical to the full-matrix oracle no matter how the
doc axis is tiled -- which is what buys *bit*-exactness against the
composed fp32 path (code_match's BLOCK_C chunking and jnp.sum's
shape-dependent reduction order both trade that away; here BLOCK_D is
the VMEM release valve instead).

Init is branchless: at doc-tile 0 the accumulator read is replaced by
(-inf, 0) placeholders via ``where`` on the grid index, so slots that
never see a finite score report score -inf with an unspecified id
(ops.py documents this contract; ids are clamped in-range there).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 8
DEFAULT_BLOCK_D = 512


def _fold_topk(prev_s, prev_i, tile_s, tile_i, page):
    """One accumulator fold: stable top-k over [acc | tile]."""
    cat_s = jnp.concatenate([prev_s, tile_s], axis=1)
    cat_i = jnp.concatenate([prev_i, tile_i], axis=1)
    top_s, pos = jax.lax.top_k(cat_s, page)
    return top_s, jnp.take_along_axis(cat_i, pos, axis=1)


def _acc_read(os_ref, oi_ref, j):
    """Accumulator contents, or (-inf, 0) placeholders on the first doc
    tile (the output block is uninitialized storage at j == 0)."""
    first = j == 0
    prev_s = jnp.where(first, -jnp.inf, os_ref[...])
    prev_i = jnp.where(first, 0, oi_ref[...])
    return prev_s, prev_i


def _fused_kernel(q_ref, w_ref, d_ref, lv_ref, os_ref, oi_ref, *,
                  block_d: int, page: int):
    """fp32 code-match tile + running top-k fold.  Scores via the shared
    fixed-tree reduction (ref.match_scores), so the tile's bits match the
    full-matrix oracle exactly."""
    from .ref import match_scores

    j = pl.program_id(1)
    qc = q_ref[...]                            # (BQ, C) int
    dc = d_ref[...]                            # (BD, C) int
    w = w_ref[...]                             # (BQ, C) f32
    s = match_scores(dc, qc, w)                # (BQ, BD)
    lv = lv_ref[...][:, 0]                     # (BD,)
    s = jnp.where(lv[None, :], s, -jnp.inf)
    ids = j * block_d + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    prev_s, prev_i = _acc_read(os_ref, oi_ref, j)
    os_ref[...], oi_ref[...] = _fold_topk(prev_s, prev_i, s, ids, page)


def _fused_quant_kernel(q_ref, qsum_ref, d8_ref, sc_ref, zp_ref, lv_ref,
                        os_ref, oi_ref, *, block_d: int, page: int):
    """int8 quantized-dot tile + running top-k fold.  Scores the
    dequantized rows without materializing them:
    ``scale * (codes . query) + zero * sum(query)``."""
    j = pl.program_id(1)
    q = q_ref[...]                             # (BQ, n) f32
    d8 = d8_ref[...].astype(jnp.float32)       # (BD, n)
    raw = jax.lax.dot_general(
        q, d8, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # (BQ, BD)
    sc = sc_ref[...][:, 0]                     # (BD,)
    zp = zp_ref[...][:, 0]
    s = raw * sc[None, :] + qsum_ref[...] * zp[None, :]
    lv = lv_ref[...][:, 0]
    s = jnp.where(lv[None, :], s, -jnp.inf)
    ids = j * block_d + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    prev_s, prev_i = _acc_read(os_ref, oi_ref, j)
    os_ref[...], oi_ref[...] = _fold_topk(prev_s, prev_i, s, ids, page)


def _call(kernel, doc_inputs, q_inputs, Q, d, page, block_q, block_d,
          interpret):
    """Shared pallas_call plumbing: query-tile inputs replicate over the
    doc grid axis, doc-tile inputs over the query axis, and both outputs
    revisit the same (BLOCK_Q, page) block for every doc tile."""
    grid = (Q // block_q, d // block_d)
    q_specs = [pl.BlockSpec((block_q, x.shape[-1]), lambda i, j: (i, 0))
               for x in q_inputs]
    d_specs = [pl.BlockSpec((block_d, x.shape[-1]), lambda i, j: (j, 0))
               for x in doc_inputs]
    out_spec = pl.BlockSpec((block_q, page), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(kernel, block_d=block_d, page=page),
        grid=grid,
        in_specs=q_specs + d_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((Q, page), jnp.float32),
                   jax.ShapeDtypeStruct((Q, page), jnp.int32)],
        interpret=interpret,
    )(*q_inputs, *doc_inputs)


@functools.partial(
    jax.jit, static_argnames=("page", "block_q", "block_d", "interpret"))
def fused_phase1_pallas(
    doc_codes: jnp.ndarray,    # (d, C) int
    qcodes: jnp.ndarray,       # (Q, C) int
    col_weights: jnp.ndarray,  # (Q, C) f32
    live: jnp.ndarray,         # (d,) bool
    page: int,
    block_q: int = DEFAULT_BLOCK_Q,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
):
    """Padded-shape fp32 pallas call; use :mod:`.ops` for the wrapper."""
    d, _ = doc_codes.shape
    Q = qcodes.shape[0]
    assert Q % block_q == 0 and d % block_d == 0, (Q, d, block_q, block_d)
    return _call(_fused_kernel, [doc_codes, live[:, None]],
                 [qcodes, col_weights], Q, d, page, block_q, block_d,
                 interpret)


@functools.partial(
    jax.jit, static_argnames=("page", "block_q", "block_d", "interpret"))
def fused_phase1_quant_pallas(
    qcodes8: jnp.ndarray,      # (d, n) int8
    scale: jnp.ndarray,        # (d,) f32
    zero: jnp.ndarray,         # (d,) f32
    queries: jnp.ndarray,      # (Q, n) f32
    qsum: jnp.ndarray,         # (Q, 1) f32 precomputed row sums
    live: jnp.ndarray,         # (d,) bool
    page: int,
    block_q: int = DEFAULT_BLOCK_Q,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
):
    """Padded-shape int8 pallas call; use :mod:`.ops` for the wrapper."""
    d, _ = qcodes8.shape
    Q = queries.shape[0]
    assert Q % block_q == 0 and d % block_d == 0, (Q, d, block_q, block_d)
    return _call(_fused_quant_kernel,
                 [qcodes8, scale[:, None], zero[:, None], live[:, None]],
                 [queries, qsum], Q, d, page, block_q, block_d, interpret)
