"""Public wrappers for the fused phase-1 kernel.

Backend selection follows the code_match convention: on TPU the compiled
Pallas kernel runs natively; on CPU small problems run the same kernel body
under ``interpret=True`` (what the tier-1 property sweeps exercise), and
large problems take a ``lax.scan`` STREAMING fallback -- the same
tile-score + stable-top-k fold, so it keeps the kernel's memory behaviour
(no (Q, d) score matrix) *and* its bit-exactness against the composed
reference.  All three implementations return identical bits for finite
scores: per-tile scores use the reference's elementary expression
unchunked, and the streamed fold is equivalent to one global stable top-k
(tie-breaks prefer lower doc ids, exactly like ``jax.lax.top_k`` over the
dense matrix).

Contract for -inf slots: when fewer than ``page`` candidates are live, the
trailing -inf slots carry an UNSPECIFIED (but always in-range) doc id --
the composed reference surfaces arbitrary dead ids there instead.  Every
consumer (dist/shard_index's merge, rerank) masks scores by liveness
before ids matter, so only the finite prefix is load-bearing; the parity
suite pins scores everywhere and ids wherever finite.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import (DEFAULT_BLOCK_D, DEFAULT_BLOCK_Q, fused_phase1_pallas,
                     fused_phase1_quant_pallas)

_INTERPRET_ELEMENT_LIMIT = 1 << 22  # interpret mode is python-speed; cap it
# doc-tile width of the scan fallback: 512 keeps the (Q, block, C) select
# intermediate inside cache -- measured 1.6x faster than 2048 at the
# BENCH_kernel_scale sizes, and the where/sum scorer is bit-invariant to
# the tile width (verified for odd widths too), so retuning never moves
# parity
_STREAM_BLOCK_D = 512


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_docs(arrs, live, d, block_d):
    """Pad doc-axis inputs to a BLOCK_D multiple; pad rows go live=False
    so they score -inf and can never displace a real candidate."""
    pad = (-d) % block_d
    if pad:
        arrs = [jnp.pad(a, ((0, pad), (0, 0))) for a in arrs]
        live = jnp.pad(live, (0, pad))
    return arrs, live


def _finish(scores, ids, Q, d):
    """Slice off query padding and clamp ids in-range (-inf slots may
    carry a padded doc id; everything downstream masks them by score,
    but an out-of-range id must never escape)."""
    return scores[:Q], jnp.minimum(ids[:Q], d - 1)


def _score_tile_codes(blk, qfree):
    from .ref import match_scores

    dc, = blk
    qc, w = qfree
    return match_scores(dc, qc, w)


def _score_tile_quant(blk, qfree):
    d8, sc, zp = blk
    q, qs = qfree
    raw = jax.lax.dot_general(
        q, d8.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return raw * sc[:, 0][None, :] + qs * zp[:, 0][None, :]


@partial(jax.jit, static_argnames=("score_tile", "page", "block_d"))
def _stream_fold(tiles, tile_lives, bases, qfree, score_tile, *, page,
                 block_d):
    """Shared scan fallback: score one doc tile at a time, fold into a
    running top-``page`` -- brute_force_topk's pattern, phase-1 scores."""
    Q = qfree[0].shape[0]

    def body(carry, inp):
        acc_s, acc_i = carry
        blk, lv, base = inp
        s = score_tile(blk, qfree)                      # (Q, block_d)
        s = jnp.where(lv[None, :], s, -jnp.inf)
        ids = base + jnp.arange(block_d, dtype=jnp.int32)
        cat_s = jnp.concatenate([acc_s, s], axis=1)
        cat_i = jnp.concatenate(
            [acc_i, jnp.broadcast_to(ids, (Q, block_d))], axis=1)
        ts, pos = jax.lax.top_k(cat_s, page)
        return (ts, jnp.take_along_axis(cat_i, pos, axis=1)), None

    init = (jnp.full((Q, page), -jnp.inf, jnp.float32),
            jnp.zeros((Q, page), jnp.int32))
    (acc_s, acc_i), _ = jax.lax.scan(body, init, (tiles, tile_lives, bases))
    return acc_s, acc_i


def _stream(doc_arrs, live, qfree, score_tile, page, d):
    """Reshape doc-axis inputs into scan tiles and fold."""
    doc_arrs, live = _pad_docs(doc_arrs, live, d, _STREAM_BLOCK_D)
    nb = live.shape[0] // _STREAM_BLOCK_D
    tiles = tuple(a.reshape(nb, _STREAM_BLOCK_D, a.shape[-1])
                  for a in doc_arrs)
    tile_lives = live.reshape(nb, _STREAM_BLOCK_D)
    bases = (jnp.arange(nb) * _STREAM_BLOCK_D).astype(jnp.int32)
    return _stream_fold(tiles, tile_lives, bases, qfree, score_tile,
                        page=page, block_d=_STREAM_BLOCK_D)


def fused_phase1(
    doc_codes: jnp.ndarray,    # (d, C) int
    qcodes: jnp.ndarray,       # (Q, C) int
    col_weights: jnp.ndarray,  # (Q, C) f32
    page: int,
    live: Optional[jnp.ndarray] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_d: int = DEFAULT_BLOCK_D,
    force_pallas: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused fp32 phase-1: code-match scores + top-``page`` in one pass
    -> (scores (Q, page) f32, ids (Q, page) int32), bit-identical to
    ``ref.fused_phase1_ref`` (scores everywhere; ids where finite)."""
    d, C = doc_codes.shape
    Q = qcodes.shape[0]
    page = int(min(page, d))
    lv = jnp.ones((d,), bool) if live is None else live

    on_tpu = _on_tpu()
    if not on_tpu and not force_pallas and Q * d * C > _INTERPRET_ELEMENT_LIMIT:
        s, i = _stream((doc_codes,), lv, (qcodes, col_weights),
                       _score_tile_codes, page, d)
        return _finish(s, i, Q, d)

    block_q = min(block_q, max(Q, 1))
    block_d = min(block_d, max(d, 1))
    pad_q = (-Q) % block_q
    qc = jnp.pad(qcodes, ((0, pad_q), (0, 0)))
    w = jnp.pad(col_weights, ((0, pad_q), (0, 0)))
    (dc,), lv = _pad_docs([doc_codes], lv, d, block_d)
    s, i = fused_phase1_pallas(dc, qc, w, lv, page=page, block_q=block_q,
                               block_d=block_d, interpret=not on_tpu)
    return _finish(s, i, Q, d)


def fused_phase1_quant(
    qcodes8: jnp.ndarray,      # (d, n) int8 quantized rows
    scale: jnp.ndarray,        # (d,) f32
    zero: jnp.ndarray,         # (d,) f32
    queries: jnp.ndarray,      # (Q, n) f32
    page: int,
    live: Optional[jnp.ndarray] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_d: int = DEFAULT_BLOCK_D,
    force_pallas: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused int8 phase-1: quantized-dot scores + top-``page`` in one
    pass.  Candidate selection only -- callers rescore the returned page
    against the exact fp32 vectors."""
    d, n = qcodes8.shape
    Q = queries.shape[0]
    page = int(min(page, d))
    lv = jnp.ones((d,), bool) if live is None else live
    qsum = jnp.sum(queries, axis=-1, keepdims=True)     # (Q, 1)

    on_tpu = _on_tpu()
    if not on_tpu and not force_pallas and Q * d * n > _INTERPRET_ELEMENT_LIMIT:
        s, i = _stream((qcodes8, scale[:, None], zero[:, None]), lv,
                       (queries, qsum), _score_tile_quant, page, d)
        return _finish(s, i, Q, d)

    block_q = min(block_q, max(Q, 1))
    block_d = min(block_d, max(d, 1))
    pad_q = (-Q) % block_q
    q = jnp.pad(queries, ((0, pad_q), (0, 0)))
    qs = jnp.pad(qsum, ((0, pad_q), (0, 0)))
    (d8, sc, zp), lv = _pad_docs(
        [qcodes8, scale[:, None], zero[:, None]], lv, d, block_d)
    s, i = fused_phase1_quant_pallas(
        d8, sc[:, 0], zp[:, 0], q, qs, lv, page=page, block_q=block_q,
        block_d=block_d, interpret=not on_tpu)
    return _finish(s, i, Q, d)
