"""Pallas TPU kernel: fused row-normalize + quantize (index build / query encode).

One pass over a (BLOCK_B, n) tile of raw vectors produces the int codes for
one encoder: ``round(x / ||x|| * scale)`` (rounding) or
``floor(x / ||x|| / width)`` (interval).  Fusing the normalisation avoids a
second HBM pass over the f32 vectors during index builds -- encode is the
only step of the paper's pipeline that touches full-precision vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _bucketize_kernel(x_ref, o_ref, *, mode: str, param: float):
    x = x_ref[...].astype(jnp.float32)                       # (BB, n)
    norm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    x = x / jnp.maximum(norm, 1e-12)
    if mode == "round":
        scaled = x * param
        b = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    elif mode == "floor":
        b = jnp.floor(x / param)
    else:
        raise ValueError(mode)
    o_ref[...] = b.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "param", "out_dtype", "block_b", "interpret")
)
def bucketize_pallas(
    x: jnp.ndarray,          # (B, n) raw vectors
    mode: str,               # "round" (param=scale) | "floor" (param=width)
    param: float,
    out_dtype=jnp.int8,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jnp.ndarray:
    B, n = x.shape
    assert B % block_b == 0, (B, block_b)
    kernel = functools.partial(_bucketize_kernel, mode=mode, param=param)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n), out_dtype),
        interpret=interpret,
    )(x)
