"""Pure-jnp oracle for the bucketize kernel."""

from __future__ import annotations

import jax.numpy as jnp


def bucketize_ref(x: jnp.ndarray, mode: str, param: float, out_dtype=jnp.int8):
    x = x.astype(jnp.float32)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    x = x / jnp.maximum(norm, 1e-12)
    if mode == "round":
        scaled = x * param
        b = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    elif mode == "floor":
        b = jnp.floor(x / param)
    else:
        raise ValueError(mode)
    return b.astype(out_dtype)
