"""Public wrapper for the bucketize kernel: encoder-aware fused encode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.encoding import CombinedEncoder, Encoder, IntervalEncoder, RoundingEncoder

from .kernel import DEFAULT_BLOCK_B, bucketize_pallas
from .ref import bucketize_ref

_INTERPRET_ELEMENT_LIMIT = 1 << 20


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _single(x, mode, param, out_dtype, block_b, force_pallas):
    B, n = x.shape
    on_tpu = _on_tpu()
    if not on_tpu and not force_pallas and B * n > _INTERPRET_ELEMENT_LIMIT:
        return bucketize_ref(x, mode, param, out_dtype)
    block_b = min(block_b, B)
    pad = (-B) % block_b
    xp = jnp.pad(x, ((0, pad), (0, 0)), constant_values=1.0)
    out = bucketize_pallas(
        xp, mode, param, out_dtype=out_dtype, block_b=block_b, interpret=not on_tpu
    )
    return out[:B]


def encode(
    x: jnp.ndarray,
    encoder: Encoder,
    block_b: int = DEFAULT_BLOCK_B,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """Fused normalize+quantize; matches ``encoder.encode(normalize(x))``."""
    dt = jnp.dtype(encoder.code_dtype)
    if isinstance(encoder, RoundingEncoder):
        return _single(x, "round", float(encoder.scale), dt, block_b, force_pallas)
    if isinstance(encoder, IntervalEncoder):
        return _single(x, "floor", float(encoder.width), dt, block_b, force_pallas)
    if isinstance(encoder, CombinedEncoder):
        r = encode(x, encoder.rounding, block_b, force_pallas).astype(dt)
        i = encode(x, encoder.interval, block_b, force_pallas).astype(dt)
        return jnp.concatenate([r, i], axis=-1)
    raise TypeError(f"unknown encoder {encoder!r}")
