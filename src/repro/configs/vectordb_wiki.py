"""The paper's own system as a dry-runnable arch: English-Wikipedia-scale
semantic search (4,181,352 articles -- padded to 4,181,504 = 8167 x 512 --
x LSA-400, unit-normalised), rounding-P2 int8 codes, trim 0.05, page 320.

Cells (extra, beyond the 40 assigned):
* ``search_b128`` -- throughput shape: 128 queries, two-phase search
* ``search_b1``   -- latency shape: 1 query
* ``encode_4m``   -- index build: fused normalize+quantize of the corpus

Docs shard over ("pod","data") -- the analogue of the paper's 48 ES shards;
features/codes columns stay unsharded (400 is awkward /16; the hillclimb in
EXPERIMENTS.md §Perf evaluates a "model"-axis code-column split instead).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, SDS, _bspec, batch_axes
from repro.core.encoding import RoundingEncoder
from repro.core.filtering import TrimFilter, expand_mask, feature_mask
from repro.core.codes import score_codes
from repro.core.rerank import normalize, rerank_topk

N_DOCS = 4_181_504          # 4,181,352 padded to x512
N_FEATURES = 400
ENCODER = RoundingEncoder(2)


def _search(doc_vecs, doc_codes, queries, page: int, k: int, trim: float):
    q = normalize(queries.astype(jnp.float32))
    qcodes = ENCODER.encode(q)
    mask = expand_mask(feature_mask(q, trim=TrimFilter(trim)), qcodes.shape[-1])
    w = jnp.where(mask, 1.0, 0.0)
    scores1 = score_codes(doc_codes, qcodes, w, block=131072)
    _, cand = jax.lax.top_k(scores1, page)
    return rerank_topk(doc_vecs, cand, q, k)


def _encode(vectors):
    from repro.kernels.bucketize.ref import bucketize_ref
    return bucketize_ref(vectors, "round", float(ENCODER.scale),
                         jnp.dtype(ENCODER.code_dtype))


class VectorDBArch:
    family = "vectordb"
    SHAPES = {
        "search_b128": dict(kind="search", queries=128, page=320),
        "search_b1": dict(kind="search", queries=1, page=320),
        "encode_4m": dict(kind="encode"),
    }
    skip_shapes = ()

    def cell(self, shape_name: str, mesh) -> Cell:
        info = self.SHAPES[shape_name]
        vecs = SDS((N_DOCS, N_FEATURES), jnp.float32)
        codes = SDS((N_DOCS, N_FEATURES), jnp.dtype(ENCODER.code_dtype))
        if info["kind"] == "encode":
            return Cell(
                arch="vectordb-wiki", shape=shape_name, kind="encode",
                fn=_encode, args=(vecs,),
                in_specs=(_bspec(mesh, vecs),),
                out_specs=_bspec(mesh, codes),
            )
        fn = functools.partial(_search, page=info["page"], k=10, trim=0.05)
        qs = SDS((info["queries"], N_FEATURES), jnp.float32)
        return Cell(
            arch="vectordb-wiki", shape=shape_name, kind="search",
            fn=fn, args=(vecs, codes, qs),
            in_specs=(_bspec(mesh, vecs), _bspec(mesh, codes), P()),
            out_specs=(P(), P()),
            note="paper system: trim=0.05, page=320, P2 int8 codes",
        )


ARCH = VectorDBArch()
