"""Config layer: per-family cell builders for the multi-pod dry-run.

Every assigned architecture exposes, per input shape, one ``Cell``:
the jittable step function, abstract args (ShapeDtypeStructs -- nothing is
allocated), and in/out PartitionSpec trees for the production mesh.  The
dry-run (launch/dryrun.py) lowers+compiles each cell on the 16x16 and
2x16x16 meshes; smoke tests instantiate ``smoke()`` reduced configs with
real arrays.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import (
    MODEL_AXIS,
    batch_axes,
    generic_param_spec,
    lm_param_spec,
    opt_state_spec,
    tree_specs,
)
from repro.models.gnn import gin
from repro.models.recsys import models as rs
from repro.models.transformer import model as lm
from repro.train.grad import make_train_step
from repro.train.optimizer import (
    AdafactorState,
    AdamWConfig,
    AdamWState,
    adafactor_init,
    adamw_init,
)

SDS = jax.ShapeDtypeStruct

METRIC_SPECS = {"loss": P(), "grad_norm": P(), "lr_scale": P()}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                    # train | prefill | decode | serve | retrieval
    fn: Callable
    args: Tuple
    in_specs: Tuple
    out_specs: Any               # None -> compiler-chosen
    note: str = ""


def _key_sds():
    return SDS((2,), jnp.uint32)


def _bspec(mesh: Mesh, sds, batch_dim: int = 0) -> P:
    """Shard the batch dim over the data axes iff it divides evenly."""
    bd = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in bd]))
    parts = [None] * len(sds.shape)
    if sds.shape and sds.shape[batch_dim] % n == 0 and sds.shape[batch_dim] >= n:
        parts[batch_dim] = bd
    return P(*parts)


def _batch_specs(mesh, batch):
    return jax.tree.map(lambda s: _bspec(mesh, s), batch)


# ===================================================================== LM
class LMArch:
    family = "lm"
    SHAPES = {
        # accum=8: microbatched grad accumulation keeps the (B, S, V) logits
        # tensor at 1/8 size (the full-batch logits alone would be ~1 TB/dev
        # for 150k-vocab archs; found via dry-run memory_analysis)
        "train_4k": dict(kind="train", seq=4096, batch=256, accum=8),
        "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
        "decode_32k": dict(kind="decode", seq=32768, batch=128),
        "long_500k": dict(kind="decode", seq=524288, batch=1, seq_sharded=True),
    }

    def __init__(self, cfg: lm.LMConfig, optimizer: str = "adamw",
                 skip_shapes: Tuple[str, ...] = (), smoke_cfg=None,
                 accum: Optional[int] = None):
        self.cfg = cfg
        self.optimizer = optimizer
        self.skip_shapes = skip_shapes
        self._smoke = smoke_cfg
        self.accum = accum              # override SHAPES accum (MoE memory)

    # ---------------------------------------------------------- abstractions
    def params_abstract(self):
        return jax.eval_shape(lambda k: lm.init_params(k, self.cfg), _key_sds())

    def opt_abstract(self, params_abs):
        init = adamw_init if self.optimizer == "adamw" else adafactor_init
        return jax.eval_shape(init, params_abs)

    def param_specs(self, mesh, params_abs):
        return tree_specs(params_abs, mesh, lm_param_spec)

    def opt_specs(self, mesh, params_abs):
        pspecs = self.param_specs(mesh, params_abs)
        if self.optimizer == "adamw":
            return AdamWState(step=P(), mu=pspecs, nu=pspecs)
        vr = jax.tree.map(
            lambda sp, pa: opt_state_spec(sp, len(pa.shape), "vr") if len(pa.shape) >= 2 else P(),
            pspecs, params_abs)
        vc = jax.tree.map(
            lambda sp, pa: opt_state_spec(sp, len(pa.shape), "vc") if len(pa.shape) >= 2 else P(),
            pspecs, params_abs)
        v = jax.tree.map(lambda sp, pa: P() if len(pa.shape) >= 2 else sp,
                         pspecs, params_abs)
        return AdafactorState(step=P(), vr=vr, vc=vc, v=v)

    def _cache_abstract(self, cfg, batch, seq):
        return jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq))

    def _cache_specs(self, mesh, cfg, batch, seq, seq_sharded: bool):
        ms = mesh.shape[MODEL_AXIS]
        bd = batch_axes(mesh)
        ndata = int(np.prod([mesh.shape[a] for a in bd]))

        def kv_spec(leaf):
            # (L, B, S_c, KV, dh)
            L, B, S_c, KV, dh = leaf.shape
            model_dim = 3 if KV % ms == 0 and KV >= ms else (4 if dh % ms == 0 else None)
            parts: list = [None] * 5
            if model_dim is not None:
                parts[model_dim] = MODEL_AXIS
            if seq_sharded:
                if S_c % ndata == 0:
                    parts[2] = bd
            elif B % ndata == 0 and B >= ndata:
                parts[1] = bd
            return P(*parts)

        cache_abs = self._cache_abstract(cfg, batch, seq)
        return jax.tree.map(
            lambda leaf: kv_spec(leaf) if leaf.ndim == 5 else P(), cache_abs
        )

    # ----------------------------------------------------------------- cells
    def cell(self, shape_name: str, mesh: Mesh) -> Optional[Cell]:
        if shape_name in self.skip_shapes:
            return None
        info = self.SHAPES[shape_name]
        cfg = self.cfg
        params_abs = self.params_abstract()
        pspecs = self.param_specs(mesh, params_abs)
        name = cfg.name

        if info["kind"] == "train":
            opt_abs = self.opt_abstract(params_abs)
            ospecs = self.opt_specs(mesh, params_abs)
            loss = functools.partial(_lm_loss_cfg, cfg=cfg)
            accum = self.accum or info.get("accum", 1)
            step = make_train_step(loss, AdamWConfig(), accum=accum,
                                   optimizer=self.optimizer)
            batch = {
                "tokens": SDS((info["batch"], info["seq"]), jnp.int32),
                "labels": SDS((info["batch"], info["seq"]), jnp.int32),
            }
            return Cell(
                arch=name, shape=shape_name, kind="train", fn=step,
                args=(params_abs, opt_abs, batch),
                in_specs=(pspecs, ospecs, _batch_specs(mesh, batch)),
                out_specs=(pspecs, ospecs, METRIC_SPECS),
            )

        if info["kind"] == "prefill":
            fn = functools.partial(_lm_prefill_cfg, cfg=cfg, max_seq=info["seq"])
            toks = SDS((info["batch"], info["seq"]), jnp.int32)
            cache_specs = self._cache_specs(mesh, cfg, info["batch"], info["seq"], False)
            logits_spec = P(batch_axes(mesh), None,
                            MODEL_AXIS if cfg.vocab % mesh.shape[MODEL_AXIS] == 0 else None)
            return Cell(
                arch=name, shape=shape_name, kind="prefill", fn=fn,
                args=(params_abs, toks),
                in_specs=(pspecs, _bspec(mesh, toks)),
                out_specs=(logits_spec, cache_specs),
            )

        # decode
        seq_sharded = info.get("seq_sharded", False)
        dcfg = dataclasses.replace(cfg, cache_update="masked") if seq_sharded else cfg
        fn = functools.partial(_lm_decode_cfg, cfg=dcfg)
        cache_abs = self._cache_abstract(dcfg, info["batch"], info["seq"])
        cache_specs = self._cache_specs(mesh, dcfg, info["batch"], info["seq"], seq_sharded)
        toks = SDS((info["batch"], 1), jnp.int32)
        pos = SDS((), jnp.int32)
        logits_spec = P(
            batch_axes(mesh) if not seq_sharded else None, None,
            MODEL_AXIS if cfg.vocab % mesh.shape[MODEL_AXIS] == 0 else None)
        return Cell(
            arch=name, shape=shape_name, kind="decode", fn=fn,
            args=(params_abs, cache_abs, toks, pos),
            in_specs=(pspecs, cache_specs, _bspec(mesh, toks), P()),
            out_specs=(logits_spec, cache_specs),
            note="seq-sharded masked-ring cache" if seq_sharded else "",
        )

    def smoke(self):
        return self._smoke


def _lm_loss_cfg(params, batch, cfg):
    return lm.lm_loss(params, batch, cfg)


def _lm_prefill_cfg(params, tokens, cfg, max_seq):
    return lm.prefill(params, tokens, cfg, max_seq)


def _lm_decode_cfg(params, cache, tokens, cur_pos, cfg):
    return lm.serve_step(params, cache, tokens, cur_pos, cfg)


# ===================================================================== GNN
def _pad512(n: int) -> int:
    return ((n + 511) // 512) * 512


class GNNArch:
    family = "gnn"
    # (d_feat, n_classes, nodes, edges) per shape; padded to /512 so the
    # fixed meshes shard evenly (pads are masked: -1 edges, 0 label_mask).
    SHAPES = {
        "full_graph_sm": dict(kind="train", mode="node", d_in=1433, classes=7,
                              nodes=_pad512(2708), edges=_pad512(10556)),
        "minibatch_lg": dict(kind="train", mode="node", d_in=602, classes=41,
                             nodes=_pad512(1024 + 1024 * 15 + 1024 * 150),
                             edges=_pad512(1024 * 15 + 1024 * 150)),
        "ogb_products": dict(kind="train", mode="node", d_in=100, classes=47,
                             nodes=_pad512(2_449_029), edges=_pad512(61_859_140)),
        "molecule": dict(kind="train", mode="graph", d_in=16, classes=2,
                         batch=128, nodes=30, edges=64),
    }

    def __init__(self, base_cfg: gin.GINConfig):
        self.base_cfg = base_cfg

    def cfg_for(self, shape_name: str) -> gin.GINConfig:
        info = self.SHAPES[shape_name]
        return dataclasses.replace(
            self.base_cfg, d_in=info["d_in"], n_classes=info["classes"]
        )

    def cell(self, shape_name: str, mesh: Mesh) -> Cell:
        info = self.SHAPES[shape_name]
        cfg = self.cfg_for(shape_name)
        params_abs = jax.eval_shape(lambda k: gin.init_params(k, cfg), _key_sds())
        pspecs = tree_specs(params_abs, mesh, generic_param_spec)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)

        if info["mode"] == "node":
            loss = functools.partial(_gnn_node_loss, cfg=cfg)
            N, E = info["nodes"], info["edges"]
            batch = {
                "x": SDS((N, info["d_in"]), jnp.float32),
                "edge_src": SDS((E,), jnp.int32),
                "edge_dst": SDS((E,), jnp.int32),
                "labels": SDS((N,), jnp.int32),
                "label_mask": SDS((N,), jnp.float32),
            }
        else:
            loss = functools.partial(_gnn_graph_loss, cfg=cfg)
            B, N, E = info["batch"], info["nodes"], info["edges"]
            batch = {
                "x": SDS((B, N, info["d_in"]), jnp.float32),
                "edge_src": SDS((B, E), jnp.int32),
                "edge_dst": SDS((B, E), jnp.int32),
                "node_mask": SDS((B, N), jnp.float32),
                "labels": SDS((B,), jnp.int32),
            }
        step = make_train_step(loss, AdamWConfig())
        return Cell(
            arch=self.base_cfg.name, shape=shape_name, kind="train", fn=step,
            args=(params_abs, opt_abs, batch),
            in_specs=(pspecs, ospecs, _batch_specs(mesh, batch)),
            out_specs=(pspecs, ospecs, METRIC_SPECS),
            note="nodes/edges padded to x512 (masked)",
        )


def _gnn_node_loss(params, batch, cfg):
    return gin.node_loss(params, batch, cfg)


def _gnn_graph_loss(params, batch, cfg):
    return gin.graph_loss(params, batch, cfg)


# =================================================================== RecSys
class RecsysArch:
    family = "recsys"
    SHAPES = {
        "train_batch": dict(kind="train", batch=65536),
        "serve_p99": dict(kind="serve", batch=512),
        "serve_bulk": dict(kind="serve", batch=262144),
        "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_000),
    }

    def __init__(self, cfg, init_fn, forward_fn, user_fn, seq: bool):
        self.cfg = cfg
        self.init_fn = init_fn
        self.forward_fn = forward_fn
        self.user_fn = user_fn
        self.seq = seq                      # DIN/BST style history batches

    def _batch_sds(self, B: int):
        c = self.cfg
        if self.seq:
            return {
                "hist_ids": SDS((B, c.seq_len), jnp.int32),
                "hist_mask": SDS((B, c.seq_len), jnp.float32),
                "target_id": SDS((B,), jnp.int32),
                "dense": SDS((B, c.n_dense), jnp.float32),
                "label": SDS((B,), jnp.float32),
            }
        return {
            "sparse_ids": SDS((B, c.n_sparse), jnp.int32),
            "dense": SDS((B, c.n_dense), jnp.float32),
            "label": SDS((B,), jnp.float32),
        }

    def cell(self, shape_name: str, mesh: Mesh) -> Cell:
        info = self.SHAPES[shape_name]
        cfg = self.cfg
        params_abs = jax.eval_shape(lambda k: self.init_fn(k, cfg), _key_sds())
        pspecs = tree_specs(params_abs, mesh, generic_param_spec)
        name = cfg.name

        if info["kind"] == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
            loss = functools.partial(_rs_loss, fwd=self.forward_fn, cfg=cfg)
            step = make_train_step(loss, AdamWConfig())
            batch = self._batch_sds(info["batch"])
            return Cell(
                arch=name, shape=shape_name, kind="train", fn=step,
                args=(params_abs, opt_abs, batch),
                in_specs=(pspecs, ospecs, _batch_specs(mesh, batch)),
                out_specs=(pspecs, ospecs, METRIC_SPECS),
            )

        if info["kind"] == "serve":
            fn = functools.partial(_rs_forward, fwd=self.forward_fn, cfg=cfg)
            batch = self._batch_sds(info["batch"])
            return Cell(
                arch=name, shape=shape_name, kind="serve", fn=fn,
                args=(params_abs, batch),
                in_specs=(pspecs, _batch_specs(mesh, batch)),
                out_specs=_bspec(mesh, SDS((info["batch"],), jnp.float32)),
            )

        # retrieval: the paper's two-phase search over candidate embeddings
        from repro.serve.retrieval import retrieval_step
        from repro.core.encoding import RoundingEncoder

        D = cfg.embed_dim
        enc = RoundingEncoder(2)
        fn = functools.partial(
            _rs_retrieval, user_fn=self.user_fn, cfg=cfg, encoder=enc
        )
        batch = self._batch_sds(info["batch"])
        N = info["n_cand"]
        cand_vecs = SDS((N, D), jnp.float32)
        cand_codes = SDS((N, D), jnp.dtype(enc.code_dtype))
        return Cell(
            arch=name, shape=shape_name, kind="retrieval", fn=fn,
            args=(params_abs, batch, cand_vecs, cand_codes),
            in_specs=(pspecs, _batch_specs(mesh, batch),
                      _bspec(mesh, cand_vecs), _bspec(mesh, cand_codes)),
            out_specs=(P(), P()),
            note="paper-integrated two-phase retrieval",
        )


def _rs_loss(params, batch, fwd, cfg):
    return rs.bce_loss(fwd, params, batch, cfg)


def _rs_forward(params, batch, fwd, cfg):
    return fwd(params, batch, cfg)


def _rs_retrieval(params, batch, cand_vecs, cand_codes, user_fn, cfg, encoder):
    from repro.serve.retrieval import retrieval_step

    u = user_fn(params, batch, cfg)
    return retrieval_step(u, cand_vecs, cand_codes, encoder=encoder,
                          page=512, k=100, trim_threshold=0.05)
