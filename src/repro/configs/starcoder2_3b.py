"""starcoder2-3b [arXiv:2402.19173]: 30L d_model=3072 24H (GQA kv=2)
d_ff=12288 vocab=49152, SWA-4096, RoPE, biases on."""
from repro.configs.base import LMArch
from repro.models.transformer.model import LMConfig

CFG = LMConfig(
    name="starcoder2-3b",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab=49152,
    attn_pattern="swa", window=4096, qkv_bias=True, act="gelu",
    rope_theta=100000.0,
)
SMOKE = LMConfig(
    name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=512, attn_pattern="swa", window=16,
    qkv_bias=True, act="gelu", q_chunk=16, kv_chunk=16,
)
ARCH = LMArch(CFG, smoke_cfg=SMOKE)
