"""gin-tu [arXiv:1810.00826]: 5 layers, d_hidden=64, sum aggregator,
learnable eps.  d_in/n_classes resolve per input shape (Cora-like /
Reddit-like / ogbn-products-like / molecules)."""
from repro.configs.base import GNNArch
from repro.models.gnn.gin import GINConfig

CFG = GINConfig(name="gin-tu", n_layers=5, d_hidden=64, learn_eps=True)
ARCH = GNNArch(CFG)
