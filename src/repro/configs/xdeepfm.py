"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim=10,
CIN 200-200-200, MLP 400-400."""
from repro.configs.base import RecsysArch
from repro.models.recsys.models import (XDeepFMConfig, xdeepfm_forward,
                                        xdeepfm_init, xdeepfm_user_embedding)

CFG = XDeepFMConfig(field_vocab=1_048_576)
SMOKE = XDeepFMConfig(field_vocab=128, cin_layers=(16, 16), mlp=(32,))
ARCH = RecsysArch(CFG, xdeepfm_init, xdeepfm_forward, xdeepfm_user_embedding, seq=False)
ARCH.smoke_cfg = SMOKE
