"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4; unverified]:
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1 +
shared expert, interleaved (MoE every 2nd layer), iRoPE: chunked-local
attention with a NoPE global layer every 4th.  bf16 params + Adafactor
(400B AdamW-f32 state does not fit 256 x 16 GiB; see EXPERIMENTS.md)."""
from repro.configs.base import LMArch
from repro.models.transformer.model import LMConfig

CFG = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048,
    moe_experts=128, moe_top_k=1, moe_every=2, moe_shared=1,
    attn_pattern="chunked_global4", window=8192,
    rope_theta=500000.0, act="silu", param_dtype="bfloat16",
)
SMOKE = LMConfig(
    name="llama4-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=512, moe_experts=8, moe_top_k=1, moe_every=2,
    moe_shared=1, attn_pattern="chunked_global4", window=16,
    q_chunk=16, kv_chunk=16, capacity_factor=4.0,
)
ARCH = LMArch(CFG, optimizer="adafactor", smoke_cfg=SMOKE, accum=32)
