"""bst [arXiv:1905.06874]: embed_dim=32, 20-item behaviour sequence,
1 transformer block x 8 heads, MLP 1024-512-256."""
from repro.configs.base import RecsysArch
from repro.models.recsys.models import (BSTConfig, bst_forward, bst_init,
                                        bst_user_embedding)

CFG = BSTConfig(item_vocab=16_777_216)
SMOKE = BSTConfig(item_vocab=256, seq_len=8, mlp=(64, 32))
ARCH = RecsysArch(CFG, bst_init, bst_forward, bst_user_embedding, seq=True)
ARCH.smoke_cfg = SMOKE
