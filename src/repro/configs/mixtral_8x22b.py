"""mixtral-8x22b [arXiv:2401.04088]: 56L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=32768, MoE 8e top-2, SWA (per assignment)."""
from repro.configs.base import LMArch
from repro.models.transformer.model import LMConfig

CFG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=32768,
    moe_experts=8, moe_top_k=2,
    attn_pattern="swa", window=4096, rope_theta=1000000.0, act="silu",
)
SMOKE = LMConfig(
    name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=512, moe_experts=4, moe_top_k=2,
    attn_pattern="swa", window=16, q_chunk=16, kv_chunk=16, capacity_factor=4.0,
)
ARCH = LMArch(CFG, smoke_cfg=SMOKE, accum=32)
