"""Architecture registry: --arch <id> resolves here."""
import importlib

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "mixtral-8x22b": "mixtral_8x22b",
    "gemma2-27b": "gemma2_27b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen2-0.5b": "qwen2_0_5b",
    "gin-tu": "gin_tu",
    "xdeepfm": "xdeepfm",
    "autoint": "autoint",
    "din": "din",
    "bst": "bst",
    "vectordb-wiki": "vectordb_wiki",
}

ARCH_IDS = [a for a in _MODULES if a != "vectordb-wiki"]  # the 10 assigned
ALL_IDS = list(_MODULES)


def get_arch(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.ARCH


def arch_shapes(arch_id: str):
    arch = get_arch(arch_id)
    return [s for s in type(arch).SHAPES if s not in getattr(arch, "skip_shapes", ())]
