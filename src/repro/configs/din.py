"""din [arXiv:1706.06978]: embed_dim=18, 100-item history, attention MLP
80-40, MLP 200-80."""
from repro.configs.base import RecsysArch
from repro.models.recsys.models import (DINConfig, din_forward, din_init,
                                        din_user_embedding)

CFG = DINConfig(item_vocab=16_777_216)
SMOKE = DINConfig(item_vocab=256, seq_len=10)
ARCH = RecsysArch(CFG, din_init, din_forward, din_user_embedding, seq=True)
ARCH.smoke_cfg = SMOKE
