"""autoint [arXiv:1810.11921]: 39 fields, embed_dim=16, 3 self-attn layers,
2 heads, d_attn=32."""
from repro.configs.base import RecsysArch
from repro.models.recsys.models import (AutoIntConfig, autoint_forward,
                                        autoint_init, autoint_user_embedding)

CFG = AutoIntConfig(field_vocab=1_048_576)
SMOKE = AutoIntConfig(field_vocab=128, d_attn=8)
ARCH = RecsysArch(CFG, autoint_init, autoint_forward, autoint_user_embedding, seq=False)
ARCH.smoke_cfg = SMOKE
