"""gemma2-27b [arXiv:2408.00118]: 46L d_model=4608 32H (GQA kv=16)
d_ff=36864 vocab=256000, local(4096)+global alternating, logit softcaps."""
from repro.configs.base import LMArch
from repro.models.transformer.model import LMConfig

CFG = LMConfig(
    name="gemma2-27b",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=36864, vocab=256000,
    attn_pattern="alt_local_global", window=4096,
    softcap_attn=50.0, softcap_final=30.0,
    embed_scale=True, act="gelu", rope_theta=10000.0,
)
SMOKE = LMConfig(
    name="gemma2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=256, vocab=512, attn_pattern="alt_local_global", window=16,
    softcap_attn=50.0, softcap_final=30.0, embed_scale=True, act="gelu",
    q_chunk=16, kv_chunk=16,
)
ARCH = LMArch(CFG, smoke_cfg=SMOKE)
