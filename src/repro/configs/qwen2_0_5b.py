"""qwen2-0.5b [arXiv:2407.10671]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936, QKV bias, tied embeddings, pure full attention.
long_500k is SKIPPED by rule: pure full attention has no sub-quadratic
path (DESIGN.md §4)."""
from repro.configs.base import LMArch
from repro.models.transformer.model import LMConfig

CFG = LMConfig(
    name="qwen2-0.5b",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab=151936,
    attn_pattern="full", qkv_bias=True, tied_embeddings=True,
    rope_theta=1000000.0, act="silu",
)
SMOKE = LMConfig(
    name="qwen2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=512, attn_pattern="full", qkv_bias=True,
    tied_embeddings=True, q_chunk=16, kv_chunk=16,
)
ARCH = LMArch(CFG, skip_shapes=("long_500k",), smoke_cfg=SMOKE)
