"""TF-IDF weighting over padded bag-of-words corpora (paper §2.3, §3)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TfIdf", "fit_tfidf", "transform"]


class TfIdf(NamedTuple):
    idf: jnp.ndarray  # (vocab,) f32
    vocab_size: int


def fit_tfidf(doc_terms: jnp.ndarray, vocab_size: int) -> TfIdf:
    """doc_terms: (d, T) int32 padded with -1."""
    d = doc_terms.shape[0]
    valid = doc_terms >= 0
    tid = jnp.where(valid, doc_terms, vocab_size)
    df = jax.ops.segment_sum(
        valid.astype(jnp.float32).reshape(-1),
        tid.reshape(-1),
        num_segments=vocab_size + 1,
    )[:vocab_size]
    idf = jnp.log1p(d / (1.0 + df))
    return TfIdf(idf=idf, vocab_size=vocab_size)


def transform(model: TfIdf, doc_terms: jnp.ndarray, doc_tf: jnp.ndarray) -> jnp.ndarray:
    """-> (d, T) l2-normalised tf-idf weights aligned with doc_terms."""
    valid = doc_terms >= 0
    tid = jnp.maximum(doc_terms, 0)
    w = (1.0 + jnp.log(jnp.maximum(doc_tf, 1.0))) * model.idf[tid]
    w = jnp.where(valid, w, 0.0)
    norm = jnp.maximum(jnp.linalg.norm(w, axis=-1, keepdims=True), 1e-12)
    return w / norm
