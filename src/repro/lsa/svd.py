"""Randomized truncated SVD for padded-sparse corpora, pure JAX.

Computes the rank-``k`` LSA factorisation of the implicit tf-idf matrix
``A (docs x vocab)`` given in padded (terms, weights) form, without ever
densifying ``A``:

* ``A @ Y``  -> embedding-bag: gather ``Y[terms]``, weight, sum over the pad
  axis -- ``O(nnz * r)``.
* ``A.T @ X`` -> scatter: ``segment_sum`` of ``w * X[doc]`` over term ids --
  the same primitive the recsys/GNN substrates use.

Halko-Martinsson-Tropp randomized range finder with power iterations and QR
re-orthogonalisation; distributes over the doc axis (both primitives are
row-parallel + one ``psum``), which is how the full 4.18M-doc Wikipedia run
maps onto a pod.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["LsaModel", "randomized_svd", "matvec_bags", "rmatvec_bags", "fold_in"]


class LsaModel(NamedTuple):
    v: jnp.ndarray        # (vocab, k) right singular vectors
    s: jnp.ndarray        # (k,) singular values
    doc_vecs: jnp.ndarray  # (d, k) = U*S, unit-normalised rows


def matvec_bags(terms: jnp.ndarray, weights: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """A @ Y for padded bags: (d, T) x (vocab, r) -> (d, r)."""
    valid = (terms >= 0)[..., None]
    g = Y[jnp.maximum(terms, 0)]                     # (d, T, r)
    return jnp.sum(jnp.where(valid, weights[..., None] * g, 0.0), axis=1)


def rmatvec_bags(
    terms: jnp.ndarray, weights: jnp.ndarray, X: jnp.ndarray, vocab_size: int
) -> jnp.ndarray:
    """A.T @ X: (d, T) x (d, r) -> (vocab, r) via scatter-add."""
    d, T = terms.shape
    valid = terms >= 0
    tid = jnp.where(valid, terms, vocab_size).reshape(-1)
    contrib = (weights[..., None] * X[:, None, :]).reshape(d * T, -1)
    out = jax.ops.segment_sum(contrib, tid, num_segments=vocab_size + 1)
    return out[:vocab_size]


@partial(jax.jit, static_argnames=("k", "oversample", "n_iter", "vocab_size"))
def randomized_svd(
    terms: jnp.ndarray,
    weights: jnp.ndarray,
    vocab_size: int,
    k: int = 400,
    oversample: int = 16,
    n_iter: int = 3,
    seed: int = 0,
) -> LsaModel:
    r = k + oversample
    key = jax.random.PRNGKey(seed)
    omega = jax.random.normal(key, (vocab_size, r), jnp.float32)

    Y = matvec_bags(terms, weights, omega)           # (d, r)
    Y, _ = jnp.linalg.qr(Y)
    for _ in range(n_iter):
        Z = rmatvec_bags(terms, weights, Y, vocab_size)   # (v, r)
        Z, _ = jnp.linalg.qr(Z)
        Y = matvec_bags(terms, weights, Z)
        Y, _ = jnp.linalg.qr(Y)
    Q = Y                                            # (d, r) orthonormal
    B = rmatvec_bags(terms, weights, Q, vocab_size).T  # (r, v)
    Ub, S, Vt = jnp.linalg.svd(B, full_matrices=False)
    U = Q @ Ub[:, :k]                                # (d, k)
    s = S[:k]
    V = Vt[:k].T                                     # (v, k)
    doc = U * s[None, :]
    doc = doc / jnp.maximum(jnp.linalg.norm(doc, axis=-1, keepdims=True), 1e-12)
    return LsaModel(v=V, s=s, doc_vecs=doc)


def fold_in(model: LsaModel, terms: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Project new tf-idf bags into the LSA space: q = A_q @ V, unit rows."""
    q = matvec_bags(terms, weights, model.v)
    return q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
