"""End-to-end LSA pipeline: corpus -> tf-idf -> randomized SVD -> unit vectors.

This is the paper's §3 setup ("LSA with 400 features over TF-IDF ... all
vectors normalized to unit length") as one call.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.data.synthetic import TopicCorpus

from .svd import LsaModel, fold_in, randomized_svd
from .tfidf import TfIdf, fit_tfidf, transform

__all__ = ["LsaPipeline", "build_lsa"]


class LsaPipeline(NamedTuple):
    tfidf: TfIdf
    lsa: LsaModel

    @property
    def doc_vectors(self) -> jnp.ndarray:
        return self.lsa.doc_vecs

    def embed(self, doc_terms: jnp.ndarray, doc_tf: jnp.ndarray) -> jnp.ndarray:
        """Fold new documents into the latent space (unit rows)."""
        w = transform(self.tfidf, doc_terms, doc_tf)
        return fold_in(self.lsa, doc_terms, w)


def build_lsa(
    corpus: TopicCorpus,
    n_features: int = 400,
    oversample: int = 16,
    n_iter: int = 3,
    seed: int = 0,
) -> LsaPipeline:
    tfidf = fit_tfidf(jnp.asarray(corpus.doc_terms), corpus.vocab_size)
    w = transform(tfidf, jnp.asarray(corpus.doc_terms), jnp.asarray(corpus.doc_tf))
    lsa = randomized_svd(
        jnp.asarray(corpus.doc_terms),
        w,
        vocab_size=corpus.vocab_size,
        k=n_features,
        oversample=oversample,
        n_iter=n_iter,
        seed=seed,
    )
    return LsaPipeline(tfidf=tfidf, lsa=lsa)
