from .pipeline import LsaPipeline, build_lsa
from .svd import LsaModel, fold_in, randomized_svd
from .tfidf import TfIdf, fit_tfidf, transform

__all__ = ["LsaPipeline", "build_lsa", "LsaModel", "fold_in", "randomized_svd",
           "TfIdf", "fit_tfidf", "transform"]
