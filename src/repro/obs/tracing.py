"""Per-request span traces (the ES slow-log + tasks-API + profile layer).

A :class:`Trace` follows ONE query through the serving stack as a list
of host-side spans -- ``submit`` -> queue wait -> batch formation ->
device dispatch -- with point-in-time *events* for the control-plane
things that happen to it on the way: a least-loaded **spill** off its
pinned replica group, a **failover resubmit** after a group failure, the
**down**/**readmit** health transitions its failure triggered.  This is
what ES scatters across three APIs: the slow log (per-query phase
timings), the tasks API (where is my request right now), and the profile
API (per-phase breakdown); here it is one object per request.

Discipline (same as :mod:`repro.obs.metrics`): spans carry host-side
timestamps taken *around* jitted program dispatch, never inside it --
tracing can never perturb a compiled program or its bit-parity.  To line
host spans up with what the device actually did, ``annotation(name)``
optionally opens a ``jax.profiler.TraceAnnotation`` around the dispatch
(enabled via ``Tracer(annotate=True)``): when a ``jax.profiler`` device
trace is being captured, the host span names then appear on the
profiler's timeline next to the device ops they enclose.

Retention is a bounded ring buffer (``capacity`` most recent finished
traces, ES ``tasks``-style dump-on-demand via :meth:`Tracer.dump`), and
admission is sampled: ``sample=1/16`` keeps one query in 16 (counter-
based, deterministic -- no RNG on the hot path).  Unsampled queries get
the singleton :data:`NULL_TRACE` whose every method is a no-op, so call
sites never branch.
"""

from __future__ import annotations

import contextlib
import itertools
import threading
import time
from collections import deque
from typing import List, Optional

__all__ = ["Span", "Trace", "Tracer", "NULL_TRACE", "annotation"]


def annotation(name: str, enabled: bool = True):
    """Context manager: a ``jax.profiler.TraceAnnotation`` around a
    program dispatch when enabled and jax is importable, else a no-op.
    Host-side only -- it never changes what is compiled or executed."""
    if not enabled:
        return contextlib.nullcontext()
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # pragma: no cover - jax always present in-repo
        return contextlib.nullcontext()
    return TraceAnnotation(name)


class Span:
    """One timed phase of a request.  ``t0``/``t1`` are
    ``time.monotonic()`` seconds; ``attrs`` are small scalars (group,
    batch size); ``events`` are (name, t, attrs) points."""

    __slots__ = ("name", "t0", "t1", "attrs", "events")

    def __init__(self, name: str, t0: Optional[float] = None, **attrs):
        self.name = name
        self.t0 = time.monotonic() if t0 is None else t0
        self.t1: Optional[float] = None
        self.attrs = attrs
        self.events: List[tuple] = []

    def end(self, t1: Optional[float] = None) -> "Span":
        self.t1 = time.monotonic() if t1 is None else t1
        return self

    @property
    def duration_s(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "duration_s": self.duration_s, "attrs": dict(self.attrs),
                "events": [{"name": n, "t": t, "attrs": a}
                           for n, t, a in self.events]}


class Trace:
    """All spans + events for one request.  Thread-safe: the submitting
    thread, the batcher worker, and the failover callback all append
    concurrently (a failed-over query's spans come from two different
    group workers)."""

    __slots__ = ("name", "trace_id", "t0", "t1", "attrs", "_spans",
                 "_lock", "_tracer")

    def __init__(self, name: str, trace_id: int,
                 tracer: Optional["Tracer"] = None, **attrs):
        self.name = name
        self.trace_id = trace_id
        self.t0 = time.monotonic()
        self.t1: Optional[float] = None
        self.attrs = attrs
        self._spans: List[Span] = []
        self._lock = threading.Lock()
        self._tracer = tracer

    def span(self, name: str, t0: Optional[float] = None,
             t1: Optional[float] = None, **attrs) -> Span:
        """Append a span; with ``t1`` given it is already closed (the
        batcher records queue-wait/dispatch spans after the fact, from
        the SAME clock reads its own accounting uses, so the trace and
        the batcher can never disagree on a wait)."""
        s = Span(name, t0=t0, **attrs)
        if t1 is not None:
            s.end(t1)
        with self._lock:
            self._spans.append(s)
        return s

    def event(self, name: str, **attrs) -> None:
        """Point-in-time control-plane event (spill, resubmit, down,
        readmit), attached to the most recent open span or the trace
        root."""
        t = time.monotonic()
        with self._lock:
            for s in reversed(self._spans):
                if s.t1 is None:
                    s.events.append((name, t, attrs))
                    return
            self._spans.append(Span("events", t0=t))
            self._spans[-1].events.append((name, t, attrs))
            self._spans[-1].end(t)

    def finish(self, error: Optional[str] = None) -> None:
        """Close the trace and hand it to the tracer's ring buffer.
        Idempotent: resubmit races finish exactly once."""
        with self._lock:
            if self.t1 is not None:
                return
            self.t1 = time.monotonic()
            if error is not None:
                self.attrs["error"] = error
        if self._tracer is not None:
            self._tracer._retain(self)

    @property
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def to_dict(self) -> dict:
        with self._lock:
            spans = list(self._spans)
            return {"name": self.name, "trace_id": self.trace_id,
                    "t0": self.t0, "t1": self.t1,
                    "duration_s": (None if self.t1 is None
                                   else self.t1 - self.t0),
                    "attrs": dict(self.attrs),
                    "spans": [s.to_dict() for s in spans]}


class _NullTrace:
    """Do-nothing stand-in for unsampled requests: call sites record
    unconditionally, the null trace swallows it all at attribute-call
    cost.  Falsy, so ``if trace:`` skips optional extra work."""

    __slots__ = ()

    def span(self, name, t0=None, t1=None, **attrs):
        return self

    def event(self, name, **attrs):
        return None

    def finish(self, error=None):
        return None

    def end(self, t1=None):
        return self

    def to_dict(self):
        return {}

    def __bool__(self):
        return False


NULL_TRACE = _NullTrace()


class Tracer:
    """Sampled per-request trace factory + bounded retention.

    ``sample`` is the admission fraction (1.0 = every request, the
    default 1/16 keeps steady-state overhead negligible while still
    surfacing one full trace per batch on average); admission is a
    deterministic counter (every ``round(1/sample)``-th start), so runs
    reproduce.  ``capacity`` bounds retained finished traces (oldest
    evicted).  ``annotate=True`` additionally opens
    ``jax.profiler.TraceAnnotation`` spans around program dispatch so
    host spans line up with captured device profiles.
    """

    def __init__(self, capacity: int = 256, sample: float = 1.0 / 16,
                 annotate: bool = False):
        if not 0.0 < sample <= 1.0:
            raise ValueError(f"sample must be in (0, 1], got {sample}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.sample = sample
        self.period = max(1, round(1.0 / sample))
        self.annotate = annotate
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)
        # admission draws from an itertools counter (C-level atomic, so
        # the sampled-OUT path -- the common case -- takes no lock);
        # _n_seen mirrors it for stats() and is exact when starts don't
        # race each other
        self._counter = itertools.count()
        self._n_started = 0
        self._n_seen = 0

    def start(self, name: str = "query", **attrs) -> "Trace | _NullTrace":
        """Admit (or null-admit) one request.  Sampled-out requests get
        :data:`NULL_TRACE` -- lock-free, a counter draw and a modulo."""
        n = next(self._counter)
        self._n_seen = n + 1
        if n % self.period:
            return NULL_TRACE
        with self._lock:
            self._n_started += 1
            tid = self._n_started
        return Trace(name, tid, tracer=self, **attrs)

    def _retain(self, trace: Trace) -> None:
        with self._lock:
            self._ring.append(trace)

    def dump(self, clear: bool = False) -> List[dict]:
        """Finished traces, oldest first, as plain dicts (the
        dump-on-demand ES ``tasks``/slow-log read path)."""
        with self._lock:
            out = [t.to_dict() for t in self._ring]
            if clear:
                self._ring.clear()
        return out

    def stats(self) -> dict:
        with self._lock:
            return {"seen": self._n_seen, "sampled": self._n_started,
                    "retained": len(self._ring),
                    "capacity": self._ring.maxlen, "sample": self.sample}
