"""Recompile telemetry: count and attribute XLA compiles per call site.

In a JAX serving stack the usual answer to *why did p99 just double* is
a silent recompile -- a new batch shape, a grown segment width, a
forgotten static argument -- and nothing in the metrics plane observed
it.  This module closes that gap with ES hot-threads-style attribution:

* every jitted entry point in the serving path is wrapped in a cheap
  :func:`watch_region` (a thread-local push/pop around the dispatch);
* one process-wide ``jax.monitoring`` listener receives the backend
  compile-duration event and attributes it to the innermost region
  active ON THE CALLING THREAD (JAX compiles synchronously inside the
  dispatching call, so the region on top of the stack is the culprit);
  compiles outside any region land in an ``<unattributed>`` bucket;
* a :class:`CompileWatch` counts compiles per (region, signature),
  records compile wall time into the ``compile.duration_s`` histogram,
  and -- after :meth:`~CompileWatch.mark_steady` -- treats any further
  region-attributed compile as a steady-state recompile:
  ``compiles_steady_state`` in stats, and a hard error from
  :meth:`~CompileWatch.check` (``serve.py --fail-on-recompile``).

The ``sig`` a region carries is the abstract-shape signature of the
dispatch (batch shape, dtype, engine, static config), so two compiles
under one region with different sigs read as "new shape reached the
jit cache" while a repeat sig reads as genuine cache churn.

Regions nest: an engine-level ``engine.dispatch`` region encloses the
index's finer ``search.query_phase``/``search.merge_select`` regions,
and attribution always goes to the innermost -- each compile is counted
exactly once.  ``<unattributed>`` compiles (host-side analytics, test
scaffolding) never count against the steady state: the watch guards the
serving paths that were wrapped, not the whole process.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["CompileWatch", "active_watch", "watch_region"]

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_UNATTRIBUTED = "<unattributed>"

_TLS = threading.local()            # .stack: [(watch, region, sig), ...]
_install_lock = threading.Lock()
_installed = False
_default: "Optional[CompileWatch]" = None
_default_lock = threading.Lock()


def _on_event(event: str, duration: float, **kwargs) -> None:
    if event != _COMPILE_EVENT:
        return
    stack = getattr(_TLS, "stack", None)
    if stack:
        watch, region, sig = stack[-1]
    else:
        watch, region, sig = active_watch(), _UNATTRIBUTED, ()
    watch._record(region, sig, duration)


def _ensure_listener() -> None:
    """Register the (one, process-wide) monitoring listener.  JAX offers
    no per-listener unregister, so a single dispatcher routes events to
    whichever watch owns the active region."""
    global _installed
    if _installed:
        return
    with _install_lock:
        if _installed:
            return
        try:
            from jax import monitoring

            monitoring.register_event_duration_secs_listener(_on_event)
        except Exception:  # pragma: no cover - jax always present in-repo
            pass
        _installed = True


class _Region:
    __slots__ = ("watch", "name", "sig")

    def __init__(self, watch: "CompileWatch", name: str, sig: Tuple):
        self.watch, self.name, self.sig = watch, name, sig

    def __enter__(self):
        stack = getattr(_TLS, "stack", None)
        if stack is None:
            stack = _TLS.stack = []
        stack.append((self.watch, self.name, self.sig))
        return self

    def __exit__(self, *exc):
        _TLS.stack.pop()
        return False


class CompileWatch:
    """Per-(region, signature) compile counters + steady-state guard.

    ``metrics`` (default: the process registry) receives
    ``compile.total`` / ``compile.steady_state`` counters and the
    ``compile.duration_s`` histogram, all labelled ``fn=<region>``, so
    ``stats()`` rollups and the Prometheus exporter see compiles next to
    the latencies they perturb.
    """

    def __init__(self, metrics=None, enabled: bool = True):
        from repro.obs.cost import CostTable, ensure_cost_capture
        from repro.obs.metrics import default_registry

        self.enabled = enabled
        self.metrics = metrics if metrics is not None else default_registry()
        self._lock = threading.Lock()
        self._counts: Dict[Tuple[str, Tuple], int] = {}
        self._steady = False
        self._steady_events: List[dict] = []
        self._total = 0
        self._steady_total = 0
        # static FLOPs/bytes per compiled program, attributed through the
        # same region stack as compile counting (repro.obs.cost)
        self.costs = CostTable()
        if enabled:
            _ensure_listener()
            ensure_cost_capture()

    # -------------------------------------------------------------- regions
    def region(self, name: str, sig=()):
        """Context manager attributing any compile inside to ``name``
        with abstract-shape signature ``sig`` (a small hashable tuple).
        Cost when nothing compiles: a thread-local append/pop."""
        if not self.enabled:
            return contextlib.nullcontext()
        return _Region(self, name, tuple(sig))

    # ------------------------------------------------------------ recording
    def _record(self, region: str, sig: Tuple, duration: float) -> None:
        with self._lock:
            key = (region, sig)
            repeat = key in self._counts
            self._counts[key] = self._counts.get(key, 0) + 1
            self._total += 1
            # steady-state violations are REGION compiles only: the watch
            # guards the wrapped serving paths, not unrelated host code
            steady = self._steady and region != _UNATTRIBUTED
            if steady:
                self._steady_total += 1
                self._steady_events.append({
                    "fn": region,
                    "sig": [str(s) for s in sig],
                    "duration_s": float(duration),
                    "repeat_sig": repeat,
                })
        self.metrics.histogram("compile.duration_s", fn=region).observe(
            duration)
        self.metrics.counter("compile.total", fn=region).inc()
        if steady:
            self.metrics.counter("compile.steady_state", fn=region).inc()

    # ----------------------------------------------------------- steadiness
    def mark_steady(self) -> None:
        """Declare warmup over: every region-attributed compile after
        this point is an unexpected steady-state recompile."""
        with self._lock:
            self._steady = True

    def check(self) -> None:
        """Raise ``RuntimeError`` listing every steady-state recompile
        (the ``--fail-on-recompile`` hard error); no-op when clean."""
        with self._lock:
            events = list(self._steady_events)
        if events:
            detail = "; ".join(
                f"{e['fn']}(sig={','.join(e['sig']) or '-'}"
                f"{', repeat' if e['repeat_sig'] else ''})"
                for e in events)
            raise RuntimeError(
                f"{len(events)} steady-state recompile(s): {detail}")

    def reset(self) -> None:
        with self._lock:
            self._counts.clear()
            self._steady_events.clear()
            self._steady = False
            self._total = 0
            self._steady_total = 0

    # ---------------------------------------------------------------- stats
    @property
    def compiles_total(self) -> int:
        with self._lock:
            return self._total

    @property
    def compiles_steady_state(self) -> int:
        with self._lock:
            return self._steady_total

    def stats(self) -> dict:
        """The stats-section dict: totals, per-function compile counts,
        distinct signatures seen, and any steady-state events."""
        with self._lock:
            by_fn: Dict[str, int] = {}
            for (region, _sig), c in self._counts.items():
                by_fn[region] = by_fn.get(region, 0) + c
            return {
                "compiles_total": self._total,
                "compiles_steady_state": self._steady_total,
                "steady": self._steady,
                "signatures": len(self._counts),
                "by_function": by_fn,
                "steady_events": list(self._steady_events),
            }


def active_watch() -> CompileWatch:
    """The process-default watch (what engines and serve.py share when
    none is injected -- the :func:`repro.obs.metrics.default_registry`
    pattern)."""
    global _default
    if _default is None:
        w = CompileWatch()
        with _default_lock:
            if _default is None:
                _default = w
    return _default


def watch_region(name: str, sig=()):
    """A region on whichever watch is already active on this thread
    (else the process default) -- how the index's inner jitted seams
    (``search.query_phase``, ``ingest.append``, ``merge.postings``)
    inherit the engine's watch without threading a reference through
    every call."""
    stack = getattr(_TLS, "stack", None)
    watch = stack[-1][0] if stack else active_watch()
    return watch.region(name, sig)
