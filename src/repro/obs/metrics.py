"""Thread-safe metrics registry: counters, gauges, log-bucketed histograms.

The node-stats layer of the observability subsystem (the data behind ES
``GET _nodes/stats`` and ``_cat/thread_pool``): every serving component
records into one :class:`MetricsRegistry`, and the ES-style ``stats()``
snapshots (:mod:`repro.obs.stats`) read it back out.  Three instrument
kinds, all label-aware (``registry.counter("engine.requests.completed",
group=0)`` and ``group=1`` are independent series, the way ES stats key
by node/index/shard):

* :class:`Counter` -- monotonic event count (requests served, failover
  resubmits, compactions applied);
* :class:`Gauge` -- last-write-wins level (queue depth, batch occupancy
  at this instant);
* :class:`Histogram` -- log-bucketed latency distribution with exact
  ``count``/``sum``/``min``/``max`` and bucketed p50/p90/p99.

Design constraints, in order:

1. **Off the jitted hot path.**  Nothing here touches jax; instruments
   record host-side timestamps taken around program *dispatch* only, so
   instrumentation can never perturb compiled programs or bit-parity.
2. **Low overhead.**  One ``threading.Lock`` acquisition and O(1) work
   (bisect over precomputed bucket bounds for histograms) per record.
   At ms-scale search dispatch a ~1 us record disappears; the
   ``benchmarks/obs_overhead.py`` bench pins the end-to-end cost < 3%.
3. **Switchable.**  ``registry.enabled = False`` turns every record into
   a single attribute check and nothing else -- the off-config of the
   overhead bench, and the escape hatch for latency-critical deploys.

Histogram bucket math (pinned by ``tests/test_obs.py``): bucket *i* has
upper bound ``LOW * GROWTH**i`` (LOW = 1e-6 s, GROWTH = 2**0.25, i.e.
~19% relative width, 1 us .. >100 s in 108 buckets).  A sample lands in
the first bucket whose bound is >= the sample (Prometheus ``le``
semantics); quantiles report the *upper bound* of the bucket holding the
q-th sample, so a reported p99 is a guaranteed upper bound with at most
one bucket (~19%) of relative error.  ``bucket_le(x)`` exposes the
mapping so tests can compute expected quantiles exactly.
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_left
from typing import Dict, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry"]

# histogram geometry: LOW * GROWTH**i upper bounds, 1 us .. >100 s
_HIST_LOW = 1e-6
_HIST_GROWTH = 2.0 ** 0.25
_HIST_BUCKETS = 108
_HIST_BOUNDS = tuple(_HIST_LOW * _HIST_GROWTH ** i
                     for i in range(_HIST_BUCKETS))


def _labels_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    """Canonical hashable label identity: sorted (key, str(value))."""
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Instrument:
    """Shared plumbing: every record checks the owning registry's
    ``enabled`` flag first, so a disabled registry costs one attribute
    load per call site and mutates nothing."""

    __slots__ = ("name", "labels", "_registry", "_lock")

    def __init__(self, name: str, labels: Tuple, registry: "MetricsRegistry"):
        self.name = name
        self.labels = labels
        self._registry = registry
        self._lock = threading.Lock()


class Counter(_Instrument):
    """Monotonic event counter (ES stats ``*_total`` fields)."""

    __slots__ = ("_value",)

    def __init__(self, name, labels, registry):
        super().__init__(name, labels, registry)
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge(_Instrument):
    """Last-write-wins level (queue depth, occupancy)."""

    __slots__ = ("_value",)

    def __init__(self, name, labels, registry):
        super().__init__(name, labels, registry)
        self._value = 0.0

    def set(self, v: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Instrument):
    """Log-bucketed distribution: exact count/sum/min/max, bucketed
    quantiles (upper-bound semantics -- see module docstring)."""

    __slots__ = ("_counts", "_n", "_sum", "_min", "_max")

    def __init__(self, name, labels, registry):
        super().__init__(name, labels, registry)
        self._counts = [0] * (_HIST_BUCKETS + 1)   # +1: overflow bucket
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    @staticmethod
    def bucket_le(x: float) -> float:
        """The bucket upper bound ``x`` maps to -- the value quantiles
        report for any sample in that bucket.  Samples past the last
        bound map to +inf (the overflow bucket)."""
        i = bisect_left(_HIST_BOUNDS, x)
        return _HIST_BOUNDS[i] if i < _HIST_BUCKETS else math.inf

    def observe(self, x: float) -> None:
        if not self._registry.enabled:
            return
        x = float(x)
        i = bisect_left(_HIST_BOUNDS, x)
        with self._lock:
            self._counts[i] += 1
            self._n += 1
            self._sum += x
            if x < self._min:
                self._min = x
            if x > self._max:
                self._max = x

    def observe_many(self, xs) -> None:
        """Record a batch of samples under ONE lock acquisition -- the
        batcher worker records a whole batch's queue waits this way, so
        per-request cost amortises to a bisect plus a few adds."""
        if not self._registry.enabled:
            return
        xs = [float(x) for x in xs]
        if not xs:
            return
        idx = [bisect_left(_HIST_BOUNDS, x) for x in xs]
        with self._lock:
            for i in idx:
                self._counts[i] += 1
            self._n += len(xs)
            self._sum += sum(xs)
            lo, hi = min(xs), max(xs)
            if lo < self._min:
                self._min = lo
            if hi > self._max:
                self._max = hi

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-th sample (q in
        [0, 1]); NaN on an empty histogram.  q = 0 maps to the first
        sample, q = 1 to the last."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self._n == 0:
                return math.nan
            # rank of the q-th sample, 1-based (ceil, min 1): the sample
            # below which a fraction q of the distribution sits
            rank = max(1, math.ceil(q * self._n))
            seen = 0
            for i, c in enumerate(self._counts):
                seen += c
                if seen >= rank:
                    return (_HIST_BOUNDS[i] if i < _HIST_BUCKETS
                            else math.inf)
            return math.inf               # pragma: no cover - unreachable

    @property
    def count(self) -> int:
        with self._lock:
            return self._n

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def snapshot(self) -> dict:
        """count/sum/min/max/mean + p50/p90/p99/p999, one lock
        acquisition.  p999 is the tail the slow log keys off: a
        ``slow_threshold_s`` near the steady p999 captures the genuine
        outliers instead of half the traffic."""
        with self._lock:
            n, total = self._n, self._sum
            counts = list(self._counts)
            lo, hi = self._min, self._max
        out = {"count": n, "sum": total,
               "min": (None if n == 0 else lo),
               "max": (None if n == 0 else hi),
               "mean": (None if n == 0 else total / n)}
        for q, key in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99"),
                       (0.999, "p999")):
            if n == 0:
                out[key] = None
                continue
            rank = max(1, math.ceil(q * n))
            seen = 0
            for i, c in enumerate(counts):
                seen += c
                if seen >= rank:
                    out[key] = (_HIST_BOUNDS[i] if i < _HIST_BUCKETS
                                else math.inf)
                    break
        return out


class MetricsRegistry:
    """One process-wide (or per-test) home for every instrument.

    ``counter``/``gauge``/``histogram`` get-or-create by (name, labels):
    the same series object comes back every time, so call sites may
    either cache the instrument (hot paths do) or look it up ad hoc.
    ``enabled`` flips all recording on/off without touching call sites.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple, _Instrument] = {}

    def _get(self, cls, name: str, labels: dict) -> _Instrument:
        key = (cls.__name__, name, _labels_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = self._instruments[key] = cls(name, key[2], self)
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def value(self, name: str, default=0, **labels):
        """Current value of a counter/gauge series WITHOUT creating it
        (stats snapshots read series that may never have fired)."""
        for kind in ("Counter", "Gauge"):
            inst = self._instruments.get((kind, name, _labels_key(labels)))
            if inst is not None:
                return inst.value
        return default

    def series(self, name: str) -> dict:
        """Every counter/gauge series recorded under ``name``, keyed by
        its "k=v,k=v" label string ("" for the unlabelled series) -- the
        per-group breakdown the stats snapshots render (e.g. merges
        applied per replica group)."""
        out = {}
        with self._lock:
            items = list(self._instruments.items())
        for (kind, n, labels), inst in items:
            if n == name and kind in ("Counter", "Gauge"):
                out[",".join(f"{k}={v}" for k, v in labels)] = inst.value
        return out

    def total(self, name: str, default=0):
        """Sum of a counter's value across ALL label series (the
        cluster-level reconciliation helper: queries issued must equal
        the sum of per-group completed counts)."""
        out, seen = default, False
        for (kind, n, _), inst in list(self._instruments.items()):
            if kind == "Counter" and n == name:
                out = (0 if not seen else out) + inst.value
                seen = True
        return out

    def snapshot(self) -> dict:
        """{"counters": {name: {label_str: value}}, "gauges": {...},
        "histograms": {name: {label_str: {count,sum,min,max,mean,pXX}}}}
        -- label_str is "k=v,k=v" ("" for unlabelled series)."""
        with self._lock:
            items = list(self._instruments.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        section = {"Counter": "counters", "Gauge": "gauges",
                   "Histogram": "histograms"}
        for (kind, name, labels), inst in items:
            label_str = ",".join(f"{k}={v}" for k, v in labels)
            val = (inst.snapshot() if kind == "Histogram" else inst.value)
            out[section[kind]].setdefault(name, {})[label_str] = val
        return out


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry components fall back to when no
    explicit one is injected (tests inject their own for isolation)."""
    return _DEFAULT
