"""Threshold slow log with tail-based capture (the ES index/search slow log).

The Tracer's 1/16 head sampling answers "what does a typical request
look like" -- but the requests an operator actually needs are exactly
the ones head sampling usually drops: the slow ones and the failed
ones.  Tail-based capture fixes the selection bias:

* EVERY request gets a lightweight span skeleton -- a real
  :class:`~repro.obs.tracing.Trace` whose retention sink is this slow
  log (creation cost: one small object; the spans were being recorded
  into NULL_TRACE-shaped call sites anyway);
* at ``finish()`` the skeleton is retained only if total latency
  crossed ``threshold_s`` or the request errored -- promoted to a full
  record with its :func:`~repro.obs.profile.profile_from_trace` tree --
  otherwise it is dropped on the floor.  Slow queries are captured at
  100% regardless of the head-sampling rate.

Retention is a bounded ring (newest ``capacity`` records) plus an
optional append-only JSONL sink (``path=``), one JSON object per
captured request -- the grep-able ES slow-log file.

:func:`start_request_trace` is the one admission helper every submit
path uses: with a slow log attached, a head-sampled request gets ONE
trace retained by BOTH sinks (tracer ring + slow-log threshold check,
via a fan-out retainer) and an unsampled request gets a slow-log-only
skeleton; with no slow log, behavior is exactly the old tracer path.
"""

from __future__ import annotations

import itertools
import json
import threading
from collections import deque
from typing import List, Optional

from .profile import profile_from_trace
from .tracing import NULL_TRACE, Trace

__all__ = ["SlowLog", "start_request_trace"]


class _Fanout:
    """Retention sink that forwards a finished trace to several sinks
    (the tracer's ring AND the slow log's threshold check)."""

    __slots__ = ("sinks",)

    def __init__(self, *sinks):
        self.sinks = sinks

    def _retain(self, trace) -> None:
        for s in self.sinks:
            s._retain(trace)


class SlowLog:
    """Tail-based capture of slow/failed requests.

    ``threshold_s=0.0`` captures every finished request (the smoke-run
    configuration -- capture then reconciles exactly with requests
    seen); errors are captured regardless of latency.  Counters land in
    ``metrics`` (``slowlog.seen`` / ``slowlog.captured`` /
    ``slowlog.errors``) so the stats rollup and exporter see capture
    rates without touching the ring.
    """

    def __init__(self, threshold_s: float = 0.1, capacity: int = 256,
                 path: Optional[str] = None, metrics=None):
        from repro.obs.metrics import default_registry

        if threshold_s < 0:
            raise ValueError(f"threshold_s must be >= 0, got {threshold_s}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.threshold_s = float(threshold_s)
        self.path = path
        self.metrics = metrics if metrics is not None else default_registry()
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)
        # lock-free seen counting, the Tracer admission pattern
        self._counter = itertools.count()
        self._n_seen = 0
        self._n_slow = 0
        self._n_errors = 0
        self._file = None
        self._c_seen = self.metrics.counter("slowlog.seen")
        self._c_captured = self.metrics.counter("slowlog.captured")
        self._c_errors = self.metrics.counter("slowlog.errors")

    # ----------------------------------------------------------- admission
    def start(self, name: str = "query", **attrs) -> Trace:
        """The span skeleton: a real Trace whose retention sink is this
        slow log.  Every request gets one -- the threshold decides at
        finish() whether it survives."""
        n = next(self._counter)
        self._n_seen = n + 1
        self._c_seen.inc()
        return Trace(name, n + 1, tracer=self, **attrs)

    def _note_seen(self) -> None:
        """Count a request whose skeleton the TRACER created (the
        head-sampled path of :func:`start_request_trace`) so ``seen``
        means every request, not just slow-log-created skeletons."""
        n = next(self._counter)
        self._n_seen = n + 1
        self._c_seen.inc()

    # ----------------------------------------------------------- retention
    def _retain(self, trace) -> None:
        """Trace.finish() hands every skeleton here; keep it only past
        the threshold or on error (tail-based capture)."""
        t1 = trace.t1 if trace.t1 is not None else trace.t0
        duration = t1 - trace.t0
        error = trace.attrs.get("error")
        if error is None and duration < self.threshold_s:
            return
        record = trace.to_dict()
        record["slowlog"] = {
            "reason": "error" if error is not None else "slow",
            "duration_s": duration,
            "threshold_s": self.threshold_s,
        }
        # the promotion: a captured request carries its full profile tree
        record["profile"] = profile_from_trace(record)
        with self._lock:
            if error is not None:
                self._n_errors += 1
            else:
                self._n_slow += 1
            self._ring.append(record)
            f = self._file
            if f is None and self.path is not None:
                f = self._file = open(self.path, "a", encoding="utf-8")
            if f is not None:
                f.write(json.dumps(record) + "\n")
                f.flush()
        self._c_captured.inc()
        if error is not None:
            self._c_errors.inc()

    # ---------------------------------------------------------------- reads
    def dump(self, clear: bool = False) -> List[dict]:
        """Captured records, oldest first (each carries its trace spans,
        the slowlog reason/threshold block, and the promoted profile
        tree)."""
        with self._lock:
            out = list(self._ring)
            if clear:
                self._ring.clear()
        return out

    def stats(self) -> dict:
        with self._lock:
            return {
                "seen": self._n_seen,
                "captured": self._n_slow + self._n_errors,
                "slow": self._n_slow,
                "errors": self._n_errors,
                "retained": len(self._ring),
                "capacity": self._ring.maxlen,
                "threshold_s": self.threshold_s,
            }

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


def start_request_trace(tracer, slowlog, name: str = "query", **attrs):
    """One admission point for every submit path.

    * no tracer, no slow log -> :data:`~repro.obs.tracing.NULL_TRACE`;
    * tracer only -> the tracer's head-sampled admission (old behavior);
    * slow log attached -> every request gets a skeleton: head-sampled
      requests get ONE trace fanned out to both sinks, the rest get a
      slow-log-only skeleton.  Either way a slow or failed request is
      captured at 100%.
    """
    if slowlog is None:
        if tracer is None:
            return NULL_TRACE
        return tracer.start(name, **attrs)
    if tracer is not None:
        t = tracer.start(name, **attrs)
        if t:
            t._tracer = _Fanout(tracer, slowlog)
            slowlog._note_seen()
            return t
    return slowlog.start(name, **attrs)
