"""Metrics exposition: Prometheus text format + a JSONL history ring.

Two consumers want :meth:`~repro.obs.metrics.MetricsRegistry.snapshot`
in a stable serialized form: scrapers/dashboards (Prometheus text
exposition, the format everything speaks) and the repo's own benches
(periodic JSONL snapshots with monotonic timestamps, so a latency spike
in ``BENCH_*`` rows can be lined up against the counter deltas around
it).  This module is that one seam:

* :func:`prometheus_text` -- render a registry snapshot as Prometheus
  text: counters/gauges as single samples, histograms summary-style
  (``{quantile="0.5"}`` samples + ``_count`` + ``_sum``).  Metric names
  mangle ``engine.requests.completed`` -> ``repro_engine_requests_
  completed``; the registry's ``"k=v,k=v"`` label strings become
  ``{k="v",...}`` label sets.
* :class:`MetricsExporter` -- a bounded in-memory history ring of
  ``{"t_monotonic", "metrics"}`` snapshot records, optionally mirrored
  to an append-only JSONL file, optionally collected periodically by a
  background thread (``serve.py --metrics-file`` wires both).

No sockets anywhere -- exposition is pull-from-file/ring by design (the
``--metrics-port-less`` in the issue title): a scrape endpoint is one
``open().read()`` away for whoever wants to serve it.
"""

from __future__ import annotations

import json
import math
import re
import threading
import time
from collections import deque
from typing import List, Optional

__all__ = ["prometheus_text", "MetricsExporter", "health_gauges",
           "device_gauges"]

_QUANTILES = ("p50", "p90", "p99", "p999")

# exposition grammar: metric names are [a-zA-Z_:][a-zA-Z0-9_:]*, label
# names [a-zA-Z_][a-zA-Z0-9_]*.  Registry names are dotted and benign by
# convention, but nothing stops a caller labelling with arbitrary
# strings -- sanitize at the seam so the output always parses.
_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _name(prefix: str, name: str, suffix: str = "") -> str:
    n = _NAME_BAD.sub("_", name)
    if n and n[0].isdigit():
        n = "_" + n
    return prefix + n + suffix


def _label_name(k: str) -> str:
    k = _LABEL_BAD.sub("_", k)
    if not k or k[0].isdigit():
        k = "_" + k
    return k


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labelset(label_str: str, extra: str = "") -> str:
    """Registry ``"k=v,k=v"`` label identity -> ``{k="v",...}`` (plus an
    optional pre-rendered extra pair, for quantile labels).  Label
    values may themselves contain ``,``/``=`` (device names, paths);
    splitting on the FIRST ``=`` of each comma part and gluing valueless
    parts back onto the previous value keeps such identities lossless
    enough for exposition, and ``_escape`` guarantees the rendered text
    always parses."""
    pairs = []
    if label_str:
        for part in label_str.split(","):
            k, eq, v = part.partition("=")
            if not eq and pairs:
                # a comma inside the previous value: re-attach
                prev_k, prev_v = pairs[-1]
                pairs[-1] = (prev_k, prev_v + "," + part)
                continue
            pairs.append((k, v))
    rendered = [f'{_label_name(k)}="{_escape(v)}"' for k, v in pairs]
    if extra:
        rendered.append(extra)
    return "{" + ",".join(rendered) + "}" if rendered else ""


def _num(v) -> str:
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return "NaN"
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


def prometheus_text(snapshot: dict, prefix: str = "repro_") -> str:
    """One registry snapshot -> Prometheus text exposition format."""
    lines: List[str] = []
    for name in sorted(snapshot.get("counters", ())):
        metric = _name(prefix, name, "_total")
        lines.append(f"# TYPE {metric} counter")
        for label_str, v in sorted(snapshot["counters"][name].items()):
            lines.append(f"{metric}{_labelset(label_str)} {_num(v)}")
    for name in sorted(snapshot.get("gauges", ())):
        metric = _name(prefix, name)
        lines.append(f"# TYPE {metric} gauge")
        for label_str, v in sorted(snapshot["gauges"][name].items()):
            lines.append(f"{metric}{_labelset(label_str)} {_num(v)}")
    for name in sorted(snapshot.get("histograms", ())):
        metric = _name(prefix, name)
        lines.append(f"# TYPE {metric} summary")
        for label_str, h in sorted(snapshot["histograms"][name].items()):
            for key in _QUANTILES:
                v = h.get(key)
                if v is None:
                    continue
                quant = 'quantile="0.' + key[1:] + '"'
                lines.append(f"{metric}{_labelset(label_str, quant)}"
                             f" {_num(v)}")
            lines.append(f"{metric}_count{_labelset(label_str)}"
                         f" {_num(h['count'])}")
            lines.append(f"{metric}_sum{_labelset(label_str)}"
                         f" {_num(h['sum'])}")
    return "\n".join(lines) + "\n"


class MetricsExporter:
    """Periodic registry snapshots -> bounded ring + optional JSONL file.

    Records are ``{"t_monotonic": <time.monotonic()>, "metrics":
    registry.snapshot()}`` -- monotonic by construction, so consumers
    can difference counters across records without wall-clock hazards.
    ``start()`` spawns the periodic collector (daemon thread) when
    ``interval_s`` is set; :meth:`collect` is the manual tick the tests
    and the serve launcher's final dump use.  :meth:`text` renders the
    CURRENT registry state as Prometheus text (scrape-on-demand).
    """

    def __init__(self, registry, path: Optional[str] = None,
                 capacity: int = 64, interval_s: Optional[float] = None,
                 prefix: str = "repro_"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if interval_s is not None and interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.registry = registry
        self.path = path
        self.prefix = prefix
        self.interval_s = interval_s
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._file = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ snapshots
    def collect(self) -> dict:
        """Take one snapshot record: append to the ring (and the JSONL
        sink when configured) and return it."""
        rec = {"t_monotonic": time.monotonic(),
               "metrics": self.registry.snapshot()}
        with self._lock:
            self._ring.append(rec)
            f = self._file
            if f is None and self.path is not None:
                f = self._file = open(self.path, "a", encoding="utf-8")
            if f is not None:
                f.write(json.dumps(rec) + "\n")
                f.flush()
        return rec

    def history(self) -> List[dict]:
        """The retained snapshot records, oldest first."""
        with self._lock:
            return list(self._ring)

    def text(self) -> str:
        """Prometheus text exposition of the registry's CURRENT state."""
        return prometheus_text(self.registry.snapshot(), self.prefix)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "MetricsExporter":
        if self.interval_s is None:
            return self
        if self._thread is not None:
            raise RuntimeError("exporter already started")

        def loop():
            while not self._stop.wait(self.interval_s):
                self.collect()

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


# -------------------------------------------------- derived gauge series
_STATUS_CODE = {"green": 0, "yellow": 1, "red": 2}


def health_gauges(registry, health: dict) -> None:
    """Mirror a :func:`~repro.obs.stats.cluster_health` dict into gauge
    series (``cluster.health.*``), so scrapers get the ``_cluster/
    health`` verdict without parsing stats JSON.  Status encodes
    green=0 / yellow=1 / red=2 -- alert on ``> 0``."""
    registry.gauge("cluster.health.status").set(
        _STATUS_CODE.get(health["status"], 2))
    registry.gauge("cluster.health.up_groups").set(health["up_groups"])
    registry.gauge("cluster.health.n_groups").set(health["n_groups"])
    registry.gauge("cluster.health.pending_requests").set(
        health["pending_requests"])
    registry.gauge("cluster.health.in_flight_restores").set(
        health["in_flight_restores"])
    registry.gauge("cluster.health.pending_maintenance").set(
        len(health["pending_maintenance"]))
    registry.gauge("cluster.health.generation").set(health["generation"])


def device_gauges(registry, device: dict, **labels) -> None:
    """Mirror a :func:`~repro.obs.device.device_bytes` dict into gauge
    series: total index bytes (plus any caller labels, e.g.
    ``group=g``), one labelled series per section, one per device."""
    registry.gauge("device.index_bytes", **labels).set(
        device["total_bytes"])
    for section, b in device["sections"].items():
        registry.gauge("device.index_section_bytes", section=section,
                       **labels).set(b)
    for dev, b in device.get("per_device", {}).items():
        registry.gauge("device.resident_bytes", device=dev,
                       **labels).set(b)
